"""Cluster-scale scenario: a day of training/serving jobs gang-scheduled
onto pod slices with DAGPS vs Tez-style FIFO — the L2 adaptation, with
stage profiles pulled from the dry-run roofline artifacts when available.

All engine backends and scheme presets are reachable from the CLI:

  PYTHONPATH=src python examples/cluster_sim.py
  PYTHONPATH=src python examples/cluster_sim.py --backend jit --profile
  PYTHONPATH=src python examples/cluster_sim.py --schemes tez,tez+tetris,dagps \
      --slices 64 --jobs 30
"""

import argparse

import numpy as np

from repro.core import available_backends
from repro.launch.cluster import TPUJob, job_from_roofline, schedule_cluster


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--backend", default=None, choices=available_backends(),
                    help="placement engine for offline construction "
                         "(default: batched, or $REPRO_PLACEMENT_BACKEND)")
    ap.add_argument("--schemes", default="tez,dagps",
                    help="comma-separated scheme presets to compare "
                         "(tez, tez+cp, tez+tetris, tez+drf, random, dagps, "
                         "dagps-noob)")
    ap.add_argument("--slices", type=int, default=32, help="pod slices")
    ap.add_argument("--jobs", type=int, default=15, help="jobs to schedule")
    ap.add_argument("--interarrival", type=float, default=30.0,
                    help="mean Poisson interarrival seconds")
    ap.add_argument("--build-workers", type=int, default=1,
                    help="overlap offline constructions across this many "
                         "build-service workers (0 = auto/CPU count; "
                         "decisions are bit-identical to serial)")
    ap.add_argument("--shards", type=int, default=0,
                    help="online-matcher machine shards (0 = auto by "
                         "slice count; decisions are bit-identical for "
                         "any shard count)")
    ap.add_argument("--profile", action="store_true",
                    help="print per-phase wall-clock timings")
    ap.add_argument("--fault-plan", default=None, metavar="SPEC",
                    help="fault-injection plan spec (core/faults.py "
                         "grammar), e.g. "
                         "'seed=7;shard_launch:raise@0.3;"
                         "heartbeat:drop@0.05' — exact-recoverable seams "
                         "reproduce the healthy decisions bit-for-bit")
    ap.add_argument("--heartbeat-period", type=float, default=None,
                    help="simulated machine heartbeat period in seconds "
                         "(enables heartbeat-loss semantics: suspicion, "
                         "declared-lost requeue, rejoin on flap)")
    ap.add_argument("--hb-suspect-after", type=float, default=None,
                    help="silence before a machine stops receiving tasks "
                         "(default 2.5 heartbeat periods)")
    ap.add_argument("--hb-lost-after", type=float, default=None,
                    help="silence before a machine is declared lost and "
                         "its tasks requeue (default 5 periods)")
    args = ap.parse_args()

    archs = ["granite3_8b", "gemma2_2b", "mixtral_8x7b", "rwkv6_7b",
             "phi4_mini_3_8b"]
    jobs = []
    for i in range(args.jobs):
        arch = archs[i % len(archs)]
        jobs.append(job_from_roofline(f"job-{i}-{arch}", arch,
                                      "artifacts/dryrun", steps=50 + 20 * (i % 4),
                                      group=i % 2))
    for policy in args.schemes.split(","):
        res = schedule_cluster(jobs, n_slices=args.slices,
                               interarrival=args.interarrival, policy=policy,
                               placement_backend=args.backend,
                               build_workers=args.build_workers or None,
                               matcher_shards=args.shards or None,
                               profile=args.profile,
                               fault_plan=args.fault_plan,
                               heartbeat_period=args.heartbeat_period,
                               hb_suspect_after=args.hb_suspect_after,
                               hb_lost_after=args.hb_lost_after)
        jcts = res.jcts()
        print(f"{policy:10s}: median JCT {np.median(jcts):8.1f}s  "
              f"p75 {np.percentile(jcts, 75):8.1f}s  makespan {res.makespan:8.1f}s")
        if args.profile and res.phase_times:
            pt = res.phase_times
            print(f"{'':10s}  phases: build {pt['build']:.2f}s  "
                  f"match {pt['match']:.2f}s  recovery {pt['recovery']:.2f}s  "
                  f"event {pt['event']:.2f}s  total {pt['total']:.2f}s")
        if args.fault_plan or args.heartbeat_period:
            fs = res.fault_stats or {}
            hb = fs.get("heartbeat", {})
            print(f"{'':10s}  faults: injected {fs.get('injections', {})}  "
                  f"shard {fs.get('shard', {})}")
            if args.heartbeat_period:
                print(f"{'':10s}  heartbeats: {hb}")


if __name__ == "__main__":
    main()
