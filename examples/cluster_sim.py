"""Cluster-scale scenario: a day of training/serving jobs gang-scheduled
onto 32 pod slices with DAGPS vs Tez-style FIFO — the L2 adaptation, with
stage profiles pulled from the dry-run roofline artifacts when available.

  PYTHONPATH=src python examples/cluster_sim.py
"""

import numpy as np

from repro.launch.cluster import TPUJob, job_from_roofline, schedule_cluster


def main():
    archs = ["granite3_8b", "gemma2_2b", "mixtral_8x7b", "rwkv6_7b",
             "phi4_mini_3_8b"]
    jobs = []
    for i in range(15):
        arch = archs[i % len(archs)]
        jobs.append(job_from_roofline(f"job-{i}-{arch}", arch,
                                      "artifacts/dryrun", steps=50 + 20 * (i % 4),
                                      group=i % 2))
    for policy in ("tez", "dagps"):
        res = schedule_cluster(jobs, n_slices=32, interarrival=30.0, policy=policy)
        jcts = res.jcts()
        print(f"{policy:6s}: median JCT {np.median(jcts):8.1f}s  "
              f"p75 {np.percentile(jcts, 75):8.1f}s  makespan {res.makespan:8.1f}s")


if __name__ == "__main__":
    main()
