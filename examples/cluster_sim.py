"""Cluster-scale scenario: a day of training/serving jobs gang-scheduled
onto pod slices with DAGPS vs Tez-style FIFO — the L2 adaptation, with
stage profiles pulled from the dry-run roofline artifacts when available.

All engine backends and scheme presets are reachable from the CLI:

  PYTHONPATH=src python examples/cluster_sim.py
  PYTHONPATH=src python examples/cluster_sim.py --backend jit --profile
  PYTHONPATH=src python examples/cluster_sim.py --schemes tez,tez+tetris,dagps \
      --slices 64 --jobs 30
"""

import argparse
import json

import numpy as np

from repro.core import available_backends
from repro.launch.cluster import TPUJob, job_from_roofline, schedule_cluster


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--backend", default=None, choices=available_backends(),
                    help="placement engine for offline construction "
                         "(default: batched, or $REPRO_PLACEMENT_BACKEND)")
    ap.add_argument("--schemes", default="tez,dagps",
                    help="comma-separated scheme presets to compare "
                         "(tez, tez+cp, tez+tetris, tez+drf, random, dagps, "
                         "dagps-noob)")
    ap.add_argument("--slices", type=int, default=32, help="pod slices")
    ap.add_argument("--jobs", type=int, default=15, help="jobs to schedule")
    ap.add_argument("--interarrival", type=float, default=30.0,
                    help="mean Poisson interarrival seconds")
    ap.add_argument("--build-workers", type=int, default=1,
                    help="overlap offline constructions across this many "
                         "build-service workers (0 = auto/CPU count; "
                         "decisions are bit-identical to serial)")
    ap.add_argument("--shards", type=int, default=0,
                    help="online-matcher machine shards (0 = auto by "
                         "slice count; decisions are bit-identical for "
                         "any shard count)")
    ap.add_argument("--matcher-mode", choices=["exact", "routed"],
                    default="exact",
                    help="online wave mode: exact (decision-exact global "
                         "wave, default) or routed (distributed per-shard "
                         "matching — lossy preset, see core/shard.py)")
    ap.add_argument("--profile", action="store_true",
                    help="print per-phase wall-clock timings")
    ap.add_argument("--fault-plan", default=None, metavar="SPEC",
                    help="fault-injection plan spec (core/faults.py "
                         "grammar), e.g. "
                         "'seed=7;shard_launch:raise@0.3;"
                         "heartbeat:drop@0.05' — exact-recoverable seams "
                         "reproduce the healthy decisions bit-for-bit")
    ap.add_argument("--heartbeat-period", type=float, default=None,
                    help="simulated machine heartbeat period in seconds "
                         "(enables heartbeat-loss semantics: suspicion, "
                         "declared-lost requeue, rejoin on flap)")
    ap.add_argument("--hb-suspect-after", type=float, default=None,
                    help="silence before a machine stops receiving tasks "
                         "(default 2.5 heartbeat periods)")
    ap.add_argument("--hb-lost-after", type=float, default=None,
                    help="silence before a machine is declared lost and "
                         "its tasks requeue (default 5 periods)")
    ap.add_argument("--dynamic", action="store_true",
                    help="script mid-run dynamics: a deadline pull-in on "
                         "a running job (repaired via delta rebuild) and "
                         "a slice speed change")
    ap.add_argument("--serve", action="store_true",
                    help="run through the scheduler service (svc/) instead "
                         "of the simulator: inproc scheduler + one agent "
                         "per slice, lease-based placements, real "
                         "heartbeats; healthy runs match the simulator "
                         "bit-for-bit")
    ap.add_argument("--json", action="store_true",
                    help="emit one JSON document per scheme instead of "
                         "text: JCT stats plus fault_stats, "
                         "mutation_stats and phase timings")
    args = ap.parse_args()

    archs = ["granite3_8b", "gemma2_2b", "mixtral_8x7b", "rwkv6_7b",
             "phi4_mini_3_8b"]
    jobs = []
    for i in range(args.jobs):
        arch = archs[i % len(archs)]
        jobs.append(job_from_roofline(f"job-{i}-{arch}", arch,
                                      "artifacts/dryrun", steps=50 + 20 * (i % 4),
                                      group=i % 2))
    mutations = None
    if args.dynamic:
        from repro.sim.workload import mut_retarget
        mutations = [(30.0, min(1, args.jobs - 1), mut_retarget(0.8)),
                     (60.0, "speed", 0, 1.5)]
    for policy in args.schemes.split(","):
        res = schedule_cluster(jobs, n_slices=args.slices,
                               interarrival=args.interarrival, policy=policy,
                               placement_backend=args.backend,
                               build_workers=args.build_workers or None,
                               matcher_shards=args.shards or None,
                               matcher_mode=args.matcher_mode,
                               profile=args.profile,
                               fault_plan=args.fault_plan,
                               heartbeat_period=args.heartbeat_period,
                               hb_suspect_after=args.hb_suspect_after,
                               hb_lost_after=args.hb_lost_after,
                               mutations=mutations,
                               serve=args.serve)
        jcts = res.jcts()
        if args.json:
            print(json.dumps({
                "policy": policy,
                "median_jct": float(np.median(jcts)),
                "p75_jct": float(np.percentile(jcts, 75)),
                "makespan": res.makespan,
                "jobs": len(res.jobs),
                "phase_times": res.phase_times,
                "fault_stats": res.fault_stats,
                "mutation_stats": res.mutation_stats,
            }))
            continue
        print(f"{policy:10s}: median JCT {np.median(jcts):8.1f}s  "
              f"p75 {np.percentile(jcts, 75):8.1f}s  makespan {res.makespan:8.1f}s")
        if args.profile and res.phase_times:
            pt = res.phase_times
            print(f"{'':10s}  phases: build {pt['build']:.2f}s  "
                  f"match {pt['match']:.2f}s  recovery {pt['recovery']:.2f}s  "
                  f"event {pt['event']:.2f}s  total {pt['total']:.2f}s")
        if args.fault_plan or args.heartbeat_period:
            fs = res.fault_stats or {}
            hb = fs.get("heartbeat", {})
            print(f"{'':10s}  faults: injected {fs.get('injections', {})}  "
                  f"shard {fs.get('shard', {})}")
            if args.heartbeat_period:
                print(f"{'':10s}  heartbeats: {hb}")
        if args.dynamic and res.mutation_stats:
            print(f"{'':10s}  mutations: {res.mutation_stats}")


if __name__ == "__main__":
    main()
