"""Serve a small model with batched requests through the slot-based
continuous batcher (prefill -> decode with explicit state).

  PYTHONPATH=src python examples/serve_lm.py --requests 12 --new 16
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model as M
from repro.serve import Batcher, ServeConfig, greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=rng.integers(4, 12)).astype(np.int32)
               for _ in range(args.requests)]

    # single-request path
    t0 = time.time()
    out = greedy_generate(params, cfg, jnp.asarray(prompts[0])[None], args.new)
    print(f"greedy_generate: {out.shape} in {time.time()-t0:.1f}s -> {np.asarray(out)[0][:8]}...")

    # batched continuous serving
    batcher = Batcher(params, cfg, ServeConfig(max_seq=64, batch=args.batch))
    t0 = time.time()
    results = batcher.serve(prompts, n_new=args.new)
    dt = time.time() - t0
    done = sum(r is not None for r in results)
    toks = sum(len(r) for r in results if r is not None)
    print(f"served {done}/{len(prompts)} requests, {toks} tokens "
          f"in {dt:.1f}s ({toks/dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
