"""The paper's technique inside one training step: DAGPS builds the
pipeline-parallel microbatch schedule and rediscovers 1F1B-quality
interleaving; on heterogeneous stage times it beats the uniform baselines.

  PYTHONPATH=src python examples/pipeline_dagps.py
"""

from repro.train import (gpipe_makespan, ideal_makespan, one_f_one_b_makespan,
                         schedule_pipeline)


def main():
    for P, M in ((4, 8), (4, 16), (8, 16)):
        plan = schedule_pipeline(P, M, t_fwd=1.0)
        print(f"{P} stages x {M} microbatches: "
              f"dagps={plan.makespan:6.1f}  gpipe={gpipe_makespan(P, M, 1.0):6.1f}  "
              f"1f1b={one_f_one_b_makespan(P, M, 1.0):6.1f}  "
              f"ideal={ideal_makespan(P, M, 1.0):6.1f}  "
              f"bubble={plan.bubble_fraction:.2f}")
        first = ["FB"[k == "B"] + f"{s}{m}" for (k, s, m) in plan.order[:12]]
        print("   first events:", " ".join(first))


if __name__ == "__main__":
    main()
