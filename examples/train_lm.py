"""End-to-end training driver: train a small LM for a few hundred steps.

Default is a ~10M-param model sized for the CPU container; pass --arch and
--steps to scale up (any of the 10 assigned architectures' smoke or full
configs).  On a real TPU mesh this is the same code path the dry-run
lowers for the 16x16 production mesh.

  PYTHONPATH=src python examples/train_lm.py --steps 300
  PYTHONPATH=src python examples/train_lm.py --arch gemma2_2b --smoke --steps 50
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.ckpt import latest_step, restore, save
from repro.data import DataConfig, make_batch
from repro.models import model as M
from repro.optim import AdamWConfig
from repro.train import TrainConfig, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite3_8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default=None, help="checkpoint dir (enables save/restore)")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    tcfg = TrainConfig(optimizer=AdamWConfig(
        lr=args.lr, warmup_steps=min(20, args.steps // 10 + 1),
        total_steps=args.steps))
    params, opt = init_train_state(cfg, tcfg, jax.random.PRNGKey(0),
                                   dtype=jnp.float32)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.batch}x{args.seq}")

    start = 0
    if args.ckpt and latest_step(args.ckpt) is not None:
        start, tree = restore(args.ckpt)
        params, opt = tree["params"], tree["opt"]
        print(f"restored checkpoint at step {start} — data pipeline replays "
              f"deterministically from there")

    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
    dcfg = DataConfig(seed=1234)
    t0 = time.time()
    for i in range(start, args.steps):
        batch = make_batch(cfg, dcfg, i, args.batch, args.seq)
        params, opt, metrics = step_fn(params, opt, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:5d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"gnorm {float(metrics['grad_norm']):.2f}  "
                  f"({(time.time()-t0):.1f}s)")
        if args.ckpt and (i + 1) % args.ckpt_every == 0:
            save(args.ckpt, i + 1, params, opt)
    print("done.")


if __name__ == "__main__":
    main()
