"""Quickstart: build a DAGPS schedule for one job DAG and compare it with
the baselines the paper evaluates (Fig. 2 + Fig. 12 in miniature).

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import all_bounds, build_schedule
from repro.core.baselines import bfs_order, cp_order, simulate_execution
from repro.sim.workload import production_dag

def main():
    dag = production_dag(np.random.default_rng(0), share=4, name="demo")
    m = 4
    print(f"DAG '{dag.name}': {dag.n} tasks, {dag.n_stages} stages")
    bounds = all_bounds(dag, m)
    print("lower bounds:", {k: round(v, 1) for k, v in bounds.items()})

    sched = build_schedule(dag, m)
    sched.validate()
    trouble = int(sched.trouble_mask.sum()) if sched.trouble_mask is not None else 0
    print(f"\nDAGPS constructed schedule: makespan={sched.makespan:.1f}s "
          f"({sched.makespan / bounds['newlb']:.2f}x NewLB), "
          f"{trouble} troublesome tasks placed first")

    rows = {
        "bfs (Tez)": simulate_execution(dag, m, order=bfs_order(dag)),
        "critical path": simulate_execution(dag, m, order=cp_order(dag)),
        "tetris (packer)": simulate_execution(dag, m, policy="tetris"),
        "dagps (online)": simulate_execution(dag, m, policy="dagps",
                                             pri_score=sched.pri_score),
    }
    print("\nexecuted makespans on %d machines:" % m)
    for k, v in rows.items():
        print(f"  {k:18s} {v:8.1f}s   ({v / bounds['newlb']:.2f}x NewLB)")

if __name__ == "__main__":
    main()
