"""Training step builder: loss -> grads -> AdamW, with gradient
accumulation over microbatches.

Microbatch execution order is pluggable: `microbatch_order` takes the
static permutation produced by the DAGPS pipeline scheduler
(train/pipeline.py) so the gradient-accumulation loop runs microbatches in
the schedule's order (semantically neutral for pure grad-accum, load-
bearing for the pipeline executor which shares this code path).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from ..models import model as M
from ..optim import AdamWConfig, apply_updates, init_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    microbatches: int = 1
    microbatch_order: tuple[int, ...] | None = None   # from DAGPS (L3)


def make_train_step(cfg: M.ArchConfig, tcfg: TrainConfig) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss(params, batch):
        return M.loss_fn(params, cfg, batch)

    grad_fn = jax.value_and_grad(loss)

    def train_step(params, opt_state, batch):
        n_mb = tcfg.microbatches
        if n_mb <= 1:
            l, grads = grad_fn(params, batch)
        else:
            B = batch["tokens"].shape[0]
            assert B % n_mb == 0
            mb = B // n_mb
            order = jnp.asarray(tcfg.microbatch_order
                                if tcfg.microbatch_order is not None
                                else range(n_mb), dtype=jnp.int32)

            def slice_mb(i):
                start = order[i] * mb
                return {k: lax.dynamic_slice_in_dim(v, start, mb, axis=0)
                        for k, v in batch.items()}

            def acc_fn(carry, i):
                acc, lsum = carry
                li, gi = grad_fn(params, slice_mb(i))
                acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, gi)
                return (acc, lsum + li), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gacc, lsum), _ = lax.scan(acc_fn, (zeros, 0.0), jnp.arange(n_mb))
            grads = jax.tree.map(lambda g: g / n_mb, gacc)
            l = lsum / n_mb
        new_params, new_opt, om = apply_updates(tcfg.optimizer, params, grads, opt_state)
        metrics = {"loss": l, **om}
        return new_params, new_opt, metrics

    return train_step


def init_train_state(cfg: M.ArchConfig, tcfg: TrainConfig, rng, dtype=jnp.bfloat16):
    params = M.init_params(cfg, rng, dtype)
    opt_state = init_state(tcfg.optimizer, params)
    return params, opt_state
