from .step import TrainConfig, init_train_state, make_train_step
from .pipeline import (PipelinePlan, gpipe_makespan, ideal_makespan,
                       one_f_one_b_makespan, pipeline_dag, schedule_pipeline)
