"""DAGPS applied inside one training step (the L3 adaptation, DESIGN.md §2).

A pipeline-parallel training step is a DAG: tasks F(s, m) / B(s, m) for
stage s and microbatch m, with F(s,m) <- F(s-1,m), B(s,m) <- B(s+1,m),
B(last,m) <- F(last,m).  Stages are exclusive executors, which maps onto
the paper's d-resource model by giving stage s its own resource dimension
with demand 1.0 (capacity 1 = one stage runs one task at a time).

Task durations come from the dry-run roofline (seconds of compute per
microbatch per stage), i.e. the §7.1 profile source adapted to TPU.
`schedule_pipeline` runs the paper's BuildSchedule on this DAG and returns
the execution order plus the makespan; `gpipe_makespan`/`one_f_one_b`
are the classical baselines evaluated in the same model — so the benchmark
shows the paper's scheduler *rediscovering* 1F1B-quality interleaving from
first principles, and beating GPipe's bubble.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.builder import build_schedule
from ..core.dag import DAG


@dataclasses.dataclass
class PipelinePlan:
    order: list[tuple[str, int, int]]       # (F|B, stage, microbatch) by start
    makespan: float
    bubble_fraction: float
    microbatch_order: tuple[int, ...]        # stage-0 forward order


def pipeline_dag(n_stages: int, n_micro: int, t_fwd: float, t_bwd: float | None = None) -> DAG:
    t_bwd = 2.0 * t_fwd if t_bwd is None else t_bwd
    n = 2 * n_stages * n_micro
    dur = np.empty(n)
    dem = np.zeros((n, n_stages))
    stage_of = np.empty(n, dtype=np.int64)
    parents: list[np.ndarray] = [None] * n  # type: ignore

    def fid(s, m):
        return m * n_stages + s

    def bid(s, m):
        return n_stages * n_micro + m * n_stages + (n_stages - 1 - s)

    for m in range(n_micro):
        for s in range(n_stages):
            i = fid(s, m)
            dur[i] = t_fwd
            dem[i, s] = 1.0
            stage_of[i] = s
            parents[i] = np.array([fid(s - 1, m)], np.int64) if s else np.empty(0, np.int64)
    for m in range(n_micro):
        for s in range(n_stages - 1, -1, -1):
            i = bid(s, m)
            dur[i] = t_bwd
            dem[i, s] = 1.0
            stage_of[i] = n_stages + s
            ps = [bid(s + 1, m)] if s < n_stages - 1 else [fid(n_stages - 1, m)]
            parents[i] = np.array(sorted(ps), np.int64)
    return DAG(duration=dur, demand=dem, stage_of=stage_of, parents=parents,
               name=f"pipeline-{n_stages}x{n_micro}")


def _ideal(n_stages, n_micro, t_fwd, t_bwd):
    return n_micro * (t_fwd + t_bwd)  # perfectly full slowest-stage timeline


def schedule_pipeline(n_stages: int, n_micro: int, t_fwd: float,
                      t_bwd: float | None = None, ticks: int = 512) -> PipelinePlan:
    t_bwd = 2.0 * t_fwd if t_bwd is None else t_bwd
    dag = pipeline_dag(n_stages, n_micro, t_fwd, t_bwd)
    sched = build_schedule(dag, m=1, ticks=ticks, use_partitions=False)
    order = []
    for t in sched.order:
        s = int(dag.stage_of[t])
        kind = "F" if s < n_stages else "B"
        stage = s if s < n_stages else s - n_stages
        micro = (int(t) % (n_stages * n_micro)) // n_stages
        order.append((kind, stage, micro))
    mb_order = tuple(m for (k, s, m) in order if k == "F" and s == 0)
    ideal = _ideal(n_stages, n_micro, t_fwd, t_bwd)
    return PipelinePlan(order=order, makespan=sched.makespan,
                        bubble_fraction=float(sched.makespan / ideal - 1.0),
                        microbatch_order=mb_order)


def gpipe_makespan(n_stages: int, n_micro: int, t_fwd: float,
                   t_bwd: float | None = None) -> float:
    """GPipe: all forwards (with fill bubble), barrier, all backwards."""
    t_bwd = 2.0 * t_fwd if t_bwd is None else t_bwd
    fwd = (n_stages - 1) * t_fwd + n_micro * t_fwd
    bwd = (n_stages - 1) * t_bwd + n_micro * t_bwd
    return fwd + bwd


def one_f_one_b_makespan(n_stages: int, n_micro: int, t_fwd: float,
                         t_bwd: float | None = None) -> float:
    """1F1B (non-interleaved) steady-state makespan (classical closed form)."""
    t_bwd = 2.0 * t_fwd if t_bwd is None else t_bwd
    # warmup fills the pipeline, then each microbatch costs t_fwd + t_bwd on
    # the bottleneck stage, then drain.
    return (n_stages - 1) * (t_fwd + t_bwd) + n_micro * (t_fwd + t_bwd)


def ideal_makespan(n_stages: int, n_micro: int, t_fwd: float,
                   t_bwd: float | None = None) -> float:
    t_bwd = 2.0 * t_fwd if t_bwd is None else t_bwd
    return _ideal(n_stages, n_micro, t_fwd, t_bwd)
