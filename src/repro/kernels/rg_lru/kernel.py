"""RG-LRU linear-recurrence Pallas kernel (TPU target, interpret-validated).

h_t = a_t * h_{t-1} + x_t, per channel.  The XLA path uses
lax.associative_scan (log-depth, but materializes O(log S) intermediates in
HBM); the kernel streams (CHUNK, D_BLK) tiles through VMEM and carries h in
a VMEM scratch register file:

  grid = (B, D / D_BLK, S / CHUNK)   (chunk axis innermost/sequential)
  x/a tiles: (1, CHUNK, D_BLK);  h scratch: (D_BLK,) fp32

D_BLK = 128 matches the VPU lane width.  One HBM read of x/a and one write
of y per element — the memory-bound optimum for a 1-flop/byte recurrence.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(x_ref, a_ref, h0_ref, y_ref, hT_ref, h, *,
                  chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h[...] = h0_ref[0].astype(jnp.float32)

    def step(t, _):
        ht = a_ref[0, t].astype(jnp.float32) * h[...] + x_ref[0, t].astype(jnp.float32)
        y_ref[0, t] = ht.astype(y_ref.dtype)
        h[...] = ht
        return ()

    lax.fori_loop(0, chunk, step, ())

    @pl.when(ic == n_chunks - 1)
    def _final():
        hT_ref[0] = h[...].astype(hT_ref.dtype)


def rglru_scan(x: jax.Array, a: jax.Array, h0: jax.Array, *,
               chunk: int = 256, d_block: int = 128,
               interpret: bool = True):
    """x, a: (B, S, D); h0: (B, D) fp32 -> (h (B,S,D), hT (B,D))."""
    B, S, D = x.shape
    chunk = min(chunk, S)
    d_block = min(d_block, D)
    assert S % chunk == 0 and D % d_block == 0, (S, chunk, D, d_block)
    n_chunks = S // chunk
    kern = functools.partial(_rglru_kernel, chunk=chunk, n_chunks=n_chunks)
    io = pl.BlockSpec((1, chunk, d_block), lambda b, d, c: (b, c, d))
    hspec = pl.BlockSpec((1, d_block), lambda b, d, c: (b, d))
    y, hT = pl.pallas_call(
        kern,
        grid=(B, D // d_block, n_chunks),
        in_specs=[io, io, hspec],
        out_specs=[io, hspec],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), x.dtype),
            jax.ShapeDtypeStruct((B, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d_block,), jnp.float32)],
        interpret=interpret,
    )(x, a, h0)
    return y, hT
