"""Jit'd entry: Pallas kernel on TPU, interpret elsewhere, ref fallback."""

from __future__ import annotations

from functools import partial

import jax

from . import kernel, ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@partial(jax.jit, static_argnames=("chunk", "d_block", "use_kernel"))
def rglru_scan(x, a, h0, *, chunk=256, d_block=128, use_kernel=True):
    if not use_kernel:
        return ref.rglru_scan(x, a, h0)
    return kernel.rglru_scan(x, a, h0, chunk=chunk, d_block=d_block,
                             interpret=not _on_tpu())
