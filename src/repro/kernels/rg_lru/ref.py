"""Pure-jnp oracle for the RG-LRU recurrence: sequential lax.scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def rglru_scan(x: jax.Array, a: jax.Array, h0: jax.Array):
    """x, a: (B, S, D); h0: (B, D) -> (h (B,S,D), hT (B,D)).  fp32 math."""
    xf, af = x.astype(jnp.float32), a.astype(jnp.float32)

    def step(h, inp):
        xt, at = inp
        h = at * h + xt
        return h, h

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(af, 1, 0))
    hT, hs = lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype), hT
