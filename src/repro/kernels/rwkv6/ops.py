"""Jit'd entry: Pallas WKV kernel on TPU, interpret elsewhere, ref fallback."""

from __future__ import annotations

from functools import partial

import jax

from . import kernel, ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@partial(jax.jit, static_argnames=("chunk", "use_kernel"))
def wkv6(r, k, v, w, u, state, *, chunk=128, use_kernel=True):
    if not use_kernel:
        return ref.wkv6(r, k, v, w, u, state)
    return kernel.wkv6(r, k, v, w, u, state, chunk=chunk,
                       interpret=not _on_tpu())
