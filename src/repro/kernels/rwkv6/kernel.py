"""RWKV6 WKV Pallas kernel (TPU target, validated in interpret mode).

The WKV recurrence is elementwise-heavy (VPU work on TPU), and its cost on
a naive lax.scan is dominated by HBM round-trips of the (B, H, N, N) state
every timestep.  The kernel keeps the state in VMEM across a whole chunk:

  grid = (B, H, S / CHUNK)   (chunk axis innermost -> sequential on TPU)
  r/k/v/w tiles: (1, CHUNK, 1, N) VMEM blocks
  state: (N, N) fp32 VMEM scratch persisting across chunk steps

HBM traffic drops from O(S * N^2) to O(S * N + (S / CHUNK) * 0) — the state
never leaves VMEM during the sequence (it is written back once at the end
via the state output ref).  N is the RWKV head size (64): the (N, N)
outer-product update uses VPU lanes; fp32 accumulation throughout.

This is the TPU adaptation of the CUDA wkv kernels (which use shared
memory per head the same way).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
                state, *, chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)              # (N,)

    def step(t, _):
        r_t = r_ref[0, t, 0].astype(jnp.float32)  # (N,)
        k_t = k_ref[0, t, 0].astype(jnp.float32)
        v_t = v_ref[0, t, 0].astype(jnp.float32)
        w_t = w_ref[0, t, 0].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]          # (N, N)
        y = (r_t[:, None] * (state[...] + u[:, None] * kv)).sum(axis=0)
        y_ref[0, t, 0] = y.astype(y_ref.dtype)
        state[...] = w_t[:, None] * state[...] + kv
        return ()

    lax.fori_loop(0, chunk, step, ())

    @pl.when(ic == n_chunks - 1)
    def _final():
        sT_ref[0, 0] = state[...].astype(sT_ref.dtype)


def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, state: jax.Array, *, chunk: int = 128,
         interpret: bool = True):
    """r,k,v,w: (B,S,H,N); u: (H,N); state: (B,H,N,N) fp32.

    Returns (y (B,S,H,N), final state (B,H,N,N)).
    """
    B, S, H, N = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    kern = functools.partial(_wkv_kernel, chunk=chunk, n_chunks=n_chunks)
    io_spec = pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0))
    y, sT = pl.pallas_call(
        kern,
        grid=(B, H, n_chunks),
        in_specs=[
            io_spec, io_spec, io_spec, io_spec,
            pl.BlockSpec((1, N), lambda b, h, c: (h, 0)),          # u
            pl.BlockSpec((1, 1, N, N), lambda b, h, c: (b, h, 0, 0)),  # s0
        ],
        out_specs=[
            io_spec,
            pl.BlockSpec((1, 1, N, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, N), r.dtype),
            jax.ShapeDtypeStruct((B, H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, state)
    return y, sT
