"""Pure-jnp oracle for the RWKV6 (Finch) WKV recurrence.

Per head (key dim N_k == value dim N_v == N):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

All math in fp32; a sequential lax.scan over time — the ground truth the
chunked Pallas kernel is validated against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, state: jax.Array, chunk: int | None = None):
    """r,k,v,w: (B,S,H,N); u: (H,N); state: (B,H,N,N) -> (y (B,S,H,N), state).

    chunk: when set (and S % chunk == 0), the time scan runs per chunk with
    jax.checkpoint on the chunk body — backward stores only chunk-boundary
    states instead of one (B,H,N,N) residual per *timestep*, which is the
    difference between ~GBs and ~TBs of training memory at 4k tokens.
    """
    B, S, H, N = r.shape
    # keep r/k/v in their storage dtype until inside the scan step: the
    # cross-shard gathers then move bf16, not hoisted-fp32 (w stays fp32 —
    # decays ~0.999 are not representable in bf16)
    rf, kf, vf = r, k, v
    wf = w.astype(jnp.float32)
    uf = u.astype(jnp.float32)

    def step(S_carry, inp):
        r_t, k_t, v_t, w_t = inp                      # (B,H,N)
        r_t, k_t, v_t = (t.astype(jnp.float32) for t in (r_t, k_t, v_t))
        kv = k_t[..., :, None] * v_t[..., None, :]    # (B,H,Nk,Nv)
        y = jnp.einsum("bhn,bhnm->bhm", r_t, S_carry + uf[None, :, :, None] * kv)
        S_new = w_t[..., :, None] * S_carry + kv
        return S_new, y

    if chunk and S % chunk == 0 and S > chunk:
        n_chunks = S // chunk

        def chunk_body(S_carry, inp):
            xs = tuple(jnp.moveaxis(x, 1, 0) for x in inp)      # (C,B,H,N)
            S_new, ys = lax.scan(step, S_carry, xs)
            return S_new, jnp.moveaxis(ys, 0, 1)                # (B,C,H,N)

        chunks = tuple(
            x.reshape(B, n_chunks, chunk, H, N).transpose(1, 0, 2, 3, 4)
            for x in (rf, kf, vf, wf))
        final, ys = lax.scan(jax.checkpoint(chunk_body, prevent_cse=False),
                             state.astype(jnp.float32), chunks)
        y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, N)
        return y.astype(r.dtype), final

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (rf, kf, vf, wf))  # (S,B,H,N)
    final, ys = lax.scan(step, state.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1)                        # (B,S,H,N)
    return y.astype(r.dtype), final


def wkv6_step(r, k, v, w, u, state):
    """Single decode step: r,k,v,w (B,H,N) -> (y (B,H,N), new state)."""
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)
    kv = kf[..., :, None] * vf[..., None, :]
    y = jnp.einsum("bhn,bhnm->bhm", rf, state + uf[None, :, :, None] * kv)
    new = wf[..., :, None] * state + kv
    return y.astype(r.dtype), new
