"""Pure-jnp oracles for the placement-scan ops.

Semantics mirror the numpy kernels in ``core/engine/kernels.py``:

  * ``scan_bitmaps`` — feasible-start bitmaps: bit (g, w, machine) says
    whether task g's demand fits machine for ks[g] consecutive ticks
    starting at window offset w, counting only ticks < t_live.
  * ``heartbeat_eligible`` — sound-superset heartbeat eligibility over
    directed-rounded float32 operands (see the dispatch layer's module
    docstring for the soundness argument).

Both are exact integer/boolean pipelines over float32 comparisons, so the
Pallas kernels must match them bit-for-bit (tests/test_placement_kernels).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def scan_bitmaps(win: jax.Array, Vs: jax.Array, ks: jax.Array,
                 t_live, W: int) -> jax.Array:
    """win (m, L, d) f32; Vs (g, d) f32; ks (g,) i32 -> (g, W, m) int8.

    Requires L >= W + max(ks) so every run read stays in bounds; ticks at
    index >= t_live never count toward a run (grid-edge truncation).
    """
    m, L, _d = win.shape
    ok = (win[None, :, :, :] >= Vs[:, None, None, :]).all(axis=3)  # (g, m, L)
    ok = ok & (jnp.arange(L) < t_live)[None, None, :]
    c = jnp.cumsum(ok.astype(jnp.int32), axis=2)
    cz = jnp.pad(c, ((0, 0), (0, 0), (1, 0)))                      # (g, m, L+1)
    ends = jnp.arange(W)[None, :] + ks[:, None]                    # (g, W)
    take = jnp.broadcast_to(ends[:, None, :], (Vs.shape[0], m, W))
    run = jnp.take_along_axis(cz, take, axis=2) - cz[:, :, :W]
    good = run == ks[:, None, None]                                # (g, m, W)
    return jnp.swapaxes(good, 1, 2).astype(jnp.int8)               # (g, W, m)


def heartbeat_eligible(dem32: jax.Array, thr_fit: jax.Array,
                       thr_fung: jax.Array, fd_mask: jax.Array,
                       rd_mask: jax.Array, gd_mask: jax.Array) -> jax.Array:
    """dem32 (n, d); thr_* (m, d); *_mask (d,) f32 {0,1} -> (n, m) int8.

    eligible = fits-on-all-fit-dims OR (rigid dims fit AND fungible dims
    fit within slack); masked-out dims compare against +inf.
    """
    inf = jnp.float32(jnp.inf)
    tf = jnp.where(fd_mask > 0, thr_fit, inf)[None, :, :]
    tr = jnp.where(rd_mask > 0, thr_fit, inf)[None, :, :]
    tg = jnp.where(gd_mask > 0, thr_fung, inf)[None, :, :]
    dm = dem32[:, None, :]
    fits = (dm <= tf).all(axis=2)
    rigid = (dm <= tr).all(axis=2)
    fung = (dm <= tg).all(axis=2)
    return (fits | (rigid & fung)).astype(jnp.int8)
