"""Placement-scan Pallas kernels (TPU target, interpret-validated).

Two kernels back the scheduler's accelerator path:

``scan_bitmaps`` — the windowed feasibility scan.  One program per task
row: the whole (m, L, d) window sits in VMEM (the builder's windows are a
few hundred ticks over a handful of machines — tens of KB), the demand row
and duration arrive per-program, and the run-length test is one cumsum
along the tick axis followed by a *scalar-start* dynamic slice: per-row
durations come from SMEM, so the shifted-cumsum subtraction needs no
gather (gathers lower poorly on TPU; ``pl.ds`` with an SMEM scalar is
cheap).  All comparisons are float32-vs-float32 (demands pre-rounded with
``ceil32`` by the caller) and run counting is int32, so the bitmaps are
bit-identical to the numpy and XLA implementations.

``heartbeat_eligible`` — the online matcher's machine-eligibility test.
One program per candidate block: the candidate's rounded demand row is
compared against the three per-machine threshold matrices (fit / rigid /
fungible-with-slack, dims selected by {0,1} masks so the kernel shape does
not depend on the config's dim subsets).

Tiling note: the arrays here are small and oddly shaped for the MXU
(machines ~O(10), resources d=4); the kernels are written for correctness
under both interpret mode and Mosaic's small-array padding, not for peak
TPU throughput — the scan is launch-latency-bound, which is exactly what
the device-resident session amortizes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_kernel(ks_ref, tlive_ref, win_ref, vs_ref, out_ref, *,
                 W: int, L: int):
    k = ks_ref[pl.program_id(0)]
    tlive = tlive_ref[0]
    win = win_ref[...]                                   # (m, L, d)
    v = vs_ref[0]                                        # (d,)
    ok = (win >= v[None, None, :]).all(axis=2)           # (m, L)
    live = jax.lax.broadcasted_iota(jnp.int32, (1, L), 1) < tlive
    ok = ok & live
    c = jnp.cumsum(ok.astype(jnp.int32), axis=1)         # (m, L)
    cz = jnp.concatenate(
        [jnp.zeros((win.shape[0], 1), jnp.int32), c], axis=1)  # (m, L+1)
    # run[w] = cz[w + k] - cz[w]: the k-shift is a scalar dynamic slice,
    # no per-row gather (k comes from SMEM)
    hi = jax.lax.dynamic_slice(cz, (0, k), (win.shape[0], W))
    run = hi - cz[:, :W]                                 # (m, W)
    out_ref[0] = (run == k).astype(jnp.int8).T           # (W, m)


def scan_bitmaps(win: jax.Array, Vs: jax.Array, ks: jax.Array, t_live,
                 W: int, *, interpret: bool = True) -> jax.Array:
    """win (m, L, d) f32; Vs (g, d) f32; ks (g,) i32 -> (g, W, m) int8.

    Requires L >= W + max(ks) (the dynamic k-slice must stay in bounds);
    the caller pads the window and masks the padding via ``t_live``.
    """
    m, L, d = win.shape
    g = Vs.shape[0]
    kern = functools.partial(_scan_kernel, W=W, L=L)
    grid = (g,)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((g,), lambda i: (0,)),              # ks (SMEM-ish)
            pl.BlockSpec((1,), lambda i: (0,)),              # t_live
            pl.BlockSpec((m, L, d), lambda i: (0, 0, 0)),    # full window
            pl.BlockSpec((1, d), lambda i: (i, 0)),          # demand row
        ],
        out_specs=pl.BlockSpec((1, W, m), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, W, m), jnp.int8),
        interpret=interpret,
    )(ks, jnp.asarray([t_live], jnp.int32), win, Vs)


def _wave_kernel(avail_ref, order_ref, dem_ref, pri_ref, srpt_ref, gidx_ref,
                 loc_ref, taken_ref, ema_ref, deficit_ref, share_ref,
                 fdm_ref, rdm_ref, fgm_ref, consts_ref, avail_out_ref,
                 ema_out_ref, deficit_out_ref, rows_ref, mach_ref, over_ref,
                 obs_ref, cnt_ref, *, core):
    out = core(avail_ref[...], order_ref[...], dem_ref[...], pri_ref[...],
               srpt_ref[...], gidx_ref[...], loc_ref[...], taken_ref[...],
               ema_ref[...], deficit_ref[...], share_ref[...], fdm_ref[...],
               rdm_ref[...], fgm_ref[...], consts_ref[...])
    avail_out_ref[...] = out[0]
    ema_out_ref[...] = out[1]
    deficit_out_ref[...] = out[2]
    rows_ref[...] = out[3]
    mach_ref[...] = out[4]
    over_ref[...] = out[5]
    obs_ref[...] = out[6]
    cnt_ref[0] = out[7]


def match_wave_walk(avail, order, dem, pri, srpt, gidx, loc, taken0, ema,
                    deficit, share, fd_mask, rd_mask, fg_mask, consts, *,
                    bundle_limit: int, use_packing: bool, use_srpt: bool,
                    use_overbooking: bool, drf: bool,
                    interpret: bool = True):
    """One fused heartbeat wave as a single sequential Pallas program.

    The wave is a data-dependent sequential walk (each pick changes the
    availability the next comparison sees), so there is nothing to tile:
    one program holds the whole state — the (m, d) availability matrix,
    (n, d) candidate columns and the scalar EMA/deficit ledgers are VMEM-
    sized, the walk state (pick count, stop flags) lives in scalars — and
    runs the shared ``engine/wave.py::wave_core`` machine scan.  Sharing
    the traced core with the xla implementation is the exactness story:
    both lower the identical float64, FMA-laundered op sequence, so the
    pick stream is bit-identical to the numpy matcher on either path.

    float64 is unsupported on real TPUs, so this kernel is exercised in
    interpret mode (registration gates it to CPU backends); a Mosaic
    deployment needs the fixed-point demand/score encoding tracked in the
    ROADMAP.
    """
    from ...core.engine.wave import wave_core  # lazy: avoids import cycle

    m, d = avail.shape
    n = dem.shape[0]
    g = deficit.shape[0]
    f64 = avail.dtype
    core = functools.partial(wave_core, bundle_limit=bundle_limit,
                             use_packing=use_packing, use_srpt=use_srpt,
                             use_overbooking=use_overbooking, drf=drf)
    out_shape = [
        jax.ShapeDtypeStruct((m, d), f64),           # avail'
        jax.ShapeDtypeStruct((2,), f64),             # ema'
        jax.ShapeDtypeStruct((g,), f64),             # deficit'
        jax.ShapeDtypeStruct((n,), jnp.int32),       # pick rows
        jax.ShapeDtypeStruct((n,), jnp.int32),       # pick machines
        jax.ShapeDtypeStruct((n,), jnp.int8),        # overbook flags
        jax.ShapeDtypeStruct((n,), f64),             # observed scores
        jax.ShapeDtypeStruct((1,), jnp.int32),       # pick count
    ]
    return pl.pallas_call(
        functools.partial(_wave_kernel, core=core),
        out_shape=out_shape,
        interpret=interpret,
    )(avail, order, dem, pri, srpt, gidx, loc, taken0, ema, deficit,
      share, fd_mask, rd_mask, fg_mask, consts)


def _elig_kernel(dem_ref, tf_ref, tr_ref, tg_ref, out_ref):
    dm = dem_ref[0][None, :]                             # (1, d)
    fits = (dm <= tf_ref[...]).all(axis=1)               # (m,)
    rigid = (dm <= tr_ref[...]).all(axis=1)
    fung = (dm <= tg_ref[...]).all(axis=1)
    out_ref[0] = (fits | (rigid & fung)).astype(jnp.int8)


def heartbeat_eligible(dem32: jax.Array, thr_fit: jax.Array,
                       thr_fung: jax.Array, fd_mask: jax.Array,
                       rd_mask: jax.Array, gd_mask: jax.Array, *,
                       interpret: bool = True) -> jax.Array:
    """dem32 (n, d); thr_* (m, d); masks (d,) f32 {0,1} -> (n, m) int8."""
    n, d = dem32.shape
    m = thr_fit.shape[0]
    inf = jnp.float32(jnp.inf)
    tf = jnp.where(fd_mask > 0, thr_fit, inf)
    tr = jnp.where(rd_mask > 0, thr_fit, inf)
    tg = jnp.where(gd_mask > 0, thr_fung, inf)
    full = pl.BlockSpec((m, d), lambda i: (0, 0))
    return pl.pallas_call(
        _elig_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, d), lambda i: (i, 0)), full, full, full],
        out_specs=pl.BlockSpec((1, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.int8),
        interpret=interpret,
    )(dem32, tf, tr, tg)
