"""Jit'd entry: Pallas kernel on TPU, interpret elsewhere, ref fallback."""

from __future__ import annotations

from functools import partial

import jax

from . import kernel, ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


@partial(jax.jit, static_argnames=("W", "use_kernel"))
def scan_bitmaps(win, Vs, ks, t_live, *, W: int, use_kernel: bool = True):
    """Feasible-start bitmaps (g, W, m) int8; see kernel.scan_bitmaps."""
    if not use_kernel:
        return ref.scan_bitmaps(win, Vs, ks, t_live, W)
    return kernel.scan_bitmaps(win, Vs, ks, t_live, W,
                               interpret=not _on_tpu())


@partial(jax.jit, static_argnames=("use_kernel",))
def heartbeat_eligible(dem32, thr_fit, thr_fung, fd_mask, rd_mask, gd_mask,
                       *, use_kernel: bool = True):
    """Sound-superset heartbeat eligibility (n, m) int8."""
    if not use_kernel:
        return ref.heartbeat_eligible(dem32, thr_fit, thr_fung,
                                      fd_mask, rd_mask, gd_mask)
    return kernel.heartbeat_eligible(dem32, thr_fit, thr_fung,
                                     fd_mask, rd_mask, gd_mask,
                                     interpret=not _on_tpu())


@partial(jax.jit, static_argnames=("bundle_limit", "use_packing", "use_srpt",
                                   "use_overbooking", "drf"))
def match_wave_walk(avail, order, dem, pri, srpt, gidx, loc, taken0, ema,
                    deficit, share, fd_mask, rd_mask, fg_mask, consts, *,
                    bundle_limit: int, use_packing: bool, use_srpt: bool,
                    use_overbooking: bool, drf: bool):
    """One fused heartbeat wave; see kernel.match_wave_walk.

    Always interpret mode: the wave's bit-exactness contract needs
    float64, which real TPUs lack (the registry only offers this impl on
    CPU backends).  Call under ``jax.experimental.enable_x64``.
    """
    out = kernel.match_wave_walk(
        avail, order, dem, pri, srpt, gidx, loc, taken0, ema, deficit,
        share, fd_mask, rd_mask, fg_mask, consts,
        bundle_limit=bundle_limit, use_packing=use_packing,
        use_srpt=use_srpt, use_overbooking=use_overbooking, drf=drf,
        interpret=True)
    return (*out[:7], out[7][0])
