"""Jit'd entry: Pallas kernel on TPU, interpret elsewhere, ref fallback."""

from __future__ import annotations

from functools import partial

import jax

from . import kernel, ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


@partial(jax.jit, static_argnames=("W", "use_kernel"))
def scan_bitmaps(win, Vs, ks, t_live, *, W: int, use_kernel: bool = True):
    """Feasible-start bitmaps (g, W, m) int8; see kernel.scan_bitmaps."""
    if not use_kernel:
        return ref.scan_bitmaps(win, Vs, ks, t_live, W)
    return kernel.scan_bitmaps(win, Vs, ks, t_live, W,
                               interpret=not _on_tpu())


@partial(jax.jit, static_argnames=("use_kernel",))
def heartbeat_eligible(dem32, thr_fit, thr_fung, fd_mask, rd_mask, gd_mask,
                       *, use_kernel: bool = True):
    """Sound-superset heartbeat eligibility (n, m) int8."""
    if not use_kernel:
        return ref.heartbeat_eligible(dem32, thr_fit, thr_fung,
                                      fd_mask, rd_mask, gd_mask)
    return kernel.heartbeat_eligible(dem32, thr_fit, thr_fung,
                                     fd_mask, rd_mask, gd_mask,
                                     interpret=not _on_tpu())
