"""Placement-scan Pallas ops: windowed feasibility scan + heartbeat match.

Follows the repo kernel convention:
  ref.py    — pure-jnp oracle
  kernel.py — Pallas kernels (TPU target, interpret-validated)
  ops.py    — jit'd entry: kernel on TPU, interpret elsewhere

Registered as the ``pallas`` implementations of the ``scan`` and
``machines_with_candidates`` ops in ``core/engine/kernels.py``.
"""

from . import kernel, ops, ref  # noqa: F401
