"""Pallas TPU kernels for the compute hot-spots, each with:
  kernel.py — pl.pallas_call + BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd wrapper with interpret fallback + shape plumbing
  ref.py    — pure-jnp oracle used by tests and by the XLA model path
"""
