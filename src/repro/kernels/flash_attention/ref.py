"""Pure-jnp oracle for flash attention: naive full-matrix softmax attention."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int | None = None,
              cap: float | None = None, scale: float | None = None) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd) -> (B, Sq, H, hd).  fp32 math."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    rep = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kf = jnp.repeat(k, rep, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, rep, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kf) * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return o.astype(q.dtype)
