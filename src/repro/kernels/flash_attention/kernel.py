"""Flash attention Pallas kernel (TPU target, validated in interpret mode).

Tiling: grid = (batch, q_heads, Sq/BQ, Sk/BK) with the KV axis innermost —
on TPU the innermost grid axis is sequential, so the online-softmax
accumulators (m, l, acc) live in VMEM scratch across KV steps and the HBM
traffic is exactly one read of Q/K/V tiles + one write of O per tile
(flash-attention's memory bound).  The MXU sees (BQ x hd) @ (hd x BK) and
(BQ x BK) @ (BK x hd) matmuls; BQ/BK default to 128 to match the 128x128
systolic array, hd is the model's head_dim.

GQA is handled in the K/V index_map (q-head h reads kv-head h // rep) —
no repeated KV materialization.  Causal masking, sliding windows and
logit soft-caps are fused into the tile loop; fully-masked tiles skip the
matmuls via pl.when.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: int | None,
                 cap: float | None, bq: int, bk: int, n_kv_blocks: int):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if cap is not None:
            s = cap * jnp.tanh(s / cap)
        if causal:
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        if window is not None:
            s = jnp.where((qpos - kpos) < window, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    # tiles that are fully masked (above the diagonal / outside the window)
    # are skipped entirely
    conds = []
    if causal:
        conds.append(iq * bq + bq - 1 >= ik * bk)
    if window is not None:
        conds.append((iq * bq) - (ik * bk + bk - 1) < window)
    if conds:
        live = functools.reduce(jnp.logical_and, conds)
        pl.when(live)(_compute)
    else:
        _compute()

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    cap: float | None = None, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd) -> (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    rep = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    n_q, n_k = Sq // bq, Sk // bk
    # (B, S, H, hd) -> (B, H, S, hd) tile-friendly layout
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window, cap=cap,
        bq=bq, bk=bk, n_kv_blocks=n_k)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik, rep=rep: (b, h // rep, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik, rep=rep: (b, h // rep, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
