"""Jit'd entry point: picks the Pallas kernel on TPU, interpret elsewhere."""

from __future__ import annotations

from functools import partial

import jax

from . import kernel, ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@partial(jax.jit, static_argnames=("causal", "window", "cap", "scale",
                                   "block_q", "block_k", "use_kernel"))
def flash_attention(q, k, v, *, causal=True, window=None, cap=None, scale=None,
                    block_q=128, block_k=128, use_kernel=True):
    if not use_kernel:
        return ref.attention(q, k, v, causal=causal, window=window, cap=cap,
                             scale=scale)
    return kernel.flash_attention(
        q, k, v, causal=causal, window=window, cap=cap, scale=scale,
        block_q=block_q, block_k=block_k, interpret=not _on_tpu())
