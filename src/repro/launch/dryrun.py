import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init) — do not move them.  This proves, without hardware:
  * the sharding config is coherent (SPMD partitioning succeeds),
  * the per-device memory fits (memory_analysis),
  * and yields the FLOPs / bytes / collective schedule that §Roofline and
    the §Perf hill-climb read (cost_analysis + HLO collective parse).

Usage:
  python -m repro.launch.dryrun --all [--mesh both] [--out artifacts/dryrun]
  python -m repro.launch.dryrun --arch mixtral_8x7b --shape train_4k --mesh single
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs
from ..data import batch_specs
from ..models import model as M
from ..models.sharding import activation_sharding
from ..optim import AdamWConfig
from ..train import TrainConfig, make_train_step
from . import roofline as RL
from .mesh import data_axes, make_production_mesh
from .sharding import (activation_rules, batch_shardings, state_shardings,
                       tree_shardings)

HBM_PER_CHIP = 16 * 1024**3  # v5e


def _sds(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def lower_cell(arch_id: str, shape_name: str, multi_pod: bool,
               perf_variant: str = "base", cfg=None, unroll: int = 1) -> dict:
    """Lower+compile one cell; returns the JSON record."""
    if cfg is None:
        cfg = configs.get(arch_id)
    if unroll != 1:
        cfg = dataclasses.replace(cfg, scan_unroll=unroll)
    shape = configs.SHAPES[shape_name]
    B, S, kind = shape["batch"], shape["seq"], shape["kind"]
    expert_axis = 0
    if perf_variant.startswith("moe3d"):
        expert_axis = int(perf_variant[5:] or "8")
    mesh = make_production_mesh(multi_pod=multi_pod, expert_axis=expert_axis)
    n_dev = mesh.size
    # sequence sharding beat feature sharding for the recurrent archs too
    # (§Perf iteration 3): projections stay shard-local, only the recurrence
    # gathers the time axis, in bf16
    rules = activation_rules(mesh, B, n_kv=cfg.n_kv_heads, embed_shard=False)
    rec = dict(arch=arch_id, shape=shape_name, mesh="multi" if multi_pod else "single",
               n_devices=n_dev, batch=B, seq=S, kind=kind, variant=perf_variant)
    t0 = time.time()
    with mesh, activation_sharding(mesh, rules):
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        params_s = jax.eval_shape(lambda k: M.init_params(cfg, k), key)
        pspec = tree_shardings(params_s, mesh, fsdp=(kind == "train"))
        n_params = sum(int(jnp.prod(jnp.array(x.shape))) for x in jax.tree.leaves(params_s))
        n_active = cfg.n_active_params()
        rec["n_params"] = n_params
        rec["n_active_params"] = n_active

        if kind == "train":
            tcfg = TrainConfig(optimizer=AdamWConfig())
            from ..optim import init_state
            opt_s = jax.eval_shape(lambda p: init_state(tcfg.optimizer, p), params_s)
            ospec = tree_shardings(opt_s, mesh)
            bsd = batch_specs(cfg, B, S)
            bspec = batch_shardings(bsd, mesh)
            step = make_train_step(cfg, tcfg)
            jitted = jax.jit(step, in_shardings=(pspec, ospec, bspec),
                             out_shardings=(pspec, ospec, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_s, opt_s, bsd)
            model_flops = 6.0 * n_active * B * S
        elif kind == "prefill":
            bsd = batch_specs(cfg, B, S)
            bsd.pop("labels")
            bspec = batch_shardings(bsd, mesh)
            fn = lambda p, b: M.prefill(p, cfg, b, last_only=True)
            state_s = jax.eval_shape(fn, params_s, bsd)[1]
            sspec = state_shardings(state_s, mesh, B)
            if cfg.n_codebooks > 1:   # logits (B, 1, K, V)
                lspec = NamedSharding(mesh, P(data_axes(mesh), None, None, "model"))
            else:                      # logits (B, 1, V)
                lspec = NamedSharding(mesh, P(data_axes(mesh), None, "model"))
            jitted = jax.jit(fn, in_shardings=(pspec, bspec),
                             out_shardings=(lspec, sspec))
            lowered = jitted.lower(params_s, bsd)
            model_flops = 2.0 * n_active * B * S
        else:  # decode
            state_s = jax.eval_shape(
                lambda: M.init_decode_state(cfg, B, S))
            sspec = state_shardings(state_s, mesh, B)
            tok_shape = (B, 1, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, 1)
            tok = jax.ShapeDtypeStruct(tok_shape, jnp.int32)
            pos = jax.ShapeDtypeStruct((B,), jnp.int32)
            dp = data_axes(mesh)
            tspec = batch_shardings({"t": tok}, mesh)["t"]
            pspec_pos = batch_shardings({"p": pos}, mesh)["p"]
            vlm_free = cfg
            fn = lambda p, st, t, ps: M.decode_step(p, vlm_free, st, t, ps)
            lspec = jax.tree.map(
                lambda _: None,
                jax.eval_shape(fn, params_s, state_s, tok, pos)[0])
            jitted = jax.jit(fn, in_shardings=(pspec, sspec, tspec, pspec_pos),
                             out_shardings=(None, sspec), donate_argnums=(1,))
            lowered = jitted.lower(params_s, state_s, tok, pos)
            model_flops = 2.0 * n_active * B
        rec["lower_s"] = round(time.time() - t0, 2)

        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "code_bytes": int(mem.generated_code_size_in_bytes),
        }
        live = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
        rec["memory"]["peak_live_bytes"] = int(live)
        rec["memory"]["fits_16g"] = bool(live <= HBM_PER_CHIP)

        cost = compiled.cost_analysis() or {}
        if isinstance(cost, list):  # jax 0.4.x: list of per-computation dicts
            cost = cost[0] if cost else {}
        text = compiled.as_text()
        coll = RL.parse_collectives(text, n_dev)
        rl = RL.roofline_terms(cost, coll, n_dev, model_flops)
        rec["collectives"] = coll.to_json()
        rec["roofline"] = rl.to_json()
        rec["model_flops"] = model_flops
        rec["hlo_lines"] = text.count("\n")
    return rec


def roofline_cell(arch_id: str, shape_name: str, multi_pod: bool,
                  perf_variant: str = "base", cfg=None) -> dict:
    """Full cell record with *loop-corrected* roofline terms.

    XLA's cost_analysis counts a while-loop body once regardless of trip
    count, so a scanned model under-reports by ~n_layers.  We compile the
    scan at unroll=1 and unroll=2: the difference isolates one body copy,
    and  total = T(1) + (G-1) * (T(2) - T(1))  recovers the true cost
    (validated against a full unroll: <2% error, see EXPERIMENTS.md).
    Memory and the compile proof come from the unroll=1 artifact.
    """
    base = lower_cell(arch_id, shape_name, multi_pod, perf_variant, cfg=cfg)
    if cfg is None:
        cfg0 = configs.get(arch_id)
    else:
        cfg0 = cfg
    G = cfg0.n_body
    if G <= 1:
        base["roofline"]["extrapolated"] = False
        return base
    two = lower_cell(arch_id, shape_name, multi_pod, perf_variant, cfg=cfg0, unroll=2)
    r1, r2 = base["roofline"], two["roofline"]
    # T(2)-T(1) isolates one body copy when G is even; for odd G lax.scan
    # inlines a remainder copy so the delta holds *two* copies (verified
    # empirically -- see EXPERIMENTS.md dry-run methodology).
    per_copy = 1.0 if G % 2 == 0 else 2.0

    def extrap(a, b):
        return max(a + (G - 1) * max(b - a, 0.0) / per_copy, a)
    flops = extrap(r1["flops_per_device"], r2["flops_per_device"])
    byts = extrap(r1["bytes_per_device"], r2["bytes_per_device"])
    cb = extrap(r1["collective_bytes_per_device"], r2["collective_bytes_per_device"])
    cost = {"flops": flops, "bytes accessed": byts}
    coll = RL.CollectiveStats(base["collectives"]["bytes_by_kind"], cb,
                              base["collectives"]["count_by_kind"])
    rl = RL.roofline_terms(cost, coll, base["n_devices"], base["model_flops"])
    base["roofline"] = rl.to_json()
    base["roofline"]["extrapolated"] = True
    base["roofline_probe_unroll2"] = r2
    return base


def run_cells(cells, meshes, out_dir: str, variant: str = "base") -> list[dict]:
    os.makedirs(out_dir, exist_ok=True)
    records = []
    for arch, shape in cells:
        for mesh_name in meshes:
            tag = f"{arch}_{shape}_{mesh_name}" + ("" if variant == "base" else f"_{variant}")
            path = os.path.join(out_dir, tag + ".json")
            if os.path.exists(path):
                with open(path) as f:
                    rec = json.load(f)
                print(f"[cached] {tag}: {rec.get('roofline', {}).get('dominant', rec.get('error', '?'))}")
                records.append(rec)
                continue
            try:
                rec = roofline_cell(arch, shape, mesh_name == "multi", variant)
                rl = rec["roofline"]
                print(f"[ok] {tag}: compile={rec['compile_s']}s "
                      f"dom={rl['dominant']} "
                      f"terms=({rl['compute_s']:.4f},{rl['memory_s']:.4f},{rl['collective_s']:.4f})s "
                      f"mem={rec['memory']['peak_live_bytes']/2**30:.2f}GiB "
                      f"fits={rec['memory']['fits_16g']}")
            except Exception as e:  # record and continue: these are bugs to fix
                rec = dict(arch=arch, shape=shape, mesh=mesh_name, variant=variant,
                           error=f"{type(e).__name__}: {e}",
                           traceback=traceback.format_exc()[-4000:])
                print(f"[FAIL] {tag}: {type(e).__name__}: {str(e)[:300]}")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            records.append(rec)
    return records


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    if args.list:
        for a, s in configs.cells():
            print(a, s)
        return
    if args.all:
        cells = configs.cells()
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    records = run_cells(cells, meshes, args.out, args.variant)
    n_fail = sum(1 for r in records if "error" in r)
    print(f"\n{len(records) - n_fail}/{len(records)} cells compiled")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
