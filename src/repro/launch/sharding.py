"""Sharding rules: param/optimizer/state/batch PartitionSpecs per mesh.

Strategy (baseline; the §Perf loop iterates on this):
  * batch over (pod, data); "pod" is pure DP across pods.
  * tensor parallelism over "model": attention heads, MLP hidden, experts
    (expert-parallel when n_experts divides the axis, expert-TP on d_ff
    otherwise), vocab for embedding/logits.
  * FSDP over "data": the non-"model" dim of every big matrix is sharded
    over the data axis; optimizer state mirrors params leaf-for-leaf, so
    ZeRO-style optimizer sharding falls out for free.
  * every rule is guarded by divisibility: an axis that does not divide a
    dim is dropped (e.g. kv_heads=4 < model=16 -> KV replicated; the KV
    *cache* falls back to sequence sharding instead).

`spec_for_path` is pure (path, shape) -> PartitionSpec, so the same rules
apply to params, grads, adam m/v/master, and anything tree-shaped.
"""

from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import axis_size, data_axes

FSDP = "data"
TP = "model"


def tp_axes(mesh):
    """The tensor-parallel axes: ('expert','model') on expert-factorized
    meshes (a beyond-baseline variant for few-expert MoE), else 'model'."""
    return ("expert", TP) if "expert" in mesh.axis_names else TP


def _resolve(rule: tuple, mesh) -> tuple:
    """Replace the TP sentinel with the mesh's actual TP axes."""
    tpa = tp_axes(mesh)
    if tpa == TP:
        return rule
    out = []
    for r in rule:
        if r == TP:
            out.append(tpa)
        elif isinstance(r, tuple):
            out.append(tuple(tpa if a == TP else a for a in r))
        else:
            out.append(r)
    return tuple(out)


def _guard(spec: tuple, shape: tuple[int, ...], mesh) -> P:
    """Drop axes that don't divide; never reuse a mesh axis twice."""
    used: set[str] = set()
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        axes = tuple(a for a in axes if a in mesh.axis_names and a not in used)
        size = axis_size(mesh, axes)
        if size <= 1 or dim % size != 0:
            # try a shrinking prefix (e.g. ('data','model') -> ('data',))
            while axes and (axis_size(mesh, axes) <= 1 or dim % axis_size(mesh, axes) != 0):
                axes = axes[:-1]
        if not axes:
            out.append(None)
            continue
        used.update(axes)
        out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


# rules keyed by the *last named component* of the tree path;
# each is a spec tuple for the leaf's trailing dims (leading stack dims in
# scanned bodies are padded with None automatically).
_PARAM_RULES: dict[str, tuple] = {
    "embed":      (TP, FSDP),             # (V, d); musicgen (K, V, d) padded
    "lm_head":    (FSDP, TP),             # (d, V); musicgen (K, d, V)
    "patch_proj": (FSDP, TP),
    "wq":         (FSDP, TP, None),       # (d, H, hd)
    "wk":         (FSDP, TP, None),       # (d, KV, hd) — guard drops TP if KV<axis
    "wv":         (FSDP, TP, None),
    "wo":         (TP, None, FSDP),       # (H, hd, d)
    "bq":         (TP, None),
    "bk":         (TP, None),
    "bv":         (TP, None),
    "wg":         (FSDP, TP),             # mlp (d, f); moe (E, d, f) handled below
    "wu":         (FSDP, TP),
    "wd":         (TP, FSDP),             # mlp (f, d)
    "w1":         (FSDP, TP),
    "w2":         (TP, FSDP),
    "b1":         (TP,),
    "b2":         (None,),
    "router":     (FSDP, None),           # (d, E)
    # rwkv
    "wr":         (FSDP, TP),
    "cm_k":       (FSDP, TP),
    "cm_v":       (TP, FSDP),
    "cm_r":       (FSDP, TP),
    "tm_a":       (FSDP, None),
    "tm_b":       (None, None, FSDP),
    "wd_a":       (FSDP, None),
    "wd_b":       (None, FSDP),
    # rglru
    "w_in_x":     (FSDP, TP),
    "w_in_g":     (FSDP, TP),
    "w_out":      (TP, FSDP),
    "conv_w":     (None, TP),
    "conv_b":     (TP,),
    "wa":         (TP, None),
    "wx":         (TP, None),
    "ba":         (None,),
    "bx":         (None,),
    "lam":        (TP,),
}

_MOE_3D = {"wg": (TP, FSDP, None), "wu": (TP, FSDP, None), "wd": (TP, None, FSDP)}
_MOE_3D_FEW = {"wg": (None, FSDP, TP), "wu": (None, FSDP, TP), "wd": (None, TP, FSDP)}


def spec_for_path(path: tuple, shape: tuple[int, ...], mesh,
                  fsdp: bool = True) -> P:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    leaf = names[-1]
    rule = _PARAM_RULES.get(leaf)
    moe_leaf = leaf in ("wg", "wu", "wd") and len(shape) >= 3 and "moe" in names
    if moe_leaf:
        E = shape[-3]
        if "expert" in mesh.axis_names:
            # expert-factorized mesh: experts on their own axis, f on model
            rule = {"wg": ("expert", FSDP, TP), "wu": ("expert", FSDP, TP),
                    "wd": ("expert", TP, FSDP)}[leaf]
        else:
            rule = _MOE_3D[leaf] if E % axis_size(mesh, TP) == 0 else _MOE_3D_FEW[leaf]
    if rule is not None and not moe_leaf:
        rule = _resolve(rule, mesh)
    if rule is None:
        return P()  # norms, scalars, step counters: replicated
    if not fsdp:
        # inference: TP-only, replicate over data axes (weights stay resident,
        # no per-layer gathers on the latency path)
        rule = tuple(None if r == FSDP else r for r in rule)
    pad = len(shape) - len(rule)
    spec = (None,) * pad + tuple(rule)
    return _guard(spec, shape, mesh)


def tree_shardings(tree: Any, mesh, fsdp: bool = True) -> Any:
    """NamedSharding pytree matching `tree` (params / opt state / grads)."""

    def f(path, leaf):
        return NamedSharding(mesh, spec_for_path(path, leaf.shape, mesh, fsdp))

    return jax.tree_util.tree_map_with_path(f, tree)


# ----------------------------------------------------------------------
# batch / decode-state shardings
# ----------------------------------------------------------------------

def batch_shardings(specs: dict, mesh) -> dict:
    dp = data_axes(mesh)
    out = {}
    for k, v in specs.items():
        spec = (dp,) + (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, _guard(spec, v.shape, mesh))
    return out


def decode_state_spec(path: tuple, shape: tuple[int, ...], mesh, batch: int) -> P:
    """KV caches (.., B, S, KV, hd) and recurrent states.

    Heads are TP-sharded when they divide the axis; otherwise the cache is
    sharded along the *sequence* (flash-decoding style).  Batch over dp
    when divisible.
    """
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    dp = data_axes(mesh)
    leaf = names[-1]
    if leaf in ("k", "v") and len(shape) >= 4:
        B, S, KV, hd = shape[-4:]
        kv_tp = KV % axis_size(mesh, TP) == 0
        spec4 = (dp, None if kv_tp else TP, TP if kv_tp else None, None)
        spec = (None,) * (len(shape) - 4) + spec4
        return _guard(spec, shape, mesh)
    if leaf == "s" and len(shape) >= 4:        # rwkv state (.., B, H, N, N)
        spec = (None,) * (len(shape) - 4) + (dp, TP, None, None)
        return _guard(spec, shape, mesh)
    if leaf == "h" and len(shape) >= 2:        # rglru (.., B, dr)
        spec = (None,) * (len(shape) - 2) + (dp, TP)
        return _guard(spec, shape, mesh)
    if leaf == "conv" and len(shape) >= 3:     # (.., B, W-1, dr)
        spec = (None,) * (len(shape) - 3) + (dp, None, TP)
        return _guard(spec, shape, mesh)
    if leaf in ("x_tm", "rwkv_cm") and len(shape) >= 2:  # (.., B, d)
        spec = (None,) * (len(shape) - 2) + (dp, TP)
        return _guard(spec, shape, mesh)
    # fallback: shard nothing
    return P()


def state_shardings(state_tree: Any, mesh, batch: int) -> Any:
    def f(path, leaf):
        return NamedSharding(mesh, decode_state_spec(path, leaf.shape, mesh, batch))

    return jax.tree_util.tree_map_with_path(f, state_tree)


def activation_rules(mesh, batch: int, n_kv: int | None = None,
                     seq_shard: bool = True, embed_shard: bool = False) -> dict:
    """Logical-name -> mesh-axis mapping for models.sharding.logical().

    seq_shard=True gives Megatron-style sequence parallelism between
    blocks: the residual stream (and hence the remat carries — the biggest
    training-memory term) is sharded over the model axis; XLA inserts the
    all-gather before attention and reduce-scatter after, which shows up
    in the collective roofline term honestly.
    """
    dp = data_axes(mesh)
    tpa = tp_axes(mesh)
    kv_tp = n_kv is not None and n_kv % axis_size(mesh, tpa) == 0
    rules = {
        "batch": dp if batch % axis_size(mesh, dp) == 0 else None,
        # recurrent archs shard the residual stream on the feature dim
        # (channels are independent); attention archs shard the sequence
        # (Megatron-SP).  Never both — logical() dedups per tensor.
        "seq": tpa if (seq_shard and not embed_shard) else None,
        "embed": tpa if embed_shard else None,
        "vocab": tpa,
        "heads": tpa,
        "kv_seq": None if kv_tp else tpa,
        "expert": "expert" if "expert" in mesh.axis_names else TP,
        "capacity": tpa,
        "ffn": TP,
        # MoE dispatch groups cover the whole (data x model) grid; the
        # buffer between dispatch and the expert einsum keeps only the
        # data-axis part on its group dim (the TP part moves to experts)
        "moe_group": ((dp if batch % axis_size(mesh, dp) == 0 else ())
                      + (tpa if isinstance(tpa, tuple) else (tpa,))),
        "moe_batch": dp if batch % axis_size(mesh, dp) == 0 else None,
    }
    return rules
