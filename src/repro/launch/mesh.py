"""Production mesh construction.

Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) — the pod axis is
pure data parallelism across pods (gradient all-reduce crosses the slower
inter-pod links; everything bandwidth-hungry stays inside a pod).

Defined as functions so importing this module never touches jax device
state (dryrun.py must set XLA_FLAGS before any jax initialization).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, expert_axis: int = 0):
    """expert_axis > 0 factorizes the in-pod model dimension as
    (expert_axis x 16//expert_axis) — the few-expert MoE variant from
    EXPERIMENTS.md §Perf iteration 6.  Chip count is unchanged."""
    if expert_axis:
        tp = 16 // expert_axis
        shape = (2, 16, expert_axis, tp) if multi_pod else (16, expert_axis, tp)
        axes = (("pod", "data", "expert", "model") if multi_pod
                else ("data", "expert", "model"))
        return jax.make_mesh(shape, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh for CPU tests (requires >= n_data*n_model local devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """Axes that shard the batch: ('pod','data') on multi-pod meshes."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh, name) -> int:
    if isinstance(name, (tuple, list)):
        s = 1
        for n in name:
            s *= axis_size(mesh, n)
        return s
    return mesh.shape[name] if name in mesh.axis_names else 1
