"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (see EXPERIMENTS.md
§Roofline):

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = collective_bytes_per_device / ICI_BW

cost_analysis() provides per-device FLOPs / bytes-accessed of the SPMD
module.  Collective bytes are NOT in cost_analysis: we parse the compiled
HLO text, sum the operand/result sizes of every collective op, and weight
by the ring cost for its replica-group size:

  all-gather      (n-1)/n * result
  reduce-scatter  (n-1)   * result   (result is the scattered shard)
  all-reduce      2(n-1)/n * result
  all-to-all      (n-1)/n * result
  collective-permute       result

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of 'bf16[4,128]' or a '(bf16[..], f32[..])' tuple."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, n_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota form [groups, group_size]<=[N]
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    m = re.search(r"replica_groups=\[(\d+)(?:,(\d+))+\]", line)
    if m:
        return int(line[m.start():m.end()].split(",")[1].rstrip("]"))
    return n_devices


def _permute_pairs(line: str) -> int:
    m = re.search(r"source_target_pairs=\{(.*?)\}\}", line)
    return 1  # permute cost is size regardless of pairs


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    total_bytes: float
    count_by_kind: dict
    top: list = dataclasses.field(default_factory=list)   # largest single ops

    def to_json(self):
        return {"bytes_by_kind": self.bytes_by_kind,
                "count_by_kind": self.count_by_kind,
                "total_bytes": self.total_bytes,
                "top": self.top}


_LINE_RE = re.compile(
    r"= (.+?) (all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    bytes_by_kind: dict[str, float] = defaultdict(float)
    count_by_kind: dict[str, int] = defaultdict(int)
    tops: list = []
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = _LINE_RE.search(ls)
        if not m:
            continue
        kind, suffix = m.group(2), m.group(3)
        if suffix == "-done":
            continue  # counted at -start (same transfer)
        shapes = [_shape_bytes(s + "[" + d + "]")
                  for s, d in _SHAPE_RE.findall(m.group(1))]
        if not shapes:
            continue
        if suffix == "-start" and len(shapes) > 1:
            # async form returns (operand, result): pick the true result
            size = max(shapes) if kind == "all-gather" else (
                min(shapes) if kind == "reduce-scatter" else shapes[-1])
        else:
            size = sum(shapes)
        n = max(_group_size(ls, n_devices), 1)
        if kind == "all-gather":
            cost = size * (n - 1) / n
        elif kind == "reduce-scatter":
            cost = size * (n - 1)
        elif kind == "all-reduce":
            cost = 2 * size * (n - 1) / n
        elif kind == "all-to-all":
            cost = size * (n - 1) / n
        else:  # collective-permute
            cost = size
        bytes_by_kind[kind] += cost
        count_by_kind[kind] += 1
        mm = re.search(r'op_name="([^"]{0,120})', ls)
        tops.append((cost, kind, m.group(1)[:80], mm.group(1) if mm else ""))
    tops.sort(reverse=True)
    return CollectiveStats(dict(bytes_by_kind), float(sum(bytes_by_kind.values())),
                           dict(count_by_kind),
                           [dict(bytes=round(c), kind=k, shape=s, op=o)
                            for c, k, s, o in tops[:12]])


@dataclasses.dataclass
class Roofline:
    flops: float                  # per-device
    bytes_accessed: float         # per-device
    collective_bytes: float       # per-device (ring-weighted)
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float            # 6*N*D (or 2*N*B for decode)
    useful_ratio: float           # model_flops / (flops * n_devices)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful model flops-time / bound time (how close to roofline)."""
        if self.bound_s <= 0:
            return 0.0
        ideal = self.model_flops / (PEAK_FLOPS * max(self.n_devices, 1))
        return ideal / self.bound_s

    n_devices: int = 1

    def to_json(self):
        return {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "collective_bytes_per_device": self.collective_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "n_devices": self.n_devices,
        }


def roofline_terms(cost: dict, collectives: CollectiveStats, n_devices: int,
                   model_flops: float) -> Roofline:
    flops = float(cost.get("flops", 0.0) or 0.0)
    byts = float(cost.get("bytes accessed", 0.0) or 0.0)
    cb = collectives.total_bytes
    total_flops = flops * n_devices
    return Roofline(
        flops=flops, bytes_accessed=byts, collective_bytes=cb,
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=cb / ICI_BW,
        model_flops=model_flops,
        useful_ratio=(model_flops / total_flops) if total_flops else 0.0,
        n_devices=n_devices,
    )
