"""Multi-job TPU-cluster gang scheduler (DESIGN.md L2 adaptation).

The paper's online matcher schedules *jobs' stage executions* onto pod
slices: a job = a DAG of stages (data prep -> train epochs -> eval ->
export, or prefill/decode phases of a serving rollout), each stage = a
gang-scheduled step program with a d-resource demand vector derived from
its dry-run roofline (chips-fraction, HBM, HBM-bw-seconds, ICI-bw-seconds
-> normalized per slice).

This reuses the cluster simulator with machines = pod slices, which is how
we validate scheduling policy at 1000+ node scale without hardware: the
simulator *is* the control plane; on a real deployment the `start_task`
callback launches `repro.launch.train` on the slice instead of advancing
virtual time.
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from ..core.dag import DAG, from_stage_graph
from ..sim.cluster import ClusterSim, SimConfig, scheme


@dataclasses.dataclass
class TPUJob:
    """A training/serving job expressed as a stage DAG over slices."""

    name: str
    arch: str
    stages: list[dict]        # {name, slices, seconds, hbm, hbm_bw, ici_bw, deps}
    group: int = 0

    def to_dag(self) -> DAG:
        q, durs, dems, deps = [], [], [], []
        for st in self.stages:
            q.append(int(st.get("slices", 1)))
            durs.append(float(st["seconds"]))
            dems.append(np.clip(np.array([
                st.get("chips", 0.5),
                st.get("hbm", 0.5),
                st.get("hbm_bw", 0.3),
                st.get("ici_bw", 0.3),
            ]), 0.01, 0.9))
            deps.append(list(st.get("deps", [])))
        return from_stage_graph(q, durs, dems, deps, name=self.name)


def job_from_roofline(name: str, arch: str, dryrun_dir: str,
                      steps: int = 100, group: int = 0) -> TPUJob:
    """Build a train job whose stage profile comes from the dry-run
    artifacts (§7.1 adapted: compiled-cost profiles instead of container
    histories)."""
    path = os.path.join(dryrun_dir, f"{arch}_train_4k_single.json")
    secs, hbm_frac, bw, ici = 60.0, 0.5, 0.5, 0.3
    if os.path.exists(path):
        rec = json.load(open(path))
        if "roofline" in rec:
            rl = rec["roofline"]
            step_s = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
            secs = step_s * steps
            hbm_frac = min(rec["memory"]["peak_live_bytes"] / (16 * 2**30), 0.9)
            total = step_s or 1.0
            bw = min(rl["memory_s"] / total, 0.9)
            ici = min(rl["collective_s"] / total, 0.9)
    stages = [
        dict(name="warmup", slices=1, seconds=30.0, chips=0.2, hbm=0.2,
             hbm_bw=0.1, ici_bw=0.05, deps=[]),
        dict(name="train", slices=1, seconds=secs, chips=0.9, hbm=hbm_frac,
             hbm_bw=bw, ici_bw=ici, deps=[0]),
        dict(name="eval", slices=1, seconds=secs * 0.1, chips=0.5,
             hbm=hbm_frac * 0.7, hbm_bw=bw * 0.5, ici_bw=ici * 0.3, deps=[1]),
        dict(name="export", slices=1, seconds=20.0, chips=0.1, hbm=0.3,
             hbm_bw=0.6, ici_bw=0.05, deps=[2]),
    ]
    return TPUJob(name=name, arch=arch, stages=stages, group=group)


def schedule_cluster(jobs: list[TPUJob], n_slices: int = 32,
                     interarrival: float = 60.0, seed: int = 0,
                     policy: str = "dagps",
                     placement_backend: str | None = None,
                     build_workers: int | None = 1,
                     matcher_shards: int | None = None,
                     matcher_mode: str = "exact",
                     profile: bool = False,
                     fault_plan=None,
                     heartbeat_period: float | None = None,
                     hb_suspect_after: float | None = None,
                     hb_lost_after: float | None = None,
                     recovery=None,
                     mutations=None,
                     speculate: bool = True,
                     serve: bool = False):
    """Gang-schedule the jobs' stage DAGs onto pod slices with DAGPS.

    ``placement_backend`` selects the offline construction engine
    (reference / batched / jit) for the schemes that build preferred
    schedules; ``build_workers`` overlaps per-arrival construction across
    a core.buildsvc worker pool (>1 or None = CPU count; decisions stay
    bit-identical); ``matcher_shards`` partitions the online matcher's
    machine axis (None = auto by slice count; any value is bit-identical,
    see core/shard.py); ``matcher_mode`` selects the online wave —
    "exact" (default, decision-exact for any shard count) or "routed"
    (fully distributed per-shard matching, an explicitly lossy preset);
    ``profile`` collects per-phase wall-clock timings on the returned
    result.

    Degraded-mode knobs (core/faults.py + docs/architecture.md):
    ``fault_plan`` is a ``FaultPlan`` or its spec string, installed for
    the run; ``heartbeat_period`` (+ ``hb_suspect_after`` /
    ``hb_lost_after``) turns on heartbeat-loss semantics in the
    simulator; ``recovery`` is a shared ``RecoveryPolicy``.

    ``mutations`` scripts mid-run dynamics (SimConfig.mutations): DAG
    edits via the core.dag mutation helpers — repaired incrementally
    through delta rebuilds — and slice speed changes.  The result's
    ``fault_stats`` and ``mutation_stats`` report what fired and how much
    of the previous placements each repair replayed.

    ``serve=True`` routes the same workload through the scheduler
    *service* instead of the simulator: a `svc.SchedulerService` plus one
    agent per slice over inproc comms, driven in virtual time
    (`svc.run_service_workload`).  Healthy runs produce placements and
    JCTs bit-identical to the simulator path with ``speculate=False``
    (the service places by lease, never speculatively); with a
    ``fault_plan`` touching the ``comm_send``/``agent`` seams the run
    exercises the lease-reclaim/exactly-once machinery instead.
    """
    rng = np.random.default_rng(seed)
    arrivals = []
    t = 0.0
    for j in jobs:
        arrivals.append((t, j.to_dag(), j.group))
        t += float(rng.exponential(interarrival))
    if serve:
        if mutations:
            raise ValueError("serve=True does not support scripted "
                             "mutations (simulator-only for now)")
        if matcher_mode != "exact":
            raise ValueError("serve=True supports matcher_mode='exact' only")
        from ..svc import ServiceConfig, run_service_workload
        scfg = ServiceConfig(n_machines=n_slices, seed=seed,
                             build_machines=max(n_slices // 8, 2),
                             placement_backend=placement_backend,
                             build_workers=build_workers,
                             matcher_shards=matcher_shards,
                             heartbeat_period=heartbeat_period or 1.0,
                             hb_suspect_after=hb_suspect_after,
                             hb_lost_after=hb_lost_after,
                             recovery=recovery)
        return run_service_workload(arrivals, scfg, scheme(policy),
                                    fault_plan=fault_plan)
    cfg = SimConfig(n_machines=n_slices, seed=seed,
                    build_machines=max(n_slices // 8, 2),
                    placement_backend=placement_backend,
                    build_workers=build_workers,
                    matcher_shards=matcher_shards,
                    matcher_mode=matcher_mode, profile=profile,
                    fault_plan=fault_plan,
                    heartbeat_period=heartbeat_period,
                    hb_suspect_after=hb_suspect_after,
                    hb_lost_after=hb_lost_after,
                    recovery=recovery,
                    speculate=speculate,
                    mutations=mutations)
    return ClusterSim(cfg, scheme(policy)).run(arrivals)
