"""Render the §Dry-run and §Roofline tables from artifacts/dryrun JSONs.

  PYTHONPATH=src python -m repro.launch.report [--dir artifacts/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_: str, mesh: str = "single", variant: str = "") -> list[dict]:
    out = []
    suffix = f"_{mesh}{('_' + variant) if variant else ''}.json"
    for f in sorted(glob.glob(os.path.join(dir_, f"*{suffix}"))):
        base = os.path.basename(f)[: -len(suffix)]
        if not variant and any(base.endswith(x) for x in ("_opt", "_v1", "_v2", "_v3")):
            continue
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def fmt_bytes(b) -> str:
    return f"{b / 2**30:.2f}"


def roofline_table(recs: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "model TFLOPs | useful | roofline frac | mem GiB | fits |")
    sep = "|" + "---|" * 11
    rows = [hdr, sep]
    for r in recs:
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | - | - | - | ERROR | - | - | - | - | -- |")
            continue
        rl = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.4f} | "
            f"{rl['memory_s']:.4f} | {rl['collective_s']:.4f} | {rl['dominant']} | "
            f"{rl['model_flops'] / 1e12:.0f} | {rl['useful_ratio']:.2f} | "
            f"{rl['roofline_fraction']:.3f} | "
            f"{fmt_bytes(r['memory']['peak_live_bytes'])} | "
            f"{'yes' if r['memory']['fits_16g'] else 'NO'} |")
    return "\n".join(rows)


def dryrun_table(recs: list[dict]) -> str:
    hdr = "| arch | shape | mesh | compile s | HLO lines | collectives (count by kind) | mem GiB | fits 16G |"
    sep = "|" + "---|" * 8
    rows = [hdr, sep]
    for r in recs:
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','?')} | FAIL | - | {r['error'][:60]} | - | - |")
            continue
        cc = r["collectives"]["count_by_kind"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']} | "
            f"{r['hlo_lines']} | {cc} | "
            f"{fmt_bytes(r['memory']['peak_live_bytes'])} | "
            f"{'yes' if r['memory']['fits_16g'] else 'NO'} |")
    return "\n".join(rows)


def pick_hillclimb(recs: list[dict]) -> list[str]:
    """worst roofline fraction / most collective-bound / most paper-representative."""
    ok = [r for r in recs if "roofline" in r]
    worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
    coll = max(ok, key=lambda r: r["roofline"]["collective_s"]
               / max(max(r["roofline"]["compute_s"], r["roofline"]["memory_s"]), 1e-12))
    # paper-representative: the technique is a cluster/EP scheduler — the MoE
    # train cell exercises expert-parallel placement hardest
    rep = next((r for r in ok if r["arch"] == "deepseek_moe_16b"
                and r["shape"] == "train_4k"), ok[0])
    return [f"{r['arch']}/{r['shape']}" for r in (worst, coll, rep)]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", default="")
    ap.add_argument("--what", default="roofline", choices=["roofline", "dryrun", "pick"])
    args = ap.parse_args()
    recs = load(args.dir, args.mesh, args.variant)
    if args.what == "roofline":
        print(roofline_table(recs))
    elif args.what == "dryrun":
        print(dryrun_table(recs))
    else:
        print("\n".join(pick_hillclimb(recs)))


if __name__ == "__main__":
    main()
