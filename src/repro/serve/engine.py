"""Serving engine: prefill + batched decode with explicit state.

Continuous-batching-lite: a request queue is served in fixed-size decode
batches; finished rows are refilled from the queue (slot reuse).  The
engine is deliberately functional — state in, state out — so the same
decode_step lowers for the dry-run decode cells.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as M


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 1024
    batch: int = 8
    temperature: float = 0.0   # 0 = greedy


def pad_prefill_state(cfg: M.ArchConfig, state: dict, S_max: int) -> dict:
    """Grow prefill KV caches to S_max slots (recurrent states untouched)."""

    def grow(path_leaf):
        return path_leaf

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k == "kv":
                    pad = S_max - v["k"].shape[-3]
                    out[k] = {
                        "k": jnp.pad(v["k"], ((0, 0),) * (v["k"].ndim - 3)
                                     + ((0, pad), (0, 0), (0, 0))),
                        "v": jnp.pad(v["v"], ((0, 0),) * (v["v"].ndim - 3)
                                     + ((0, pad), (0, 0), (0, 0))),
                    }
                else:
                    out[k] = walk(v)
            return out
        return node

    return walk(state)


def make_decode_fn(cfg: M.ArchConfig) -> Callable:
    @jax.jit
    def step(params, state, tokens, pos):
        return M.decode_step(params, cfg, state, tokens, pos)
    return step


def greedy_generate(params, cfg: M.ArchConfig, prompt: jax.Array, n_new: int,
                    s_max: int | None = None):
    """Generate n_new tokens after `prompt` (B, S0). Returns (B, n_new)."""
    B, S0 = prompt.shape[:2]
    s_max = s_max or (S0 + n_new)
    logits, state = M.prefill(params, cfg, {"tokens": prompt})
    state = pad_prefill_state(cfg, state, s_max)
    step = make_decode_fn(cfg)
    if cfg.n_codebooks > 1:
        last = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)  # (B,1,K)
    else:
        last = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)  # (B,1)
    out = []
    pos = jnp.full((B,), S0, jnp.int32)
    for t in range(n_new):
        logits, state = step(params, state, last, pos)
        last = jnp.argmax(logits[:, -1:] if cfg.n_codebooks == 1 else logits[:, -1:],
                          axis=-1).astype(jnp.int32)
        out.append(last)
        pos = pos + 1
    return jnp.concatenate(out, axis=1)


class Batcher:
    """Slot-based continuous batching over a request queue."""

    def __init__(self, params, cfg: M.ArchConfig, scfg: ServeConfig):
        self.params, self.cfg, self.scfg = params, cfg, scfg
        self.step = make_decode_fn(cfg)

    def serve(self, prompts: list[np.ndarray], n_new: int) -> list[np.ndarray]:
        """Serve a list of (S0,) prompts; returns list of (n_new,) outputs."""
        cfg, scfg = self.cfg, self.scfg
        results: list[np.ndarray | None] = [None] * len(prompts)
        queue = list(range(len(prompts)))
        B = scfg.batch
        state = M.init_decode_state(cfg, B, scfg.max_seq)
        slot_req = [-1] * B
        slot_pos = np.zeros(B, np.int32)
        slot_out: list[list] = [[] for _ in range(B)]
        cur = jnp.zeros((B, 1) if cfg.n_codebooks == 1 else (B, 1, cfg.n_codebooks),
                        jnp.int32)

        def admit(slot):
            if not queue:
                slot_req[slot] = -1
                return
            rid = queue.pop(0)
            slot_req[slot] = rid
            prompt = prompts[rid]
            # prefill by stepping tokens through this slot (simple engine;
            # the bulk-prefill path is used by the dry-run prefill cells)
            slot_pos[slot] = 0
            slot_out[slot] = []
            self._pending_prompt = getattr(self, "_pending_prompt", {})
            self._pending_prompt[slot] = list(np.asarray(prompt).tolist())

        self._pending_prompt = {}
        for s in range(B):
            admit(s)
        active = any(r >= 0 for r in slot_req)
        cur_np = np.zeros(cur.shape, np.int32)
        while active:
            # feed either the next prompt token or the last generated token
            for s in range(B):
                if slot_req[s] < 0:
                    continue
                pend = self._pending_prompt.get(s) or []
                if pend:
                    tok = pend.pop(0)
                    cur_np[s] = tok
            cur = jnp.asarray(cur_np)
            pos = jnp.asarray(slot_pos)
            logits, state = self.step(self.params, state, cur, pos)
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
            for s in range(B):
                if slot_req[s] < 0:
                    continue
                slot_pos[s] += 1
                if not self._pending_prompt.get(s):
                    slot_out[s].append(nxt[s].copy())
                    cur_np[s] = nxt[s]
                    if len(slot_out[s]) >= n_new:
                        results[slot_req[s]] = np.array(slot_out[s])
                        admit(s)
            active = any(r >= 0 for r in slot_req)
        return results  # type: ignore
