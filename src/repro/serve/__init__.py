from .engine import Batcher, ServeConfig, greedy_generate, make_decode_fn, pad_prefill_state
