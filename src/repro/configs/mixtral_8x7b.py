"""mixtral-8x7b [moe]: 32L d=4096 32H (kv=8) d_ff=14336 v=32000.

8 experts top-2, sliding-window attention (4096) [arXiv:2401.04088].
SWA bounds the decode KV cache -> long_500k runs.
"""
from ..models.model import ArchConfig
from ..models.layers import MoEConfig

WINDOW = 4096


def full() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x7b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=32000, rope_theta=1e6,
        block_pattern=("local",), window=WINDOW,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=14336,
                      router_mode="topk_softmax"),
        tie_embeddings=False, subquadratic=True,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="mixtral-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, rope_theta=1e6,
        block_pattern=("local",), window=16,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=128, capacity_factor=4.0,
                      router_mode="topk_softmax"),
        tie_embeddings=False, subquadratic=True, query_chunk=64,
    )
