"""codeqwen1.5-7b [dense]: 32L d=4096 32H (kv=32, MHA) d_ff=13440 v=92416.

qwen1.5 architecture: RoPE theta 1e6, attention qkv bias, SwiGLU
[hf:Qwen/CodeQwen1.5-7B].  Full attention -> long_500k skipped.
"""
from ..models.model import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="codeqwen1.5-7b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
        d_ff=13440, vocab=92416, rope_theta=1e6, qkv_bias=True,
        tie_embeddings=False, subquadratic=False,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="codeqwen-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, rope_theta=1e6, qkv_bias=True,
        tie_embeddings=False, subquadratic=False, query_chunk=64,
    )
