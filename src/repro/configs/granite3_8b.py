"""granite-3-8b [dense]: 40L d=4096 32H (kv=8) d_ff=12800 v=49155.

GQA llama-style decoder [hf:ibm-granite].  Full attention -> long_500k
skipped.
"""
from ..models.model import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="granite-3-8b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=12800, vocab=49155, rope_theta=1e4,
        tie_embeddings=True, subquadratic=False,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="granite-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, rope_theta=1e4,
        tie_embeddings=True, subquadratic=False, query_chunk=64,
    )
