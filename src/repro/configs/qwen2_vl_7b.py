"""qwen2-vl-7b [vlm]: 28L d=3584 28H (kv=4) d_ff=18944 v=152064.

M-RoPE (t/h/w sections 16/24/24), dynamic resolution [arXiv:2409.12191].
Modality frontend is a stub per assignment: input_specs() provides
precomputed patch embeddings which the backbone projects and prepends.
Full attention -> long_500k skipped.
"""
from ..models.model import ArchConfig

N_PATCHES = 256   # stub frontend: fixed patch budget prepended to the text


def full() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-7b", family="vlm",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
        d_ff=18944, vocab=152064, rope_theta=1e6,
        mrope_sections=(16, 24, 24), qkv_bias=True,
        vlm_patches=N_PATCHES,
        tie_embeddings=False, subquadratic=False,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, rope_theta=1e6,
        mrope_sections=(2, 3, 3), qkv_bias=True, vlm_patches=4,
        tie_embeddings=False, subquadratic=False, query_chunk=64,
    )
