"""rwkv6-7b (Finch) [ssm]: 32L d=4096 attn-free d_ff=14336 v=65536.

Data-dependent decay WKV recurrence, head size 64 [arXiv:2404.05892].
O(1) state -> long_500k runs.
"""
from ..models.model import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-7b", family="ssm",
        n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
        d_ff=14336, vocab=65536,
        block_pattern=("rwkv",), rwkv_head_size=64,
        pos_embedding="none", tie_embeddings=False, subquadratic=True,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256,
        block_pattern=("rwkv",), rwkv_head_size=16,
        pos_embedding="none", tie_embeddings=False, subquadratic=True,
        query_chunk=64,
    )
