"""deepseek-moe-16b [moe]: 28L d=2048 16H (kv=16) d_ff=1408/expert v=102400.

2 shared + 64 routed top-6 fine-grained experts [arXiv:2401.06066].
Layer 0 is a dense SwiGLU (d_ff=10944) per the published config.
Full attention -> long_500k skipped.
"""
from ..models.model import ArchConfig
from ..models.layers import MoEConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=1408, vocab=102400, rope_theta=1e4,
        moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                      router_mode="softmax_topk"),
        first_k_dense=1, dense_ff=10944,
        tie_embeddings=False, subquadratic=False,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="deepseek-moe-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=96, vocab=256, rope_theta=1e4,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=96, n_shared=1,
                      capacity_factor=4.0, router_mode="softmax_topk"),
        first_k_dense=1, dense_ff=128,
        tie_embeddings=False, subquadratic=False, query_chunk=64,
    )
