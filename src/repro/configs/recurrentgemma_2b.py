"""recurrentgemma-2b [hybrid]: 26L d=2560 10H (kv=1, MQA) d_ff=7680 v=256000.

Griffin: RG-LRU recurrent blocks + local attention (window 2048), pattern
(rec, rec, attn) [arXiv:2402.19427].  26 layers = 8 triples + 2 recurrent
remainder (unrolled tail).  O(1) recurrent state + windowed KV ->
long_500k runs.
"""
from ..models.model import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-2b", family="hybrid",
        n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
        d_ff=7680, vocab=256000, rope_theta=1e4,
        block_pattern=("rglru", "rglru", "local"), window=2048,
        rglru_width=2560, conv_width=4,
        norm_plus_one=True, mlp_kind="geglu", embed_scale=True,
        logit_cap=30.0,
        tie_embeddings=True, subquadratic=True,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-smoke", family="hybrid",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=256, rope_theta=1e4,
        block_pattern=("rglru", "rglru", "local"), window=16,
        rglru_width=64, conv_width=4,
        norm_plus_one=True, mlp_kind="geglu", embed_scale=True,
        logit_cap=30.0,
        tie_embeddings=True, subquadratic=True, query_chunk=64,
    )
