"""musicgen-large [audio]: 48L d=2048 32H (kv=32, MHA) d_ff=8192 v=2048.

Decoder-only over EnCodec tokens, 4 codebooks with summed embeddings and
per-codebook output heads, sinusoidal positions, LayerNorm-free variant
(we use RMSNorm per the shared substrate; GELU MLP) [arXiv:2306.05284].
The EnCodec frontend and text conditioning are stubs per assignment:
input_specs() provides the token grid directly.  Full attention ->
long_500k skipped.
"""
from ..models.model import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="musicgen-large", family="audio",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=8192, vocab=2048,
        n_codebooks=4, pos_embedding="sinusoidal", mlp_kind="mlp",
        tie_embeddings=False, subquadratic=False,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="musicgen-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=64,
        n_codebooks=4, pos_embedding="sinusoidal", mlp_kind="mlp",
        tie_embeddings=False, subquadratic=False, query_chunk=64,
    )
