"""Assigned architecture configs (--arch <id>) + reduced smoke variants.

Each module defines `full()` and `smoke()` returning an ArchConfig with the
exact published hyperparameters (full) or a tiny same-family config (smoke).
`get(arch_id)` / `get_smoke(arch_id)` look them up; SHAPES defines the
assigned input-shape cells and `cells()` enumerates the dry-run grid with
the long_500k sub-quadratic skip rule applied (see DESIGN.md §4).
"""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "deepseek_moe_16b",
    "mixtral_8x7b",
    "qwen2_vl_7b",
    "rwkv6_7b",
    "gemma2_2b",
    "codeqwen15_7b",
    "granite3_8b",
    "phi4_mini_3_8b",
    "recurrentgemma_2b",
    "musicgen_large",
]

# assigned shape cells: (name, seq_len, global_batch, kind)
SHAPES = {
    "train_4k":    dict(seq=4096,    batch=256, kind="train"),
    "prefill_32k": dict(seq=32768,   batch=32,  kind="prefill"),
    "decode_32k":  dict(seq=32768,   batch=128, kind="decode"),
    "long_500k":   dict(seq=524288,  batch=1,   kind="decode"),
}


def _mod(arch_id: str):
    return importlib.import_module(f".{arch_id}", __package__)


def get(arch_id: str):
    return _mod(arch_id.replace("-", "_")).full()


def get_smoke(arch_id: str):
    return _mod(arch_id.replace("-", "_")).smoke()


def cells(include_multipod: bool = False) -> list[tuple[str, str]]:
    """All (arch, shape) dry-run cells, honoring the long_500k skip rule."""
    out = []
    for a in ARCH_IDS:
        cfg = get(a)
        for s in SHAPES:
            if s == "long_500k" and not cfg.subquadratic:
                continue  # pure full-attention arch: noted in DESIGN.md
            out.append((a, s))
    return out
