"""phi4-mini-3.8b [dense]: 32L d=3072 24H (kv=8) d_ff=8192 v=200064.

RoPE + SwiGLU + GQA [arXiv:2412.08905].  Full attention -> long_500k
skipped.
"""
from ..models.model import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="phi4-mini-3.8b", family="dense",
        n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab=200064, rope_theta=1e4,
        tie_embeddings=True, subquadratic=False,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="phi4-smoke", family="dense",
        n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, head_dim=12,
        d_ff=96, vocab=256, rope_theta=1e4,
        tie_embeddings=True, subquadratic=False, query_chunk=64,
    )
