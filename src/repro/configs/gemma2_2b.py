"""gemma2-2b [dense]: 26L d=2304 8H (kv=4) d_ff=9216 v=256000.

Alternating local(4096)/global attention, attn softcap 50, final logit
softcap 30, (1+w) RMSNorm with post-norms, tied embeddings scaled by
sqrt(d) [arXiv:2408.00118].  Local layers bound their KV; global layers
decode against the full cache (linear per step) -> long_500k runs, with
the global-layer cache sharded over the sequence axis.
"""
from ..models.model import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="gemma2-2b", family="dense",
        n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
        d_ff=9216, vocab=256000, rope_theta=1e4,
        block_pattern=("local", "attn"), window=4096,
        attn_cap=50.0, logit_cap=30.0,
        norm_plus_one=True, post_norm=True, mlp_kind="geglu",
        embed_scale=True, tie_embeddings=True, subquadratic=True,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="gemma2-smoke", family="dense",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, rope_theta=1e4,
        block_pattern=("local", "attn"), window=16,
        attn_cap=50.0, logit_cap=30.0,
        norm_plus_one=True, post_norm=True, mlp_kind="geglu",
        embed_scale=True, tie_embeddings=True, subquadratic=True,
        query_chunk=64,
    )
