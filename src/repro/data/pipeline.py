"""Deterministic synthetic data pipeline.

Batches are a pure function of (seed, step, arch) — which is what makes
checkpoint/restart exactly replayable (fault tolerance: a restarted run
consumes the identical token stream with no data-loader state to persist).
Host sharding: each data shard slices its rows by process index, matching
the global batch sharding the launch layer sets up.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    # zipf-ish unigram skew so losses move like real text rather than
    # uniform noise
    zipf_alpha: float = 1.1


def batch_specs(cfg: ArchConfig, batch: int, seq: int) -> dict:
    """ShapeDtypeStructs for one training batch (dry-run input_specs)."""
    tok_shape = (batch, seq, cfg.n_codebooks) if cfg.n_codebooks > 1 else (batch, seq)
    specs = {
        "tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32),
        "labels": jax.ShapeDtypeStruct(tok_shape, jnp.int32),
    }
    if cfg.vlm_patches:
        specs["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.vlm_patches, cfg.d_model), jnp.bfloat16)
    return specs


def make_batch(cfg: ArchConfig, dcfg: DataConfig, step: int, batch: int, seq: int,
               rows: slice | None = None) -> dict:
    """Materialize the batch for `step` (numpy; host-side)."""
    rng = np.random.default_rng((dcfg.seed, step))
    b = batch if rows is None else (rows.stop - rows.start)
    # zipf-ish unigram distribution over the vocab
    ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
    probs = ranks ** (-dcfg.zipf_alpha)
    probs /= probs.sum()
    shape = (b, seq + 1, cfg.n_codebooks) if cfg.n_codebooks > 1 else (b, seq + 1)
    toks = rng.choice(cfg.vocab, size=shape, p=probs).astype(np.int32)
    out = {"tokens": jnp.asarray(toks[:, :seq]),
           "labels": jnp.asarray(toks[:, 1:])}
    if cfg.vlm_patches:
        out["patches"] = jnp.asarray(
            rng.standard_normal((b, cfg.vlm_patches, cfg.d_model)).astype(np.float32),
            dtype=jnp.bfloat16)
    return out
