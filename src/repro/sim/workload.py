"""Workload generators matching the paper's evaluated DAG populations.

§2.3's characterization of production DAGs drives `production_dag`:
  * median depth 7 (number of tasks on the critical path),
  * complex structure: median in-degree 7, out-degree 1 (75th: 48 / 4),
  * CoV of resource demands ~ 1 across tasks (Table 1),
  * task durations from sub-second to hundreds of seconds,
  * tasks grouped into stages with similar profiles.

Other generators model the paper's other workloads: TPC-H / TPC-DS /
BigBench-style query DAGs (§8.1), mostly-2-stage E-Hive jobs, distributed
build systems and request-response workflows (§9).

Scale note: production DAGs have a median of ~1000 tasks; to keep the
single-core simulator tractable we default to tens-to-hundreds of tasks per
DAG with the same structural statistics.  The construction algorithm is
size-agnostic; `scale` lifts task counts when desired.
"""

from __future__ import annotations

import numpy as np

from ..core.dag import (DAG, append_stage, from_stage_graph, resize_stage,
                        retarget_deadline, scale_speeds)


def _lognormal(rng, median: float, sigma: float) -> float:
    return float(median * np.exp(sigma * rng.standard_normal()))


def _stage_demand(rng: np.random.Generator) -> np.ndarray:
    """Per-stage demand vector with CoV ~ 1 across stages (Table 1)."""
    kind = rng.random()
    base = np.array([0.12, 0.12, 0.08, 0.08])
    if kind < 0.3:    # compute heavy (user-defined code)
        base = np.array([0.35, 0.10, 0.05, 0.05])
    elif kind < 0.55:  # memory heavy (in-memory sorts / joins)
        base = np.array([0.10, 0.40, 0.05, 0.08])
    elif kind < 0.8:   # shuffle heavy (network + disk)
        base = np.array([0.08, 0.10, 0.30, 0.25])
    dem = base * np.exp(0.75 * rng.standard_normal(4))
    return np.clip(dem, 0.01, 0.9)


def production_dag(rng: np.random.Generator, scale: float = 1.0, share: int = 4,
                   name: str = "prod") -> DAG:
    """Production-like DAG: staged graph with embedded long/heavy motifs.

    Structure follows §2.3: median depth ~7, substantial unordered work,
    demands CoV ~1, durations spanning ~3 orders of magnitude.  Crucially it
    embeds the pathology the paper shows is common in production (Fig. 2,
    Figs. 17-19): *long, resource-heavy stages at staggered depths whose
    enabling (wide, cheap) stages conflict with them on a dominant resource*.
    A greedy/CP scheduler starts a long stage as soon as it is runnable,
    which blocks the enablers of the other long stages and serializes them;
    overlapping the long stages requires placing them deliberately.

    `share` is the cluster share (machines) the job is sized for: stage
    widths scale with it so the job's share is actually contended (the
    regime the paper's production DAGs live in).
    """
    m = max(int(share * scale), 2)
    n_groups = int(rng.integers(2, 5))          # overlap motifs
    stages, durs, dems, deps = [], [], [], []

    def add(q, dur, dem, parents):
        stages.append(max(int(q), 1))
        durs.append(float(dur))
        dems.append(np.clip(np.asarray(dem, dtype=np.float64), 0.01, 0.9))
        deps.append(sorted(set(int(p) for p in parents)))
        return len(stages) - 1

    long_res = rng.permutation(4)
    prev_tail: int | None = None
    long_ids = []
    for g in range(n_groups):
        r_long = int(long_res[g % 4])
        # wide enabler stage: cheap tasks dominant on this group's long
        # resource, so a running long stage blocks the *next* enablers.
        wide_dem = np.full(4, 0.05) * np.exp(0.4 * rng.standard_normal(4))
        wide_dem[r_long] = rng.uniform(0.35, 0.55)
        parents = [prev_tail] if prev_tail is not None else []
        w = add(int(rng.integers(3 * m, 6 * m)),
                max(1.0, _lognormal(rng, 3.0, 0.5)), wide_dem, parents)
        # long heavy stage (spans most of the share for a long time)
        long_dem = np.full(4, 0.05) * np.exp(0.4 * rng.standard_normal(4))
        long_dem[r_long] = rng.uniform(0.5, 0.75)
        l = add(int(rng.integers(max(m // 2, 1), m + 1)),
                _lognormal(rng, 50.0, 0.4), long_dem, [w])
        long_ids.append(l)
        # a medium processing stage continues the chain
        mid_dem = np.full(4, 0.07) * np.exp(0.5 * rng.standard_normal(4))
        mid_dem[int(rng.integers(0, 4))] = rng.uniform(0.25, 0.5)
        prev_tail = add(int(rng.integers(2 * m, 4 * m)),
                        max(0.5, _lognormal(rng, 6.0, 0.8)), mid_dem, [w])
    # join/aggregate tail over the long stages and the chain
    agg_dem = np.full(4, 0.1) * np.exp(0.4 * rng.standard_normal(4))
    agg_dem[int(rng.integers(0, 4))] = rng.uniform(0.2, 0.4)
    add(int(rng.integers(1, 4)), max(1.0, _lognormal(rng, 8.0, 0.6)),
        agg_dem, long_ids + ([prev_tail] if prev_tail is not None else []))
    # noise stages: unordered side work with CoV~1 demands, mixed durations
    for _ in range(int(rng.integers(2, 6))):
        par = [int(rng.integers(0, len(stages) - 1))] if rng.random() < 0.5 else []
        dd = np.full(4, 0.08) * np.exp(0.6 * rng.standard_normal(4))
        dd[int(rng.integers(0, 4))] = rng.uniform(0.2, 0.6)
        add(max(1, int(_lognormal(rng, 4.0, 0.9))),
            max(0.3, _lognormal(rng, 5.0, 1.1)), dd, par)
    return from_stage_graph(stages, durs, dems, deps, name=name, rng=rng,
                            duration_jitter=0.15, demand_jitter=0.1)


# ----------------------------------------------------------------------
# Appendix adversarial DAGs (Lemmas 1-2, Figs. 17-19)
# ----------------------------------------------------------------------

def lemma1_dag(d: int = 4, k: int = 6, t: float = 10.0) -> DAG:
    """Fig. 17: d groups of k tasks, each group's 'red' task gates the next.

    Group i's tasks each consume all of resource i; the red task is
    structurally identical except it parents every task of group i+1.
    Any dependency-blind scheduler is Omega(d) x OPT in expectation.
    """
    stages, durs, dems, deps = [], [], [], []
    red_prev: int | None = None
    for i in range(d):
        dem = np.full(d, 0.02)
        dem[i] = 0.9
        parents = [red_prev] if red_prev is not None else []
        # siblings first: a dependency-blind scheduler that breaks ties by
        # id runs the red task *last* (the adversary's choice in the proof).
        stages.append(k - 1); durs.append(t); dems.append(dem.copy()); deps.append(list(parents))
        red = len(stages)
        stages.append(1); durs.append(t); dems.append(dem.copy()); deps.append(list(parents))
        red_prev = red
    return from_stage_graph(stages, durs, dems, deps, name=f"lemma1-d{d}")


def tetris_trap_dag(d: int = 4, t: float = 30.0) -> DAG:
    """Fig. 19 spirit: long tasks score highest for Tetris but serialize.

    d-1 long tasks (one per resource) can all co-run; each long task's wide
    parent stage conflicts with the *previous* long task.  Tetris greedily
    runs each long task as soon as it appears, blocking the next group's
    wide parents -> ~(2d-2) x OPT.  Placing the long tasks first overlaps
    them.
    """
    stages, durs, dems, deps = [], [], [], []
    eps = 0.04
    for j in range(1, d):
        wide = np.full(d, eps)
        wide[j - 1] = 0.55          # conflicts with long task j-1
        stages.append(4); durs.append(t * 0.1); dems.append(wide); deps.append([])
        long = np.full(d, eps)
        long[j] = 0.8
        stages.append(1); durs.append(t); dems.append(long)
        deps.append([len(stages) - 2])
    return from_stage_graph(stages, durs, dems, deps, name=f"tetris-trap-d{d}")


def query_dag(rng: np.random.Generator, preset: str = "tpch", name: str | None = None) -> DAG:
    """Tree-shaped analytical query DAGs: scans -> joins -> aggregates.

    Presets vary structure: TPC-H (moderate joins), TPC-DS (deeper, bushier),
    BigBench (CP-dominant: long chains), E-Hive (mostly 2-stage map-reduce).
    """
    cfg = {
        "tpch":    dict(n_scans=(2, 5), join_depth=(1, 3), chain=0.0),
        "tpcds":   dict(n_scans=(3, 7), join_depth=(2, 4), chain=0.15),
        "bigbench": dict(n_scans=(2, 4), join_depth=(1, 3), chain=0.6),
        "ehive":   dict(n_scans=(1, 2), join_depth=(0, 1), chain=0.0),
    }[preset]
    tasks: list[int] = []
    durs: list[float] = []
    dems: list[np.ndarray] = []
    deps: list[list[int]] = []

    def add_stage(q, dur, dem, parents):
        tasks.append(q)
        durs.append(dur)
        dems.append(dem)
        deps.append(parents)
        return len(tasks) - 1

    scans = []
    for _ in range(int(rng.integers(*cfg["n_scans"]) + 1)):
        q = max(2, int(_lognormal(rng, 8, 0.7)))
        scans.append(add_stage(
            q, max(1.0, _lognormal(rng, 8, 0.8)),
            np.clip(np.array([0.1, 0.08, 0.05, 0.3]) * np.exp(0.5 * rng.standard_normal(4)), 0.01, 0.9),
            [],
        ))
    frontier = scans
    depth = int(rng.integers(cfg["join_depth"][0], cfg["join_depth"][1] + 1))
    for _ in range(depth):
        if len(frontier) < 2:
            break
        nxt = []
        it = iter(frontier)
        for a in it:
            b = next(it, None)
            parents = [a] if b is None else [a, b]
            q = max(1, int(_lognormal(rng, 5, 0.6)))
            nxt.append(add_stage(
                q, max(1.0, _lognormal(rng, 15, 0.9)),
                np.clip(np.array([0.2, 0.3, 0.2, 0.1]) * np.exp(0.5 * rng.standard_normal(4)), 0.01, 0.9),
                parents,
            ))
        frontier = nxt
    # aggregate tail; BigBench-style adds a CP-dominant chain
    tail = add_stage(
        max(1, int(rng.integers(1, 4))), max(2.0, _lognormal(rng, 20, 0.6)),
        np.clip(np.array([0.25, 0.2, 0.1, 0.1]) * np.exp(0.4 * rng.standard_normal(4)), 0.01, 0.9),
        frontier,
    )
    while rng.random() < cfg["chain"]:
        tail = add_stage(
            1, max(2.0, _lognormal(rng, 25, 0.5)),
            np.clip(np.array([0.3, 0.2, 0.05, 0.05]) * np.exp(0.4 * rng.standard_normal(4)), 0.01, 0.9),
            [tail],
        )
    return from_stage_graph(tasks, durs, dems, deps, name=name or preset, rng=rng,
                            duration_jitter=0.15, demand_jitter=0.1)


def build_system_dag(rng: np.random.Generator, size: str = "medium", name: str = "build") -> DAG:
    """Distributed build DAG (§9): compile -> lib link -> bin link -> tests."""
    n_modules = {"small": 3, "medium": 6, "large": 12}[size]
    tasks, durs, dems, deps = [], [], [], []

    def add(q, dur, dem, parents):
        tasks.append(q)
        durs.append(dur)
        dems.append(np.asarray(dem))
        deps.append(parents)
        return len(tasks) - 1

    compiles = [
        add(max(2, int(_lognormal(rng, 10, 0.6))), max(0.5, _lognormal(rng, 4, 0.7)),
            np.clip(np.array([0.3, 0.12, 0.02, 0.08]) * np.exp(0.3 * rng.standard_normal(4)), 0.01, 0.9), [])
        for _ in range(n_modules)
    ]
    libs = [
        add(1, max(1.0, _lognormal(rng, 10, 0.5)),
            [0.15, 0.35, 0.05, 0.2], [c])
        for c in compiles
    ]
    binary = add(1, max(2.0, _lognormal(rng, 20, 0.4)), [0.2, 0.5, 0.05, 0.3], libs)
    for _ in range(int(rng.integers(2, 6))):
        add(max(2, int(_lognormal(rng, 6, 0.6))), max(2.0, _lognormal(rng, 30, 0.8)),
            np.clip(np.array([0.25, 0.15, 0.1, 0.05]) * np.exp(0.3 * rng.standard_normal(4)), 0.01, 0.9),
            [binary])
    return from_stage_graph(tasks, durs, dems, deps, name=name, rng=rng,
                            duration_jitter=0.2, demand_jitter=0.1)


def workflow_dag(rng: np.random.Generator, name: str = "workflow") -> DAG:
    """Request-response workflow (§9): dependent RPCs, ms-scale, shared pool."""
    depth = int(rng.integers(3, 8))
    tasks, durs, dems, deps = [], [], [], []
    prev: list[int] = []
    for lvl in range(depth):
        width = 1 if lvl in (0, depth - 1) else int(rng.integers(1, 5))
        cur = []
        for _ in range(width):
            tasks.append(max(1, int(rng.integers(1, 4))))
            durs.append(max(0.001, _lognormal(rng, 0.020, 0.8)))
            dems.append(np.clip(
                np.array([0.15, 0.1, 0.25, 0.05]) * np.exp(0.5 * rng.standard_normal(4)),
                0.01, 0.9))
            parents = prev if prev else []
            deps.append(list(parents))
            cur.append(len(tasks) - 1)
        prev = cur
    return from_stage_graph(tasks, durs, dems, deps, name=name, rng=rng,
                            duration_jitter=0.1, demand_jitter=0.1)


def periodic_dag(rng: np.random.Generator, name: str = "periodic") -> DAG:
    """Recurring-pipeline DAG: one phase template repeated behind barriers.

    Production clusters run large fractions of *recurring* jobs — the same
    pipeline executed over successive data windows (the paper's §2 notes
    over 40% of cluster workload recurs), and iterative jobs have the same
    shape: identical phases separated by synchronization barriers.  Each
    period here is scan -> two parallel process stages -> a barrier
    aggregate, with every period drawn ONCE and repeated verbatim, so
    `partition_totally_ordered` splits the DAG into identical sub-builds —
    the regime the cross-partition construction memo serves (identical
    partitions quantize to the same ticks, so the windowed place memo of
    period 1 answers the placements of periods 2..P).
    """
    periods = int(rng.integers(3, 6))
    # the template is drawn once; periods repeat it bit-identically
    scan_q = int(rng.integers(6, 14))
    scan_dur = max(1.0, _lognormal(rng, 6.0, 0.5))
    scan_dem = _stage_demand(rng)
    proc = [(int(rng.integers(3, 9)),
             max(1.0, _lognormal(rng, 12.0, 0.5)),
             _stage_demand(rng)) for _ in range(2)]
    agg_dur = max(1.0, _lognormal(rng, 4.0, 0.4))
    agg_dem = _stage_demand(rng)

    tasks, durs, dems, deps = [], [], [], []

    def add(q, dur, dem, parents):
        tasks.append(q)
        durs.append(dur)
        dems.append(dem)
        deps.append(parents)
        return len(tasks) - 1

    barrier = None
    for _p in range(periods):
        s = add(scan_q, scan_dur, scan_dem, [barrier] if barrier is not None else [])
        ps = [add(q, dur, dem, [s]) for q, dur, dem in proc]
        barrier = add(1, agg_dur, agg_dem, ps)
    # no jitter: periods must stay bit-identical (that IS the workload)
    return from_stage_graph(tasks, durs, dems, deps, name=name, rng=rng)


# ----------------------------------------------------------------------
# Dynamic-DAG scenarios (s12): scripted mutations for SimConfig.mutations
# ----------------------------------------------------------------------

def mut_append_stage(q: int = 2, duration: float = 4.0, demand=None):
    """Curried `core.dag.append_stage`: a late-arriving stage hung off the
    DAG's last stage (the 'tasks added to a running job' production case)."""
    dem = np.full(4, 0.1) if demand is None else np.asarray(demand, float)

    def mut(dag: DAG):
        return append_stage(dag, q, duration, dem,
                            parent_stages=(int(dag.stage_of.max()),))
    return mut


def mut_resize_stage(stage: int = 1, delta_q: int = 1):
    """Curried `core.dag.resize_stage`: grow/shrink one stage by delta_q."""
    def mut(dag: DAG):
        q = int((dag.stage_of == stage).sum())
        return resize_stage(dag, stage, max(q + delta_q, 1))
    return mut


def mut_retarget(factor: float = 0.8):
    """Curried `core.dag.retarget_deadline`: pull every deadline in."""
    return lambda dag: retarget_deadline(dag, factor)


def mut_scale_speeds(factor: float = 1.5, ids=None):
    """Curried `core.dag.scale_speeds`: the job-share view of a machine
    speed edit (durations rescale)."""
    return lambda dag: scale_speeds(dag, factor, ids)


def s12_dynamic(kind: str, n_jobs: int = 6, seed: int = 0):
    """Recurring-pipeline population + scripted edits — the s12_dynamic
    scenario family.  One periodic template repeated ``n_jobs`` times (the
    paper's >40%-recurring regime) plus mutations per ``kind``:

      resize — a stage resize lands before each later arrival: the classic
               recurring-pipeline edit.  Only the edited period's partition
               re-searches; every other partition replays from the
               template's schedule (the >=50%-placement-reuse scenario).
      retime — a deadline pull-in lands before each later arrival: every
               duration changes, so nothing can replay (worst case; the
               contrast row for the reuse accounting).
      midrun — dynamics inside a running job: a task/stage arrival, a
               deadline pull-in, and a machine speed change.

    Returns ``(dags, mutations)`` for `run_workload(..., mutations=...)`.
    """
    rng = np.random.default_rng(seed)
    template = periodic_dag(rng, name="recurring")
    dags = [template] * n_jobs
    if kind == "resize":
        muts = [(0.0, k, mut_resize_stage(stage=1, delta_q=1))
                for k in range(1, n_jobs)]
    elif kind == "retime":
        muts = [(0.0, k, mut_retarget(0.8)) for k in range(1, n_jobs)]
    elif kind == "midrun":
        muts = [(1.0, 0, mut_append_stage()),
                (2.0, 0, mut_retarget(0.9)),
                (5.0, "speed", 0, 1.5)]
    else:
        raise ValueError(f"unknown s12_dynamic kind {kind!r}")
    return dags, muts


def online_mix_workload(n_jobs: int, seed: int = 0,
                        scale: float = 0.5) -> list[DAG]:
    """Cluster-scale online mix: alternating production + TPC-DS jobs.

    The population the s8/s9 online scenarios schedule — the paper's §8
    regime of heterogeneous query DAGs interleaved with production DAGs
    arriving at high rate on hundreds of machines.  `scale` sizes the
    production DAGs (0.5 keeps individual jobs small so the *count* of
    concurrent jobs, not any single DAG, is what stresses the scheduler).
    """
    rng = np.random.default_rng(seed)
    out: list[DAG] = []
    for k in range(n_jobs):
        if k % 2 == 0:
            out.append(production_dag(rng, scale=scale, name=f"prod-{k}"))
        else:
            out.append(query_dag(rng, "tpcds", name=f"tpcds-{k}"))
    return out


def make_workload(benchmark: str, n_jobs: int, seed: int = 0, scale: float = 1.0) -> list[DAG]:
    """n_jobs DAGs drawn from a benchmark family (§8.1)."""
    rng = np.random.default_rng(seed)
    out: list[DAG] = []
    for k in range(n_jobs):
        if benchmark == "production":
            out.append(production_dag(rng, scale=scale, name=f"prod-{k}"))
        elif benchmark in ("tpch", "tpcds", "bigbench", "ehive"):
            out.append(query_dag(rng, benchmark, name=f"{benchmark}-{k}"))
        elif benchmark == "build":
            out.append(build_system_dag(rng, name=f"build-{k}"))
        elif benchmark == "workflow":
            out.append(workflow_dag(rng, name=f"wf-{k}"))
        elif benchmark == "periodic":
            out.append(periodic_dag(rng, name=f"periodic-{k}"))
        elif benchmark == "mixed":
            kind = ["production", "tpch", "tpcds", "bigbench"][k % 4]
            out.extend(make_workload(kind, 1, seed=seed * 1000 + k, scale=scale))
        else:
            raise ValueError(f"unknown benchmark {benchmark!r}")
    return out
