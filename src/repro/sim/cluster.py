"""Discrete-event cluster simulator (paper §8 experimental harness).

Simulates a cluster of machines with d-resource capacity, online job
arrivals, heartbeat-driven matching via `core.online.Matcher`, plus the
runtime artifacts the paper discusses:

  * stragglers (injected duration stretch) + speculative re-execution
    (the mitigation §2.3 corrects for),
  * machine failures with task re-queueing and rejoin (fault tolerance /
    elasticity at the cluster level),
  * implicit over-allocation slowdowns when a scheduler ignores some
    resource dims (Tez/CP only fit cores+memory -> network/disk overload,
    the Fig. 11 effect), and explicit bounded overbooking for DAGPS.

Scheme presets mirror §8.1's compared schemes.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Sequence

import numpy as np

from ..core.builder import build_schedule
from ..core.baselines import bfs_order, cp_order, random_order
from ..core.dag import DAG
from ..core.engine import packing
from ..core.online import (
    JobView,
    Matcher,
    MatcherConfig,
    PendingTask,
    drf_fairness,
    slot_fairness,
)


@dataclasses.dataclass
class SchemeSpec:
    name: str
    order_fn: str = "dagps"        # dagps | bfs | cp | random
    matcher: MatcherConfig = dataclasses.field(default_factory=MatcherConfig)


def scheme(name: str, **overrides) -> SchemeSpec:
    """Preset schemes from §8.1."""
    presets: dict[str, SchemeSpec] = {
        # Tez: breadth-first order on the Capacity Scheduler; knows cores+mem.
        "tez": SchemeSpec(
            "tez", "bfs",
            MatcherConfig(use_packing=False, use_srpt=False, use_overbooking=False,
                          fit_dims=(0, 1), kappa=0.02),
        ),
        "tez+cp": SchemeSpec(
            "tez+cp", "cp",
            MatcherConfig(use_packing=False, use_srpt=False, use_overbooking=False,
                          fit_dims=(0, 1), kappa=0.02),
        ),
        "tez+tetris": SchemeSpec(
            "tez+tetris", "bfs",
            MatcherConfig(use_packing=True, use_srpt=True, use_overbooking=False,
                          use_priority=False, kappa=10.0),  # Tetris: unbounded unfairness
        ),
        "tez+drf": SchemeSpec(
            "tez+drf", "bfs",
            MatcherConfig(use_packing=False, use_srpt=False, use_overbooking=False,
                          fit_dims=(0, 1), kappa=0.02, fairness=drf_fairness),
        ),
        "random": SchemeSpec(
            "random", "random",
            MatcherConfig(use_packing=False, use_srpt=False, use_overbooking=False,
                          kappa=10.0),
        ),
        "dagps": SchemeSpec("dagps", "dagps", MatcherConfig()),
        # ablation: DAGPS order without overbooking
        "dagps-noob": SchemeSpec(
            "dagps-noob", "dagps", MatcherConfig(use_overbooking=False)
        ),
    }
    spec = presets[name]
    if overrides:
        spec = dataclasses.replace(spec, matcher=dataclasses.replace(spec.matcher, **overrides))
    return spec


@dataclasses.dataclass
class SimConfig:
    n_machines: int = 50
    d: int = 4
    seed: int = 0
    expose_per_job: int = 8        # runnable tasks an AM exposes per heartbeat
    build_machines: int | None = None  # m for offline construction (job share)
    straggle_prob: float = 0.0
    straggle_factor: tuple[float, float] = (2.0, 5.0)
    speculate: bool = True
    spec_threshold: float = 1.8
    failure_rate: float = 0.0      # machine-failures per simulated second
    repair_time: float = 120.0
    record_usage: bool = False
    placement_backend: str | None = None  # engine backend for offline builds


@dataclasses.dataclass
class JobResult:
    job_id: int
    group: int
    arrival: float
    finish: float
    n_tasks: int

    @property
    def jct(self) -> float:
        return self.finish - self.arrival


@dataclasses.dataclass
class SimResult:
    jobs: list[JobResult]
    makespan: float
    usage_samples: list[tuple[float, np.ndarray]]
    allocations: list[tuple[float, float, int, float]]  # start, end, group, weight
    speculative_launches: int = 0
    failed_tasks_requeued: int = 0

    def jcts(self) -> np.ndarray:
        return np.array([j.jct for j in self.jobs])

    def jain_index(self, window: float, shares: dict[int, float]) -> float:
        """Mean Jain's index of per-group usage over fixed windows (Table 4)."""
        if not self.allocations:
            return 1.0
        horizon = self.makespan
        total_share = sum(shares.values()) or 1.0
        idxs = []
        t = 0.0
        while t < horizon:
            hi = t + window
            usage = {g: 0.0 for g in shares}
            for (s, e, g, w) in self.allocations:
                ov = max(0.0, min(e, hi) - max(s, t))
                if ov > 0 and g in usage:
                    usage[g] += ov * w
            xs = np.array([usage[g] / (shares[g] / total_share) for g in shares])
            if xs.sum() > 0:
                idxs.append(float(xs.sum() ** 2 / (len(xs) * (xs ** 2).sum())))
            t = hi
        return float(np.mean(idxs)) if idxs else 1.0


class _Job:
    def __init__(self, job_id: int, dag: DAG, arrival: float, group: int,
                 pri: np.ndarray):
        self.job_id = job_id
        self.dag = dag
        self.arrival = arrival
        self.group = group
        self.pri = pri
        self.pending_parents = np.array([len(dag.parents[i]) for i in range(dag.n)])
        self.runnable: set[int] = {i for i in range(dag.n) if self.pending_parents[i] == 0}
        self.running: set[int] = set()
        self.done: set[int] = set()
        weight = np.abs(dag.demand).sum(axis=1)
        self._work = dag.duration * weight
        self.srpt = float(self._work.sum())
        self.finish: float | None = None

    def task_started(self, t: int) -> None:
        self.runnable.discard(t)
        self.running.add(t)

    def task_requeued(self, t: int) -> None:
        self.running.discard(t)
        self.runnable.add(t)

    def task_done(self, t: int) -> list[int]:
        if t in self.done:
            return []
        self.running.discard(t)
        self.runnable.discard(t)
        self.done.add(t)
        self.srpt -= float(self._work[t])
        newly = []
        for c in self.dag.children[t]:
            self.pending_parents[c] -= 1
            if self.pending_parents[c] == 0 and c not in self.done:
                newly.append(int(c))
                self.runnable.add(int(c))
        return newly

    @property
    def complete(self) -> bool:
        return len(self.done) == self.dag.n


class ClusterSim:
    """Event-driven simulation of many DAG jobs under a scheme."""

    def __init__(self, cfg: SimConfig, spec: SchemeSpec):
        self.cfg = cfg
        self.spec = spec

    def _make_pri(self, dag: DAG, rng: np.random.Generator) -> np.ndarray:
        kind = self.spec.order_fn
        if kind == "dagps":
            m = self.cfg.build_machines or max(self.cfg.n_machines // 10, 4)
            return build_schedule(dag, m, backend=self.cfg.placement_backend).pri_score
        if kind == "bfs":
            order = bfs_order(dag)
        elif kind == "cp":
            order = cp_order(dag)
        else:
            order = random_order(dag, int(rng.integers(1 << 31)))
        rank = np.empty(dag.n)
        rank[order] = np.arange(dag.n)
        return 1.0 - rank / max(dag.n, 1)

    # ------------------------------------------------------------------
    def run(self, arrivals: Sequence[tuple[float, DAG, int]]) -> SimResult:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed)
        M, d = cfg.n_machines, cfg.d
        avail = np.ones((M, d), dtype=np.float64)
        alive = np.ones(M, dtype=bool)
        groups = sorted({g for (_, _, g) in arrivals})
        shares = {g: 1.0 for g in groups}
        matcher = Matcher(self.spec.matcher, capacity=float(M), shares=shares)

        jobs: dict[int, _Job] = {}
        counter = itertools.count()
        events: list[tuple[float, int, str, tuple]] = []
        for k, (t, dag, g) in enumerate(arrivals):
            heapq.heappush(events, (float(t), next(counter), "arrival", (k, dag, g)))
        if cfg.failure_rate > 0:
            t_fail = float(rng.exponential(1.0 / cfg.failure_rate))
            heapq.heappush(events, (t_fail, next(counter), "fail", ()))

        running: dict[int, dict] = {}   # run_id -> info
        run_counter = itertools.count()
        task_active: dict[tuple[int, int], list[int]] = {}  # (job,task) -> run_ids
        results: list[JobResult] = []
        usage_samples: list[tuple[float, np.ndarray]] = []
        allocations: list[tuple[float, float, int, float]] = []
        spec_launches = 0
        requeued = 0
        t_now = 0.0

        def machine_load(m: int) -> np.ndarray:
            return 1.0 - avail[m]

        def start_task(job: _Job, tid: int, m: int, now: float, speculative: bool = False) -> None:
            nonlocal spec_launches
            dem = job.dag.demand[tid]
            avail[m] -= dem
            base = float(job.dag.duration[tid])
            dur = base
            if not speculative and cfg.straggle_prob > 0 and rng.random() < cfg.straggle_prob:
                lo, hi = cfg.straggle_factor
                dur = base * float(rng.uniform(lo, hi))
            # implicit/explicit overload on fungible dims slows this task down
            load = machine_load(m)
            overload = float(max(load[2:].max() if d > 2 else 0.0, 1.0))
            dur_eff = dur * overload
            rid = next(run_counter)
            running[rid] = dict(job=job.job_id, task=tid, machine=m,
                                start=now, expected=base, dead=False)
            task_active.setdefault((job.job_id, tid), []).append(rid)
            if not speculative:
                job.task_started(tid)
            else:
                spec_launches += 1
            heapq.heappush(events, (now + dur_eff, next(counter), "finish", (rid,)))
            if cfg.speculate and not speculative:
                chk = now + cfg.spec_threshold * base
                heapq.heappush(events, (chk, next(counter), "spec", (rid,)))
            allocations.append((now, now + dur_eff, job.group, float(np.abs(dem).sum())))

        def free_run(rid: int) -> None:
            info = running[rid]
            if not info["dead"]:
                info["dead"] = True
                avail[info["machine"]] += jobs[info["job"]].dag.demand[info["task"]]

        def _candidates() -> tuple[list[PendingTask], dict[int, JobView]]:
            cands: list[PendingTask] = []
            views: dict[int, JobView] = {}
            for j in jobs.values():
                if j.complete or not j.runnable:
                    continue
                views[j.job_id] = JobView(j.job_id, j.group, j.srpt)
                top = sorted(j.runnable, key=lambda t: -j.pri[t])[: cfg.expose_per_job]
                for tid in top:
                    cands.append(PendingTask(
                        job_id=j.job_id, task_id=tid,
                        demand=j.dag.demand[tid], duration=float(j.dag.duration[tid]),
                        pri_score=float(j.pri[tid]),
                    ))
            return cands, views

        def match_machine(m: int, now: float) -> None:
            if not alive[m]:
                return
            cands, views = _candidates()
            if not cands:
                return
            picks = matcher.find_tasks_for_machine(m, avail[m], cands, views)
            for task, _over in picks:
                start_task(jobs[task.job_id], task.task_id, m, now)

        def match_all(now: float) -> None:
            cands, views = _candidates()
            if not cands:
                return
            order = np.argsort(-avail.sum(axis=1))
            for m in order:
                m = int(m)
                if not alive[m] or not (avail[m] > 1e-9).any():
                    continue
                if not cands:
                    break
                # sound skip: machine can host nothing if its availability is
                # below the per-dim minimum demand of all remaining candidates
                min_dem = np.min([t.demand for t in cands], axis=0)
                fd = list(self.spec.matcher.fit_dims)
                if (not packing.fits_mask(avail[m], min_dem, dims=fd)
                        and not self.spec.matcher.use_overbooking):
                    continue
                picks = matcher.find_tasks_for_machine(m, avail[m], cands, views)
                started_ids = set()
                for task, _over in picks:
                    start_task(jobs[task.job_id], task.task_id, m, now)
                    started_ids.add((task.job_id, task.task_id))
                if started_ids:
                    cands = [t for t in cands if (t.job_id, t.task_id) not in started_ids]

        while events:
            t_now, _, kind, data = heapq.heappop(events)
            if kind == "arrival":
                k, dag, g = data
                pri = self._make_pri(dag, rng)
                job = _Job(k, dag, t_now, g, pri)
                jobs[k] = job
                match_all(t_now)
            elif kind == "finish":
                (rid,) = data
                info = running[rid]
                if info["dead"]:
                    continue
                job = jobs[info["job"]]
                tid = info["task"]
                free_run(rid)
                # kill sibling speculative copies
                for sib in task_active.get((job.job_id, tid), []):
                    if sib != rid and not running[sib]["dead"]:
                        free_run(sib)
                job.task_done(tid)
                if job.complete and job.finish is None:
                    job.finish = t_now
                    results.append(JobResult(job.job_id, job.group, job.arrival,
                                             t_now, job.dag.n))
                if cfg.record_usage:
                    usage_samples.append((t_now, (1.0 - avail[alive]).sum(axis=0)))
                # drain simultaneous finishes before re-matching
                while events and events[0][2] == "finish" and events[0][0] <= t_now + 1e-9:
                    _, _, _, (rid2,) = heapq.heappop(events)
                    info2 = running[rid2]
                    if info2["dead"]:
                        continue
                    job2 = jobs[info2["job"]]
                    tid2 = info2["task"]
                    free_run(rid2)
                    for sib in task_active.get((job2.job_id, tid2), []):
                        if sib != rid2 and not running[sib]["dead"]:
                            free_run(sib)
                    job2.task_done(tid2)
                    if job2.complete and job2.finish is None:
                        job2.finish = t_now
                        results.append(JobResult(job2.job_id, job2.group, job2.arrival,
                                                 t_now, job2.dag.n))
                match_all(t_now)
            elif kind == "spec":
                (rid,) = data
                info = running[rid]
                if info["dead"]:
                    continue
                job = jobs[info["job"]]
                tid = info["task"]
                # only speculate if some machine can host a copy right now
                dem = job.dag.demand[tid]
                fit = np.nonzero(alive & packing.fits_mask(avail, dem))[0]
                if len(fit):
                    start_task(job, tid, int(fit[0]), t_now, speculative=True)
            elif kind == "fail":
                m = int(rng.integers(M))
                if alive[m]:
                    alive[m] = False
                    for rid, info in list(running.items()):
                        if not info["dead"] and info["machine"] == m:
                            free_run(rid)
                            job = jobs[info["job"]]
                            job.task_requeued(info["task"])
                            requeued += 1
                    avail[m] = 0.0
                    heapq.heappush(events, (t_now + cfg.repair_time, next(counter), "join", (m,)))
                still_work = any(not j.complete for j in jobs.values()) or any(
                    e[2] == "arrival" for e in events
                )
                if cfg.failure_rate > 0 and still_work:
                    nxt = t_now + float(rng.exponential(1.0 / cfg.failure_rate))
                    heapq.heappush(events, (nxt, next(counter), "fail", ()))
            elif kind == "join":
                (m,) = data
                alive[m] = True
                avail[m] = 1.0
                match_machine(m, t_now)

        makespan = max((j.finish for j in results), default=0.0)
        return SimResult(results, makespan, usage_samples, allocations,
                         spec_launches, requeued)


def run_workload(
    dags: Sequence[DAG],
    scheme_name: str,
    n_machines: int = 50,
    interarrival: float = 25.0,
    n_groups: int = 1,
    seed: int = 0,
    **cfg_overrides,
) -> SimResult:
    """Poisson arrivals (§8.1: avg 25s apart), even group assignment."""
    rng = np.random.default_rng(seed)
    t = 0.0
    arrivals = []
    for k, dag in enumerate(dags):
        arrivals.append((t, dag, k % n_groups))
        t += float(rng.exponential(interarrival))
    sim_fields = {f.name for f in dataclasses.fields(SimConfig)}
    matcher_fields = {f.name for f in dataclasses.fields(MatcherConfig)}
    sim_kwargs = {k: v for k, v in cfg_overrides.items() if k in sim_fields}
    matcher_kwargs = {k: v for k, v in cfg_overrides.items() if k in matcher_fields}
    unknown = set(cfg_overrides) - sim_fields - matcher_fields
    if unknown:
        raise TypeError(f"unknown overrides: {unknown}")
    cfg = SimConfig(n_machines=n_machines, seed=seed, **sim_kwargs)
    return ClusterSim(cfg, scheme(scheme_name, **matcher_kwargs)).run(arrivals)
