"""Discrete-event cluster simulator (paper §8 experimental harness).

Simulates a cluster of machines with d-resource capacity, online job
arrivals, heartbeat-driven matching via `core.online.Matcher`, plus the
runtime artifacts the paper discusses:

  * stragglers (injected duration stretch) + speculative re-execution
    (the mitigation §2.3 corrects for),
  * machine failures with task re-queueing and rejoin (fault tolerance /
    elasticity at the cluster level),
  * implicit over-allocation slowdowns when a scheduler ignores some
    resource dims (Tez/CP only fit cores+memory -> network/disk overload,
    the Fig. 11 effect), and explicit bounded overbooking for DAGPS.

Scheme presets mirror §8.1's compared schemes.

The event loop runs on the vectorized online data path (see
docs/architecture.md): a persistent `TaskPool` replaces per-heartbeat
candidate rebuilds, heartbeat waves route through the sharded matcher
(`core/shard.py` — one batched `machines_with_candidates` eligibility
launch per machine shard, fanned out over a thread pool, auto-selecting
the accelerated sound-superset kernels at large m, decisions pinned to
one global `Matcher` so any shard count is bit-identical), run records
live in a SoA
`_RunTable` indexed by the heap's integer payloads, and offline builds are
memoized by DAG content digest — all bit-identical to the object-list
implementation this replaced (tests/test_online_parity.py,
tests/data/golden_sim.json).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from typing import Sequence

import numpy as np

from ..core import faults
from ..core.builder import build_schedule, rebuild_schedule
from ..core.buildsvc import BuildService
from ..core.baselines import bfs_order, cp_order, random_order
from ..core.dag import DAG, dag_digest
from ..core.engine import get_backend, kernels, packing
from ..core.online import (
    JobState,
    Matcher,
    MatcherConfig,
    TaskPool,
    drf_fairness,
    overload_factor,
    slot_fairness,
)
from ..core.shard import ShardedMatcher

# event codes (heap entries are (time, seq, code, int_arg) — payloads live in
# side tables indexed by the int arg, never in per-event tuples/dicts).
# _HB = a machine emits a heartbeat, _HBA = a delayed heartbeat arrives at
# the scheduler, _HBCHK = the scheduler checks a machine's silence deadline,
# _MUT = a scripted dynamic-DAG / machine-speed mutation fires
_ARRIVAL, _FINISH, _SPEC, _FAIL, _JOIN, _HB, _HBA, _HBCHK, _MUT = range(9)


class _RunTable:
    """SoA records of every launched task copy, indexed by run id.

    Replaces the per-run dict objects: `fail` events select a machine's
    live runs with one vectorized mask instead of scanning a dict, and
    `finish`/`spec` events index straight into the arrays.
    """

    def __init__(self, cap: int = 256):
        self.job = np.empty(cap, dtype=np.int64)
        self.task = np.empty(cap, dtype=np.int64)
        self.machine = np.empty(cap, dtype=np.int64)
        self.start = np.empty(cap, dtype=np.float64)
        self.expected = np.empty(cap, dtype=np.float64)
        self.dead = np.zeros(cap, dtype=bool)
        self.n = 0

    def append(self, job: int, task: int, machine: int, start: float,
               expected: float) -> int:
        if self.n == len(self.job):
            for name in ("job", "task", "machine", "start", "expected", "dead"):
                arr = getattr(self, name)
                grown = np.zeros(2 * len(arr), dtype=arr.dtype)
                grown[: len(arr)] = arr
                setattr(self, name, grown)
        rid = self.n
        self.job[rid] = job
        self.task[rid] = task
        self.machine[rid] = machine
        self.start[rid] = start
        self.expected[rid] = expected
        self.dead[rid] = False
        self.n += 1
        return rid

    def live_on(self, machine: int) -> np.ndarray:
        """Run ids alive on a machine, ascending (== launch order)."""
        return np.flatnonzero(~self.dead[: self.n]
                              & (self.machine[: self.n] == machine))


# Exact memo of offline construction: build_schedule is deterministic, so
# identical (DAG content, share, backend) triples yield identical priScore
# vectors (keyed by the canonical core.dag.dag_digest — the same digest
# the build service dedups on).  Benchmarks replay the same DAG
# population through several schemes/configs; caching makes every dagps
# build after the first free while leaving outputs bit-identical.
_PRI_CACHE: dict[tuple, np.ndarray] = {}
_PRI_CACHE_CAP = 1024


def clear_schedule_cache() -> None:
    """Drop the cross-run schedule cache (bench harnesses: makes repeat
    runs of one population pay construction again for honest timing)."""
    _PRI_CACHE.clear()


@dataclasses.dataclass
class SchemeSpec:
    name: str
    order_fn: str = "dagps"        # dagps | bfs | cp | random
    matcher: MatcherConfig = dataclasses.field(default_factory=MatcherConfig)


def scheme(name: str, **overrides) -> SchemeSpec:
    """Preset schemes from §8.1."""
    presets: dict[str, SchemeSpec] = {
        # Tez: breadth-first order on the Capacity Scheduler; knows cores+mem.
        "tez": SchemeSpec(
            "tez", "bfs",
            MatcherConfig(use_packing=False, use_srpt=False, use_overbooking=False,
                          fit_dims=(0, 1), kappa=0.02),
        ),
        "tez+cp": SchemeSpec(
            "tez+cp", "cp",
            MatcherConfig(use_packing=False, use_srpt=False, use_overbooking=False,
                          fit_dims=(0, 1), kappa=0.02),
        ),
        "tez+tetris": SchemeSpec(
            "tez+tetris", "bfs",
            MatcherConfig(use_packing=True, use_srpt=True, use_overbooking=False,
                          use_priority=False, kappa=10.0),  # Tetris: unbounded unfairness
        ),
        "tez+drf": SchemeSpec(
            "tez+drf", "bfs",
            MatcherConfig(use_packing=False, use_srpt=False, use_overbooking=False,
                          fit_dims=(0, 1), kappa=0.02, fairness=drf_fairness),
        ),
        "random": SchemeSpec(
            "random", "random",
            MatcherConfig(use_packing=False, use_srpt=False, use_overbooking=False,
                          kappa=10.0),
        ),
        "dagps": SchemeSpec("dagps", "dagps", MatcherConfig()),
        # ablation: DAGPS order without overbooking
        "dagps-noob": SchemeSpec(
            "dagps-noob", "dagps", MatcherConfig(use_overbooking=False)
        ),
    }
    spec = presets[name]
    if overrides:
        spec = dataclasses.replace(spec, matcher=dataclasses.replace(spec.matcher, **overrides))
    return spec


@dataclasses.dataclass
class SimConfig:
    n_machines: int = 50
    d: int = 4
    seed: int = 0
    expose_per_job: int = 8        # runnable tasks an AM exposes per heartbeat
    build_machines: int | None = None  # m for offline construction (job share)
    straggle_prob: float = 0.0
    straggle_factor: tuple[float, float] = (2.0, 5.0)
    speculate: bool = True
    spec_threshold: float = 1.8
    failure_rate: float = 0.0      # machine-failures per simulated second
    repair_time: float = 120.0
    record_usage: bool = False
    #: record every non-speculative placement as (t, job, task, machine)
    #: in SimResult.placements — the decision stream the service-parity
    #: suite compares bit-for-bit against an inproc scheduler-service run
    record_placements: bool = False
    placement_backend: str | None = None  # engine backend for offline builds
    schedule_cache: bool = True    # memoize identical offline builds (exact)
    #: dagps builds per arrival: 1 = serial in the arrival event (seed
    #: behavior); >1 or None (= host CPU count) submits every arrival's
    #: construction to a core.buildsvc.BuildService worker pool at run
    #: start and the event loop consumes completed orders — bit-identical
    #: decisions (build_schedule is deterministic and construction is
    #: instantaneous in sim time), wall-clock overlapped
    build_workers: int | None = 1
    #: machine shards for the online matcher (core/shard.py): 1 = one
    #: flat shard; None = auto (ceil(n_machines / REPRO_SHARD_MACHINES,
    #: default 2048/shard)).  Any value yields bit-identical decisions —
    #: sharding changes only how eligibility launches are batched and how
    #: deficit ledgers are bookkept (merged + rebalanced every wave).
    matcher_shards: int | None = None
    #: online wave mode: "exact" (default) is the decision-exact global
    #: wave (`ShardedMatcher.match_wave`, dispatched through the fused
    #: ``match_wave`` kernel op); "routed" is the fully distributed
    #: per-shard wave (`match_wave_routed`) — an explicitly lossy preset:
    #: each shard's own matcher serves its machine slice from routed
    #: candidates, so decisions (and JCT/fairness) deviate from the exact
    #: path, while bounded unfairness survives via the wave-end deficit
    #: handoff.  The s13 bench rows quantify the gap.
    matcher_mode: str = "exact"
    profile: bool = False          # collect per-phase wall-clock timings
    #: heartbeat-loss modeling (None disables it — the seed behavior, in
    #: which matching waves are implicit and machines never go silent):
    #: machines emit a heartbeat every `heartbeat_period` sim-seconds; a
    #: machine silent for `hb_suspect_after` (default 2.5 periods) stops
    #: receiving new tasks (suspected), and one silent for `hb_lost_after`
    #: (default 5 periods) is declared lost — its running tasks requeue —
    #: until a later heartbeat gets through and it rejoins (flap-
    #: tolerant).  Distinct from `failure_rate` machine *failures*: a
    #: lost machine's work is intact but unreachable, the paper-level
    #: partition/GC-pause case.  Losses only occur when a `fault_plan`
    #: drops or delays heartbeats; healthy heartbeats are decision-
    #: neutral except for wave timing ties with the finish-drain loop.
    heartbeat_period: float | None = None
    hb_suspect_after: float | None = None
    hb_lost_after: float | None = None
    #: core.faults.FaultPlan (or its parse() spec string) installed for
    #: the duration of the run; None leaves any ambient plan (installed
    #: or REPRO_FAULTS) active
    fault_plan: object | None = None
    #: recovery knobs shared by the sharded matcher and build service
    recovery: faults.RecoveryPolicy | None = None
    #: scripted dynamics (core/dag.py mutation helpers): each entry is
    #: either ``(t, job_idx, mutator)`` — `mutator` maps the job's current
    #: DAG to ``(new_dag, DagDelta)``, applied mid-run if the job is
    #: running (delta rebuild replays untouched partitions) or swapped
    #: under the pending arrival otherwise — or ``(t, "speed", machine,
    #: factor)``, rescaling one machine's (None = every machine's)
    #: effective speed for tasks launched after ``t``.  A mutation whose
    #: touched tasks all finished already, or that targets a completed
    #: job, is counted as a no-op.  Empty/None = seed behavior, bit-exact.
    mutations: Sequence | None = None


@dataclasses.dataclass
class JobResult:
    job_id: int
    group: int
    arrival: float
    finish: float
    n_tasks: int

    @property
    def jct(self) -> float:
        return self.finish - self.arrival


@dataclasses.dataclass
class SimResult:
    jobs: list[JobResult]
    makespan: float
    usage_samples: list[tuple[float, np.ndarray]]
    allocations: list[tuple[float, float, int, float]]  # start, end, group, weight
    speculative_launches: int = 0
    failed_tasks_requeued: int = 0
    #: per-phase wall-clock seconds (build / match / event / total) when
    #: SimConfig.profile is set, else None
    phase_times: dict[str, float] | None = None
    #: sharded-matcher accounting (n_shards / waves / picks / handoffs /
    #: per-shard heartbeat-kernel seconds), always collected
    shard_stats: dict | None = None
    #: degraded-mode accounting, always collected: plan injections fired
    #: during the run, shard launch retries/quarantines, build service
    #: retries/crashes/fallbacks, kernel demotions, heartbeat-loss counts
    fault_stats: dict | None = None
    #: dynamic-DAG accounting when SimConfig.mutations is set, else None:
    #: mutation events applied/no-oped, delta vs full rebuild counts and
    #: the partition/placement reuse they achieved
    mutation_stats: dict | None = None
    #: (t, job, task, machine) per non-speculative launch when
    #: SimConfig.record_placements is set, else None
    placements: list[tuple[float, int, int, int]] | None = None

    def jcts(self) -> np.ndarray:
        return np.array([j.jct for j in self.jobs])

    def jain_index(self, window: float, shares: dict[int, float]) -> float:
        """Mean Jain's index of per-group usage over fixed windows (Table 4)."""
        if not self.allocations:
            return 1.0
        horizon = self.makespan
        total_share = sum(shares.values()) or 1.0
        idxs = []
        t = 0.0
        while t < horizon:
            hi = t + window
            usage = {g: 0.0 for g in shares}
            for (s, e, g, w) in self.allocations:
                ov = max(0.0, min(e, hi) - max(s, t))
                if ov > 0 and g in usage:
                    usage[g] += ov * w
            xs = np.array([usage[g] / (shares[g] / total_share) for g in shares])
            if xs.sum() > 0:
                idxs.append(float(xs.sum() ** 2 / (len(xs) * (xs ** 2).sum())))
            t = hi
        return float(np.mean(idxs)) if idxs else 1.0


# per-job DAG progress state now lives in core.online.JobState, shared
# with the scheduler service core (svc/scheduler.py) so both advance
# identical job state through identical transitions
_Job = JobState


class ClusterSim:
    """Event-driven simulation of many DAG jobs under a scheme."""

    def __init__(self, cfg: SimConfig, spec: SchemeSpec):
        self.cfg = cfg
        self.spec = spec

    def _build_m(self) -> int:
        """m for offline construction (the job's cluster share)."""
        return self.cfg.build_machines or max(self.cfg.n_machines // 10, 4)

    def _pri_cache_key(self, dag: DAG) -> tuple:
        return (dag_digest(dag), self._build_m(),
                get_backend(self.cfg.placement_backend).name)

    # -- dynamic-DAG (mutations-scripted) schedule bookkeeping ---------

    def _count_rebuild(self, sched, dag: DAG) -> None:
        """Fold one (re)build's partition-reuse accounting into the run's
        mutation stats."""
        ms = self._mut_stats
        info = getattr(sched, "build_info", None)
        if info is None:
            ms["full_builds"] += 1
            return
        ms["delta_builds" if info.reused_parts else "full_builds"] += 1
        ms["parts_reused"] += int(info.reused_parts)
        ms["parts_total"] += int(info.total_parts or 1)
        ms["tasks_reused"] += int(info.reused_tasks)
        ms["tasks_total"] += int(dag.n)

    def _dyn_sched(self, dag: DAG, idx: int | None):
        """Schedule for an arriving job in a dynamic run.

        Dynamic runs bypass the pri-only cross-run cache: they must keep
        the full Schedule (its ``build_info`` carries the content-keyed
        partition map) so later mutations delta-rebuild instead of
        re-searching.  Digest-equal DAGs share one Schedule; a job whose
        DAG was mutated before arrival delta-rebuilds from its base
        digest's Schedule when one was built this run.
        """
        dig = dag_digest(dag)
        delta = self._predeltas.pop(idx, None)
        sched = self._by_digest.get(dig)
        if sched is None:
            handle = self._builds.pop(idx, None)
            if handle is not None:
                sched = handle.result()
            else:
                prev = (self._by_digest.get(delta.base_digest)
                        if delta is not None else None)
                if prev is not None and prev.build_info is not None:
                    sched = rebuild_schedule(
                        prev, dag, backend=self.cfg.placement_backend)
                else:
                    sched = build_schedule(dag, self._build_m(),
                                           backend=self.cfg.placement_backend)
            if idx in self._mut_jobs:
                self._count_rebuild(sched, dag)
            self._by_digest[dig] = sched
        if idx is not None:
            self._scheds[idx] = sched
        return sched

    def _dyn_sched_mut(self, k: int, new_dag: DAG):
        """Re-plan job k after a mid-run mutation: delta rebuild from its
        retained Schedule when possible, full construction otherwise."""
        prev = self._scheds.get(k)
        if prev is not None and prev.build_info is not None:
            sched = rebuild_schedule(prev, new_dag,
                                     backend=self.cfg.placement_backend)
        else:
            sched = build_schedule(new_dag, self._build_m(),
                                   backend=self.cfg.placement_backend)
        self._count_rebuild(sched, new_dag)
        self._by_digest[dag_digest(new_dag)] = sched
        self._scheds[k] = sched
        return sched

    def _make_pri(self, dag: DAG, rng: np.random.Generator,
                  idx: int | None = None) -> np.ndarray:
        kind = self.spec.order_fn
        if kind == "dagps":
            if getattr(self, "_dynamic", False):
                return self._dyn_sched(dag, idx).pri_score
            use_cache = self.cfg.schedule_cache
            key = self._pri_cache_key(dag) if use_cache else None
            if use_cache:
                pri = _PRI_CACHE.get(key)
                if pri is not None:
                    return pri
            # prefetched by the build service at run start: consuming the
            # handle blocks only until that job's construction finishes —
            # later arrivals' builds keep running on the pool meanwhile
            handle = getattr(self, "_builds", {}).pop(idx, None)
            if handle is not None:
                pri = handle.result().pri_score
            else:
                pri = build_schedule(
                    dag, self._build_m(),
                    backend=self.cfg.placement_backend).pri_score
            if use_cache:
                if len(_PRI_CACHE) >= _PRI_CACHE_CAP:
                    _PRI_CACHE.pop(next(iter(_PRI_CACHE)))
                _PRI_CACHE[key] = pri
            return pri
        if kind == "bfs":
            order = bfs_order(dag)
        elif kind == "cp":
            order = cp_order(dag)
        else:
            order = random_order(dag, int(rng.integers(1 << 31)))
        rank = np.empty(dag.n)
        rank[order] = np.arange(dag.n)
        return 1.0 - rank / max(dag.n, 1)

    # ------------------------------------------------------------------
    def run(self, arrivals: Sequence[tuple[float, DAG, int]]) -> SimResult:
        plan = faults.coerce(self.cfg.fault_plan)
        if plan is None:
            return self._run(arrivals)
        with faults.scope(plan):
            return self._run(arrivals)

    def _run(self, arrivals: Sequence[tuple[float, DAG, int]]) -> SimResult:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed)
        M, d = cfg.n_machines, cfg.d
        avail = np.ones((M, d), dtype=np.float64)
        alive = np.ones(M, dtype=bool)
        groups = sorted({g for (_, _, g) in arrivals})
        shares = {g: 1.0 for g in groups}
        mcfg = self.spec.matcher
        if cfg.matcher_mode not in ("exact", "routed"):
            raise ValueError(f"unknown matcher_mode {cfg.matcher_mode!r}; "
                             "have ('exact', 'routed')")
        smatcher = ShardedMatcher(mcfg, M, shares,
                                  n_shards=cfg.matcher_shards,
                                  capacity=float(M),
                                  recovery=cfg.recovery)
        matcher = smatcher.matcher
        # degraded-mode accounting baselines (kernel demotions are sticky
        # process state; injection stats accumulate on a reused plan)
        ap = faults.active_plan()
        inj0 = ap.snapshot() if ap is not None else {}
        dem0 = kernels.demotions_snapshot()

        jobs: dict[int, _Job] = {}
        pool = TaskPool(d=d, expose=cfg.expose_per_job)
        counter = itertools.count()
        events: list[tuple[float, int, int, int]] = []
        for k, (t, _dag, _g) in enumerate(arrivals):
            heapq.heappush(events, (float(t), next(counter), _ARRIVAL, k))
        if cfg.failure_rate > 0:
            t_fail = float(rng.exponential(1.0 / cfg.failure_rate))
            heapq.heappush(events, (t_fail, next(counter), _FAIL, 0))

        # scripted dynamics (SimConfig.mutations).  DAG mutations make the
        # run "dynamic": dagps jobs keep their full Schedule (not just the
        # pri vector) so mutations delta-rebuild, and the cross-run pri
        # cache is bypassed.  `speed` is the sim-level machine-speed edit:
        # 1.0 everywhere is bit-exact seed behavior (never divided by).
        muts = list(cfg.mutations or ())
        self._dynamic = any(
            not (len(mu) > 1 and mu[1] == "speed") for mu in muts)
        self._by_digest: dict[bytes, object] = {}
        self._scheds: dict[int, object] = {}
        self._predeltas: dict[int, object] = {}
        self._mut_jobs: set[int] = set()
        mut_stats = {"events": 0, "applied": 0, "noops": 0, "pre_arrival": 0,
                     "speed_changes": 0, "delta_builds": 0, "full_builds": 0,
                     "parts_reused": 0, "parts_total": 0,
                     "tasks_reused": 0, "tasks_total": 0}
        self._mut_stats = mut_stats
        speed = np.ones(M, dtype=np.float64)
        if muts:
            arrivals = list(arrivals)   # pre-arrival mutations swap entries
            for i, mu in enumerate(muts):
                heapq.heappush(events, (float(mu[0]), next(counter), _MUT, i))

        # heartbeat-loss state (disabled by default: no events scheduled,
        # no rng consumed, both masks stay all-False — bit-identical to
        # the implicit-heartbeat seed behavior)
        hb_period = cfg.heartbeat_period
        hb_on = hb_period is not None and hb_period > 0
        suspected = np.zeros(M, dtype=bool)
        hb_lost = np.zeros(M, dtype=bool)
        hb_stats = {"beats": 0, "dropped": 0, "delayed": 0, "suspects": 0,
                    "losses": 0, "rejoins": 0, "requeued": 0,
                    "forced_rejoins": 0}
        if hb_on:
            hb_suspect = cfg.hb_suspect_after or 2.5 * hb_period
            hb_lost_after = cfg.hb_lost_after or 5.0 * hb_period
            last_seen = np.zeros(M, dtype=np.float64)
            beat_no = np.zeros(M, dtype=np.int64)
            hb_forced = np.zeros(M, dtype=bool)
            for m in range(M):
                heapq.heappush(events, (hb_period, next(counter), _HB, m))
                # arm the silence check up front: a machine whose beats
                # never arrive at all must still be detected
                heapq.heappush(events, (hb_suspect, next(counter), _HBCHK, m))

        runs = _RunTable()
        task_active: dict[tuple[int, int], list[int]] = {}  # (job,task) -> run_ids
        results: list[JobResult] = []
        usage_samples: list[tuple[float, np.ndarray]] = []
        allocations: list[tuple[float, float, int, float]] = []
        placements: list[tuple[float, int, int, int]] | None = \
            [] if cfg.record_placements else None
        spec_launches = 0
        requeued = 0
        pending_arrivals = len(arrivals)
        incomplete_jobs = 0
        t_now = 0.0
        prof = {"build": 0.0, "match": 0.0} if cfg.profile else None
        t_run0 = time.perf_counter() if cfg.profile else 0.0
        # heartbeat-kernel accounting: seconds spent inside the dispatched
        # heartbeat eligibility ops (a subset of the match phase), so the
        # bench rows can attribute matcher time to the kernel layer
        kprof0 = kernels.profile_snapshot() if cfg.profile else None

        def timed(key, fn, *args):
            if prof is None:
                return fn(*args)
            t0 = time.perf_counter()
            try:
                return fn(*args)
            finally:
                prof[key] += time.perf_counter() - t0

        def start_task(job: _Job, tid: int, m: int, now: float, speculative: bool = False) -> None:
            nonlocal spec_launches
            dem = job.dag.demand[tid]
            avail[m] -= dem
            base = float(job.dag.duration[tid])
            dur = base
            if not speculative and cfg.straggle_prob > 0 and rng.random() < cfg.straggle_prob:
                lo, hi = cfg.straggle_factor
                dur = base * float(rng.uniform(lo, hi))
            # implicit/explicit overload on fungible dims slows this task down
            dur_eff = dur * overload_factor(avail[m])
            if speed[m] != 1.0:   # machine-speed mutations: future launches
                dur_eff = dur_eff / speed[m]
            rid = runs.append(job.job_id, tid, m, now, base)
            task_active.setdefault((job.job_id, tid), []).append(rid)
            if not speculative:
                job.task_started(tid)
                pool.mark_dirty(job.job_id)
                if placements is not None:
                    placements.append((now, job.job_id, tid, m))
            else:
                spec_launches += 1
            heapq.heappush(events, (now + dur_eff, next(counter), _FINISH, rid))
            if cfg.speculate and not speculative:
                chk = now + cfg.spec_threshold * base
                heapq.heappush(events, (chk, next(counter), _SPEC, rid))
            allocations.append((now, now + dur_eff, job.group, float(np.abs(dem).sum())))

        def free_run(rid: int) -> None:
            if not runs.dead[rid]:
                runs.dead[rid] = True
                avail[runs.machine[rid]] += \
                    jobs[int(runs.job[rid])].dag.demand[runs.task[rid]]

        def requeue_machine(m: int) -> int:
            """Kill every live run on a machine and requeue its tasks
            (shared by hard failures and declared heartbeat losses)."""
            cnt = 0
            for rid in runs.live_on(m):
                rid = int(rid)
                free_run(rid)
                job = jobs[int(runs.job[rid])]
                job.task_requeued(int(runs.task[rid]))
                pool.mark_dirty(job.job_id)
                cnt += 1
            return cnt

        def settle_finish(rid: int, now: float) -> None:
            """One task-copy completion: free it, kill speculative siblings,
            advance the DAG, retire the job when done."""
            nonlocal incomplete_jobs
            job = jobs[int(runs.job[rid])]
            tid = int(runs.task[rid])
            free_run(rid)
            for sib in task_active.get((job.job_id, tid), ()):
                if sib != rid and not runs.dead[sib]:
                    free_run(sib)
            # exposure only depends on the runnable set: task_done changes
            # it when it unlocks children OR when the task was requeued
            # (machine failure) and a surviving speculative copy finished —
            # then task_done itself pulls it back out of runnable.  srpt
            # always moves; the pool patches that one column without
            # re-sorting clean jobs.
            was_runnable = tid in job.runnable
            if job.task_done(tid) or was_runnable:
                pool.mark_dirty(job.job_id)
            pool.set_srpt(job.job_id, job.srpt)
            if job.complete and job.finish is None:
                job.finish = now
                results.append(JobResult(job.job_id, job.group, job.arrival,
                                         now, job.dag.n))
                pool.remove_job(job.job_id)
                incomplete_jobs -= 1

        def matchable() -> np.ndarray:
            """Machines a wave may serve: alive, and (with heartbeats on)
            neither suspected nor declared lost."""
            if not hb_on:
                return alive
            return alive & ~suspected & ~hb_lost

        def match_machine(m: int, now: float) -> None:
            if not alive[m] or suspected[m] or hb_lost[m]:
                return
            batch = pool.refresh()
            if batch is None or len(batch) == 0:
                return
            picks = matcher.match_batch(m, avail[m], batch)
            for i, _over in picks:
                start_task(jobs[int(batch.job[i])], int(batch.tid[i]), m, now)
                smatcher.record_allocation(m, int(batch.grp[i]),
                                           mcfg.fairness(batch.dem[i]))

        # concurrent multi-job construction (core/buildsvc.py): submit every
        # arrival's build up front and let the event loop consume completed
        # priority orders — per-job builds are independent (own DAG, Space,
        # memo) and build_schedule is deterministic, so decisions stay
        # bit-identical to the serial path; only wall-clock overlap changes.
        svc = None
        self._builds = {}
        if self.spec.order_fn == "dagps" and (
                cfg.build_workers is None or cfg.build_workers > 1):
            svc = BuildService(workers=cfg.build_workers,
                               recovery=cfg.recovery)
            m_build = self._build_m()
            for k, (_t, dag, _g) in enumerate(arrivals):
                # dynamic runs prefetch everything (the pri cache is
                # bypassed; the service dedups identical DAGs itself)
                if (cfg.schedule_cache and not self._dynamic
                        and self._pri_cache_key(dag) in _PRI_CACHE):
                    continue
                self._builds[k] = svc.submit(
                    dag, m_build, backend=cfg.placement_backend)

        def hb_arrive(m: int, now: float) -> None:
            """One heartbeat reaches the scheduler: refresh the machine's
            silence clock, rejoin it if suspected/lost, arm the next
            silence check."""
            if not alive[m] or now <= last_seen[m]:
                return                      # dead machine / stale delayed beat
            last_seen[m] = now
            if hb_lost[m]:
                # rejoin on flap: the machine is fresh capacity again (its
                # requeued tasks may already be running elsewhere)
                hb_lost[m] = False
                suspected[m] = False
                avail[m] = 1.0
                hb_stats["rejoins"] += 1
                timed("match", match_machine, m, now)
            elif suspected[m]:
                suspected[m] = False
                timed("match", match_machine, m, now)
            heapq.heappush(events, (now + hb_suspect, next(counter),
                                    _HBCHK, m))

        def match_all(now: float) -> None:
            batch = pool.refresh()
            if batch is None or len(batch) == 0:
                return
            # one heartbeat wave through the sharded matcher: the exact
            # mode pins decisions to the single global matcher (the wave
            # dispatches through the fused match_wave kernel op and is
            # bit-identical for any shard count and implementation); the
            # routed mode is the distributed lossy preset.  Either way the
            # pick stream is consumed through start_task via start_cb.
            wave = (smatcher.match_wave_routed
                    if cfg.matcher_mode == "routed" else smatcher.match_wave)
            wave(avail, matchable(), batch,
                 lambda gi, m: start_task(jobs[int(batch.job[gi])],
                                          int(batch.tid[gi]), m, now))

        def mutate_job(k: int, mutator, now: float) -> None:
            """Apply one scripted DAG mutation (a core.dag helper curried
            over its arguments) to job k and repair its schedule."""
            nonlocal incomplete_jobs
            job = jobs.get(k)
            if job is None:
                # pre-arrival: swap the DAG under the pending arrival; a
                # prefetched construction is resubmitted as a delta so the
                # worker pool replays the old build's untouched partitions
                t_a, old_dag, g = arrivals[k]
                new_dag, delta = mutator(old_dag)
                arrivals[k] = (t_a, new_dag, g)
                self._predeltas[k] = delta
                self._mut_jobs.add(k)
                h = self._builds.get(k)
                if svc is not None and h is not None:
                    self._builds[k] = svc.resubmit(h, new_dag, delta)
                mut_stats["pre_arrival"] += 1
                return
            if job.complete:
                mut_stats["noops"] += 1
                return
            new_dag, delta = mutator(job.dag)
            old_n = job.dag.n
            idm = delta.id_map
            identity = new_dag.n == old_n and bool(
                np.array_equal(idm, np.arange(old_n)))
            if identity and len(delta.touched) and all(
                    int(x) in job.done for x in delta.touched):
                # every touched task already finished — re-prioritizing
                # completed work cannot change any remaining decision
                mut_stats["noops"] += 1
                return
            for x in np.flatnonzero(idm < 0):
                if int(x) in job.running:
                    raise ValueError(
                        f"mutation drops running task {int(x)} of job {k}")
            # re-plan: delta rebuild from the retained Schedule when the
            # scheme builds one, else recompute the baseline order
            if self.spec.order_fn == "dagps":
                pri = self._dyn_sched_mut(k, new_dag).pri_score
            else:
                pri = self._make_pri(new_dag, rng)
            # remap live state through the delta's id map, then rebuild
            # the derived per-job arrays against the new graph
            if not identity:
                sel = np.flatnonzero(runs.job[: runs.n] == k)
                runs.task[sel] = idm[runs.task[sel]]  # dead dropped -> -1
                for key in [key for key in task_active if key[0] == k]:
                    lst = task_active.pop(key)
                    nt = int(idm[key[1]])
                    if nt >= 0:
                        task_active[(k, nt)] = lst
                job.done = {int(idm[x]) for x in job.done if idm[x] >= 0}
                job.running = {int(idm[x]) for x in job.running}
            job.dag = new_dag
            job.pri = pri
            job._work = new_dag.duration * np.abs(new_dag.demand).sum(axis=1)
            job.pending_parents = np.array(
                [sum(1 for p in new_dag.parents[i] if int(p) not in job.done)
                 for i in range(new_dag.n)])
            job.runnable = {i for i in range(new_dag.n)
                            if i not in job.done and i not in job.running
                            and job.pending_parents[i] == 0}
            mask = np.ones(new_dag.n, dtype=bool)
            if job.done:
                mask[list(job.done)] = False
            job.srpt = float(job._work[mask].sum())
            mut_stats["applied"] += 1
            pool.remove_job(k)
            if job.complete and job.finish is None:
                # a shrink can retire the job outright
                job.finish = now
                results.append(JobResult(k, job.group, job.arrival, now,
                                         new_dag.n))
                incomplete_jobs -= 1
                return
            pool.add_job(k, job.group, new_dag.demand, pri, job.runnable,
                         job.srpt)
            match_all(now)

        try:
            while events:
                t_now, _, kind, arg = heapq.heappop(events)
                if kind == _ARRIVAL:
                    _t_arr, dag, g = arrivals[arg]
                    pri = timed("build", self._make_pri, dag, rng, arg)
                    job = _Job(arg, dag, t_now, g, pri)
                    jobs[arg] = job
                    pool.add_job(arg, g, dag.demand, pri, job.runnable, job.srpt)
                    pending_arrivals -= 1
                    if not job.complete:    # zero-task jobs never finish events
                        incomplete_jobs += 1
                    timed("match", match_all, t_now)
                elif kind == _FINISH:
                    if runs.dead[arg]:
                        continue
                    settle_finish(arg, t_now)
                    if cfg.record_usage:
                        usage_samples.append((t_now, (1.0 - avail[alive]).sum(axis=0)))
                    # drain simultaneous finishes before re-matching
                    while events and events[0][2] == _FINISH and events[0][0] <= t_now + 1e-9:
                        _, _, _, rid2 = heapq.heappop(events)
                        if runs.dead[rid2]:
                            continue
                        settle_finish(rid2, t_now)
                    timed("match", match_all, t_now)
                elif kind == _SPEC:
                    if runs.dead[arg]:
                        continue
                    job = jobs[int(runs.job[arg])]
                    tid = int(runs.task[arg])
                    # only speculate if some machine can host a copy right now
                    dem = job.dag.demand[tid]
                    fit = np.nonzero(matchable() & packing.fits_mask(avail, dem))[0]
                    if len(fit):
                        start_task(job, tid, int(fit[0]), t_now, speculative=True)
                elif kind == _FAIL:
                    m = int(rng.integers(M))
                    if alive[m]:
                        alive[m] = False
                        requeued += requeue_machine(m)
                        avail[m] = 0.0
                        heapq.heappush(events, (t_now + cfg.repair_time,
                                                next(counter), _JOIN, m))
                    if cfg.failure_rate > 0 and (incomplete_jobs > 0
                                                 or pending_arrivals > 0):
                        nxt = t_now + float(rng.exponential(1.0 / cfg.failure_rate))
                        heapq.heappush(events, (nxt, next(counter), _FAIL, 0))
                elif kind == _JOIN:
                    alive[arg] = True
                    avail[arg] = 1.0
                    timed("match", match_machine, arg, t_now)
                elif kind == _MUT:
                    mu = muts[arg]
                    mut_stats["events"] += 1
                    if len(mu) > 1 and mu[1] == "speed":
                        _t_mu, _sp, mm, factor = mu
                        if mm is None:
                            speed[:] = float(factor)
                        else:
                            speed[int(mm)] = float(factor)
                        mut_stats["speed_changes"] += 1
                    else:
                        timed("build", mutate_job, int(mu[1]), mu[2], t_now)
                elif kind == _HB:
                    m = arg
                    beat = int(beat_no[m])
                    beat_no[m] += 1
                    if incomplete_jobs == 0 and pending_arrivals == 0:
                        continue        # workload done: drain the clock
                    hb_stats["beats"] += 1
                    force = False
                    if (pending_arrivals == 0
                            and not (~runs.dead[:runs.n]).any()
                            and not any(ev[2] not in (_HB, _HBA, _HBCHK)
                                        for ev in events)):
                        # nothing running, nothing arriving, nothing ahead
                        # but heartbeats: only a machine recovery can still
                        # unblock the workload.
                        if matchable().all():
                            # every machine already serves, so no beat can
                            # change state — stop the clock; like the
                            # no-heartbeat path, unplaceable work ends the
                            # run with those jobs unfinished
                            continue
                        # some machine is unreachable: force its beats
                        # through even if the plan would swallow them (the
                        # operator-intervention analogue), so partitioned
                        # clusters always recover and the sim terminates
                        force = True
                    heapq.heappush(events, (t_now + hb_period,
                                            next(counter), _HB, m))
                    if not alive[m]:
                        continue        # hard-failed machines emit nothing
                    sp = None if hb_forced[m] \
                        else faults.query("heartbeat", machine=m, beat=beat)
                    if sp is not None and force:
                        # sticky: a forced machine's link counts as repaired
                        # — without this, re-losing it before any task
                        # longer than hb_lost_after completes would
                        # livelock a fully partitioned cluster
                        hb_forced[m] = True
                        hb_stats["forced_rejoins"] += 1
                        sp = None
                    if sp is None:
                        hb_arrive(m, t_now)
                    elif sp.kind == "delay":
                        hb_stats["delayed"] += 1
                        heapq.heappush(events,
                                       (t_now + max(sp.delay, 0.0),
                                        next(counter), _HBA, m))
                    else:               # drop (and any other kind)
                        hb_stats["dropped"] += 1
                elif kind == _HBA:
                    hb_arrive(arg, t_now)
                elif kind == _HBCHK:
                    m = arg
                    if incomplete_jobs == 0 and pending_arrivals == 0:
                        continue    # workload done: silence is expected
                    if not alive[m] or hb_lost[m]:
                        continue
                    silent = t_now - last_seen[m]
                    if silent + 1e-9 >= hb_lost_after:
                        # declared lost: unreachable, not dead — requeue
                        # its work and stop counting its capacity until a
                        # heartbeat gets through again
                        hb_lost[m] = True
                        suspected[m] = True
                        n_req = requeue_machine(m)
                        hb_stats["requeued"] += n_req
                        hb_stats["losses"] += 1
                        avail[m] = 0.0
                        if n_req:
                            timed("match", match_all, t_now)
                    elif silent + 1e-9 >= hb_suspect:
                        if not suspected[m]:
                            suspected[m] = True
                            hb_stats["suspects"] += 1
                        heapq.heappush(events,
                                       (last_seen[m] + hb_lost_after,
                                        next(counter), _HBCHK, m))

        finally:
            self._builds = {}
            self._by_digest = {}
            self._scheds = {}
            self._predeltas = {}
            if svc is not None:
                svc.shutdown(wait=False)
            smatcher.close()
        makespan = max((j.finish for j in results), default=0.0)
        # recovery seconds: shard-launch retries/backoff accrue inside the
        # match phase, build-retry backoff inside the build phase — pull
        # both out into their own key so degraded runs don't silently
        # inflate the phases they happen to block
        rec_shard = smatcher.recovery_secs
        rec_build = float(svc.stats["recovery_secs"]) if svc is not None \
            else 0.0
        phase_times = None
        if prof is not None:
            total = time.perf_counter() - t_run0
            build_t = max(prof["build"] - rec_build, 0.0)
            match_t = max(prof["match"] - rec_shard, 0.0)
            phase_times = {"build": build_t, "match": match_t,
                           "recovery": rec_shard + rec_build,
                           "event": max(total - prof["build"] - prof["match"], 0.0),
                           "total": total}
            kprof1 = kernels.profile_snapshot()
            # both heartbeat eligibility ops count: above the auto-promotion
            # threshold the dispatched impl is heartbeat_masks-/mwc-xla and
            # must stay visible in the bench JSON
            hb = sum(sec - kprof0.get(key, (0, 0.0))[1]
                     for key, (_calls, sec) in kprof1.items()
                     if key.startswith(("machines_with_candidates.",
                                        "heartbeat_masks.")))
            phase_times["heartbeat"] = hb
        sstats = smatcher.stats()
        ap1 = faults.active_plan()
        inj1 = ap1.snapshot() if ap1 is not None else {}
        dem1 = kernels.demotions_snapshot()
        fault_stats = {
            "injections": {k: v - inj0.get(k, 0) for k, v in inj1.items()
                           if v - inj0.get(k, 0)},
            "shard": {k: sstats[k] for k in
                      ("launch_retries", "launch_failures", "quarantines",
                       "quarantined_shards", "quarantined_launches",
                       "probe_recoveries")},
            "build": {k: svc.stats[k] for k in
                      ("retries", "worker_crashes", "quarantined_digests",
                       "inline_fallbacks", "resubmits", "resubmit_deduped")}
            if svc is not None else {},
            "kernel_demotions": {k: v - dem0.get(k, 0)
                                 for k, v in dem1.items()
                                 if v - dem0.get(k, 0)},
            "heartbeat": hb_stats,
            "recovery_secs": round(rec_shard + rec_build, 6),
        }
        return SimResult(results, makespan, usage_samples, allocations,
                         spec_launches, requeued, phase_times,
                         sstats, fault_stats,
                         mut_stats if muts else None,
                         placements)


def run_workload(
    dags: Sequence[DAG],
    scheme_name: str,
    n_machines: int = 50,
    interarrival: float = 25.0,
    n_groups: int = 1,
    seed: int = 0,
    **cfg_overrides,
) -> SimResult:
    """Poisson arrivals (§8.1: avg 25s apart), even group assignment."""
    rng = np.random.default_rng(seed)
    t = 0.0
    arrivals = []
    for k, dag in enumerate(dags):
        arrivals.append((t, dag, k % n_groups))
        t += float(rng.exponential(interarrival))
    sim_fields = {f.name for f in dataclasses.fields(SimConfig)}
    matcher_fields = {f.name for f in dataclasses.fields(MatcherConfig)}
    sim_kwargs = {k: v for k, v in cfg_overrides.items() if k in sim_fields}
    matcher_kwargs = {k: v for k, v in cfg_overrides.items() if k in matcher_fields}
    unknown = set(cfg_overrides) - sim_fields - matcher_fields
    if unknown:
        raise TypeError(f"unknown overrides: {unknown}")
    cfg = SimConfig(n_machines=n_machines, seed=seed, **sim_kwargs)
    return ClusterSim(cfg, scheme(scheme_name, **matcher_kwargs)).run(arrivals)
