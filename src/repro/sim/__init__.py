"""Cluster simulation substrate: event-driven simulator + workload generators."""
from .cluster import (ClusterSim, SimConfig, SimResult, clear_schedule_cache,
                      run_workload, scheme)
from .workload import (make_workload, online_mix_workload, periodic_dag,
                       production_dag, query_dag, build_system_dag,
                       workflow_dag)
