"""Model substrate: layers + assembled decoder architectures."""
