"""Logical activation sharding annotations.

Model code calls `logical(x, "batch", "seq", None, ...)` with one logical
name (or None) per array axis.  Outside any mesh context this is identity;
`launch.sharding.activation_rules(...)` installs a mapping from logical
names to mesh axes, turning the calls into with_sharding_constraint — the
single knob the perf loop (§Perf) uses to move activation layouts without
touching model code.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


def current_rules() -> Optional[dict]:
    return getattr(_state, "rules", None)


def current_mesh():
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def activation_sharding(mesh, rules: dict):
    """rules: logical axis name -> mesh axis (str | tuple | None)."""
    prev_rules = getattr(_state, "rules", None)
    prev_mesh = getattr(_state, "mesh", None)
    _state.rules, _state.mesh = rules, mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = prev_rules, prev_mesh


def _axis_size(mesh, ax) -> int:
    if ax is None:
        return 1
    axes = ax if isinstance(ax, (tuple, list)) else (ax,)
    s = 1
    for a in axes:
        s *= mesh.shape[a] if a in mesh.axis_names else 1
    return s


def logical(x: jax.Array, *names: Optional[str]) -> jax.Array:
    rules, mesh = current_rules(), current_mesh()
    if rules is None or mesh is None:
        return x
    if len(names) != x.ndim:
        # name prefix allowed; remaining axes unsharded
        names = tuple(names) + (None,) * (x.ndim - len(names))
    axes = []
    used: set = set()
    for dim, n in zip(x.shape, names):
        ax = rules.get(n) if n else None
        if ax is not None and (dim % _axis_size(mesh, ax) != 0):
            ax = None  # divisibility guard: replicate rather than pad
        if ax is not None:
            flat = ax if isinstance(ax, tuple) else (ax,)
            if any(a in used for a in flat):
                ax = None  # first dim wins a contested mesh axis
            else:
                used.update(flat)
        axes.append(ax)
    spec = P(*axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
