"""Decoder assembly: config -> params -> train forward / prefill / decode.

One generic decoder covers the whole architecture pool via a per-layer
*block plan*: each layer is (mixer kind, ffn kind) where

  mixer: "attn" (global), "local" (sliding window), "rwkv", "rglru"
  ffn:   "swiglu" | "geglu" | "mlp" | "moe" | "rwkv_cm" (channel mix)

Layers are grouped as head (unrolled) + body (a repeating pattern,
jax.lax.scan over stacked params — keeps HLO size independent of depth,
which both compile time and the multi-pod dry-run depend on) + tail
(unrolled remainder).

Decode/prefill thread explicit state pytrees (KV caches, recurrent states)
through the same structure; scan carries the stacked body state.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from . import layers as L
from .sharding import logical

Params = dict


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    block_pattern: tuple[str, ...] = ("attn",)
    window: int | None = None
    attn_cap: float | None = None
    logit_cap: float | None = None
    rope_theta: float = 1e4
    mrope_sections: tuple[int, int, int] | None = None
    qkv_bias: bool = False
    norm_plus_one: bool = False        # gemma-style (1+w) RMSNorm
    post_norm: bool = False            # gemma2 post-block norms
    mlp_kind: str = "swiglu"
    moe: L.MoEConfig | None = None
    first_k_dense: int = 0
    dense_ff: int | None = None
    n_codebooks: int = 1               # musicgen: EnCodec codebooks
    pos_embedding: str = "rope"        # rope | sinusoidal
    embed_scale: bool = False
    tie_embeddings: bool = True
    rwkv_head_size: int = 64
    rglru_width: int | None = None
    conv_width: int = 4
    vlm_patches: int = 0               # stub frontend: patches prepended
    subquadratic: bool = False         # supports long_500k decode
    query_chunk: int = 1024
    remat: bool = True
    scan_unroll: int = 1           # lax.scan unroll factor (roofline probes)

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 256 so the vocab dim shards
        over the model axis (logits are the largest activation)."""
        return (self.vocab + 255) // 256 * 256

    # ------------------------------------------------------------------
    @property
    def layer_plan(self) -> list[tuple[str, str]]:
        plan = []
        for i in range(self.n_layers):
            mixer = self.block_pattern[i % len(self.block_pattern)]
            if mixer == "rwkv":
                ffn = "rwkv_cm"
            elif self.moe is not None and i >= self.first_k_dense:
                ffn = "moe"
            else:
                ffn = self.mlp_kind
            plan.append((mixer, ffn))
        return plan

    @property
    def groups(self) -> tuple[list, list, list]:
        """(head_plan, body_pattern, tail_plan); body repeats n_body times."""
        plan = self.layer_plan
        head = plan[: self.first_k_dense]
        rest = plan[self.first_k_dense :]
        pat_len = len(self.block_pattern)
        n_body = len(rest) // pat_len
        body = rest[:pat_len]
        tail = rest[n_body * pat_len :]
        return head, body, tail

    @property
    def n_body(self) -> int:
        _, body, _ = self.groups
        rest = self.n_layers - self.first_k_dense
        return rest // len(self.block_pattern)

    def attn_cfg(self, mixer: str) -> L.AttnConfig:
        return L.AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.head_dim,
            rope_theta=self.rope_theta, mrope_sections=self.mrope_sections,
            window=self.window if mixer == "local" else None,
            cap=self.attn_cap, qkv_bias=self.qkv_bias,
            use_rope=self.pos_embedding == "rope",
        )

    def rwkv_cfg(self) -> L.RWKVConfig:
        return L.RWKVConfig(d_model=self.d_model,
                            n_heads=self.d_model // self.rwkv_head_size,
                            d_ff=self.d_ff)

    def rglru_cfg(self) -> L.RGLRUConfig:
        return L.RGLRUConfig(d_model=self.d_model,
                             d_rnn=self.rglru_width or self.d_model,
                             conv_width=self.conv_width)

    def n_params(self) -> int:
        """Total parameter count (for 6ND model flops)."""
        shapes = jax.eval_shape(lambda k: init_params(self, k),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
        return sum(int(math.prod(x.shape)) for x in jax.tree.leaves(shapes))

    def n_active_params(self) -> int:
        """Active params per token (MoE: routed experts count top_k/E)."""
        total = self.n_params()
        if self.moe is None:
            return total
        E, K = self.moe.n_experts, self.moe.top_k
        n_moe_layers = sum(1 for _, f in self.layer_plan if f == "moe")
        per_expert = 3 * self.d_model * self.moe.d_expert
        routed = n_moe_layers * E * per_expert
        active = total - routed + n_moe_layers * K * per_expert
        return int(active)


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------

def _block_init(rng: jax.Array, cfg: ArchConfig, mixer: str, ffn: str,
                dtype=jnp.bfloat16) -> Params:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    d = cfg.d_model
    p: Params = {"ln1": jnp.zeros((d,), jnp.float32) if cfg.norm_plus_one
                 else jnp.ones((d,), jnp.float32)}
    if mixer in ("attn", "local"):
        p["attn"] = L.attn_init(k1, cfg.attn_cfg(mixer), dtype)
    elif mixer == "rwkv":
        p["rwkv"] = L.rwkv_init(k1, cfg.rwkv_cfg(), dtype)
    elif mixer == "rglru":
        p["rglru"] = L.rglru_init(k1, cfg.rglru_cfg(), dtype)
    else:
        raise ValueError(mixer)
    p["ln2"] = p["ln1"].copy()
    if ffn == "moe":
        p["moe"] = L.moe_init(k2, d, cfg.moe, dtype)
    elif ffn == "rwkv_cm":
        pass  # rwkv_init already contains channel-mix params
    else:
        f = cfg.dense_ff if (cfg.moe is not None and cfg.dense_ff) else cfg.d_ff
        p["mlp"] = L.mlp_init(k2, d, f, ffn, dtype)
    if cfg.post_norm:
        p["pln1"] = p["ln1"].copy()
        p["pln2"] = p["ln1"].copy()
    return p


def init_params(cfg: ArchConfig, rng: jax.Array, dtype=jnp.bfloat16) -> Params:
    head, body, tail = cfg.groups
    n_body = cfg.n_body
    keys = jax.random.split(rng, 8)
    V = cfg.vocab_padded
    emb_shape = (cfg.n_codebooks, V, cfg.d_model) if cfg.n_codebooks > 1 \
        else (V, cfg.d_model)
    params: Params = {
        "embed": (jax.random.normal(keys[0], emb_shape) * 0.02).astype(dtype),
        "final_ln": jnp.zeros((cfg.d_model,), jnp.float32) if cfg.norm_plus_one
        else jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        hshape = (cfg.n_codebooks, cfg.d_model, V) if cfg.n_codebooks > 1 \
            else (cfg.d_model, V)
        params["lm_head"] = (jax.random.normal(keys[1], hshape)
                             * (1.0 / math.sqrt(cfg.d_model))).astype(dtype)
    if cfg.vlm_patches:
        params["patch_proj"] = (jax.random.normal(keys[2], (cfg.d_model, cfg.d_model))
                                * (1.0 / math.sqrt(cfg.d_model))).astype(dtype)
    for j, (mixer, ffn) in enumerate(head):
        params[f"head{j}"] = _block_init(jax.random.fold_in(keys[3], j), cfg,
                                         mixer, ffn, dtype)
    if n_body:
        def one_group(k):
            gp = {}
            for j, (mixer, ffn) in enumerate(body):
                gp[f"b{j}"] = _block_init(jax.random.fold_in(k, j), cfg, mixer, ffn, dtype)
            return gp
        gkeys = jax.random.split(keys[4], n_body)
        groups = [one_group(k) for k in gkeys]
        params["body"] = jax.tree.map(lambda *xs: jnp.stack(xs), *groups)
    for j, (mixer, ffn) in enumerate(tail):
        params[f"tail{j}"] = _block_init(jax.random.fold_in(keys[5], j), cfg,
                                         mixer, ffn, dtype)
    return params


# ----------------------------------------------------------------------
# block application (shared by train / prefill / decode)
# ----------------------------------------------------------------------

def _norm(x, w, cfg: ArchConfig):
    return L.rms_norm(x, w, plus_one=cfg.norm_plus_one)


def _apply_block(bp: Params, x: jax.Array, cfg: ArchConfig, mixer: str, ffn: str,
                 positions, mode: str, state, pos=None):
    """mode: train | prefill | decode. Returns (x, new_state)."""
    new_state: dict = {}
    h = _norm(x, bp["ln1"], cfg)
    if mixer in ("attn", "local"):
        acfg = cfg.attn_cfg(mixer)
        if mode == "train":
            a = L.attn_forward(bp["attn"], h, acfg, positions, cfg.query_chunk)
        elif mode == "prefill":
            a, kv = L.attn_prefill(bp["attn"], h, acfg, positions, cfg.query_chunk)
            new_state["kv"] = kv
        else:
            a, kv = L.attn_decode(bp["attn"], h, acfg, state["kv"], pos)
            new_state["kv"] = kv
    elif mixer == "rwkv":
        rcfg = cfg.rwkv_cfg()
        if mode == "train":
            a, _ = L.rwkv_time_mix(bp["rwkv"], h, rcfg)
        else:
            st = state.get("rwkv") if state else None
            carry = st["x_tm"] if st else None
            s0 = st["s"] if st else None
            a, (xc, s1) = L.rwkv_time_mix(bp["rwkv"], h, rcfg, carry, s0)
            new_state["rwkv"] = {"x_tm": xc, "s": s1}
    elif mixer == "rglru":
        gcfg = cfg.rglru_cfg()
        if mode == "train":
            a, _ = L.rglru_block(bp["rglru"], h, gcfg)
        else:
            st = state.get("rglru") if state else None
            a, (conv, hh) = L.rglru_block(bp["rglru"], h, gcfg,
                                          (st["conv"], st["h"]) if st else None)
            new_state["rglru"] = {"conv": conv, "h": hh}
    else:
        raise ValueError(mixer)
    if cfg.post_norm:
        a = _norm(a, bp["pln1"], cfg)
    x = x + a
    h = _norm(x, bp["ln2"], cfg)
    if ffn == "moe":
        f = L.moe_forward(bp["moe"], h, cfg.moe)
    elif ffn == "rwkv_cm":
        if mode == "train":
            f, _ = L.rwkv_channel_mix(bp["rwkv"], h)
        else:
            st = state.get("rwkv_cm") if state else None
            f, xc = L.rwkv_channel_mix(bp["rwkv"], h, st)
            new_state["rwkv_cm"] = xc
    else:
        f = L.mlp_forward(bp["mlp"], h, ffn)
    if cfg.post_norm:
        f = _norm(f, bp["pln2"], cfg)
    x = x + f
    x = logical(x, "batch", "seq", "embed")
    return x, new_state


# ----------------------------------------------------------------------
# embedding / positions / heads
# ----------------------------------------------------------------------

def _embed(params: Params, cfg: ArchConfig, batch: dict) -> tuple[jax.Array, Any]:
    """Returns (x (B,S,d), positions)."""
    if cfg.n_codebooks > 1:
        codes = batch["tokens"]                      # (B, S, K)
        x = sum(jnp.take(params["embed"][k], codes[..., k], axis=0)
                for k in range(cfg.n_codebooks))
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)  # (B,S,d)
    B, S = x.shape[:2]
    if cfg.vlm_patches:
        patches = batch["patches"].astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([patches, x], axis=1)
        S = x.shape[1]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.pos_embedding == "sinusoidal":
        x = x + L.sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]
    positions = _positions(cfg, B, S)
    x = logical(x, "batch", "seq", "embed")
    return x, positions


def _positions(cfg: ArchConfig, B: int, S: int):
    if cfg.mrope_sections is None:
        return jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    # M-RoPE: patches get a (t=0, h, w) grid; text continues with t=h=w.
    P = cfg.vlm_patches
    side = max(int(math.sqrt(max(P, 1))), 1)
    pt = jnp.concatenate([jnp.zeros(P, jnp.int32), jnp.arange(S - P)])
    ph = jnp.concatenate([jnp.arange(P) // side, jnp.arange(S - P)])
    pw = jnp.concatenate([jnp.arange(P) % side, jnp.arange(S - P)])
    pos = jnp.stack([pt, ph, pw])                    # (3, S)
    return jnp.broadcast_to(pos[:, None, :], (3, B, S))


def _lm_logits(params: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    x = _norm(x, params["final_ln"], cfg)
    if cfg.n_codebooks > 1:
        w = params["lm_head"] if not cfg.tie_embeddings else \
            jnp.swapaxes(params["embed"], 1, 2)      # (K, d, V)
        logits = jnp.einsum("bsd,kdv->bskv", x, w)
    elif cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    if cfg.logit_cap is not None:
        logits = L.softcap(logits.astype(jnp.float32), cfg.logit_cap)
    if cfg.vocab_padded != cfg.vocab:
        # mask padded vocab slots out of softmax/argmax
        pad_mask = (jnp.arange(cfg.vocab_padded) >= cfg.vocab) * jnp.asarray(
            -1e9, logits.dtype)
        logits = logits + pad_mask
    logits = logical(logits, "batch", None, "vocab")
    return logits


# ----------------------------------------------------------------------
# full passes
# ----------------------------------------------------------------------

def forward(params: Params, cfg: ArchConfig, batch: dict) -> jax.Array:
    """Training/scoring forward: logits over the full sequence."""
    x, positions = _embed(params, cfg, batch)
    head, body, tail = cfg.groups

    for j, (mixer, ffn) in enumerate(head):
        x, _ = _apply_block(params[f"head{j}"], x, cfg, mixer, ffn,
                            positions, "train", None)
    if cfg.n_body:
        def body_fn(xc, gp):
            for j, (mixer, ffn) in enumerate(body):
                xc, _ = _apply_block(gp[f"b{j}"], xc, cfg, mixer, ffn,
                                     positions, "train", None)
            return xc, None
        if cfg.remat:
            body_fn = jax.checkpoint(body_fn, prevent_cse=False)
        x, _ = lax.scan(body_fn, x, params["body"], unroll=cfg.scan_unroll)
    for j, (mixer, ffn) in enumerate(tail):
        x, _ = _apply_block(params[f"tail{j}"], x, cfg, mixer, ffn,
                            positions, "train", None)
    return _lm_logits(params, cfg, x)


def init_decode_state(cfg: ArchConfig, B: int, S_max: int, dtype=jnp.bfloat16) -> Params:
    """Zeroed decode state for every layer (KV caches + recurrent states)."""
    head, body, tail = cfg.groups

    def block_state(mixer, ffn):
        st = {}
        if mixer in ("attn", "local"):
            KV, hd = cfg.n_kv_heads, cfg.head_dim
            S_eff = min(S_max, cfg.window) if (mixer == "local" and cfg.window) else S_max
            st["kv"] = {"k": jnp.zeros((B, S_eff, KV, hd), dtype),
                        "v": jnp.zeros((B, S_eff, KV, hd), dtype)}
        elif mixer == "rwkv":
            H = cfg.d_model // cfg.rwkv_head_size
            N = cfg.rwkv_head_size
            st["rwkv"] = {"x_tm": jnp.zeros((B, cfg.d_model), dtype),
                          "s": jnp.zeros((B, H, N, N), jnp.float32)}
        elif mixer == "rglru":
            dr = cfg.rglru_width or cfg.d_model
            st["rglru"] = {"conv": jnp.zeros((B, cfg.conv_width - 1, dr), dtype),
                           "h": jnp.zeros((B, dr), jnp.float32)}
        if ffn == "rwkv_cm":
            st["rwkv_cm"] = jnp.zeros((B, cfg.d_model), dtype)
        return st

    state: Params = {}
    for j, (mixer, ffn) in enumerate(head):
        state[f"head{j}"] = block_state(mixer, ffn)
    if cfg.n_body:
        groups = []
        for _ in range(cfg.n_body):
            groups.append({f"b{j}": block_state(m, f) for j, (m, f) in enumerate(body)})
        state["body"] = jax.tree.map(lambda *xs: jnp.stack(xs), *groups)
    for j, (mixer, ffn) in enumerate(tail):
        state[f"tail{j}"] = block_state(mixer, ffn)
    return state


def decode_step(params: Params, cfg: ArchConfig, state: Params,
                tokens: jax.Array, pos: jax.Array):
    """One decode step.  tokens: (B, 1) (or (B, 1, K)); pos: (B,) write index.

    Returns (logits (B, 1, V...), new_state).
    """
    batch = {"tokens": tokens}
    if cfg.vlm_patches:
        # decode consumes only text tokens; patches live in the cache already
        cfg = dataclasses.replace(cfg, vlm_patches=0)
    sin_cfg = cfg
    if cfg.pos_embedding == "sinusoidal":
        # add position embedding at the true offset below, not inside _embed
        cfg = dataclasses.replace(cfg, pos_embedding="none")
    x, _ = _embed(params, cfg, batch)
    if sin_cfg.pos_embedding == "sinusoidal":
        d = sin_cfg.d_model
        dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
        ang = pos.astype(jnp.float32)[:, None] / jnp.power(10000.0, dim / d)
        pe = jnp.zeros((x.shape[0], d), jnp.float32)
        pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
        x = x + pe[:, None, :].astype(x.dtype)
    head, body, tail = cfg.groups
    new_state: Params = {}
    for j, (mixer, ffn) in enumerate(head):
        x, st = _apply_block(params[f"head{j}"], x, cfg, mixer, ffn,
                             None, "decode", state[f"head{j}"], pos)
        new_state[f"head{j}"] = st
    if cfg.n_body:
        # caches ride the scan CARRY (updated in place per group index):
        # a while-loop carry aliases its buffers, whereas stacked scan
        # outputs (ys) must be staged separately -- carrying the stack
        # removes the second live copy of every KV cache
        # (EXPERIMENTS.md §Perf iteration 7)
        def body_fn(carry, inp):
            xc, cstack = carry
            gp, i = inp
            gst = jax.tree.map(lambda s: lax.dynamic_index_in_dim(
                s, i, axis=0, keepdims=False), cstack)
            out_st = {}
            for j, (mixer, ffn) in enumerate(body):
                xc, st = _apply_block(gp[f"b{j}"], xc, cfg, mixer, ffn,
                                      None, "decode", gst[f"b{j}"], pos)
                out_st[f"b{j}"] = st
            cstack = jax.tree.map(
                lambda s, ns: lax.dynamic_update_index_in_dim(
                    s, ns.astype(s.dtype), i, axis=0),
                cstack, out_st)
            return (xc, cstack), None
        (x, body_state), _ = lax.scan(
            body_fn, (x, state["body"]),
            (params["body"], jnp.arange(cfg.n_body)), unroll=cfg.scan_unroll)
        new_state["body"] = body_state
    for j, (mixer, ffn) in enumerate(tail):
        x, st = _apply_block(params[f"tail{j}"], x, cfg, mixer, ffn,
                             None, "decode", state[f"tail{j}"], pos)
        new_state[f"tail{j}"] = st
    return _lm_logits(params, cfg, x), new_state


def prefill(params: Params, cfg: ArchConfig, batch: dict, last_only: bool = False):
    """Forward over a prompt, returning (logits, decode state).

    last_only=True computes the LM head only for the final position — the
    serving configuration (full-sequence logits at 32k x 256k-vocab would
    dwarf the backbone's memory).
    """
    x, positions = _embed(params, cfg, batch)
    head, body, tail = cfg.groups
    new_state: Params = {}
    for j, (mixer, ffn) in enumerate(head):
        x, st = _apply_block(params[f"head{j}"], x, cfg, mixer, ffn,
                             positions, "prefill", None)
        new_state[f"head{j}"] = st
    if cfg.n_body:
        def body_fn(xc, gp):
            out_st = {}
            for j, (mixer, ffn) in enumerate(body):
                xc, st = _apply_block(gp[f"b{j}"], xc, cfg, mixer, ffn,
                                      positions, "prefill", None)
                out_st[f"b{j}"] = st
            return xc, out_st
        if cfg.remat:
            body_fn = jax.checkpoint(body_fn, prevent_cse=False)
        x, body_state = lax.scan(body_fn, x, params["body"],
                                 unroll=cfg.scan_unroll)
        new_state["body"] = body_state
    for j, (mixer, ffn) in enumerate(tail):
        x, st = _apply_block(params[f"tail{j}"], x, cfg, mixer, ffn,
                             positions, "prefill", None)
        new_state[f"tail{j}"] = st
    if last_only:
        x = x[:, -1:]
    return _lm_logits(params, cfg, x), new_state


def forward_hidden(params: Params, cfg: ArchConfig, batch: dict) -> jax.Array:
    """Backbone only: final hidden states (B, S, d), pre LM head."""
    x, positions = _embed(params, cfg, batch)
    head, body, tail = cfg.groups
    for j, (mixer, ffn) in enumerate(head):
        x, _ = _apply_block(params[f"head{j}"], x, cfg, mixer, ffn,
                            positions, "train", None)
    if cfg.n_body:
        def body_fn(xc, gp):
            for j, (mixer, ffn) in enumerate(body):
                xc, _ = _apply_block(gp[f"b{j}"], xc, cfg, mixer, ffn,
                                     positions, "train", None)
            return xc, None
        if cfg.remat:
            body_fn = jax.checkpoint(body_fn, prevent_cse=False)
        x, _ = lax.scan(body_fn, x, params["body"], unroll=cfg.scan_unroll)
    for j, (mixer, ffn) in enumerate(tail):
        x, _ = _apply_block(params[f"tail{j}"], x, cfg, mixer, ffn,
                            positions, "train", None)
    return x


def loss_fn(params: Params, cfg: ArchConfig, batch: dict,
            seq_chunk: int = 512) -> jax.Array:
    """Next-token cross entropy (mean over tokens; fp32 logsumexp).

    The LM head + loss are computed in sequence chunks so the full
    (B, S, vocab) logits tensor is never materialized — at 256k-vocab
    training shapes the logits would otherwise dwarf the backbone memory.
    """
    x = forward_hidden(params, cfg, batch)
    labels = batch["labels"]
    if cfg.vlm_patches:
        x = x[:, cfg.vlm_patches :]
    B, S = x.shape[:2]
    while S % seq_chunk != 0:
        seq_chunk //= 2
    n = S // seq_chunk

    def chunk_loss(carry, idx):
        xc = lax.dynamic_slice_in_dim(x, idx * seq_chunk, seq_chunk, axis=1)
        lc = lax.dynamic_slice_in_dim(labels, idx * seq_chunk, seq_chunk, axis=1)
        logits = _lm_logits(params, cfg, xc).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    if n <= 1:
        total, _ = chunk_loss(jnp.zeros((), jnp.float32), 0)
    else:
        (total, _) = lax.scan(chunk_loss, jnp.zeros((), jnp.float32),
                              jnp.arange(n))[0], None
    n_tok = B * S * (cfg.n_codebooks if cfg.n_codebooks > 1 else 1)
    return total / n_tok
