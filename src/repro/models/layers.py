"""Model building blocks, pure JAX (jnp + lax), scan/shard friendly.

Covers every sub-block the assigned architecture pool needs:
  * RMSNorm / LayerNorm (gemma-style (1+w) scaling supported)
  * RoPE, M-RoPE (qwen2-vl), sinusoidal positions (musicgen)
  * GQA attention with sliding window + logit softcap, chunked
    (online-softmax / flash-structured) for long sequences — the XLA path;
    the Pallas kernel in repro.kernels is the TPU-optimized drop-in.
  * SwiGLU / GeLU MLPs
  * Mixture-of-Experts with sort-based capacity dispatch (deepseek/mixtral)
  * RWKV6 (Finch) time-mix with data-dependent decay + channel-mix
  * RG-LRU recurrent block (RecurrentGemma/Griffin)

Every function is functional: params in, activations out.  Decode variants
take and return explicit state (KV cache / recurrent state).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from .sharding import logical

Params = dict
DEFAULT_QUERY_CHUNK = 1024


# ----------------------------------------------------------------------
# norms & activations
# ----------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6, plus_one: bool = False) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * lax.rsqrt(var + eps)
    scale = (1.0 + w) if plus_one else w
    return (x * scale).astype(dtype)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return ((x - mu) * lax.rsqrt(var + eps) * w + b).astype(dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return cap * jnp.tanh(x / cap)


# ----------------------------------------------------------------------
# positions
# ----------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e4,
               mrope_sections: tuple[int, ...] | None = None) -> jax.Array:
    """x: (B, S, H, hd).  positions: (B, S) or (3, B, S) for M-RoPE."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    if mrope_sections is None:
        angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    else:
        # M-RoPE: split the hd/2 frequency slots into (t, h, w) sections,
        # each driven by its own position stream (qwen2-vl §M-RoPE).
        assert positions.ndim == 3 and positions.shape[0] == len(mrope_sections)
        parts = []
        off = 0
        for sec, pos in zip(mrope_sections, positions):
            parts.append(pos[..., None].astype(jnp.float32) * freqs[off : off + sec])
            off += sec
        angles = jnp.concatenate(parts, axis=-1)  # (B,S,hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(S: int, d: int, offset: int = 0) -> jax.Array:
    pos = jnp.arange(offset, offset + S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((S, d), dtype=jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


# ----------------------------------------------------------------------
# attention (GQA, windowed, softcapped; chunked online-softmax)
# ----------------------------------------------------------------------

def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    B, S, KV, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, KV, n_rep, hd)).reshape(B, S, KV * n_rep, hd)


def attention(
    q: jax.Array,               # (B, Sq, H, hd)
    k: jax.Array,               # (B, Sk, KV, hd)
    v: jax.Array,               # (B, Sk, KV, hd)
    *,
    causal: bool = True,
    q_offset: int | jax.Array = 0,   # absolute position of q[0]
    window: int | None = None,
    cap: float | None = None,
    scale: float | None = None,
    query_chunk: int = DEFAULT_QUERY_CHUNK,
) -> jax.Array:
    """Chunked attention with online softmax over query blocks.

    Memory is O(Sq_chunk * Sk) instead of O(Sq * Sk): the XLA analogue of
    flash attention's outer loop (the Pallas kernel tiles the inner loop
    too).  Equivalent math to naive softmax(QK^T)V.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    n_rep = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kf = _repeat_kv(k, n_rep)
    vf = _repeat_kv(v, n_rep)
    Sk = kf.shape[1]
    kpos = jnp.arange(Sk)

    def one_chunk(q_chunk: jax.Array, qpos_chunk: jax.Array) -> jax.Array:
        # q_chunk: (B, C, H, hd); logits (B, H, C, Sk) in f32
        logits = jnp.einsum("bchd,bshd->bhcs", q_chunk.astype(jnp.float32),
                            kf.astype(jnp.float32)) * scale
        if cap is not None:
            logits = softcap(logits, cap)
        mask = jnp.ones((q_chunk.shape[1], Sk), dtype=bool)
        if causal:
            mask &= qpos_chunk[:, None] >= kpos[None, :]
        if window is not None:
            mask &= qpos_chunk[:, None] - kpos[None, :] < window
        logits = jnp.where(mask[None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhcs,bshd->bchd", probs, vf.astype(jnp.float32))
        return out.astype(q.dtype)

    if Sq <= query_chunk:
        qpos = q_offset + jnp.arange(Sq)
        return one_chunk(q, qpos)
    while Sq % query_chunk != 0:
        query_chunk //= 2  # e.g. 4352 = 4096 + 256 patches -> 256
    n_chunks = Sq // query_chunk
    qr = q.reshape(B, n_chunks, query_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    qpos = (q_offset + jnp.arange(Sq)).reshape(n_chunks, query_chunk)
    out = lax.map(lambda args: one_chunk(*args), (qr, qpos))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


@dataclasses.dataclass
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 1e4
    mrope_sections: tuple[int, ...] | None = None
    window: int | None = None
    cap: float | None = None
    qkv_bias: bool = False
    use_rope: bool = True
    query_scale: float | None = None


def attn_init(rng: jax.Array, cfg: AttnConfig, dtype=jnp.bfloat16) -> Params:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": (jax.random.normal(k1, (d, H, hd)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d, KV, hd)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d, KV, hd)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (H, hd, d)) * s / math.sqrt(H * hd / d)).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((KV, hd), dtype)
        p["bv"] = jnp.zeros((KV, hd), dtype)
    return p


def attn_forward(
    p: Params, x: jax.Array, cfg: AttnConfig, positions: jax.Array,
    query_chunk: int = DEFAULT_QUERY_CHUNK,
) -> jax.Array:
    """Full-sequence causal attention (training / prefill)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    o = attention(q, k, v, causal=True, window=cfg.window, cap=cfg.cap,
                  scale=cfg.query_scale, query_chunk=query_chunk)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def attn_prefill(p: Params, x: jax.Array, cfg: AttnConfig, positions: jax.Array,
                 query_chunk: int = DEFAULT_QUERY_CHUNK):
    """Like forward but also returns the KV cache."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    o = attention(q, k, v, causal=True, window=cfg.window, cap=cfg.cap,
                  scale=cfg.query_scale, query_chunk=query_chunk)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), {"k": k, "v": v}


def attn_decode(
    p: Params, x: jax.Array, cfg: AttnConfig, cache: Params, pos: jax.Array,
) -> tuple[jax.Array, Params]:
    """One-token decode: x (B, 1, d), cache {k,v}: (B, S, KV, hd), pos (B,).

    If the cache is smaller than the absolute position (sliding-window
    layers keep only `window` slots) it is treated as a ring buffer: slots
    are recycled mod S, and every slot is valid once the ring has wrapped.
    RoPE is applied at absolute positions before writing, so recycled slots
    remain correct.
    """
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.use_rope:
        pp = pos[:, None]
        if cfg.mrope_sections is not None:
            pp = jnp.broadcast_to(pp[None], (len(cfg.mrope_sections), B, 1))
        q = apply_rope(q, pp, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, pp, cfg.rope_theta, cfg.mrope_sections)
    S = cache["k"].shape[1]
    ring = cfg.window is not None and S <= cfg.window
    wpos = pos % S if ring else pos
    # scatter-update one slot per row: aliasable with the donated cache
    # buffer (a one-hot blend rewrites the whole cache and forces a second
    # live copy -- EXPERIMENTS.md §Perf iteration 7)
    rows = jnp.arange(cache["k"].shape[0])
    newk = cache["k"].at[rows, wpos].set(k[:, 0].astype(cache["k"].dtype))
    newv = cache["v"].at[rows, wpos].set(v[:, 0].astype(cache["v"].dtype))
    kpos = jnp.arange(S)
    H, KV = cfg.n_heads, cfg.n_kv_heads
    kf = _repeat_kv(newk, H // KV)
    vf = _repeat_kv(newv, H // KV)
    kf = logical(kf, "batch", "kv_seq", "heads", None)
    vf = logical(vf, "batch", "kv_seq", "heads", None)
    scale = cfg.query_scale if cfg.query_scale is not None else 1.0 / math.sqrt(cfg.head_dim)
    logits = jnp.einsum("bchd,bshd->bhcs", q.astype(jnp.float32), kf.astype(jnp.float32)) * scale
    if cfg.cap is not None:
        logits = softcap(logits, cfg.cap)
    if ring:
        # slot valid if already written: index <= pos, or ring has wrapped
        mask = (kpos[None, :] <= pos[:, None]) | (pos[:, None] >= S)
    else:
        mask = kpos[None, :] <= pos[:, None]
        if cfg.window is not None:
            mask &= kpos[None, :] > pos[:, None] - cfg.window
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhcs,bshd->bchd", probs, vf.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, {"k": newk, "v": newv}


# ----------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------

def mlp_init(rng: jax.Array, d: int, f: int, kind: str = "swiglu", dtype=jnp.bfloat16) -> Params:
    k1, k2, k3 = jax.random.split(rng, 3)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    if kind in ("swiglu", "geglu"):
        return {
            "wg": (jax.random.normal(k1, (d, f)) * s_in).astype(dtype),
            "wu": (jax.random.normal(k2, (d, f)) * s_in).astype(dtype),
            "wd": (jax.random.normal(k3, (f, d)) * s_out).astype(dtype),
        }
    return {  # plain 2-layer MLP (musicgen)
        "w1": (jax.random.normal(k1, (d, f)) * s_in).astype(dtype),
        "b1": jnp.zeros((f,), dtype),
        "w2": (jax.random.normal(k2, (f, d)) * s_out).astype(dtype),
        "b2": jnp.zeros((d,), dtype),
    }


def mlp_forward(p: Params, x: jax.Array, kind: str = "swiglu") -> jax.Array:
    if kind == "swiglu":
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]
    if kind == "geglu":
        return (jax.nn.gelu(x @ p["wg"], approximate=True) * (x @ p["wu"])) @ p["wd"]
    h = jax.nn.gelu(x @ p["w1"] + p["b1"], approximate=True)
    return h @ p["w2"] + p["b2"]


# ----------------------------------------------------------------------
# Mixture-of-Experts with sort-based capacity dispatch
# ----------------------------------------------------------------------

@dataclasses.dataclass
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router_mode: str = "softmax_topk"   # deepseek: softmax then topk
                                        # mixtral: "topk_softmax"


def moe_init(rng: jax.Array, d: int, cfg: MoEConfig, dtype=jnp.bfloat16) -> Params:
    E, f = cfg.n_experts, cfg.d_expert
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": (jax.random.normal(k1, (d, E)) * s_in).astype(jnp.float32),
        "wg": (jax.random.normal(k2, (E, d, f)) * s_in).astype(dtype),
        "wu": (jax.random.normal(k3, (E, d, f)) * s_in).astype(dtype),
        "wd": (jax.random.normal(k4, (E, f, d)) * s_out).astype(dtype),
    }
    if cfg.n_shared:
        p["shared"] = mlp_init(k5, d, cfg.d_expert * cfg.n_shared, "swiglu", dtype)
    return p


def _axis_prod(mesh, ax) -> int:
    if ax is None:
        return 1
    axes = ax if isinstance(ax, (tuple, list)) else (ax,)
    g = 1
    for a in axes:
        g *= mesh.shape[a] if a in mesh.axis_names else 1
    return g


def _moe_groups(B: int, S: int) -> tuple[int, int]:
    """(batch-groups, seq-groups) for dispatch.

    Groups cover the *full device grid* (data x model axes) so that
    routing, sorting and capacity-dropping are device-local; the only
    model-axis crossing is then the (G, E, C, d) buffer <-> expert-sharded
    einsum — the honest EP all-to-all — instead of fp32 gradients of the
    whole gathered token tensor (see EXPERIMENTS.md §Perf iteration 1).
    """
    from .sharding import current_mesh, current_rules

    mesh, rules = current_mesh(), current_rules()
    if mesh is None or rules is None:
        return 1, 1
    g1 = _axis_prod(mesh, rules.get("batch"))
    if "expert" in mesh.axis_names:
        # expert-factorized mesh: the buffer crosses into the expert axis by
        # slicing (free); folding TP devices into dispatch groups would make
        # the group->expert transition unpartitionable (refuted variant,
        # EXPERIMENTS.md §Perf iteration 6)
        g2 = 1
    else:
        g2 = _axis_prod(mesh, rules.get("capacity"))  # the TP axis
    if B % max(g1, 1) != 0 or g1 <= 0:
        g1 = 1
    if S % max(g2, 1) != 0 or g2 <= 0:
        g2 = 1
    return g1, g2


def _group_axes(G1: int, G2: int):
    """Mesh axis names backing the dispatch-group dim (for shard_map specs)."""
    from .sharding import current_rules

    rules = current_rules() or {}
    axes: tuple = ()
    if G1 > 1:
        ba = rules.get("batch")
        axes += tuple(ba) if isinstance(ba, (tuple, list)) else ((ba,) if ba else ())
    if G2 > 1:
        ta = rules.get("capacity")
        axes += tuple(ta) if isinstance(ta, (tuple, list)) else ((ta,) if ta else ())
    return axes


def moe_forward(p: Params, x: jax.Array, cfg: MoEConfig) -> jax.Array:
    """Token-dropping MoE: device-local dispatch + EP all-to-all.

    Dispatch groups tile the full (data x model) device grid.  Routing,
    sorting, capacity dropping, gather and the combine scatter run inside
    shard_map — guaranteed device-local, no partitioner guessing (pure-pjit
    dispatch replicated the gather indices at (G, T*K, d) u32 and
    all-reduced fp32 gradients of the gathered tokens; see EXPERIMENTS.md
    §Perf iteration 1-2).  Only the (G, E, C, d) capacity buffer crosses
    the model axis, into the expert-sharded einsum and back: the honest EP
    all-to-all, in bf16, once forward and once backward.
    """
    try:  # jax >= 0.6: top-level export, varying-manual-axes check
        from jax import shard_map
        _smap_kw = {"check_vma": False}
    except ImportError:  # jax 0.4.x: experimental module, replication check
        from jax.experimental.shard_map import shard_map
        _smap_kw = {"check_rep": False}
    from jax.sharding import PartitionSpec as P

    from .sharding import current_mesh

    B, S, d = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    G1, G2 = _moe_groups(B, S)
    G = G1 * G2
    Tg = T // G
    C = int(math.ceil(Tg * K / E * cfg.capacity_factor))
    if G2 > 1:
        # (B, S, d) -> (G1, B/G1, G2, S/G2, d) -> (G, Tg, d): groups line up
        # with the (data, model) device grid
        xg = x.reshape(G1, B // G1, G2, S // G2, d)
        xg = xg.transpose(0, 2, 1, 3, 4).reshape(G, Tg, d)
    else:
        xg = x.reshape(G, Tg, d)
    router = p["router"].astype(jnp.float32)

    def dispatch(xg_blk: jax.Array, router_blk: jax.Array):
        """(g, Tg, d) -> buffer (g, E, C, d) + combine metadata. Local."""
        g = xg_blk.shape[0]
        logits = jnp.einsum("gtd,de->gte", xg_blk.astype(jnp.float32), router_blk)
        if cfg.router_mode == "softmax_topk":
            probs = jax.nn.softmax(logits, axis=-1)
            w, idx = lax.top_k(probs, K)
            w = w / jnp.clip(w.sum(-1, keepdims=True), 1e-9)
        else:  # topk_softmax (mixtral)
            lw, idx = lax.top_k(logits, K)
            w = jax.nn.softmax(lw, axis=-1)
        flat_e = idx.reshape(g, Tg * K)
        flat_t = jnp.broadcast_to(jnp.repeat(jnp.arange(Tg), K)[None], (g, Tg * K))
        flat_w = w.reshape(g, Tg * K)
        order = jnp.argsort(flat_e, axis=1, stable=True)
        se = jnp.take_along_axis(flat_e, order, axis=1)
        st = jnp.take_along_axis(flat_t, order, axis=1)
        sw = jnp.take_along_axis(flat_w, order, axis=1)
        counts = jax.vmap(lambda e: jnp.bincount(e, length=E))(se)
        starts = jnp.concatenate(
            [jnp.zeros((g, 1), counts.dtype), jnp.cumsum(counts, axis=1)[:, :-1]],
            axis=1)
        pos = jnp.arange(Tg * K)[None] - jnp.take_along_axis(starts, se, axis=1)
        keep = pos < C
        buf_idx = jnp.where(keep, se * C + pos, E * C)
        gathered = jnp.take_along_axis(xg_blk, st[..., None], axis=1)
        xb = jnp.zeros((g, E * C + 1, d), dtype=xg_blk.dtype)
        xb = jax.vmap(lambda b, i, v: b.at[i].set(v))(xb, buf_idx, gathered)
        sw_eff = jnp.where(keep, sw, 0.0).astype(xg_blk.dtype)
        return (xb[:, : E * C].reshape(g, E, C, d), st, sw_eff,
                buf_idx.astype(jnp.int32))

    def combine(yb_blk: jax.Array, st: jax.Array, sw: jax.Array,
                buf_idx: jax.Array) -> jax.Array:
        """(g, E, C, d) expert outputs -> (g, Tg, d). Local scatter-add."""
        g = yb_blk.shape[0]
        ybf = jnp.concatenate(
            [yb_blk.reshape(g, E * C, d),
             jnp.zeros((g, 1, d), yb_blk.dtype)], axis=1)
        contrib = jnp.take_along_axis(ybf, buf_idx[..., None], axis=1)
        contrib = contrib * sw[..., None]
        out = jnp.zeros((g, Tg, d), jnp.float32)
        out = jax.vmap(lambda o, i, v: o.at[i].add(v))(
            out, st, contrib.astype(jnp.float32))
        return out.astype(yb_blk.dtype)

    mesh = current_mesh()
    gaxes = _group_axes(G1, G2)
    if mesh is not None and G > 1 and gaxes:
        gspec = gaxes if len(gaxes) > 1 else gaxes[0]
        d_in = (P(gspec, None, None), P(None, None))
        d_out = (P(gspec, None, None, None), P(gspec, None), P(gspec, None),
                 P(gspec, None))
        xb, st, sw, bidx = shard_map(
            dispatch, mesh=mesh, in_specs=d_in, out_specs=d_out,
            **_smap_kw)(xg, router)
    else:
        xb, st, sw, bidx = dispatch(xg, router)
    # expert compute under pjit: the buffer reshards group->expert here
    xb = logical(xb, "moe_batch", "expert", None, None)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xb, p["wg"])) * jnp.einsum(
        "gecd,edf->gecf", xb, p["wu"])
    h = logical(h, "moe_batch", "expert", None, "ffn")
    yb = jnp.einsum("gecf,efd->gecd", h, p["wd"])
    yb = logical(yb, "moe_batch", "expert", None, None)
    if mesh is not None and G > 1 and gaxes:
        c_in = (P(gspec, None, None, None), P(gspec, None), P(gspec, None),
                P(gspec, None))
        out = shard_map(combine, mesh=mesh, in_specs=c_in,
                        out_specs=P(gspec, None, None),
                        **_smap_kw)(yb, st, sw, bidx)
    else:
        out = combine(yb, st, sw, bidx)
    if "shared" in p:
        out = out + mlp_forward(p["shared"], xg, "swiglu")
    if G2 > 1:
        out = out.reshape(G1, G2, B // G1, S // G2, d).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, S, d)


# ----------------------------------------------------------------------
# RWKV6 (Finch): time-mix with data-dependent decay + channel-mix
# ----------------------------------------------------------------------

@dataclasses.dataclass
class RWKVConfig:
    d_model: int
    n_heads: int            # head size = d_model // n_heads (usually 64)
    d_ff: int
    lora_rank: int = 64

    @property
    def head_size(self) -> int:
        return self.d_model // self.n_heads


def rwkv_init(rng: jax.Array, cfg: RWKVConfig, dtype=jnp.bfloat16) -> Params:
    d, r = cfg.d_model, cfg.lora_rank
    ks = jax.random.split(rng, 16)
    s = 1.0 / math.sqrt(d)
    p = {
        # token-shift mixing coefficients (static part) for r,k,v,g,w
        "mu": (jax.random.uniform(ks[0], (5, d)) * 0.5 + 0.25).astype(jnp.float32),
        # data-dependent token-shift LoRA (shared A, per-stream B)
        "tm_a": (jax.random.normal(ks[1], (d, 5 * 32)) * s).astype(dtype),
        "tm_b": (jax.random.normal(ks[2], (5, 32, d)) * 0.01).astype(dtype),
        "wr": (jax.random.normal(ks[3], (d, d)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[4], (d, d)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[5], (d, d)) * s).astype(dtype),
        "wg": (jax.random.normal(ks[6], (d, d)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[7], (d, d)) * s).astype(dtype),
        # decay: w = exp(-exp(w0 + lora(x)))
        "w0": (jax.random.uniform(ks[8], (d,)) * -1.0 - 5.0).astype(jnp.float32),
        "wd_a": (jax.random.normal(ks[9], (d, r)) * s).astype(dtype),
        "wd_b": (jax.random.normal(ks[10], (r, d)) * 0.01).astype(dtype),
        "u": (jax.random.uniform(ks[11], (d,)) * 0.5).astype(jnp.float32),  # bonus
        "ln_w": jnp.ones((d,), jnp.float32),   # per-head group norm
        "cm_mu": (jax.random.uniform(ks[12], (2, d)) * 0.5 + 0.25).astype(jnp.float32),
        "cm_k": (jax.random.normal(ks[13], (d, cfg.d_ff)) * s).astype(dtype),
        "cm_v": (jax.random.normal(ks[14], (cfg.d_ff, d)) * (1.0 / math.sqrt(cfg.d_ff))).astype(dtype),
        "cm_r": (jax.random.normal(ks[15], (d, d)) * s).astype(dtype),
    }
    return p


def _rwkv_streams(p: Params, x: jax.Array, x_prev: jax.Array):
    """Token-shift mixed streams (r, k, v, g, w) per RWKV6.

    x: (B, S, d); x_prev: x shifted right by one (with carry for decode).
    """
    delta = (x_prev - x).astype(jnp.float32)
    tm = jnp.tanh(x.astype(jnp.float32) @ p["tm_a"].astype(jnp.float32))
    tm = tm.reshape(*x.shape[:-1], 5, 32)
    dyn = jnp.einsum("...ni,nid->...nd", tm, p["tm_b"].astype(jnp.float32))
    mixed = x[..., None, :].astype(jnp.float32) + delta[..., None, :] * (
        p["mu"] + dyn)  # (..., 5, d)
    return [mixed[..., i, :] for i in range(5)]


def rwkv_time_mix(p: Params, x: jax.Array, cfg: RWKVConfig,
                  x_carry: jax.Array | None = None,
                  state: jax.Array | None = None,
                  wkv_fn=None):
    """RWKV6 attention-free mixer.

    x: (B, S, d).  Returns (out, (new_x_carry, new_state)).
    state: (B, H, N, N) wkv state; x_carry: (B, d) last token of prev chunk.
    """
    B, S, d = x.shape
    H, N = cfg.n_heads, cfg.head_size
    if x_carry is None:
        x_carry = jnp.zeros((B, d), x.dtype)
    x_prev = jnp.concatenate([x_carry[:, None, :], x[:, :-1, :]], axis=1)
    xr, xk, xv, xg, xw = _rwkv_streams(p, x, x_prev)
    dt = x.dtype
    r = (xr.astype(dt) @ p["wr"]).reshape(B, S, H, N)
    k = (xk.astype(dt) @ p["wk"]).reshape(B, S, H, N)
    v = (xv.astype(dt) @ p["wv"]).reshape(B, S, H, N)
    g = jax.nn.silu(xg.astype(dt) @ p["wg"])
    wlog = p["w0"] + (jnp.tanh(xw.astype(dt) @ p["wd_a"]).astype(jnp.float32)
                      @ p["wd_b"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(wlog)).reshape(B, S, H, N)  # decay in (0,1)
    u = p["u"].reshape(H, N)
    if state is None:
        state = jnp.zeros((B, H, N, N), jnp.float32)
    if wkv_fn is None:
        from ..kernels.rwkv6 import ref as _ref
        from functools import partial as _partial
        # chunked time scan: remat per chunk bounds backward memory
        wkv_fn = _partial(_ref.wkv6, chunk=128 if S % 128 == 0 and S > 128 else None)
    r = logical(r, "batch", None, "heads", None)
    k = logical(k, "batch", None, "heads", None)
    v = logical(v, "batch", None, "heads", None)
    w = logical(w, "batch", None, "heads", None)
    state = logical(state, "batch", "heads", None, None)
    y, new_state = wkv_fn(r, k, v, w, u, state)  # (B,S,H,N), (B,H,N,N)
    y = y.reshape(B, S, H, N)
    # per-head group norm
    y = rms_norm(y, p["ln_w"].reshape(H, N), eps=1e-5)
    y = y.reshape(B, S, d) * g
    out = y.astype(dt) @ p["wo"]
    return out, (x[:, -1, :], new_state)


def rwkv_channel_mix(p: Params, x: jax.Array, x_carry: jax.Array | None = None):
    """RWKV channel-mix FFN with token shift. Returns (out, new_carry)."""
    B, S, d = x.shape
    if x_carry is None:
        x_carry = jnp.zeros((B, d), x.dtype)
    x_prev = jnp.concatenate([x_carry[:, None, :], x[:, :-1, :]], axis=1)
    mu = p["cm_mu"]
    xk = x + (x_prev - x) * mu[0].astype(x.dtype)
    xr = x + (x_prev - x) * mu[1].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["cm_k"]))
    out = jax.nn.sigmoid(xr @ p["cm_r"]) * (kk @ p["cm_v"])
    return out, x[:, -1, :]


# ----------------------------------------------------------------------
# RG-LRU recurrent block (RecurrentGemma / Griffin)
# ----------------------------------------------------------------------

@dataclasses.dataclass
class RGLRUConfig:
    d_model: int
    d_rnn: int
    conv_width: int = 4
    c: float = 8.0


def rglru_init(rng: jax.Array, cfg: RGLRUConfig, dtype=jnp.bfloat16) -> Params:
    d, dr = cfg.d_model, cfg.d_rnn
    ks = jax.random.split(rng, 7)
    s = 1.0 / math.sqrt(d)
    # Lambda init so that a ~ U(0.9, 0.999)^c-ish (griffin init)
    lam = jnp.log(jnp.expm1(-jnp.log(jax.random.uniform(ks[0], (dr,)) * 0.099 + 0.9) / cfg.c))
    return {
        "w_in_x": (jax.random.normal(ks[1], (d, dr)) * s).astype(dtype),
        "w_in_g": (jax.random.normal(ks[2], (d, dr)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_width, dr)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "wa": (jax.random.normal(ks[4], (dr, dr)) * (1.0 / math.sqrt(dr))).astype(dtype),
        "ba": jnp.zeros((dr,), jnp.float32),
        "wx": (jax.random.normal(ks[5], (dr, dr)) * (1.0 / math.sqrt(dr))).astype(dtype),
        "bx": jnp.zeros((dr,), jnp.float32),
        "lam": lam.astype(jnp.float32),
        "w_out": (jax.random.normal(ks[6], (dr, d)) * (1.0 / math.sqrt(dr))).astype(dtype),
    }


def rglru_scan(x: jax.Array, a: jax.Array, h0: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + x_t via associative scan over S. x,a: (B,S,D)."""

    def combine(c1, c2):
        a1, x1 = c1
        a2, x2 = c2
        return a1 * a2, x1 * a2 + x2

    a0 = jnp.concatenate([jnp.ones_like(a[:, :1]), a[:, 1:]], axis=1)
    # fold h0 into the first element
    x = x.at[:, 0].add(a[:, 0] * h0)
    _, h = lax.associative_scan(combine, (a0, x), axis=1)
    return h


def rglru_block(p: Params, x: jax.Array, cfg: RGLRUConfig,
                state: tuple | None = None):
    """Griffin recurrent block: in-proj -> conv1d -> RG-LRU, gated.

    x: (B, S, d).  state = (conv_carry (B, W-1, dr), h (B, dr)).
    Returns (out, new_state).
    """
    B, S, d = x.shape
    dr = cfg.d_rnn
    gate = jax.nn.gelu(x @ p["w_in_g"], approximate=True)
    u = x @ p["w_in_x"]
    W = cfg.conv_width
    if state is None:
        conv_carry = jnp.zeros((B, W - 1, dr), u.dtype)
        h0 = jnp.zeros((B, dr), jnp.float32)
    else:
        conv_carry, h0 = state
    upad = jnp.concatenate([conv_carry, u], axis=1)  # (B, S+W-1, dr)
    conv = sum(upad[:, i : i + S, :] * p["conv_w"][i] for i in range(W)) + p["conv_b"]
    new_conv_carry = upad[:, S:, :] if W > 1 else conv_carry
    # RG-LRU gates
    rg = jax.nn.sigmoid(conv.astype(jnp.float32) @ p["wa"].astype(jnp.float32) + p["ba"])
    ig = jax.nn.sigmoid(conv.astype(jnp.float32) @ p["wx"].astype(jnp.float32) + p["bx"])
    log_a = -cfg.c * rg * jax.nn.softplus(p["lam"])
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gated = (ig * conv.astype(jnp.float32)) * mult
    h = rglru_scan(gated, a, h0)
    new_h = h[:, -1, :]
    out = (h.astype(x.dtype) * gate) @ p["w_out"]
    return out, (new_conv_carry, new_h)
