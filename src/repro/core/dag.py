"""DAG-of-tasks data structures (paper §2.1, §4 definitions).

A job is a DAG G = {V, E}.  Each node is a task with a duration and a
d-dimensional resource demand (normalized so that one machine has capacity
1.0 in every dimension).  Tasks are grouped into *stages* (e.g. a map or a
reduce): tasks in a stage have similar durations / demands and identical
dependencies — the structural fact DAGPS leans on (§4.4, §6).

Bitset-based ancestor/descendant closures give O(n^2/64) reachability, which
the troublesome-task closure (§4.1), the subset split and NewLB (§6) all use.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Iterable, Sequence

import numpy as np

NRES = 4  # cores, memory, network, disk (paper §2.1)


def dag_digest(dag: "DAG") -> bytes:
    """Canonical 128-bit content digest of a DAG.

    The one digest shared by the simulator's schedule cache, the build
    service's dedup front (core/buildsvc.py) and bench harnesses:
    ``build_schedule`` is a deterministic function of DAG *content*, so
    equal digests may share one constructed schedule exactly.

    Covers everything construction reads — per-task duration, demand,
    stage and the dependency structure — and nothing it does not (names,
    cached closures).  Parent lists are hashed as sorted id sets: edge
    insertion order is presentation, not content (every consumer treats
    a parent row as a set), so permuted-but-equal inputs collide by
    design.  Task *ids* stay positional: schedules are id-indexed, so two
    DAGs must only collide when every id means the same task — permuting
    identical sibling tasks leaves all arrays (and the digest) unchanged,
    while permuting distinguishable tasks changes them.
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(np.int64(dag.n).tobytes())
    h.update(np.int64(dag.d).tobytes())
    h.update(dag.duration.tobytes())
    h.update(dag.demand.tobytes())
    h.update(np.asarray(dag.stage_of, dtype=np.int64).tobytes())
    for p in dag.parents:
        h.update(np.sort(np.asarray(p, dtype=np.int64)).tobytes())
        h.update(b";")
    return h.digest()


def _pack_reach(n: int, adj: Sequence[np.ndarray]) -> np.ndarray:
    """Transitive closure as packed uint64 bitsets.

    adj[i] lists *direct* predecessors of i, and i must be topologically
    ordered so that all predecessors of i have index < i.
    Returns reach[n, ceil(n/64)] where bit j of row i => j is a strict
    ancestor of i under adj.
    """
    words = (n + 63) // 64
    reach = np.zeros((n, words), dtype=np.uint64)
    for i in range(n):
        row = reach[i]
        for p in adj[i]:
            row |= reach[p]
            row[p >> 6] |= np.uint64(1) << np.uint64(p & 63)
    return reach


def _bit_test(bits: np.ndarray, j: int) -> bool:
    return bool((bits[j >> 6] >> np.uint64(j & 63)) & np.uint64(1))


def _mask_to_ids(mask: np.ndarray) -> np.ndarray:
    return np.nonzero(mask)[0]


@dataclasses.dataclass
class DAG:
    """A job DAG over tasks, with stage grouping.

    All arrays are indexed by task id 0..n-1 in topological order.
    """

    duration: np.ndarray              # (n,) float seconds
    demand: np.ndarray                # (n, d) float in [0, 1] per machine
    stage_of: np.ndarray              # (n,) int
    parents: list[np.ndarray]         # direct predecessors per task
    name: str = "dag"

    def __post_init__(self) -> None:
        self.duration = np.asarray(self.duration, dtype=np.float64)
        self.demand = np.atleast_2d(np.asarray(self.demand, dtype=np.float64))
        self.stage_of = np.asarray(self.stage_of, dtype=np.int64)
        n = self.n
        if not (len(self.demand) == len(self.stage_of) == len(self.parents) == n):
            raise ValueError("inconsistent DAG arrays")
        self.parents = [np.asarray(p, dtype=np.int64) for p in self.parents]
        for i, ps in enumerate(self.parents):
            if len(ps) and ps.max() >= i:
                raise ValueError("tasks must be topologically ordered")
        self.children: list[np.ndarray] = [np.empty(0, np.int64) for _ in range(n)]
        kids: list[list[int]] = [[] for _ in range(n)]
        for i, ps in enumerate(self.parents):
            for p in ps:
                kids[int(p)].append(i)
        self.children = [np.asarray(k, dtype=np.int64) for k in kids]
        self.n_stages = int(self.stage_of.max()) + 1 if n else 0
        self.stages: list[np.ndarray] = [
            np.nonzero(self.stage_of == s)[0] for s in range(self.n_stages)
        ]
        self._anc_bits: np.ndarray | None = None
        self._desc_bits: np.ndarray | None = None

    # ------------------------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.duration)

    @property
    def d(self) -> int:
        return self.demand.shape[1]

    @property
    def anc_bits(self) -> np.ndarray:
        if self._anc_bits is None:
            self._anc_bits = _pack_reach(self.n, self.parents)
        return self._anc_bits

    @property
    def desc_bits(self) -> np.ndarray:
        if self._desc_bits is None:
            # D[i, j] = "j is a descendant of i" = A[j, i]: transpose the
            # unpacked ancestor matrix and repack.
            n = self.n
            words = (n + 63) // 64
            anc = np.unpackbits(
                self.anc_bits.view(np.uint8), axis=1, bitorder="little"
            )[:, :n]
            packed = np.packbits(np.ascontiguousarray(anc.T), axis=1, bitorder="little")
            full = np.zeros((n, words * 8), dtype=np.uint8)
            full[:, : packed.shape[1]] = packed
            self._desc_bits = full.view(np.uint64)
        return self._desc_bits

    # -- set helpers (masks are (n,) bool) ------------------------------
    def ancestors_mask(self, ids: Iterable[int]) -> np.ndarray:
        mask = np.zeros(self.n, dtype=bool)
        bits = np.zeros_like(self.anc_bits[0])
        for i in ids:
            bits |= self.anc_bits[i]
        _bits_into_mask(bits, mask)
        return mask

    def descendants_mask(self, ids: Iterable[int]) -> np.ndarray:
        mask = np.zeros(self.n, dtype=bool)
        bits = np.zeros_like(self.desc_bits[0])
        for i in ids:
            bits |= self.desc_bits[i]
        _bits_into_mask(bits, mask)
        return mask

    def closure_mask(self, mask: np.ndarray) -> np.ndarray:
        """Closure(T) §4.1: T plus every task on a path between two T-tasks."""
        ids = _mask_to_ids(mask)
        if len(ids) == 0:
            return mask.copy()
        anc = self.ancestors_mask(ids)
        desc = self.descendants_mask(ids)
        return mask | (anc & desc)

    def split_subsets(self, t_mask: np.ndarray):
        """Given a *closed* T, return masks (T, O, P, C) per §4.1."""
        ids = _mask_to_ids(t_mask)
        anc = self.ancestors_mask(ids)
        desc = self.descendants_mask(ids)
        p_mask = anc & ~t_mask
        c_mask = desc & ~t_mask
        o_mask = ~(t_mask | p_mask | c_mask)
        return t_mask, o_mask, p_mask, c_mask

    # -- stage-level structure -------------------------------------------
    def stage_parents(self) -> list[set[int]]:
        sp: list[set[int]] = [set() for _ in range(self.n_stages)]
        for i in range(self.n):
            si = int(self.stage_of[i])
            for p in self.parents[i]:
                ps = int(self.stage_of[p])
                if ps != si:
                    sp[si].add(ps)
        return sp

    def work(self) -> float:
        """Total work: sum over tasks of duration * demand, maxed over resources."""
        return float((self.duration[:, None] * self.demand).sum(axis=0).max())

    def validate_order(self, order: Sequence[int]) -> bool:
        pos = {int(t): k for k, t in enumerate(order)}
        return all(
            pos[int(p)] < pos[i]
            for i in range(self.n)
            for p in self.parents[i]
        )


# ----------------------------------------------------------------------
# Graph mutation (dynamic DAGs): every op returns a NEW DAG plus a
# DagDelta describing the edit.  DAGs stay immutable values — digests,
# cached schedules and memo entries keyed by content never go stale.
# Reachability bits are carried over incrementally (new rows / inserted
# rows+columns / packed OR-propagation) instead of re-running the
# per-row python loop in _pack_reach over the whole graph.
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DagDelta:
    """Record of one mutation: old/new identity plus the touched surface.

    ``touched`` lists new-dag task ids whose content or edge set changed
    (including newly created tasks); ``id_map`` maps every old task id to
    its new id (-1 = removed).  ``digest`` is the canonical key for
    dedup'ing *edits* (BuildService.resubmit): two submissions of the
    same edit to the same base collide, different edits never do.
    """

    kind: str                  # append_tasks|append_stage|resize_stage|...
    base_digest: bytes         # dag_digest of the DAG the edit applied to
    new_digest: bytes          # dag_digest of the mutated DAG
    touched: np.ndarray        # new-dag ids with changed content/edges
    id_map: np.ndarray         # (old_n,) old id -> new id, -1 if removed

    @property
    def digest(self) -> bytes:
        h = hashlib.blake2b(digest_size=16)
        h.update(self.kind.encode())
        h.update(self.base_digest)
        h.update(self.new_digest)
        return h.digest()


def _grown_anc(old: "DAG", new: "DAG") -> np.ndarray | None:
    """Ancestor bitsets for a pure append: copy old rows, derive only the
    new ones (the _pack_reach recurrence, restricted to appended ids)."""
    if old._anc_bits is None:
        return None   # base never computed closures; stay lazy
    n, words = new.n, (new.n + 63) // 64
    anc = np.zeros((n, words), dtype=np.uint64)
    anc[: old.n, : old.anc_bits.shape[1]] = old.anc_bits
    for i in range(old.n, n):
        row = anc[i]
        for p in new.parents[i]:
            row |= anc[p]
            row[p >> 6] |= np.uint64(1) << np.uint64(p & 63)
    return anc


def _repack(mat: np.ndarray) -> np.ndarray:
    """Bool (n, n) reachability matrix -> packed uint64 (n, ceil(n/64))."""
    n = len(mat)
    words = (n + 63) // 64
    packed = np.packbits(np.ascontiguousarray(mat), axis=1, bitorder="little")
    full = np.zeros((n, words * 8), dtype=np.uint8)
    full[:, : packed.shape[1]] = packed
    return full.view(np.uint64)


def _unpack(bits: np.ndarray, n: int) -> np.ndarray:
    return np.unpackbits(bits.view(np.uint8), axis=1,
                         bitorder="little")[:, :n].astype(bool)


def append_tasks(
    dag: DAG,
    duration: Sequence[float],
    demand: Sequence[Sequence[float]],
    stage_of: Sequence[int],
    parents: Sequence[Sequence[int]],
) -> tuple[DAG, DagDelta]:
    """Append k tasks at ids n..n+k-1 (task arrival into a live job).

    New tasks may depend on any earlier task (existing or earlier-appended)
    and may open new stages.  Ancestor rows are extended incrementally;
    descendant bits are re-derived lazily (already vectorized).
    """
    base = dag_digest(dag)
    n, k = dag.n, len(duration)
    if k == 0:
        raise ValueError("append_tasks: nothing to append")
    for j, ps in enumerate(parents):
        ps = np.asarray(ps, dtype=np.int64)
        if len(ps) and ps.max() >= n + j:
            raise ValueError(
                "appended task may only depend on earlier tasks "
                "(topological order / cycle guard)")
    new = DAG(
        duration=np.concatenate([dag.duration, np.asarray(duration, np.float64)]),
        demand=np.vstack([dag.demand, np.atleast_2d(np.asarray(demand, np.float64))]),
        stage_of=np.concatenate([dag.stage_of, np.asarray(stage_of, np.int64)]),
        parents=list(dag.parents) + [np.sort(np.asarray(p, np.int64)) for p in parents],
        name=dag.name,
    )
    new._anc_bits = _grown_anc(dag, new)
    delta = DagDelta("append_tasks", base, dag_digest(new),
                     touched=np.arange(n, n + k, dtype=np.int64),
                     id_map=np.arange(n, dtype=np.int64))
    return new, delta


def append_stage(
    dag: DAG,
    q: int,
    duration: float,
    demand: Sequence[float],
    parent_stages: Sequence[int] = (),
) -> tuple[DAG, DagDelta]:
    """Append one new q-task stage depending all-to-all on parent stages."""
    par = (np.sort(np.concatenate([dag.stages[int(s)] for s in parent_stages]))
           if len(parent_stages) else np.empty(0, np.int64))
    new, delta = append_tasks(
        dag,
        duration=[float(duration)] * q,
        demand=[np.asarray(demand, np.float64)] * q,
        stage_of=[dag.n_stages] * q,
        parents=[par] * q,
    )
    return new, dataclasses.replace(delta, kind="append_stage")


def resize_stage(dag: DAG, stage: int, new_q: int) -> tuple[DAG, DagDelta]:
    """Grow or shrink a stage to new_q interchangeable tasks.

    Growth clones the stage's last member (same duration, demand, parents
    AND children) immediately after it, so anc(clone) == anc(template) and
    desc(clone) == desc(template): the reachability update is an insert of
    copied rows/columns, not a recompute.  Shrink removes the highest-id
    members; every child of a removed task must keep at least one parent
    in the stage (all-to-all stage semantics), else ValueError.
    """
    base = dag_digest(dag)
    if not (0 <= stage < dag.n_stages) or len(dag.stages[stage]) == 0:
        raise ValueError(f"no such stage {stage}")
    ids = dag.stages[stage]
    q = len(ids)
    if new_q < 1:
        raise ValueError("a stage must keep at least one task")
    if new_q == q:
        raise ValueError("resize_stage: size unchanged")
    n = dag.n
    if new_q > q:
        k = new_q - q
        tmpl = int(ids[-1])
        pos = tmpl + 1                  # clones sit right after the template
        id_map = np.arange(n, dtype=np.int64)
        id_map[pos:] += k
        clone_ids = np.arange(pos, pos + k, dtype=np.int64)
        tmpl_kids = {int(c) for c in dag.children[tmpl]}
        parents: list[np.ndarray] = []
        for t in range(n):
            ps = id_map[dag.parents[t]]
            if t in tmpl_kids:          # children adopt every clone too
                ps = np.concatenate([ps, clone_ids])
            parents.insert(id_map[t], np.sort(ps))
            if t == tmpl:
                for _ in range(k):
                    parents.append(np.sort(id_map[dag.parents[tmpl]]))
        ins = np.full(k, pos, dtype=np.int64)
        new = DAG(
            duration=np.insert(dag.duration, ins, dag.duration[tmpl]),
            demand=np.insert(dag.demand, ins, dag.demand[tmpl], axis=0),
            stage_of=np.insert(dag.stage_of, ins, stage),
            parents=parents,
            name=dag.name,
        )
        if dag._anc_bits is not None:
            mat = _unpack(dag.anc_bits, n)
            mat = np.insert(mat, ins, mat[tmpl], axis=0)      # anc(clone)
            col = np.repeat(mat[:, tmpl][:, None], k, axis=1)
            mat = np.insert(mat, ins, col, axis=1)            # desc(clone)
            new._anc_bits = _repack(mat)
        touched = np.sort(np.concatenate(
            [clone_ids, id_map[sorted(tmpl_kids)]])) if tmpl_kids else clone_ids
        delta = DagDelta("resize_stage", base, dag_digest(new),
                         touched=np.asarray(touched, np.int64), id_map=id_map)
        return new, delta
    # shrink: drop the highest-id members
    drop = ids[new_q:]
    dropset = {int(t) for t in drop}
    keepset = {int(t) for t in ids[:new_q]}
    for r in drop:
        for c in dag.children[int(r)]:
            if not any(int(p) in keepset for p in dag.parents[int(c)]):
                raise ValueError(
                    f"shrinking stage {stage} would orphan task {int(c)} "
                    "from its stage dependency")
    keep = np.setdiff1d(np.arange(n), drop)
    id_map = np.full(n, -1, dtype=np.int64)
    id_map[keep] = np.arange(len(keep))
    parents = [
        np.sort(id_map[[p for p in dag.parents[int(t)] if int(p) not in dropset]])
        for t in keep
    ]
    new = DAG(
        duration=dag.duration[keep].copy(),
        demand=dag.demand[keep].copy(),
        stage_of=dag.stage_of[keep].copy(),
        parents=parents,
        name=dag.name,
    )
    if dag._anc_bits is not None:
        mat = _unpack(dag.anc_bits, n)
        new._anc_bits = _repack(mat[np.ix_(keep, keep)])
    kids = sorted({int(id_map[c]) for r in drop for c in dag.children[int(r)]
                   if id_map[c] >= 0})
    delta = DagDelta("resize_stage", base, dag_digest(new),
                     touched=np.asarray(kids, np.int64), id_map=id_map)
    return new, delta


def scale_durations(
    dag: DAG, scale: float, ids: Sequence[int] | None = None,
    kind: str = "scale_durations",
) -> tuple[DAG, DagDelta]:
    """Rescale task durations; structure and reachability carry over as-is."""
    if scale <= 0:
        raise ValueError("scale must be positive")
    base = dag_digest(dag)
    dur = dag.duration.copy()
    which = np.arange(dag.n) if ids is None else np.asarray(ids, np.int64)
    dur[which] = np.maximum(dur[which] * scale, 1e-9)
    new = DAG(duration=dur, demand=dag.demand.copy(),
              stage_of=dag.stage_of.copy(),
              parents=[p.copy() for p in dag.parents], name=dag.name)
    new._anc_bits = dag._anc_bits     # structure untouched: share closures
    new._desc_bits = dag._desc_bits
    delta = DagDelta(kind, base, dag_digest(new),
                     touched=np.sort(which.astype(np.int64)),
                     id_map=np.arange(dag.n, dtype=np.int64))
    return new, delta


def retarget_deadline(dag: DAG, factor: float) -> tuple[DAG, DagDelta]:
    """Deadline pull-in/push-out: durations are budget-relative, so moving
    the deadline by 1/factor rescales every duration by ``factor``."""
    return scale_durations(dag, factor, kind="retarget_deadline")


def scale_speeds(
    dag: DAG, factor: float, ids: Sequence[int] | None = None,
) -> tuple[DAG, DagDelta]:
    """Machine-fleet speed edit: durations are normalized machine-seconds,
    so a fleet running ``factor``x faster divides durations by it."""
    return scale_durations(dag, 1.0 / factor, ids, kind="scale_speeds")


def add_dependency(dag: DAG, parent: int, child: int) -> tuple[DAG, DagDelta]:
    """Add edge parent -> child.  ``parent < child`` is required: ids are
    topological, so a back-edge either closes a cycle outright or breaks
    the id-order invariant every consumer relies on — rejected."""
    base = dag_digest(dag)
    parent, child = int(parent), int(child)
    if not (0 <= parent < dag.n and 0 <= child < dag.n):
        raise ValueError("no such task")
    if parent >= child:
        raise ValueError(
            f"edge {parent}->{child} violates topological id order "
            "(would introduce a cycle)")
    if parent in dag.parents[child]:
        raise ValueError(f"edge {parent}->{child} already exists")
    parents = [p.copy() for p in dag.parents]
    parents[child] = np.sort(np.append(parents[child], parent))
    new = DAG(duration=dag.duration.copy(), demand=dag.demand.copy(),
              stage_of=dag.stage_of.copy(), parents=parents, name=dag.name)
    if dag._anc_bits is not None:
        # all new reachability passes through child: fold parent's closure
        # into child's row, then OR child's row into its descendants'
        anc = dag.anc_bits.copy()
        anc[child] |= anc[parent]
        anc[child, parent >> 6] |= np.uint64(1) << np.uint64(parent & 63)
        has_child = (anc[:, child >> 6] >> np.uint64(child & 63)) & np.uint64(1)
        rows = np.nonzero(has_child.astype(bool))[0]
        anc[rows] |= anc[child]
        new._anc_bits = anc
    delta = DagDelta("add_dependency", base, dag_digest(new),
                     touched=np.asarray([child], np.int64),
                     id_map=np.arange(dag.n, dtype=np.int64))
    return new, delta


def _bits_to_ids(bits: np.ndarray) -> np.ndarray:
    ids = []
    for w, word in enumerate(bits):
        word = int(word)
        while word:
            b = word & -word
            ids.append((w << 6) + b.bit_length() - 1)
            word ^= b
    return np.asarray(ids, dtype=np.int64)


def _bits_into_mask(bits: np.ndarray, mask: np.ndarray) -> None:
    n = len(mask)
    unpacked = np.unpackbits(bits.view(np.uint8), bitorder="little")
    mask |= unpacked[:n].astype(bool)


def from_stage_graph(
    stage_tasks: Sequence[int],
    stage_durations: Sequence[float],
    stage_demands: Sequence[Sequence[float]],
    stage_deps: Sequence[Sequence[int]],
    name: str = "dag",
    rng: np.random.Generator | None = None,
    duration_jitter: float = 0.0,
    demand_jitter: float = 0.0,
) -> DAG:
    """Expand a stage-level graph into a task-level DAG.

    Every task of stage s depends on *all* tasks of each parent stage
    (all-to-all shuffle semantics, the common case in data-parallel DAGs).
    """
    n_stages = len(stage_tasks)
    order = _topo_stage_order(stage_deps, n_stages)
    task_ids: list[np.ndarray] = [np.empty(0, np.int64)] * n_stages
    durations: list[float] = []
    demands: list[np.ndarray] = []
    stage_of: list[int] = []
    parents: list[np.ndarray] = []
    rng = rng or np.random.default_rng(0)
    next_id = 0
    for s in order:
        q = int(stage_tasks[s])
        ids = np.arange(next_id, next_id + q, dtype=np.int64)
        task_ids[s] = ids
        next_id += q
        par = np.concatenate([task_ids[p] for p in stage_deps[s]]) if stage_deps[s] else np.empty(0, np.int64)
        base_dur = float(stage_durations[s])
        base_dem = np.asarray(stage_demands[s], dtype=np.float64)
        for _ in range(q):
            dur = base_dur * (1.0 + duration_jitter * float(rng.standard_normal())) if duration_jitter else base_dur
            dem = base_dem * (1.0 + demand_jitter * rng.standard_normal(base_dem.shape)) if demand_jitter else base_dem
            durations.append(max(dur, 1e-3))
            demands.append(np.clip(dem, 1e-4, 1.0))
            stage_of.append(s)
            parents.append(np.sort(par))
    return DAG(
        duration=np.asarray(durations),
        demand=np.asarray(demands),
        stage_of=np.asarray(stage_of),
        parents=parents,
        name=name,
    )


def _topo_stage_order(stage_deps: Sequence[Sequence[int]], n: int) -> list[int]:
    state = [0] * n
    out: list[int] = []

    def visit(s: int) -> None:
        if state[s] == 2:
            return
        if state[s] == 1:
            raise ValueError("cycle in stage graph")
        state[s] = 1
        for p in stage_deps[s]:
            visit(int(p))
        state[s] = 2
        out.append(s)

    for s in range(n):
        visit(s)
    return out
