"""DAG-of-tasks data structures (paper §2.1, §4 definitions).

A job is a DAG G = {V, E}.  Each node is a task with a duration and a
d-dimensional resource demand (normalized so that one machine has capacity
1.0 in every dimension).  Tasks are grouped into *stages* (e.g. a map or a
reduce): tasks in a stage have similar durations / demands and identical
dependencies — the structural fact DAGPS leans on (§4.4, §6).

Bitset-based ancestor/descendant closures give O(n^2/64) reachability, which
the troublesome-task closure (§4.1), the subset split and NewLB (§6) all use.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Iterable, Sequence

import numpy as np

NRES = 4  # cores, memory, network, disk (paper §2.1)


def dag_digest(dag: "DAG") -> bytes:
    """Canonical 128-bit content digest of a DAG.

    The one digest shared by the simulator's schedule cache, the build
    service's dedup front (core/buildsvc.py) and bench harnesses:
    ``build_schedule`` is a deterministic function of DAG *content*, so
    equal digests may share one constructed schedule exactly.

    Covers everything construction reads — per-task duration, demand,
    stage and the dependency structure — and nothing it does not (names,
    cached closures).  Parent lists are hashed as sorted id sets: edge
    insertion order is presentation, not content (every consumer treats
    a parent row as a set), so permuted-but-equal inputs collide by
    design.  Task *ids* stay positional: schedules are id-indexed, so two
    DAGs must only collide when every id means the same task — permuting
    identical sibling tasks leaves all arrays (and the digest) unchanged,
    while permuting distinguishable tasks changes them.
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(np.int64(dag.n).tobytes())
    h.update(np.int64(dag.d).tobytes())
    h.update(dag.duration.tobytes())
    h.update(dag.demand.tobytes())
    h.update(np.asarray(dag.stage_of, dtype=np.int64).tobytes())
    for p in dag.parents:
        h.update(np.sort(np.asarray(p, dtype=np.int64)).tobytes())
        h.update(b";")
    return h.digest()


def _pack_reach(n: int, adj: Sequence[np.ndarray]) -> np.ndarray:
    """Transitive closure as packed uint64 bitsets.

    adj[i] lists *direct* predecessors of i, and i must be topologically
    ordered so that all predecessors of i have index < i.
    Returns reach[n, ceil(n/64)] where bit j of row i => j is a strict
    ancestor of i under adj.
    """
    words = (n + 63) // 64
    reach = np.zeros((n, words), dtype=np.uint64)
    for i in range(n):
        row = reach[i]
        for p in adj[i]:
            row |= reach[p]
            row[p >> 6] |= np.uint64(1) << np.uint64(p & 63)
    return reach


def _bit_test(bits: np.ndarray, j: int) -> bool:
    return bool((bits[j >> 6] >> np.uint64(j & 63)) & np.uint64(1))


def _mask_to_ids(mask: np.ndarray) -> np.ndarray:
    return np.nonzero(mask)[0]


@dataclasses.dataclass
class DAG:
    """A job DAG over tasks, with stage grouping.

    All arrays are indexed by task id 0..n-1 in topological order.
    """

    duration: np.ndarray              # (n,) float seconds
    demand: np.ndarray                # (n, d) float in [0, 1] per machine
    stage_of: np.ndarray              # (n,) int
    parents: list[np.ndarray]         # direct predecessors per task
    name: str = "dag"

    def __post_init__(self) -> None:
        self.duration = np.asarray(self.duration, dtype=np.float64)
        self.demand = np.atleast_2d(np.asarray(self.demand, dtype=np.float64))
        self.stage_of = np.asarray(self.stage_of, dtype=np.int64)
        n = self.n
        if not (len(self.demand) == len(self.stage_of) == len(self.parents) == n):
            raise ValueError("inconsistent DAG arrays")
        self.parents = [np.asarray(p, dtype=np.int64) for p in self.parents]
        for i, ps in enumerate(self.parents):
            if len(ps) and ps.max() >= i:
                raise ValueError("tasks must be topologically ordered")
        self.children: list[np.ndarray] = [np.empty(0, np.int64) for _ in range(n)]
        kids: list[list[int]] = [[] for _ in range(n)]
        for i, ps in enumerate(self.parents):
            for p in ps:
                kids[int(p)].append(i)
        self.children = [np.asarray(k, dtype=np.int64) for k in kids]
        self.n_stages = int(self.stage_of.max()) + 1 if n else 0
        self.stages: list[np.ndarray] = [
            np.nonzero(self.stage_of == s)[0] for s in range(self.n_stages)
        ]
        self._anc_bits: np.ndarray | None = None
        self._desc_bits: np.ndarray | None = None

    # ------------------------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.duration)

    @property
    def d(self) -> int:
        return self.demand.shape[1]

    @property
    def anc_bits(self) -> np.ndarray:
        if self._anc_bits is None:
            self._anc_bits = _pack_reach(self.n, self.parents)
        return self._anc_bits

    @property
    def desc_bits(self) -> np.ndarray:
        if self._desc_bits is None:
            # D[i, j] = "j is a descendant of i" = A[j, i]: transpose the
            # unpacked ancestor matrix and repack.
            n = self.n
            words = (n + 63) // 64
            anc = np.unpackbits(
                self.anc_bits.view(np.uint8), axis=1, bitorder="little"
            )[:, :n]
            packed = np.packbits(np.ascontiguousarray(anc.T), axis=1, bitorder="little")
            full = np.zeros((n, words * 8), dtype=np.uint8)
            full[:, : packed.shape[1]] = packed
            self._desc_bits = full.view(np.uint64)
        return self._desc_bits

    # -- set helpers (masks are (n,) bool) ------------------------------
    def ancestors_mask(self, ids: Iterable[int]) -> np.ndarray:
        mask = np.zeros(self.n, dtype=bool)
        bits = np.zeros_like(self.anc_bits[0])
        for i in ids:
            bits |= self.anc_bits[i]
        _bits_into_mask(bits, mask)
        return mask

    def descendants_mask(self, ids: Iterable[int]) -> np.ndarray:
        mask = np.zeros(self.n, dtype=bool)
        bits = np.zeros_like(self.desc_bits[0])
        for i in ids:
            bits |= self.desc_bits[i]
        _bits_into_mask(bits, mask)
        return mask

    def closure_mask(self, mask: np.ndarray) -> np.ndarray:
        """Closure(T) §4.1: T plus every task on a path between two T-tasks."""
        ids = _mask_to_ids(mask)
        if len(ids) == 0:
            return mask.copy()
        anc = self.ancestors_mask(ids)
        desc = self.descendants_mask(ids)
        return mask | (anc & desc)

    def split_subsets(self, t_mask: np.ndarray):
        """Given a *closed* T, return masks (T, O, P, C) per §4.1."""
        ids = _mask_to_ids(t_mask)
        anc = self.ancestors_mask(ids)
        desc = self.descendants_mask(ids)
        p_mask = anc & ~t_mask
        c_mask = desc & ~t_mask
        o_mask = ~(t_mask | p_mask | c_mask)
        return t_mask, o_mask, p_mask, c_mask

    # -- stage-level structure -------------------------------------------
    def stage_parents(self) -> list[set[int]]:
        sp: list[set[int]] = [set() for _ in range(self.n_stages)]
        for i in range(self.n):
            si = int(self.stage_of[i])
            for p in self.parents[i]:
                ps = int(self.stage_of[p])
                if ps != si:
                    sp[si].add(ps)
        return sp

    def work(self) -> float:
        """Total work: sum over tasks of duration * demand, maxed over resources."""
        return float((self.duration[:, None] * self.demand).sum(axis=0).max())

    def validate_order(self, order: Sequence[int]) -> bool:
        pos = {int(t): k for k, t in enumerate(order)}
        return all(
            pos[int(p)] < pos[i]
            for i in range(self.n)
            for p in self.parents[i]
        )


def _bits_to_ids(bits: np.ndarray) -> np.ndarray:
    ids = []
    for w, word in enumerate(bits):
        word = int(word)
        while word:
            b = word & -word
            ids.append((w << 6) + b.bit_length() - 1)
            word ^= b
    return np.asarray(ids, dtype=np.int64)


def _bits_into_mask(bits: np.ndarray, mask: np.ndarray) -> None:
    n = len(mask)
    unpacked = np.unpackbits(bits.view(np.uint8), bitorder="little")
    mask |= unpacked[:n].astype(bool)


def from_stage_graph(
    stage_tasks: Sequence[int],
    stage_durations: Sequence[float],
    stage_demands: Sequence[Sequence[float]],
    stage_deps: Sequence[Sequence[int]],
    name: str = "dag",
    rng: np.random.Generator | None = None,
    duration_jitter: float = 0.0,
    demand_jitter: float = 0.0,
) -> DAG:
    """Expand a stage-level graph into a task-level DAG.

    Every task of stage s depends on *all* tasks of each parent stage
    (all-to-all shuffle semantics, the common case in data-parallel DAGs).
    """
    n_stages = len(stage_tasks)
    order = _topo_stage_order(stage_deps, n_stages)
    task_ids: list[np.ndarray] = [np.empty(0, np.int64)] * n_stages
    durations: list[float] = []
    demands: list[np.ndarray] = []
    stage_of: list[int] = []
    parents: list[np.ndarray] = []
    rng = rng or np.random.default_rng(0)
    next_id = 0
    for s in order:
        q = int(stage_tasks[s])
        ids = np.arange(next_id, next_id + q, dtype=np.int64)
        task_ids[s] = ids
        next_id += q
        par = np.concatenate([task_ids[p] for p in stage_deps[s]]) if stage_deps[s] else np.empty(0, np.int64)
        base_dur = float(stage_durations[s])
        base_dem = np.asarray(stage_demands[s], dtype=np.float64)
        for _ in range(q):
            dur = base_dur * (1.0 + duration_jitter * float(rng.standard_normal())) if duration_jitter else base_dur
            dem = base_dem * (1.0 + demand_jitter * rng.standard_normal(base_dem.shape)) if demand_jitter else base_dem
            durations.append(max(dur, 1e-3))
            demands.append(np.clip(dem, 1e-4, 1.0))
            stage_of.append(s)
            parents.append(np.sort(par))
    return DAG(
        duration=np.asarray(durations),
        demand=np.asarray(demands),
        stage_of=np.asarray(stage_of),
        parents=parents,
        name=name,
    )


def _topo_stage_order(stage_deps: Sequence[Sequence[int]], n: int) -> list[int]:
    state = [0] * n
    out: list[int] = []

    def visit(s: int) -> None:
        if state[s] == 2:
            return
        if state[s] == 1:
            raise ValueError("cycle in stage graph")
        state[s] = 1
        for p in stage_deps[s]:
            visit(int(p))
        state[s] = 2
        out.append(s)

    for s in range(n):
        visit(s)
    return out
