"""Seeded fault injection + the recovery policies the scheduler runs under.

The scheduler stack is grown toward a real distributed deployment
(ROADMAP: scheduler-as-a-service) where shard eligibility launches, build
workers and accelerator kernels are separate processes that hang, crash
and misbehave.  This module makes those failures *first-class test
inputs*: a `FaultPlan` is a seeded, deterministic description of what
breaks where, injected at four named seams —

  ===================  =====================================  ==========
  seam                 injected at                            recovery
  ===================  =====================================  ==========
  ``shard_launch``     `ShardedMatcher` per-shard batched     retry w/ backoff,
                       eligibility launch (raise / hang)      quarantine -> all-
                                                              eligible mask (exact)
  ``build_worker``     `BuildService` worker executing        retry w/ backoff,
                       ``_build_slim`` (raise / crash)        digest quarantine,
                                                              inline fallback (exact)
  ``kernel_impl``      kernel dispatch of a non-numpy impl    sticky demotion down
                       (raise)                                the impl chain (exact)
  ``heartbeat``        simulated machine heartbeat            suspicion -> declared
                       (drop / delay)                         lost -> requeue ->
                                                              rejoin (lossy)
  ``memo``             construction-memo entry lookup         checksum-validated
                       (corrupt / drop)                       entries: a bad or
                                                              evicted entry is a
                                                              miss -> live search
                                                              (exact)
  ``comm_send``        service comm layer, one message send   seq-numbered idempo-
                       (drop / delay / dup)                   tent channels: acks,
                                                              capped-backoff re-
                                                              transmit, dup/reorder
                                                              gating (exact)
  ``agent``            worker-agent process                   lease reclaim on
                       (crash / partition)                    heartbeat silence ->
                                                              requeue -> rejoin
                                                              (lossy)
  ===================  =====================================  ==========

The code-seam recoveries are **decision-exact**: shard quarantine
substitutes the conservative all-eligible mask, which is a sound
superset of the real eligibility columns (`machines_with_candidates`
only ever *skips* provably-idle machines — PR 4's soundness argument),
so the matcher visits more machines but picks identically; build retries
and the inline fallback recompute the same pure function of DAG content;
kernel demotion lands on the always-available numpy oracle that defines
correct output.  Heartbeat loss genuinely changes cluster state and is
the one *lossy* seam (documented in docs/architecture.md).

Determinism: every probabilistic injection decision is a pure function
of (plan seed, spec index, seam, call context) via a keyed blake2b hash
— never Python's salted ``hash()`` — so a plan fires at the same call
sites regardless of thread interleaving, process boundaries or replay
order.  ``REPRO_FAULTS`` carries a plan into worker processes by env.

Plan spec grammar (env var and `FaultPlan.parse`)::

    seed=7;shard_launch:raise@0.3;shard_launch:hang@0.1,delay=0.2;
    build_worker:crash@1.0,attempt_lt=2;heartbeat:drop@0.05

i.e. ``;``-separated clauses, each ``seam[:kind][@prob][,key=value...]``
where extra keys are either spec knobs (``delay``, ``count`` = max
injections) or context match filters (``shard=0``, ``attempt_lt=2`` —
the ``_lt`` suffix matches when ctx[key] < value).
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import threading
import time
from contextlib import contextmanager

#: env var carrying a plan spec string into every process of a run
FAULTS_ENV = "REPRO_FAULTS"

SEAMS = ("shard_launch", "build_worker", "kernel_impl", "heartbeat", "memo",
         "comm_send", "agent")
#: seams whose recovery reproduces the fault-free decisions bit-for-bit
#: (comm_send qualifies: retransmit + sequence gating make any delivered
#: schedule of dups/reorders/drops collapse to the clean-delivery one)
EXACT_SEAMS = frozenset({"shard_launch", "build_worker", "kernel_impl",
                         "memo", "comm_send"})
KINDS = ("raise", "hang", "crash", "drop", "delay", "corrupt", "dup",
         "partition", "oom", "misaligned")


class InjectedFault(RuntimeError):
    """The exception raised by ``raise``-kind injections (and nothing
    else), so recovery paths can be asserted against real bugs."""

    def __init__(self, seam: str, ctx: dict):
        super().__init__(f"injected fault at seam {seam!r} ({ctx})")
        self.seam = seam
        self.ctx = ctx


class SimulatedOOM(InjectedFault):
    """``oom``-kind injection: models a device allocator failure inside a
    kernel impl (the pallas interpret path has no real HBM to exhaust);
    caught by dispatch like any impl error -> sticky demotion."""


class SimulatedMisalignedGrid(InjectedFault):
    """``misaligned``-kind injection: models a grid/block-shape mismatch
    raised at kernel trace time; recovery is identical to ``oom``."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injection clause: where, what, how often, and to whom."""

    seam: str
    kind: str = "raise"
    prob: float = 1.0
    delay: float = 0.25            # hang sleep / heartbeat delay seconds
    max_count: int | None = None   # stop after this many injections
    #: context equality filters; a ``key_lt`` entry matches ctx[key] < v
    match: tuple[tuple[str, object], ...] = ()

    def __post_init__(self):
        if self.seam not in SEAMS:
            raise ValueError(f"unknown fault seam {self.seam!r}; have {SEAMS}")
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; have {KINDS}")

    def matches(self, ctx: dict) -> bool:
        for k, v in self.match:
            if k.endswith("_lt"):
                got = ctx.get(k[:-3])
                if got is None or not got < v:
                    return False
            elif ctx.get(k) != v:
                return False
        return True


def _parse_value(raw: str):
    try:
        return int(raw)
    except ValueError:
        pass
    try:
        return float(raw)
    except ValueError:
        return raw


class FaultPlan:
    """An ordered set of `FaultSpec` clauses + a seed + firing stats.

    ``query`` returns the first matching spec that decides to fire for a
    call context (recording it in ``stats``); the caller interprets the
    spec's *kind*.  ``maybe_fail`` is the common interpretation for code
    seams: raise `InjectedFault`, sleep, or kill the process.
    """

    def __init__(self, specs: tuple | list = (), seed: int = 0):
        self.specs = tuple(specs)
        self.seed = int(seed)
        self._fired = [0] * len(self.specs)
        self._lock = threading.Lock()
        self.stats: dict[str, int] = {}

    # -- construction ---------------------------------------------------

    @classmethod
    def parse(cls, text: str) -> "FaultPlan":
        """Plan from the spec grammar (module docstring); '' = empty."""
        seed = 0
        specs = []
        for clause in text.split(";"):
            clause = clause.strip()
            if not clause:
                continue
            if clause.startswith("seed="):
                seed = int(clause[5:])
                continue
            head, *opts = clause.split(",")
            prob = 1.0
            if "@" in head:
                head, p = head.rsplit("@", 1)
                prob = float(p)
            seam, _, kind = head.partition(":")
            kw: dict = {"seam": seam.strip(), "prob": prob}
            if kind.strip():
                kw["kind"] = kind.strip()
            match = []
            for opt in opts:
                k, _, v = opt.partition("=")
                k, val = k.strip(), _parse_value(v.strip())
                if k == "delay":
                    kw["delay"] = float(val)
                elif k == "count":
                    kw["max_count"] = int(val)
                else:
                    match.append((k, val))
            kw["match"] = tuple(match)
            specs.append(FaultSpec(**kw))
        return cls(specs, seed=seed)

    def describe(self) -> str:
        """Round-trippable spec string (``parse(describe())`` == plan)."""
        parts = [f"seed={self.seed}"]
        for sp in self.specs:
            s = f"{sp.seam}:{sp.kind}@{sp.prob:g}"
            if sp.delay != 0.25:
                s += f",delay={sp.delay:g}"
            if sp.max_count is not None:
                s += f",count={sp.max_count}"
            for k, v in sp.match:
                s += f",{k}={v}"
            parts.append(s)
        return ";".join(parts)

    def is_exact_recoverable(self) -> bool:
        """True iff every seam's recovery is decision-exact."""
        return all(sp.seam in EXACT_SEAMS for sp in self.specs)

    # -- firing decisions ----------------------------------------------

    def _u01(self, idx: int, seam: str, ctx: dict) -> float:
        key = repr((self.seed, idx, seam, sorted(ctx.items()))).encode()
        h = hashlib.blake2b(key, digest_size=8).digest()
        return int.from_bytes(h, "big") / 2.0 ** 64

    def query(self, seam: str, **ctx) -> FaultSpec | None:
        """First spec that fires for this call, else None (stats-counted)."""
        for i, sp in enumerate(self.specs):
            if sp.seam != seam or not sp.matches(ctx):
                continue
            if sp.prob < 1.0 and self._u01(i, seam, ctx) >= sp.prob:
                continue
            with self._lock:
                if sp.max_count is not None and self._fired[i] >= sp.max_count:
                    continue
                self._fired[i] += 1
                k = f"{seam}.{sp.kind}"
                self.stats[k] = self.stats.get(k, 0) + 1
            return sp
        return None

    def maybe_fail(self, seam: str, **ctx) -> None:
        """Act out a firing spec at a code seam.

        raise/drop -> `InjectedFault`; oom/misaligned -> their simulated
        subclasses; hang/delay -> sleep ``delay`` wall-seconds; crash ->
        ``os._exit`` (worker-process seams only).  Kinds that only make
        sense to ``query``-interpreting seams (dup, partition, corrupt)
        are no-ops here.
        """
        sp = self.query(seam, **ctx)
        if sp is None:
            return
        if sp.kind in ("raise", "drop"):
            raise InjectedFault(seam, ctx)
        if sp.kind == "oom":
            raise SimulatedOOM(seam, ctx)
        if sp.kind == "misaligned":
            raise SimulatedMisalignedGrid(seam, ctx)
        if sp.kind in ("hang", "delay"):
            time.sleep(max(sp.delay, 0.0))
            return
        if sp.kind == "crash":
            os._exit(13)                      # crash: hard worker death

    def snapshot(self) -> dict[str, int]:
        with self._lock:
            return dict(self.stats)


@dataclasses.dataclass
class RecoveryPolicy:
    """Shared knobs of the degraded-mode recoveries (wall-clock units).

    One policy object covers the sharded matcher (launch timeout/retry/
    quarantine/probe) and the build service (retry budget + backoff);
    `SimConfig.recovery` threads it through both.
    """

    launch_timeout: float | None = 30.0  # per shard-launch attempt; None = no cap
    launch_retries: int = 2              # extra attempts after the first
    backoff: float = 0.05                # base of the capped exponential backoff
    backoff_cap: float = 1.0
    quarantine_after: int = 3            # consecutive shard-launch failures
    #: quarantined-shard probe cadence.  ``probe_every`` counts waves and
    #: acts as a *floor* (never probe more often than every N waves);
    #: ``probe_secs`` is the wall-clock trigger, so long waves cannot
    #: starve probes — a shard is probed once max(probe_every waves,
    #: probe_secs seconds) has elapsed, whichever is FIRST beyond the
    #: 1-wave minimum.  ``probe_secs=None`` restores pure wave counting.
    probe_every: int = 50
    probe_secs: float | None = 30.0
    build_retries: int = 3               # pool attempts before inline fallback
    #: service RPC reliability (svc/comm.py Channel): first retransmit of
    #: an unacked message after ``rpc_timeout``, then exponential backoff
    #: capped at ``backoff_cap``.  The agent reconnect loop reuses
    #: ``backoff``/``backoff_cap`` and additionally caps every wait at
    #: ``probe_secs`` so a long backoff can never starve rejoin.
    rpc_timeout: float = 0.25


# ----------------------------------------------------------------------
# ambient plan (process-wide, env-seeded) + thread-local suppression
# ----------------------------------------------------------------------

_ACTIVE: FaultPlan | None = None
_ENV_CACHE: tuple[str, FaultPlan] | None = None
_TLS = threading.local()


def install(plan: FaultPlan | str | None) -> FaultPlan | None:
    """Set the process-wide plan (str = spec grammar; None = env only)."""
    global _ACTIVE
    _ACTIVE = coerce(plan)
    return _ACTIVE


def uninstall() -> None:
    install(None)


def coerce(plan: "FaultPlan | str | None") -> FaultPlan | None:
    if plan is None or isinstance(plan, FaultPlan):
        return plan
    return FaultPlan.parse(plan)


def active_plan() -> FaultPlan | None:
    """The installed plan, else one parsed from ``REPRO_FAULTS``.

    The env fallback is what carries a plan into build-worker processes:
    children inherit the environment, and the parse is cached per raw
    value so the dispatch-hot path stays one dict probe.
    """
    if _ACTIVE is not None:
        return _ACTIVE
    raw = os.environ.get(FAULTS_ENV, "")
    if not raw:
        return None
    global _ENV_CACHE
    if _ENV_CACHE is None or _ENV_CACHE[0] != raw:
        _ENV_CACHE = (raw, FaultPlan.parse(raw))
    return _ENV_CACHE[1]


@contextmanager
def scope(plan: FaultPlan | str | None):
    """Install a plan for a block, restoring the previous one after.

    ``scope(FaultPlan())`` (an empty plan) masks any ambient env plan —
    the way tests pin a fault-free baseline under a CI smoke plan.
    """
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = coerce(plan)
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = prev


@contextmanager
def suppressed(*seams: str):
    """Disable seams on this thread (e.g. the inline build fallback —
    the trusted final resort must not itself be injected)."""
    prev = getattr(_TLS, "sup", frozenset())
    _TLS.sup = prev | frozenset(seams)
    try:
        yield
    finally:
        _TLS.sup = prev


def _is_suppressed(seam: str) -> bool:
    return seam in getattr(_TLS, "sup", ())


def query(seam: str, **ctx) -> FaultSpec | None:
    """Ask the ambient plan whether this call should fault (no action)."""
    plan = active_plan()
    if plan is None or _is_suppressed(seam):
        return None
    return plan.query(seam, **ctx)


def maybe_fail(seam: str, **ctx) -> None:
    """Act out the ambient plan's decision at a code seam (no-op when no
    plan is active or the seam is suppressed on this thread)."""
    plan = active_plan()
    if plan is not None and not _is_suppressed(seam):
        plan.maybe_fail(seam, **ctx)
