"""Online multi-DAG scheduler (paper §5, Fig. 8).

Reconciles discordant objectives when many jobs share the cluster:
  * each job's *preferred schedule* (priScore from the offline builder §4),
  * multi-resource packing (pScore = dot(demand, available), with a remote
    penalty for locality-sensitive tasks),
  * judicious overbooking of fungible resources (oScore),
  * SRPT to lower average JCT (eta * srpt_j),
  * bounded unfairness via deficit counters (kappa * C), pluggable fairness
    f() — slot fairness or DRF.

`Matcher.find_tasks_for_machine` is FindAppropriateTasksForMachine with
bundling: it returns a *set* of tasks to start on the machine in one
heartbeat (§7.2).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Sequence

import numpy as np

from .engine import packing

# resource dims (cores, memory, network, disk); network+disk are fungible —
# they can be overbooked at the price of slowdown, cores/memory cannot.
FUNGIBLE = (2, 3)
RIGID = (0, 1)


@dataclasses.dataclass
class PendingTask:
    job_id: int
    task_id: int
    demand: np.ndarray
    duration: float
    pri_score: float = 1.0
    locality: int = -1          # preferred machine id, -1 = none


@dataclasses.dataclass
class JobView:
    """What the matcher needs to know about a job (AM -> RM interface §7)."""
    job_id: int
    group: int                 # jobgroup / queue for fairness
    srpt: float                # remaining work: sum duration * |demands|


def slot_fairness(demand: np.ndarray) -> float:
    """f() = 1: slot fairness."""
    return 1.0


def drf_fairness(demand: np.ndarray) -> float:
    """f() = dominant share of the task's demand: DRF."""
    return float(np.max(demand))


@dataclasses.dataclass
class MatcherConfig:
    eta_m: float = 0.2             # paper §8.5: m in [0.1, 0.3], rec 0.2
    remote_penalty: float = 0.8    # rp (§8.5)
    kappa: float = 0.1             # unfairness bound as a fraction of C
    max_overbook: float = 1.25     # cap on fungible-resource overbooking
    fairness: Callable[[np.ndarray], float] = slot_fairness
    use_priority: bool = True      # use the preferred-schedule priScore
    use_packing: bool = True       # use pScore packing (else FIFO-ish)
    use_srpt: bool = True
    use_overbooking: bool = True
    bundle_limit: int = 64         # max tasks matched per heartbeat
    # dims the scheduler *checks* when fitting.  Tez/CP-style schedulers only
    # know cores+memory (0, 1); ignoring network/disk over-allocates them,
    # which the simulator charges back as a slowdown (Fig. 11 discussion).
    fit_dims: tuple[int, ...] = (0, 1, 2, 3)


class DeficitCounters:
    """Bounded unfairness via deficit counters (§5, [64])."""

    def __init__(self, shares: dict[int, float], capacity: float, kappa: float):
        total = sum(shares.values()) or 1.0
        self.share = {g: s / total for g, s in shares.items()}
        self.deficit = {g: 0.0 for g in shares}
        self.capacity = capacity
        self.kappa = kappa

    def most_deprived(self) -> tuple[int | None, float]:
        if not self.deficit:
            return None, 0.0
        g = max(self.deficit, key=lambda g: self.deficit[g])
        return g, self.deficit[g]

    def must_serve(self) -> int | None:
        g, d = self.most_deprived()
        if g is not None and d >= self.kappa * self.capacity:
            return g
        return None

    def allocated(self, group: int, weight: float) -> None:
        for g in self.deficit:
            self.deficit[g] += self.share[g] * weight
        self.deficit[group] -= weight

    def set_groups(self, shares: dict[int, float]) -> None:
        total = sum(shares.values()) or 1.0
        self.share = {g: s / total for g, s in shares.items()}
        for g in shares:
            self.deficit.setdefault(g, 0.0)
        for g in list(self.deficit):
            if g not in shares:
                del self.deficit[g]

    def jain_index(self, usage: dict[int, float]) -> float:
        """Jain's fairness index over normalized usages (Table 4)."""
        xs = np.array([usage.get(g, 0.0) / max(self.share[g], 1e-12) for g in self.share])
        if xs.sum() <= 0:
            return 1.0
        return float(xs.sum() ** 2 / (len(xs) * (xs ** 2).sum()))


class Matcher:
    """FindAppropriateTasksForMachine (Fig. 8) with bundling."""

    def __init__(self, cfg: MatcherConfig, capacity: float, shares: dict[int, float]):
        self.cfg = cfg
        self.deficits = DeficitCounters(shares, capacity, cfg.kappa)
        self._ema_score = 1.0
        self._ema_srpt = 1.0

    @property
    def eta(self) -> float:
        if not self.cfg.use_srpt:
            return 0.0
        return self.cfg.eta_m * self._ema_score / max(self._ema_srpt, 1e-12)

    def _observe(self, score: float, srpt: float) -> None:
        a = 0.05
        self._ema_score = (1 - a) * self._ema_score + a * score
        self._ema_srpt = (1 - a) * self._ema_srpt + a * max(srpt, 1e-12)

    def find_tasks_for_machine(
        self,
        machine_id: int,
        avail: np.ndarray,
        tasks: Sequence[PendingTask],
        jobs: dict[int, JobView],
    ) -> list[tuple[PendingTask, bool]]:
        """Returns [(task, overbooked)] to start now on this machine.

        Vectorized over candidates: each bundling iteration is a handful of
        numpy ops on (n_tasks, d) arrays.
        """
        cfg = self.cfg
        if not tasks:
            return []
        avail = avail.astype(np.float64).copy()
        dem = np.stack([t.demand for t in tasks])           # (n, d)
        pri = (np.array([t.pri_score for t in tasks])
               if cfg.use_priority else np.ones(len(tasks)))
        srpt = np.array([jobs[t.job_id].srpt for t in tasks])
        grp = np.array([jobs[t.job_id].group for t in tasks])
        rp = np.array([
            cfg.remote_penalty if (t.locality >= 0 and t.locality != machine_id) else 1.0
            for t in tasks
        ])
        fd = np.asarray(cfg.fit_dims)
        rigid = np.asarray([r for r in RIGID if r in cfg.fit_dims], dtype=int)
        fung = np.asarray([f for f in FUNGIBLE if f in cfg.fit_dims], dtype=int)
        taken = np.zeros(len(tasks), dtype=bool)
        picked: list[tuple[PendingTask, bool]] = []
        while len(picked) < cfg.bundle_limit:
            fits = packing.fits_mask(avail, dem, dims=fd)
            if cfg.use_overbooking:
                # rigid dims must really fit; fungible dims may overshoot by
                # the bounded overbooking allowance
                over = (~fits
                        & packing.fits_mask(avail, dem, dims=rigid)
                        & packing.fits_mask(avail, dem, dims=fung,
                                            slack=cfg.max_overbook - 1.0))
            else:
                over = np.zeros(len(tasks), dtype=bool)
            eligible = (fits | over) & ~taken
            must_group = self.deficits.must_serve()
            if must_group is not None and (eligible & (grp == must_group)).any():
                eligible &= grp == must_group
            if not eligible.any():
                break
            if cfg.use_packing:
                dot = packing.pack_score(avail, dem, clip=True) * rp
            else:
                dot = rp.copy()
            if len(fung):
                overshoot = np.clip((dem[:, fung] - avail[fung]).max(axis=1), 0.0, None)
            else:
                overshoot = np.zeros(len(tasks))
            base = np.where(fits, dot, dot * np.maximum(1.0 - overshoot, 0.05))
            perf = pri * base - self.eta * srpt
            # lexicographic: any fitting task beats any overbooked one
            pool = eligible & fits if (eligible & fits).any() else eligible
            score = np.where(pool, perf, -np.inf)
            i = int(np.argmax(score))
            if not np.isfinite(score[i]):
                break
            t = tasks[i]
            taken[i] = True
            picked.append((t, bool(over[i])))
            self._observe(float(pri[i] * base[i]), float(srpt[i]))
            avail -= t.demand
            np.clip(avail, 0.0, None, out=avail)
            self.deficits.allocated(jobs[t.job_id].group, cfg.fairness(t.demand))
        return picked
