"""Online multi-DAG scheduler (paper §5, Fig. 8).

Reconciles discordant objectives when many jobs share the cluster:
  * each job's *preferred schedule* (priScore from the offline builder §4),
  * multi-resource packing (pScore = dot(demand, available), with a remote
    penalty for locality-sensitive tasks),
  * judicious overbooking of fungible resources (oScore),
  * SRPT to lower average JCT (eta * srpt_j),
  * bounded unfairness via deficit counters (kappa * C), pluggable fairness
    f() — slot fairness or DRF.

`Matcher.match_batch` is FindAppropriateTasksForMachine with bundling: it
returns a *set* of tasks to start on the machine in one heartbeat (§7.2),
scored over the structure-of-arrays `CandidateBatch` columns that the
persistent `TaskPool` maintains incrementally (no per-heartbeat object
rebuilds; see "online data path" in docs/architecture.md).
`Matcher.find_tasks_for_machine` is the object-list compatibility wrapper
over the same core.

The bundling loop's own float ops stay numpy float64 on purpose: picks,
overbook flags and EMA observations are *decisions* and must be
bit-identical to the historical matcher.  The skip-only front half of a
heartbeat — which machines could start anything at all — goes through the
kernel-dispatch layer instead (`core/engine/kernels.
machines_with_candidates`, called by `sim/cluster.py`), where any sound
superset implementation is decision-exact.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from .engine import packing

# resource dims (cores, memory, network, disk); network+disk are fungible —
# they can be overbooked at the price of slowdown, cores/memory cannot.
FUNGIBLE = (2, 3)
RIGID = (0, 1)


@dataclasses.dataclass
class PendingTask:
    job_id: int
    task_id: int
    demand: np.ndarray
    duration: float
    pri_score: float = 1.0
    locality: int = -1          # preferred machine id, -1 = none


@dataclasses.dataclass
class JobView:
    """What the matcher needs to know about a job (AM -> RM interface §7)."""
    job_id: int
    group: int                 # jobgroup / queue for fairness
    srpt: float                # remaining work: sum duration * |demands|


@dataclasses.dataclass
class CandidateBatch:
    """Structure-of-arrays view of one heartbeat's candidate tasks.

    One row per candidate; the matcher scores whole columns at once instead
    of walking lists of `PendingTask` objects.  `job`/`tid` map rows back to
    (job_id, task_id) for whoever starts the picked tasks.
    """

    dem: np.ndarray    # (n, d) float64 demand
    pri: np.ndarray    # (n,) preferred-schedule priScore
    srpt: np.ndarray   # (n,) owning job's remaining work
    grp: np.ndarray    # (n,) int fairness group of the owning job
    loc: np.ndarray    # (n,) int preferred machine, -1 = none
    job: np.ndarray    # (n,) int owning job id
    tid: np.ndarray    # (n,) int task id within the job

    def __len__(self) -> int:
        return len(self.dem)

    def take(self, idx: np.ndarray) -> "CandidateBatch":
        """Compress to the given rows (contiguous copies, order preserved)."""
        return CandidateBatch(self.dem[idx], self.pri[idx], self.srpt[idx],
                              self.grp[idx], self.loc[idx], self.job[idx],
                              self.tid[idx])


class _PoolJob:
    """Per-job slot of the task pool: cached exposure + demand/pri rows."""

    __slots__ = ("job_id", "group", "demand", "pri", "runnable", "srpt",
                 "dirty", "tids", "dem_rows", "pri_rows")

    def __init__(self, job_id: int, group: int, demand: np.ndarray,
                 pri: np.ndarray, runnable: set[int], srpt: float):
        self.job_id = job_id
        self.group = group
        self.demand = np.asarray(demand)
        self.pri = np.asarray(pri)
        self.runnable = runnable      # live reference, mutated by the owner
        self.srpt = srpt
        self.dirty = True
        self.tids = np.empty(0, dtype=np.int64)
        self.dem_rows = np.empty((0, demand.shape[1]))
        self.pri_rows = np.empty(0)


class TaskPool:
    """Persistent SoA pending-task pool shared by simulator and matcher.

    Jobs register once (in arrival order — candidate ordering follows job
    registration order, matching the former per-heartbeat rebuild of the
    candidate list); afterwards the owner marks a job dirty whenever its
    runnable set changes and pushes SRPT updates as tasks finish.  A
    heartbeat then calls `refresh()`, which re-sorts only the dirty jobs'
    exposure (top `expose` runnable tasks by priScore, ties in the runnable
    set's iteration order — identical to sorting the set from scratch) and
    reuses cached per-job demand/priority rows for everyone else.  The flat
    (n, d)/(n,) arrays handed to the matcher are concatenations of those
    cached rows: no `PendingTask` objects, no per-machine `np.stack`.
    """

    def __init__(self, d: int, expose: int = 8):
        self.d = d
        self.expose = expose
        self._jobs: dict[int, _PoolJob] = {}
        self._pool_jobs: list[_PoolJob] = []
        self._any_dirty = True
        self._srpt_dirty = True
        self._batch: CandidateBatch | None = None

    def add_job(self, job_id: int, group: int, demand: np.ndarray,
                pri: np.ndarray, runnable: set[int], srpt: float) -> None:
        self._jobs[job_id] = _PoolJob(job_id, group, demand, pri, runnable,
                                      srpt)
        self._any_dirty = True

    def remove_job(self, job_id: int) -> None:
        if self._jobs.pop(job_id, None) is not None:
            self._any_dirty = True

    def mark_dirty(self, job_id: int) -> None:
        pj = self._jobs.get(job_id)
        if pj is not None:
            pj.dirty = True
            self._any_dirty = True

    def set_srpt(self, job_id: int, srpt: float) -> None:
        pj = self._jobs.get(job_id)
        if pj is not None:
            pj.srpt = srpt
            self._srpt_dirty = True

    def refresh(self) -> CandidateBatch | None:
        """Current candidate batch, rebuilding only what changed."""
        if not self._any_dirty and not self._srpt_dirty:
            return self._batch
        if self._any_dirty:
            per_job: list[_PoolJob] = []
            for pj in self._jobs.values():
                if pj.dirty:
                    # identical to the former per-heartbeat rebuild: a set's
                    # iteration order is stable between mutations, so sorting
                    # only when the set changed yields the same exposure.
                    top = sorted(pj.runnable,
                                 key=lambda t: -pj.pri[t])[: self.expose]
                    pj.tids = np.asarray(top, dtype=np.int64)
                    pj.dem_rows = pj.demand[pj.tids]
                    pj.pri_rows = pj.pri[pj.tids].astype(np.float64)
                    pj.dirty = False
                if len(pj.tids):
                    per_job.append(pj)
            if not per_job:
                self._batch = None
                self._any_dirty = self._srpt_dirty = False
                return None
            counts = [len(pj.tids) for pj in per_job]
            self._batch = CandidateBatch(
                dem=np.concatenate([pj.dem_rows for pj in per_job]),
                pri=np.concatenate([pj.pri_rows for pj in per_job]),
                srpt=np.repeat(np.asarray([pj.srpt for pj in per_job],
                                          dtype=np.float64), counts),
                grp=np.repeat(np.asarray([pj.group for pj in per_job],
                                         dtype=np.int64), counts),
                loc=np.full(sum(counts), -1, dtype=np.int64),
                job=np.repeat(np.asarray([pj.job_id for pj in per_job],
                                         dtype=np.int64), counts),
                tid=np.concatenate([pj.tids for pj in per_job]),
            )
            self._pool_jobs = per_job
        elif self._batch is not None:
            # only SRPT moved: re-gather that one column over cached rows
            counts = [len(pj.tids) for pj in self._pool_jobs]
            self._batch = dataclasses.replace(
                self._batch,
                srpt=np.repeat(np.asarray([pj.srpt for pj in self._pool_jobs],
                                          dtype=np.float64), counts))
        self._any_dirty = self._srpt_dirty = False
        return self._batch


def seq_dot(dem: np.ndarray, avail: np.ndarray) -> np.ndarray:
    """Left-to-right accumulated dot(dem, avail): the packing score.

    Deliberately NOT ``dem @ avail``: BLAS matvecs reorder and fuse the
    per-dim multiply-adds, so their last-ulp rounding differs between
    hosts, libraries and accelerators.  An explicit chain of individually
    rounded multiplies and adds has exactly one float64 result, which the
    XLA and Pallas wave kernels reproduce bit-for-bit (each product
    laundered against FMA contraction — see engine/wave.py).
    """
    acc = dem[:, 0] * avail[0]
    for k in range(1, dem.shape[1]):
        acc = acc + dem[:, k] * avail[k]
    return acc


def slot_fairness(demand: np.ndarray) -> float:
    """f() = 1: slot fairness."""
    return 1.0


def drf_fairness(demand: np.ndarray) -> float:
    """f() = dominant share of the task's demand: DRF."""
    return float(np.max(demand))


@dataclasses.dataclass
class MatcherConfig:
    eta_m: float = 0.2             # paper §8.5: m in [0.1, 0.3], rec 0.2
    remote_penalty: float = 0.8    # rp (§8.5)
    kappa: float = 0.1             # unfairness bound as a fraction of C
    max_overbook: float = 1.25     # cap on fungible-resource overbooking
    fairness: Callable[[np.ndarray], float] = slot_fairness
    use_priority: bool = True      # use the preferred-schedule priScore
    use_packing: bool = True       # use pScore packing (else FIFO-ish)
    use_srpt: bool = True
    use_overbooking: bool = True
    bundle_limit: int = 64         # max tasks matched per heartbeat
    # dims the scheduler *checks* when fitting.  Tez/CP-style schedulers only
    # know cores+memory (0, 1); ignoring network/disk over-allocates them,
    # which the simulator charges back as a slowdown (Fig. 11 discussion).
    fit_dims: tuple[int, ...] = (0, 1, 2, 3)


class DeficitCounters:
    """Bounded unfairness via deficit counters (§5, [64])."""

    def __init__(self, shares: dict[int, float], capacity: float, kappa: float):
        total = sum(shares.values()) or 1.0
        self.share = {g: s / total for g, s in shares.items()}
        self.deficit = {g: 0.0 for g in shares}
        self.capacity = capacity
        self.kappa = kappa

    def most_deprived(self) -> tuple[int | None, float]:
        if not self.deficit:
            return None, 0.0
        g = max(self.deficit, key=lambda g: self.deficit[g])
        return g, self.deficit[g]

    def must_serve(self) -> int | None:
        g, d = self.most_deprived()
        if g is not None and d >= self.kappa * self.capacity:
            return g
        return None

    def allocated(self, group: int, weight: float) -> None:
        for g in self.deficit:
            self.deficit[g] += self.share[g] * weight
        self.deficit[group] -= weight

    def set_groups(self, shares: dict[int, float]) -> None:
        total = sum(shares.values()) or 1.0
        self.share = {g: s / total for g, s in shares.items()}
        for g in shares:
            self.deficit.setdefault(g, 0.0)
        for g in list(self.deficit):
            if g not in shares:
                del self.deficit[g]

    def jain_index(self, usage: dict[int, float]) -> float:
        """Jain's fairness index over normalized usages (Table 4)."""
        xs = np.array([usage.get(g, 0.0) / max(self.share[g], 1e-12) for g in self.share])
        if xs.sum() <= 0:
            return 1.0
        return float(xs.sum() ** 2 / (len(xs) * (xs ** 2).sum()))


class Matcher:
    """FindAppropriateTasksForMachine (Fig. 8) with bundling."""

    def __init__(self, cfg: MatcherConfig, capacity: float, shares: dict[int, float]):
        self.cfg = cfg
        self.deficits = DeficitCounters(shares, capacity, cfg.kappa)
        self._ema_score = 1.0
        self._ema_srpt = 1.0
        # cfg.fit_dims is fixed for the matcher's lifetime; hoist the dim
        # split out of the per-machine hot path
        self._dim_split = (
            np.asarray(cfg.fit_dims),
            np.asarray([r for r in RIGID if r in cfg.fit_dims], dtype=int),
            np.asarray([f for f in FUNGIBLE if f in cfg.fit_dims], dtype=int),
        )

    @property
    def eta(self) -> float:
        if not self.cfg.use_srpt:
            return 0.0
        return self.cfg.eta_m * self._ema_score / max(self._ema_srpt, 1e-12)

    def _observe(self, score: float, srpt: float) -> None:
        a = 0.05
        self._ema_score = (1 - a) * self._ema_score + a * score
        self._ema_srpt = (1 - a) * self._ema_srpt + a * max(srpt, 1e-12)

    def fit_dim_split(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(fit, rigid, fungible) dim index arrays under this config."""
        return self._dim_split

    def find_tasks_for_machine(
        self,
        machine_id: int,
        avail: np.ndarray,
        tasks: Sequence[PendingTask],
        jobs: dict[int, JobView],
    ) -> list[tuple[PendingTask, bool]]:
        """Returns [(task, overbooked)] to start now on this machine.

        Object-list compatibility wrapper over `match_batch`: builds the
        SoA columns exactly as the matcher always has and maps picked rows
        back to the `PendingTask` objects.
        """
        if not tasks:
            return []
        cand = CandidateBatch(
            dem=np.stack([t.demand for t in tasks]),
            pri=np.array([t.pri_score for t in tasks]),
            srpt=np.array([jobs[t.job_id].srpt for t in tasks]),
            grp=np.array([jobs[t.job_id].group for t in tasks]),
            loc=np.array([t.locality for t in tasks], dtype=np.int64),
            job=np.array([t.job_id for t in tasks], dtype=np.int64),
            tid=np.array([t.task_id for t in tasks], dtype=np.int64),
        )
        return [(tasks[i], over)
                for i, over in self.match_batch(machine_id, avail, cand)]

    def match_batch(
        self,
        machine_id: int,
        avail: np.ndarray,
        cand: CandidateBatch,
        active: np.ndarray | None = None,
    ) -> list[tuple[int, bool]]:
        """Returns [(candidate row, overbooked)] to start on this machine.

        The sequential bundling/deficit loop over precomputed SoA columns:
        each iteration is a handful of numpy ops on (n, d) arrays, and the
        decisions (pick order, overbook flags, EMA observations, deficit
        updates) are bit-identical to the historical object-list matcher.

        ``active`` (bool (n,)), when given, excludes rows as if they were
        already taken — the wave loops pass their live mask directly so a
        per-machine call allocates O(1) instead of compressing the batch
        with ``take`` (returned row indices are global either way: they
        index ``cand``).  Masking is decision-identical to compressing:
        scores are per-row and ``argmax`` tie-breaks on first index, which
        order-preserving compression does not change.
        """
        cfg = self.cfg
        n = len(cand)
        if n == 0:
            return []
        if active is not None and not active.any():
            return []
        avail = avail.astype(np.float64).copy()
        dem = cand.dem                                      # (n, d)
        pri = cand.pri if cfg.use_priority else np.ones(n)
        srpt = cand.srpt
        grp = cand.grp
        rp = np.where((cand.loc >= 0) & (cand.loc != machine_id),
                      cfg.remote_penalty, 1.0)
        fd, rigid, fung = self.fit_dim_split()
        # hoist the per-dim gathers: each bundling iteration then compares
        # against the same `avail + slack + eps` sums the shared fits_mask
        # kernel forms, just without re-slicing the demand matrix
        dem_fd = dem[:, fd]
        dem_rigid = dem[:, rigid]
        dem_fung = dem[:, fung]
        ob_slack = cfg.max_overbook - 1.0
        no_over = np.zeros(n, dtype=bool)
        no_shoot = np.zeros(n)
        taken = ~active if active is not None else np.zeros(n, dtype=bool)
        picked: list[tuple[int, bool]] = []
        while len(picked) < cfg.bundle_limit:
            fits = (dem_fd <= avail[fd] + packing.EPS).all(axis=1)
            if cfg.use_overbooking:
                # rigid dims must really fit; fungible dims may overshoot by
                # the bounded overbooking allowance
                over = (~fits
                        & (dem_rigid <= avail[rigid] + packing.EPS).all(axis=1)
                        & (dem_fung <= avail[fung] + ob_slack + packing.EPS).all(axis=1))
            else:
                over = no_over
            eligible = (fits | over) & ~taken
            must_group = self.deficits.must_serve()
            if must_group is not None and (eligible & (grp == must_group)).any():
                eligible &= grp == must_group
            if not eligible.any():
                break
            if cfg.use_packing:
                dot = seq_dot(dem, np.clip(avail, 0.0, None)) * rp
            else:
                dot = rp.copy()
            if len(fung):
                overshoot = np.clip((dem_fung - avail[fung]).max(axis=1), 0.0, None)
            else:
                overshoot = no_shoot
            base = np.where(fits, dot, dot * np.maximum(1.0 - overshoot, 0.05))
            perf = pri * base - self.eta * srpt
            # lexicographic: any fitting task beats any overbooked one
            pool = eligible & fits if (eligible & fits).any() else eligible
            score = np.where(pool, perf, -np.inf)
            i = int(np.argmax(score))
            if not np.isfinite(score[i]):
                break
            taken[i] = True
            picked.append((i, bool(over[i])))
            self._observe(float(pri[i] * base[i]), float(srpt[i]))
            avail -= dem[i]
            np.clip(avail, 0.0, None, out=avail)
            self.deficits.allocated(int(grp[i]), cfg.fairness(dem[i]))
        return picked


def overload_factor(avail_row: np.ndarray) -> float:
    """Slowdown factor for a task launched on a machine with this
    (post-allocation) availability row: overload on the fungible dims
    (indices >= 2 — network/disk, the Fig. 11 effect) stretches every
    task on the machine.  Shared verbatim by the simulator's
    ``start_task`` and the scheduler service's lease grants so both
    compute bit-identical effective durations.
    """
    load = 1.0 - avail_row
    return float(max(load[2:].max() if avail_row.shape[0] > 2 else 0.0, 1.0))


class JobState:
    """Per-job DAG progress bookkeeping: pending-parent counts, the
    runnable/running/done partition, and the remaining-work (srpt)
    accumulator.

    One implementation shared by `sim.cluster.ClusterSim` and the
    scheduler service core (`svc.scheduler.SchedulerCore`) — decision
    parity between the two starts with them advancing identical job
    state through identical transitions.
    """

    def __init__(self, job_id: int, dag, arrival: float, group: int,
                 pri: np.ndarray):
        self.job_id = job_id
        self.dag = dag
        self.arrival = arrival
        self.group = group
        self.pri = pri
        self.pending_parents = np.array(
            [len(dag.parents[i]) for i in range(dag.n)])
        self.runnable: set[int] = {
            i for i in range(dag.n) if self.pending_parents[i] == 0}
        self.running: set[int] = set()
        self.done: set[int] = set()
        weight = np.abs(dag.demand).sum(axis=1)
        self._work = dag.duration * weight
        self.srpt = float(self._work.sum())
        self.finish: float | None = None

    def task_started(self, t: int) -> None:
        self.runnable.discard(t)
        self.running.add(t)

    def task_requeued(self, t: int) -> None:
        self.running.discard(t)
        self.runnable.add(t)

    def task_done(self, t: int) -> list[int]:
        if t in self.done:
            return []
        self.running.discard(t)
        self.runnable.discard(t)
        self.done.add(t)
        self.srpt -= float(self._work[t])
        newly = []
        for c in self.dag.children[t]:
            self.pending_parents[c] -= 1
            if self.pending_parents[c] == 0 and c not in self.done:
                newly.append(int(c))
                self.runnable.add(int(c))
        return newly

    @property
    def complete(self) -> bool:
        return len(self.done) == self.dag.n
