"""Kernel-dispatch layer: one registry for the engine's array kernels.

Every tensor-shaped inner loop of the scheduler — the windowed feasibility
scan the placement backends run, and the fit/score/heartbeat kernels the
online layers run — is registered here as an *op* with up to three
implementations:

  numpy   — the reference implementation; always available, always exact.
  xla     — a jax.jit-compiled version (shape-bucketed, float32 compute).
  pallas  — the ``src/repro/kernels/placement_scan`` Pallas kernels
            (TPU target; interpret mode elsewhere).

Selection is per-op: ``REPRO_KERNELS="scan=xla,machines_with_candidates=
pallas"`` (or ``all=<impl>``) pins an implementation, and resolution falls
back down the chain pallas -> xla -> numpy when the requested one is
unavailable (no jax, no pallas).  ``active()`` reports what actually runs;
``PROFILE`` accumulates per-(op, impl) call counts and seconds so
benchmarks can attribute time to the kernel layer.

Exactness contract per op (docs/architecture.md "Kernel layer"):

  * ``scan`` — all implementations are bit-identical: the grid is float32
    and demands are pre-rounded with ``ceil32``, so feasibility is a pure
    float32 comparison plus integer run-length counting on every path.
  * ``fits_mask`` / ``pack_score`` / ``heartbeat_masks`` — the numpy
    implementations are the decision oracles (float64).  Accelerated
    variants compute in float32 and are therefore NOT bit-exact; they are
    only offered where a sound approximation cannot change a decision.
  * ``machines_with_candidates`` — decision-exact under every
    implementation: its masks are used exclusively to *skip* machines
    that provably cannot pick a task, so any sound superset of the exact
    eligibility yields bit-identical scheduling decisions (a falsely
    eligible machine runs the matcher and picks nothing, mutating no
    state).  The accelerated implementations compute supersets by
    directed rounding: demands rounded *down* to float32, thresholds
    ``avail + slack + eps`` rounded *up*, so no exact-eligible pair is
    ever dropped.
  * ``match_wave`` — a whole heartbeat wave (eligibility → score → pick
    bundling → avail update) as one op over a `wave.WaveContext`.
    Bit-identical under every implementation: the xla/pallas kernels run
    float64 with FMA-contraction laundering so each pick, overbook flag,
    EMA observation and deficit update reproduces the numpy wave loop
    exactly (see ``engine/wave.py``).
"""

from __future__ import annotations

import os
import threading
import time
from typing import Callable, Sequence

import numpy as np

from .. import faults
from ..space import runs_of_k
from . import packing
from .base import ceil32

#: env var: comma-separated op=impl pairs, e.g. "scan=xla,all=numpy"
KERNELS_ENV = "REPRO_KERNELS"

OPS = ("scan", "fits_mask", "pack_score", "heartbeat_masks",
       "machines_with_candidates", "match_wave")
#: ops whose non-numpy implementations are approximate in ways that are
#: only safe for specific consumers (see the exactness contract above):
#: ``all=<impl>`` deliberately skips these — accelerating them requires
#: an explicit per-op opt-in, e.g. ``REPRO_KERNELS=heartbeat_masks=xla``
EXPLICIT_ONLY = ("fits_mask", "pack_score", "heartbeat_masks")
IMPLS = ("pallas", "xla", "numpy")   # fallback order, strongest first

#: heartbeat-sized ops that auto-promote to the device once the machine
#: axis is large enough to amortize launch overhead.  The two eligibility
#: ops are machine-skip filters (sound supersets are decision-exact for
#: every consumer in the repo); ``match_wave`` is bit-exact outright.
#: Above ``heartbeat_device_min_m()`` machines they auto-select xla; an
#: explicit REPRO_KERNELS pin for the op always wins.  Note the
#: heartbeat_masks caveat still applies: the auto-selected xla impl is
#: sound only for ``fits | over`` union consumers.
HEARTBEAT_AUTO_OPS = ("heartbeat_masks", "machines_with_candidates",
                      "match_wave")
#: env var overriding the auto-promotion threshold (int, machine count)
HEARTBEAT_MIN_M_ENV = "REPRO_HEARTBEAT_DEVICE_MIN_M"
_HEARTBEAT_DEFAULT_MIN_M = 1536


def heartbeat_device_min_m() -> int:
    """Machine count at/above which heartbeat ops auto-select xla."""
    raw = os.environ.get(HEARTBEAT_MIN_M_ENV, "")
    if raw:
        return int(raw)
    return _HEARTBEAT_DEFAULT_MIN_M

#: per-(op, impl) dispatch accounting: {"op.impl": [calls, seconds]}
PROFILE: dict[str, list] = {}

#: one lock for every shared accounting structure in this module (PROFILE,
#: XLA_STATS): concurrent builds (core/buildsvc.py thread mode) dispatch
#: kernels from worker threads, and unlocked ``+=`` drops increments.
#: Kernel calls are tensor-sized, so the lock is noise next to the work.
_STATS_LOCK = threading.Lock()


def reset_profile() -> None:
    with _STATS_LOCK:
        PROFILE.clear()


def profile_snapshot() -> dict[str, tuple[int, float]]:
    with _STATS_LOCK:
        return {k: (int(v[0]), float(v[1])) for k, v in PROFILE.items()}


def stat_add(key: str, n: int = 1) -> None:
    """Atomically bump one XLA_STATS counter (shared with core/engine/jit)."""
    with _STATS_LOCK:
        XLA_STATS[key] += n


def transfer_add(key: str, n: int) -> None:
    """Accumulate a host<->device transfer/launch counter in PROFILE.

    Keys follow ``"{op}.{impl}.{launches|bytes_h2d|bytes_d2h|waves}"``;
    the count lands in the calls slot of the usual PROFILE pair (seconds
    stays 0.0), so ``profile_snapshot`` deltas work unchanged and bench
    rows can derive per-wave launch/byte figures.
    """
    with _STATS_LOCK:
        slot = PROFILE.get(key)
        if slot is None:
            slot = PROFILE[key] = [0, 0.0]
        slot[0] += n


#: sticky runtime demotions: op -> impls that raised at dispatch and are
#: excluded from resolution until `reset_demotions` (degraded mode —
#: decision-exact because resolution lands on the numpy oracle, which
#: defines correct output for every op).  numpy itself is never demoted.
_DEMOTED: dict[str, set] = {}


def demote(op: str, impl: str) -> None:
    """Sticky-demote one (op, impl) after a dispatch failure.

    Counted in PROFILE under ``"{op}.{impl}.demoted"`` (calls slot; the
    seconds slot stays 0.0) so bench rows and `SimResult.fault_stats`
    can report demotion events alongside normal dispatch accounting.
    """
    if impl == "numpy":
        raise ValueError("the numpy oracle cannot be demoted")
    with _STATS_LOCK:
        _DEMOTED.setdefault(op, set()).add(impl)
        key = f"{op}.{impl}.demoted"
        slot = PROFILE.get(key)
        if slot is None:
            slot = PROFILE[key] = [0, 0.0]
        slot[0] += 1


def demoted_impls(op: str) -> frozenset:
    with _STATS_LOCK:
        return frozenset(_DEMOTED.get(op, ()))


def demotions_snapshot() -> dict[str, int]:
    """{"op.impl": demotion events} — the delta-able fault_stats view."""
    with _STATS_LOCK:
        return {k: int(v[0]) for k, v in PROFILE.items()
                if k.endswith(".demoted")}


def reset_demotions() -> None:
    """Re-admit every demoted impl (tests / operator re-enable)."""
    with _STATS_LOCK:
        _DEMOTED.clear()


# ----------------------------------------------------------------------
# numpy implementations (the reference semantics)
# ----------------------------------------------------------------------

def scan_starts(
    avail: np.ndarray,
    Vs: np.ndarray,
    ks: np.ndarray,
    plo: int,
    phi: int,
    reverse: bool = False,
) -> np.ndarray:
    """Feasible-start bitmaps for a batch of tasks over one window.

    For each task g (demand ``Vs[g]``, duration ``ks[g]`` ticks) and each
    physical start t in [plo, phi), bit (g, t, machine) says whether the
    whole run [t, t + ks[g]) fits on that machine inside the grid.

    Returns bool (g, (phi - plo) * m): rows are flattened over
    (start, machine) with starts ascending, or descending when
    ``reverse`` (the backward-pass walk order).
    """
    m, T, _d = avail.shape
    g = len(ks)
    W = phi - plo
    kmax = int(ks.max())
    hi_read = min(T, phi + kmax - 1)
    win = avail[:, plo:hi_read, :]                              # (m, L, d)
    L = hi_read - plo
    if g == 1:  # window extensions: skip the batched gather machinery
        k = int(ks[0])
        ok = (win >= Vs[0]).all(axis=2)                         # (m, L)
        good = runs_of_k(ok, k)
        full = np.zeros((W, m), dtype=bool)
        n = min(W, good.shape[1])
        full[:n] = good[:, :n].T
        if reverse:
            full = full[::-1]
        return np.ascontiguousarray(full).reshape(1, W * m)
    ok = (win[None, :, :, :] >= Vs[:, None, None, :]).all(axis=3)  # (g, m, L)
    if (ks == ks[0]).all():
        # stage peers usually share one duration: the per-task gather
        # degenerates to a single slice subtraction over the cumsums
        k0 = int(ks[0])
        good = np.zeros((g, m, W), dtype=bool)
        runs = runs_of_k(ok.reshape(g * m, L), k0).reshape(g, m, -1)
        n = min(W, runs.shape[2])
        good[:, :, :n] = runs[:, :, :n]
    else:
        cz = np.zeros((g, m, L + 1), dtype=np.int32)
        np.cumsum(ok, axis=2, out=cz[:, :, 1:])
        ends = np.minimum(np.arange(W, dtype=np.int64)[None, :] + ks[:, None], L)
        idx = np.broadcast_to(ends[:, None, :], (g, m, W))
        run = np.take_along_axis(cz, idx, axis=2) - cz[:, :, :W]
        # a run truncated by the grid edge counts < k and is correctly excluded
        good = run == ks[:, None, None]                         # (g, m, W)
    good = np.ascontiguousarray(np.swapaxes(good, 1, 2))        # (g, W, m)
    if reverse:
        good = good[:, ::-1, :]
    return good.reshape(g, W * m)


# ----------------------------------------------------------------------
# xla implementations
# ----------------------------------------------------------------------

try:  # the numpy paths must work without jax
    import jax
    import jax.numpy as jnp
    from jax import lax

    _HAVE_JAX = True
except Exception:  # pragma: no cover - exercised only on jax-less installs
    jax = jnp = lax = None
    _HAVE_JAX = False


def have_jax() -> bool:
    return _HAVE_JAX


def _have_pallas() -> bool:
    if not _HAVE_JAX:
        return False
    try:
        from ...kernels import placement_scan  # noqa: F401
        return True
    except Exception:  # pragma: no cover
        return False


def bucket(n: int, floor: int = 64) -> int:
    """Smallest size >= n on the {64, 96, 128, 192, 256, ...} ladder.

    A 1.5x-stepped power-of-two ladder: coarse enough that kernels
    retrace a handful of times per process, tight enough that padded
    compute stays within ~50% of the true shape.
    """
    if n <= floor:
        return floor
    p = floor
    while True:
        if n <= p:
            return p
        if n <= p + p // 2:
            return p + p // 2
        p *= 2


def pad8(n: int) -> int:
    return ((n + 7) // 8) * 8


#: durations above this never reach the bitmap scans (the sessions answer
#: them with chunked live probes — see core/engine/batched.py); the scan
#: buckets below lean on it to pin the window-read length per W bucket
LONG_K = 128
#: the tighter of the two window-read allowances (see scan_buckets)
SHORT_K = 32

#: window-length buckets for the compiled scans; WINDOW0 (192) dominates
W_LADDER = (192, 256, 512, 1024, 2048)


def scan_buckets(g: int, W: int, kmax: int) -> tuple[int, int, int]:
    """(gb, Lb, Wb) compile buckets for one scan shape.

    Deliberately coarse: gb in multiples of 8, Wb on a short ladder, and
    Lb = Wb plus a two-step duration allowance — SHORT_K covers the
    common case (most stage durations quantize to a few ticks), LONG_K
    the bitmap-path ceiling — a handful of distinct keys per (m, d)
    pair, so the bounded compile cache never thrashes, while the window
    read (the per-launch compute) stays ~30% leaner on typical batches.
    """
    gb = pad8(g)
    for Wb in W_LADDER:
        if W <= Wb:
            break
    else:
        Wb = bucket(W)
    if kmax <= SHORT_K:
        Lb = Wb + SHORT_K
    elif kmax <= LONG_K:
        Lb = Wb + LONG_K
    else:
        Lb = bucket(Wb + kmax)
    return gb, Lb, Wb


#: compiled-kernel bucket cache cap (satellite: bound lru growth); above
#: the cap the least-recently-used bucket is dropped and recompilation is
#: counted as a retrace
BUCKET_CAP = int(os.environ.get("REPRO_JIT_BUCKET_CAP", "32"))

#: retrace/eviction accounting for the XLA scan buckets
XLA_STATS = {"compiles": 0, "evictions": 0, "scan_calls": 0}


class _BucketCache:
    """Bounded LRU of jitted functions keyed by static shape buckets.

    Thread-safe: concurrent build sessions share these caches, so the
    pop/build/reinsert sequence runs under one per-cache lock (unlocked,
    two racing gets could both build — double-counting compiles — or
    corrupt the dict).  Holding the lock across ``build`` also means one
    key compiles once, with late arrivals waiting on the winner.
    """

    def __init__(self, build: Callable, cap: int = BUCKET_CAP):
        self._build = build
        self._cap = cap
        self._fns: dict[tuple, Callable] = {}
        self._lock = threading.Lock()

    def get(self, key: tuple) -> Callable:
        with self._lock:
            fn = self._fns.pop(key, None)
            if fn is None:
                if len(self._fns) >= self._cap:
                    self._fns.pop(next(iter(self._fns)))
                    stat_add("evictions")
                stat_add("compiles")
                fn = self._build(*key)
            self._fns[key] = fn      # (re)append = most recently used
            return fn

    def __len__(self) -> int:
        return len(self._fns)


def _build_scan_fn(m: int, d: int, gb: int, Lb: int, Wb: int, Tb: int):
    """One (m, d, gb, Lb, Wb, Tb) bucket of the windowed feasibility scan.

    Slices a (m, Lb, d) window out of a (m, Tb, d) device grid at a
    dynamic start — in-trace, so the launch stays a single asynchronous
    dispatch (an eager host-side slice would synchronize with in-flight
    work and serialize the async session).  The grid length Tb is part of
    the trace signature on purpose: it sits on the coarse allocation
    ladder and in the cache key, so those compiles are counted and capped
    like every other bucket.  Ticks at/after ``tlive`` (window-relative)
    are masked infeasible, which reproduces the numpy kernel's grid-edge
    truncation bit-for-bit.

    Run detection is *erosion by doubling* instead of the numpy kernel's
    cumsum+gather: E_{2^j}[t] = "ok for 2^j consecutive ticks from t" is
    built by log2(kmax) shifted ANDs, and each row's k combines the
    ladder levels of its set bits at accumulated offsets (one per-row
    dynamic slice per level).  Pure boolean shifts lower much better on
    CPU/TPU than a length-L integer scan plus a (g, m, W) gather, and the
    result is identical: a start is good iff all k ticks are ok.  All
    comparisons are float32-vs-float32 (demands pre-rounded via
    ``ceil32``), so the bitmaps are bit-identical to the numpy kernel.
    """
    nbits = max(LONG_K.bit_length(), (Lb - Wb).bit_length())

    def scan(buf, start, tlive, Vs, ks):
        win = lax.dynamic_slice(buf, (0, start, 0), (m, Lb, d))
        ok = (win[None, :, :, :] >= Vs[:, None, None, :]).all(axis=3)
        idx = jnp.arange(Lb, dtype=jnp.int32)
        ok = ok & (idx < tlive)[None, None, :]           # (gb, m, Lb)
        # erosion ladder: E[j][t] == all ok in [t, t + 2^j)
        E = [ok]
        for j in range(nbits - 1):
            s = 1 << j
            prev = E[-1]
            E.append(prev[:, :, : prev.shape[2] - s] & prev[:, :, s:])
        acc = jnp.ones((gb, m, Wb), dtype=bool)
        off = jnp.zeros((gb,), dtype=jnp.int32)

        def row_slice(e, o):
            return lax.dynamic_slice(e, (0, o), (m, Wb))

        for j in range(nbits):
            s = 1 << j
            # zero-pad (False) so every admissible offset slices in bounds
            # — off before level j is at most 2^j - 1, so 2s covers it; a
            # run reaching the padding correctly reads False
            Ej = jnp.pad(E[j], ((0, 0), (0, 0), (0, 2 * s)))
            bit = (ks >> j) & 1                          # (gb,)
            sl = jax.vmap(row_slice)(Ej, off)
            acc = acc & jnp.where(bit[:, None, None] > 0, sl, True)
            off = off + bit * s
        return jnp.swapaxes(acc, 1, 2)                   # (gb, Wb, m)

    return jax.jit(scan)


# eagerly constructed (cheap — jitting happens per key inside get): a lazy
# ``global X; if X is None`` init is a check-then-act race under threads
_SCAN_FNS = _BucketCache(_build_scan_fn)


def scan_fn_for(m: int, d: int, gb: int, Lb: int, Wb: int,
                Tb: int) -> Callable:
    """Compiled scan for one shape bucket (shared with the jit backend)."""
    return _SCAN_FNS.get((m, d, gb, Lb, Wb, Tb))


def _scan_xla(avail, Vs, ks, plo, phi, reverse=False):
    """Stateless XLA scan: uploads the window per call (the jit backend's
    device-resident session avoids the upload; this entry point is the
    registry implementation used for parity testing and ad-hoc routing)."""
    m, T, d = avail.shape
    g = len(ks)
    W = phi - plo
    kmax = int(ks.max())
    hi_read = min(T, phi + kmax - 1)
    L = hi_read - plo
    gb, Lb, Wb = scan_buckets(g, W, kmax)
    win_p = np.full((m, Lb, d), -1.0, dtype=np.float32)
    win_p[:, :L, :] = avail[:, plo:hi_read, :]
    Vs_p = np.full((gb, d), 2.0, dtype=np.float32)
    Vs_p[:g] = ceil32(np.asarray(Vs))
    ks_p = np.ones(gb, dtype=np.int32)
    ks_p[:g] = ks
    stat_add("scan_calls")
    fn = scan_fn_for(m, d, gb, Lb, Wb, Lb)   # buffer == window here
    good = np.asarray(fn(jnp.asarray(win_p), np.int32(0), np.int32(L),
                         Vs_p, ks_p))
    good = good[:g, :W, :]
    if reverse:
        good = good[:, ::-1, :]
    return np.ascontiguousarray(good).reshape(g, W * m)


# -- heartbeat ops: sound-superset float32 formulation -------------------

def _round_down32(x: np.ndarray) -> np.ndarray:
    """Largest float32 <= x (directed rounding for sound supersets)."""
    x32 = x.astype(np.float32)
    high = x32.astype(np.float64) > x
    if high.any():
        x32[high] = np.nextafter(x32[high], np.float32(-np.inf))
    return x32


def _round_up32(x: np.ndarray) -> np.ndarray:
    """Smallest float32 >= x."""
    x32 = x.astype(np.float32)
    low = x32.astype(np.float64) < x
    if low.any():
        x32[low] = np.nextafter(x32[low], np.float32(np.inf))
    return x32


def _superset_operands(avail, demands, fit_dims, rigid_dims, fungible_dims,
                       overbook_slack, eps=packing.EPS):
    """Host-side exact prep for the accelerated eligibility kernels.

    The exact test per (candidate, machine, dim) is
    ``demand <= avail + slack + eps`` in float64.  Rounding the demand
    *down* and the float64 threshold *up* to float32 can only turn False
    into True — a sound superset, which is all the skip-only consumers
    need.  The (m, d)/(n, d) rounding runs on host (cheap); only the
    (n, m) outer comparison runs on the accelerator.
    """
    avail = np.atleast_2d(np.asarray(avail, dtype=np.float64))
    demands = np.atleast_2d(np.asarray(demands, dtype=np.float64))
    d = avail.shape[1]
    dem32 = _round_down32(demands)
    thr_fit = _round_up32(avail + eps)
    thr_fung = _round_up32(avail + max(overbook_slack, 0.0) + eps)

    def sel(dims):
        dims = np.asarray(dims, dtype=np.int64)
        return dims if len(dims) else np.empty(0, np.int64)

    return dem32, thr_fit, thr_fung, sel(fit_dims), sel(rigid_dims), \
        sel(fungible_dims)


def _eligible_superset_np(dem32, thr_fit, thr_fung, fd, rd, gd):
    """Reference formulation of the superset masks (used by tests)."""
    def fit(thr, dims):
        if len(dims) == 0:
            return np.ones((dem32.shape[0], thr.shape[0]), dtype=bool)
        return (dem32[:, None, dims] <= thr[None, :, dims]).all(axis=2)

    eligible = fit(thr_fit, fd) | (fit(thr_fit, rd) & fit(thr_fung, gd))
    return eligible, eligible.any(axis=0)


def _build_elig_fn(n_dims_key):
    def elig(dem32, thr_fit, thr_fung, fd_mask, rd_mask, gd_mask):
        # dims enter as (d,) float32 {0, 1} masks: a masked-out dim
        # compares against +inf and never fails the fit
        inf = jnp.float32(np.inf)
        tf = jnp.where(fd_mask > 0, thr_fit[None, :, :], inf)
        tr = jnp.where(rd_mask > 0, thr_fit[None, :, :], inf)
        tg = jnp.where(gd_mask > 0, thr_fung[None, :, :], inf)
        dm = dem32[:, None, :]
        fits = (dm <= tf).all(axis=2)
        rigid = (dm <= tr).all(axis=2)
        fung = (dm <= tg).all(axis=2)
        eligible = fits | (rigid & fung)
        return eligible, eligible.any(axis=0)
    return jax.jit(elig)


_ELIG_FNS = _BucketCache(_build_elig_fn)


def _eligibility_launch_args(avail, demands, fit_dims, rigid_dims,
                             fungible_dims, overbook_slack, use_overbooking):
    """Shared preamble of the accelerated eligibility ops.

    Returns ``(dem32, thr_fit, thr_fung, masks)`` ready for either launch
    path, or the ``(eligible, machine_any)`` empty-result shortcut when
    there are no candidates.  One site on purpose: the xla and pallas
    implementations must degenerate and encode dims identically or their
    decisions could drift apart.
    """
    if not use_overbooking:
        # no overbooking: eligibility is the plain fit mask; reuse the
        # fit threshold for both halves so the kernel stays one shape
        rigid_dims = fit_dims
        fungible_dims = np.empty(0, np.int64)
        overbook_slack = 0.0
    dem32, thr_fit, thr_fung, fd, rd, gd = _superset_operands(
        avail, demands, fit_dims, rigid_dims, fungible_dims, overbook_slack)
    n, d = dem32.shape
    if n == 0:
        m = thr_fit.shape[0]
        return None, (np.zeros((n, m), dtype=bool), np.zeros(m, dtype=bool))
    masks = []
    for dims in (fd, rd, gd):
        mk = np.zeros(d, dtype=np.float32)
        mk[dims] = 1.0
        masks.append(mk)
    return (dem32, thr_fit, thr_fung, masks), None


def _machines_with_candidates_xla(avail, demands, fit_dims, rigid_dims,
                                  fungible_dims, overbook_slack=0.0,
                                  use_overbooking=True):
    """Sound-superset eligibility in one device launch (see module doc)."""
    args, empty = _eligibility_launch_args(avail, demands, fit_dims,
                                           rigid_dims, fungible_dims,
                                           overbook_slack, use_overbooking)
    if empty is not None:
        return empty
    dem32, thr_fit, thr_fung, masks = args
    fn = _ELIG_FNS.get((dem32.shape[1],))
    transfer_add("machines_with_candidates.xla.launches", 1)
    transfer_add("machines_with_candidates.xla.bytes_h2d",
                 dem32.nbytes + thr_fit.nbytes + thr_fung.nbytes
                 + sum(mk.nbytes for mk in masks))
    eligible, any_m = fn(dem32, thr_fit, thr_fung, *masks)
    eligible, any_m = np.asarray(eligible), np.asarray(any_m)
    transfer_add("machines_with_candidates.xla.bytes_d2h",
                 eligible.nbytes + any_m.nbytes)
    return eligible, any_m


def _heartbeat_masks_xla(avail, demands, fit_dims, rigid_dims, fungible_dims,
                         overbook_slack=0.0, use_overbooking=True):
    """Superset (fits, over) masks; see machines_with_candidates caveats.

    NOTE: ``over`` is derived from the superset ``fits`` via negation, so
    unlike the union mask it is *neither* a superset nor a subset of the
    exact mask — this implementation is only safe for consumers that use
    ``fits | over``.  The dispatch default therefore stays numpy.
    """
    args, empty = _eligibility_launch_args(avail, demands, fit_dims,
                                           rigid_dims, fungible_dims,
                                           overbook_slack, use_overbooking)
    if empty is not None:
        # this op's contract is (fits (n, m), over (n, m)) — not the
        # (eligible, machine_any (m,)) pair of machines_with_candidates
        return empty[0], np.zeros_like(empty[0])
    dem32, thr_fit, thr_fung, masks = args
    fn = _ELIG_FNS.get((dem32.shape[1],))
    eligible, _any = fn(dem32, thr_fit, thr_fung, *masks)
    eligible = np.asarray(eligible)
    fd_mask = masks[0] > 0
    fits = (dem32[:, None, fd_mask] <= thr_fit[None, :, fd_mask]).all(axis=2) \
        if fd_mask.any() else np.ones_like(eligible)
    return fits, eligible & ~fits


def _fits_mask_xla(avail, demand, dims=None, slack=0.0, eps=packing.EPS):
    """float32 fit mask (superset by directed rounding); not bit-exact."""
    avail = np.asarray(avail, dtype=np.float64)
    demand = np.asarray(demand, dtype=np.float64)
    if dims is not None:
        dims = np.asarray(dims, dtype=np.int64)
        if len(dims) == 0:
            if avail.ndim == 2 and demand.ndim == 2:
                return np.ones((demand.shape[0], avail.shape[0]), dtype=bool)
            shape = np.broadcast_shapes(avail.shape[:-1], demand.shape[:-1])
            return np.ones(shape, dtype=bool)
        avail = avail[..., dims]
        demand = demand[..., dims]
    thr = jnp.asarray(_round_up32(avail + slack + eps))
    dem = jnp.asarray(_round_down32(demand))
    if avail.ndim == 2 and demand.ndim == 2:
        out = (dem[:, None, :] <= thr[None, :, :]).all(axis=2)
    else:
        out = (dem <= thr).all(axis=-1)
    return np.asarray(out)


def _pack_score_xla(avail, demand, clip=False):
    """float32 Tetris dot-product scores; NOT bit-exact vs numpy float64."""
    avail = jnp.asarray(np.asarray(avail), dtype=jnp.float32)
    if clip:
        avail = jnp.clip(avail, 0.0, None)
    demand = jnp.asarray(np.asarray(demand), dtype=jnp.float32)
    if avail.ndim == 2 and demand.ndim == 2:
        return np.asarray(demand @ avail.T, dtype=np.float64)
    out = demand @ jnp.swapaxes(jnp.atleast_2d(avail), -1, -2)
    return np.asarray(out.squeeze(), dtype=np.float64)


# ----------------------------------------------------------------------
# pallas implementations (adapters over src/repro/kernels/placement_scan)
# ----------------------------------------------------------------------

def _scan_pallas(avail, Vs, ks, plo, phi, reverse=False):
    from ...kernels.placement_scan import ops as ps_ops

    m, T, d = avail.shape
    g = len(ks)
    W = phi - plo
    kmax = int(ks.max())
    hi_read = min(T, phi + kmax - 1)
    L = hi_read - plo
    # the kernel's dynamic k-slice needs L_pad >= W_pad + kmax; ticks
    # beyond t_live are masked infeasible so the padding never flips a bit
    Wb = bucket(W)
    Lp = bucket(max(Wb + kmax, L))
    gb = pad8(g)
    win_p = np.full((m, Lp, d), -1.0, dtype=np.float32)
    win_p[:, :L, :] = avail[:, plo:hi_read, :]
    Vs_p = np.full((gb, d), 2.0, dtype=np.float32)
    Vs_p[:g] = ceil32(np.asarray(Vs))
    ks_p = np.ones(gb, dtype=np.int32)
    ks_p[:g] = ks
    good = np.asarray(ps_ops.scan_bitmaps(win_p, Vs_p, ks_p, np.int32(L),
                                          W=Wb)) != 0
    good = good[:g, :W, :]
    if reverse:
        good = good[:, ::-1, :]
    return np.ascontiguousarray(good).reshape(g, W * m)


def _machines_with_candidates_pallas(avail, demands, fit_dims, rigid_dims,
                                     fungible_dims, overbook_slack=0.0,
                                     use_overbooking=True):
    from ...kernels.placement_scan import ops as ps_ops

    args, empty = _eligibility_launch_args(avail, demands, fit_dims,
                                           rigid_dims, fungible_dims,
                                           overbook_slack, use_overbooking)
    if empty is not None:
        return empty
    dem32, thr_fit, thr_fung, masks = args
    eligible = np.asarray(ps_ops.heartbeat_eligible(
        dem32, thr_fit, thr_fung, *masks)) != 0
    return eligible, eligible.any(axis=0)


# ----------------------------------------------------------------------
# registry + dispatch
# ----------------------------------------------------------------------

_REGISTRY: dict[tuple[str, str], tuple[Callable, Callable[[], bool]]] = {}


def register(op: str, impl: str, fn: Callable,
             available: Callable[[], bool] = lambda: True) -> None:
    _REGISTRY[(op, impl)] = (fn, available)


register("scan", "numpy", scan_starts)
register("fits_mask", "numpy", packing.fits_mask)
register("pack_score", "numpy", packing.pack_score)
register("heartbeat_masks", "numpy", packing.heartbeat_masks)
register("machines_with_candidates", "numpy", packing.machines_with_candidates)

# imported at the bottom on purpose: wave.py references this module's
# registry helpers lazily (inside functions), so by the time either side
# runs, both modules are fully initialized — no import cycle
from . import wave as _wave  # noqa: E402

register("match_wave", "numpy", _wave.match_wave_numpy)

if _HAVE_JAX:
    register("scan", "xla", _scan_xla, have_jax)
    register("fits_mask", "xla", _fits_mask_xla, have_jax)
    register("pack_score", "xla", _pack_score_xla, have_jax)
    register("heartbeat_masks", "xla", _heartbeat_masks_xla, have_jax)
    register("machines_with_candidates", "xla",
             _machines_with_candidates_xla, have_jax)
    register("match_wave", "xla", _wave.match_wave_xla, have_jax)
    register("scan", "pallas", _scan_pallas, _have_pallas)
    register("machines_with_candidates", "pallas",
             _machines_with_candidates_pallas, _have_pallas)
    register("match_wave", "pallas", _wave.match_wave_pallas,
             _wave.pallas_wave_available)


_REQ_CACHE: tuple[str, dict] | None = None


def _requested() -> dict[str, str]:
    """Parsed REPRO_KERNELS, cached per raw env value (dispatch-hot).

    Thread-safety: the cache is one tuple assigned in a single bytecode
    op after being fully built, and parsing is a pure function of ``raw``
    — two racing threads at worst both parse and assign equal values.
    """
    global _REQ_CACHE
    raw = os.environ.get(KERNELS_ENV, "")
    if _REQ_CACHE is not None and _REQ_CACHE[0] == raw:
        return _REQ_CACHE[1]
    out: dict[str, str] = {}
    for part in raw.split(","):
        part = part.strip()
        if not part or "=" not in part:
            continue
        op, impl = part.split("=", 1)
        op, impl = op.strip(), impl.strip()
        if impl not in IMPLS:
            raise ValueError(f"unknown kernel impl {impl!r}; have {IMPLS}")
        if op == "all":
            for o in OPS:
                if o not in EXPLICIT_ONLY:
                    out.setdefault(o, impl)
        elif op in OPS:
            out[op] = impl
        else:
            raise ValueError(f"unknown kernel op {op!r}; have {OPS}")
    _REQ_CACHE = (raw, out)
    return out


def resolve(op: str) -> tuple[str, Callable]:
    """(impl name, callable) for one op, honoring env + availability.

    The requested implementation falls back down the IMPLS chain when it
    is unregistered, reports unavailable, or has been sticky-demoted
    after a dispatch failure; numpy is always registered and never
    demoted, so resolution always succeeds.
    """
    want = _requested().get(op, "numpy")
    start = IMPLS.index(want)
    demoted = _DEMOTED.get(op, ())
    for impl in IMPLS[start:]:
        if impl in demoted:
            continue
        ent = _REGISTRY.get((op, impl))
        if ent is not None and ent[1]():
            return impl, ent[0]
    raise RuntimeError(f"no implementation available for kernel op {op!r}")


def resolve_heartbeat(op: str, n_machines: int) -> tuple[str, Callable]:
    """Machine-count-aware resolution for the heartbeat eligibility ops.

    An explicit REPRO_KERNELS pin for ``op`` always wins (including a pin
    to numpy).  Otherwise, at ``n_machines >= heartbeat_device_min_m()``
    the xla sound-superset impl is selected when available; below the
    threshold (or without jax) resolution falls through to the normal
    chain, which lands on the exact numpy oracle.
    """
    if op not in HEARTBEAT_AUTO_OPS:
        raise ValueError(f"not a heartbeat op: {op!r}; have {HEARTBEAT_AUTO_OPS}")
    if (op not in _requested() and n_machines >= heartbeat_device_min_m()
            and "xla" not in _DEMOTED.get(op, ())):
        ent = _REGISTRY.get((op, "xla"))
        if ent is not None and ent[1]():
            return "xla", ent[0]
    return resolve(op)


def heartbeat_impl(op: str, n_machines: int) -> str:
    """Impl name a heartbeat dispatch would pick at this machine count."""
    return resolve_heartbeat(op, n_machines)[0]


def active() -> dict[str, str]:
    """op -> impl actually selected right now (env + availability).

    For the HEARTBEAT_AUTO_OPS this reports the below-threshold (small-m)
    selection; use :func:`heartbeat_impl` for a machine-count-aware view.
    """
    return {op: resolve(op)[0] for op in OPS}


def _call_profiled(op: str, impl: str, fn: Callable, *args, **kwargs):
    key = f"{op}.{impl}"
    t0 = time.perf_counter()
    try:
        return fn(*args, **kwargs)
    finally:
        dt = time.perf_counter() - t0
        with _STATS_LOCK:
            slot = PROFILE.get(key)
            if slot is None:
                slot = PROFILE[key] = [0, 0.0]
            slot[0] += 1
            slot[1] += dt


def _profile_calls(key: str) -> int:
    with _STATS_LOCK:
        slot = PROFILE.get(key)
        return int(slot[0]) if slot else 0


def _run_op(op: str, resolver: Callable[[], tuple[str, Callable]],
            args, kwargs):
    """Dispatch with sticky demotion: a non-numpy impl that raises (real
    bug or injected ``kernel_impl`` fault) is demoted and the op re-
    resolves down the chain — the numpy oracle terminates the loop, so
    dispatch always returns the exact answer or propagates a genuine
    numpy-level error.  The fault seam is keyed by the impl's running
    call count so probabilistic plans fire per-call, not per-op."""
    while True:
        impl, fn = resolver()
        try:
            if impl != "numpy":
                faults.maybe_fail("kernel_impl", op=op, impl=impl,
                                  call=_profile_calls(f"{op}.{impl}"))
            return _call_profiled(op, impl, fn, *args, **kwargs)
        except Exception:
            if impl == "numpy":
                raise
            demote(op, impl)


def _dispatch(op: str, *args, **kwargs):
    return _run_op(op, lambda: resolve(op), args, kwargs)


# -- public dispatching entry points -----------------------------------

def scan(avail, Vs, ks, plo, phi, reverse=False):
    """Windowed feasibility scan through the dispatch table."""
    return _dispatch("scan", avail, Vs, ks, plo, phi, reverse)


def fits_mask(avail, demand, dims=None, slack=0.0, eps=packing.EPS):
    return _dispatch("fits_mask", avail, demand, dims, slack, eps)


def pack_score(avail, demand, clip=False):
    return _dispatch("pack_score", avail, demand, clip)


def heartbeat_masks(avail, demands, fit_dims, rigid_dims, fungible_dims,
                    overbook_slack=0.0, use_overbooking=True):
    avail = np.asarray(avail)
    return _run_op("heartbeat_masks",
                   lambda: resolve_heartbeat("heartbeat_masks",
                                             avail.shape[0]),
                   (avail, demands, fit_dims, rigid_dims, fungible_dims,
                    overbook_slack, use_overbooking), {})


def machines_with_candidates(avail, demands, fit_dims, rigid_dims,
                             fungible_dims, overbook_slack=0.0,
                             use_overbooking=True):
    avail = np.asarray(avail)
    return _run_op("machines_with_candidates",
                   lambda: resolve_heartbeat("machines_with_candidates",
                                             avail.shape[0]),
                   (avail, demands, fit_dims, rigid_dims, fungible_dims,
                    overbook_slack, use_overbooking), {})


def match_wave(ctx) -> int:
    """One fused heartbeat wave over a ``wave.WaveContext``.

    Bit-exact under every implementation, so it auto-promotes to the xla
    kernel at ``heartbeat_device_min_m()`` machines like the eligibility
    ops; a kernel failure (or injected ``kernel_impl`` fault) sticky-
    demotes back to the numpy wave loop with identical decisions — the
    device impls mutate no matcher state before their launch returns.
    """
    return _run_op("match_wave",
                   lambda: resolve_heartbeat("match_wave",
                                             ctx.avail.shape[0]),
                   (ctx,), {})
