"""Batched placement backend: candidate-parallel windowed feasibility.

Instead of rescanning the grid per task, a pass-scoped session answers a
whole ready-set at once: one `scan_starts` call lifts the cumsum
run-length trick from (m, T) to (n_tasks, m, W) over an adaptive window
and yields, per task, the bitmap of (start, machine) slots where it fits.

Exactness argument.  Capacity only decreases within a placement pass
(commits subtract; rollbacks happen only between passes), so a bitmap
scanned at grid version V is a sound *superset* of live feasibility at
any later version: a clear bit can never become placeable again.  The
walk therefore:

  * trusts the bitmap outright while the grid version still matches
    (bits are exact — nothing committed since the scan);
  * otherwise verifies the first candidate bit with one O(dur * d) live
    recheck, and on a stale hit settles the whole window with a single
    live (m, W) mini-scan — sound because everything lexicographically
    before that bit was already clear in the superset.

Walking bits in (start, machine) lexicographic order — mirrored for
backward passes — thus reproduces the reference backend's
earliest/latest-fit results tick-for-tick, including the hint fast path,
while doing ~one tensor scan per ready-set instead of one full-grid scan
per task.
"""

from __future__ import annotations

from typing import Callable, Sequence

import numpy as np

from . import kernels
from .base import (BACKWARD, FORWARD, HintKey, PeerTask, PlacementBackend,
                   PlacementSession, ceil32, register_backend)
from .kernels import scan_starts  # noqa: F401  (re-exported; moved to kernels)

#: first window size in ticks (doubles on every extension); sized so the
#: common case — placing near the packing frontier — resolves in one scan
WINDOW0 = 192
#: max ready-set peers prefetched into one scan
MAX_BATCH = 32
#: durations above this skip the bitmap machinery: a long task's window is
#: duration-dominated, so batching it multiplies large scans that a couple
#: of chunked live probes (Space.fit_first) answer outright.  Long stages
#: are also narrow (few tasks), so there is no cohort to amortize over.
#: Shared with the dispatch layer, whose compiled-scan shape buckets lean
#: on every bitmap-path duration being <= LONG_K.
LONG_K = kernels.LONG_K


class _Cand:
    """One scanned window's bitmap for one task.

    The bitmap may be *lazy*: a backend that scans asynchronously (the
    device-resident jit sessions) hands a loader instead of the flat
    array, and the first ``next_bit`` call materializes it.  The bitmap's
    *content* is fixed at scan time either way — an async launch computes
    over the grid state captured at the call — so version/edge soundness
    reasoning is untouched by when the bits arrive on the host.
    """

    __slots__ = ("wlo", "whi", "flat", "reverse", "version", "edge", "_load")

    def __init__(self, wlo: int, whi: int, flat: np.ndarray | None,
                 reverse: bool, version: int, edge: int, load=None):
        self.wlo = wlo          # lowest logical start covered
        self.whi = whi          # highest logical start covered (inclusive)
        self.flat = flat        # (W * m,) bool in walk order, or None (lazy)
        self.reverse = reverse
        self.version = version  # grid version at scan time
        # logical grid_end at scan time: starts above edge - dur had their
        # runs truncated by the grid boundary and were cleared UNSOUNDLY
        # with respect to later growth — they are NOT settled by this
        # bitmap and must be rescanned once the grid grows
        self.edge = edge
        self._load = load

    def next_bit(self, m: int, bound: int):
        """First set bit in walk order at/after ``bound`` → (machine, t)."""
        flat = self.flat
        if flat is None:
            flat = self.flat = self._load()
            self._load = None
        j0 = ((self.whi - bound) if self.reverse else (bound - self.wlo)) * m
        if j0 < 0:
            j0 = 0
        elif j0 >= flat.size:
            return None
        j = j0 + int(np.argmax(flat[j0:]))
        if not flat[j]:
            return None
        t = (self.whi - j // m) if self.reverse else (self.wlo + j // m)
        return j % m, t


#: sentinel start returned when every admissible slot is past the prune cap
PRUNED = -1


class BatchedSession(PlacementSession):
    wants_peers = True
    wants_f32 = True

    def __init__(self, space, direction: str, backend: "BatchedBackend"):
        super().__init__(space, direction)
        self._backend = backend
        self._cands: dict[int, _Cand] = {}

    # ------------------------------------------------------------------
    def place(
        self,
        tid: int,
        v: np.ndarray,
        k: int,
        anchor: int,
        key: HintKey,
        peers_fn: Callable[[], Sequence[PeerTask]] | None = None,
        cap: int | None = None,
    ) -> tuple[int, int]:
        sp = self.space
        h = self.hint.get(key)
        # compare in pure float32 (no grid-slice promotion); ceil32 keeps
        # every comparison bit-identical to the reference float64 one
        v = ceil32(v)
        if self.direction == FORWARD:
            lo = int(anchor)
            if cap is not None and lo >= cap:
                return PRUNED, cap  # even the anchor is past the prune bound
            if h is not None and h[1] >= anchor:
                if cap is not None and h[1] >= cap:
                    return PRUNED, cap
                lo = max(lo, h[1])
                mm = sp.check_fit_at(v, k, h[1])
                if mm >= 0:
                    self.hint[key] = (mm, h[1])
                    return mm, h[1]
            # mirror the reference pre-scan growth so grid extents (and the
            # deadline of later unanchored backward tasks) stay identical
            while lo < sp.grid_start:
                sp._grow_front()
            res = self._resolve_fwd(tid, v, k, lo, peers_fn, cap)
        else:
            deadline = int(anchor)
            while deadline > sp.grid_end:
                sp._grow_back()
            hi = deadline
            if cap is not None and hi - k <= cap:
                return PRUNED, cap  # even the deadline slot is past the bound
            if h is not None and h[1] + k <= deadline:
                if cap is not None and h[1] <= cap:
                    return PRUNED, cap
                hi = min(hi, h[1] + k)
                mm = sp.check_fit_at(v, k, h[1])
                if mm >= 0:
                    self.hint[key] = (mm, h[1])
                    return mm, h[1]
            res = self._resolve_bwd(tid, v, k, hi - k, peers_fn, cap)
        if res[0] >= 0:
            self.hint[key] = res
        return res

    # ------------------------------------------------------------------
    def _consume(self, cand: _Cand, v, k, bound):
        """Extreme live slot inside one window, or None if the window is dry.

        ``bound`` clips the walk (lowest admissible start forward, highest
        backward).  At most one live mini-scan per call: the grid is frozen
        during a `place`, so its result is definitive for the window.
        """
        sp = self.space
        nxt = cand.next_bit(sp.m, bound)
        if nxt is None:
            return None
        mm, t = nxt
        if cand.version == sp.version or sp.check_fit_exact(mm, t, k, v):
            return mm, t
        # stale hit: everything in walk order before (t, mm) was clear even
        # in the superset, so one live scan of [t .. window edge] decides.
        if cand.reverse:
            return sp.fit_first(v, k, cand.wlo, t, latest=True)
        return sp.fit_first(v, k, t, cand.whi, latest=False)

    def _resolve_fwd(self, tid, v, k, lo, peers_fn, cap=None):
        sp = self.space
        hi_cap = None if cap is None else cap - 1   # highest admissible start
        if k > LONG_K:
            cur = lo
            while True:
                top = sp.grid_end - k
                if hi_cap is not None:
                    top = min(top, hi_cap)
                res = sp.fit_first(v, k, cur, top)
                if res is not None:
                    return res
                if hi_cap is not None and top >= hi_cap:
                    return PRUNED, cap   # every admissible start proven dry
                nxt = sp.grid_end - k + 1  # everything below is now dry
                sp._grow_back()
                cur = max(lo, nxt)
        cur = lo
        cand = self._cands.pop(tid, None)
        if cand is not None and cand.wlo <= lo:
            res = self._consume(cand, v, k, lo)
            if res is not None:
                # this IS the earliest fit; past the cap it only proves
                # the pass is doomed
                if hi_cap is not None and res[1] > hi_cap:
                    return PRUNED, cap
                return res
            # starts above cand.edge - k had their runs truncated by the
            # then-grid boundary: not settled, resume from there
            cur = max(lo, min(cand.whi, cand.edge - k) + 1)
        W = max(WINDOW0, 2 * k)
        while True:
            if hi_cap is not None and cur > hi_cap:
                return PRUNED, cap
            if cur > sp.grid_end - k:
                sp._grow_back()
            whi = min(cur + W - 1, sp.grid_end - 1)
            if hi_cap is not None:
                whi = min(whi, hi_cap)
            if whi < cur:
                sp._grow_back()
                continue
            cand = self._scan(tid, v, k, cur, whi, peers_fn)
            res = self._consume(cand, v, k, cur)  # fresh scan: bits exact
            if res is not None:
                return res
            # same truncation rule for the window just scanned: anything in
            # (grid_end - k, whi] is only proven dry for the CURRENT grid
            cur = min(whi, sp.grid_end - k) + 1
            W *= 2
            peers_fn = None  # prefetch only on the first window of a call

    def _resolve_bwd(self, tid, v, k, hi_start, peers_fn, cap=None):
        sp = self.space
        lo_cap = None if cap is None else cap + 1   # lowest admissible start
        if k > LONG_K:
            cur = hi_start
            while True:
                bot = sp.grid_start
                if lo_cap is not None:
                    bot = max(bot, lo_cap)
                res = sp.fit_first(v, k, bot, cur, latest=True)
                if res is not None:
                    return res
                if lo_cap is not None and bot <= lo_cap:
                    return PRUNED, cap
                nxt = sp.grid_start - 1
                sp._grow_front()
                cur = min(hi_start, nxt)
        cur = hi_start
        cand = self._cands.pop(tid, None)
        if cand is not None and cand.whi >= hi_start:
            # starts above cand.edge - k had their runs truncated by the
            # then-grid boundary, so their cleared bits are unsound once the
            # deadline has grown the grid (mirror of the forward resume
            # rule): settle that top region with a live scan first
            safe_hi = min(hi_start, cand.edge - k)
            if safe_hi < hi_start:
                res = sp.fit_first(v, k, safe_hi + 1, hi_start, latest=True)
                if res is not None:
                    if lo_cap is not None and res[1] < lo_cap:
                        return PRUNED, cap
                    return res
            if safe_hi >= cand.wlo:
                res = self._consume(cand, v, k, safe_hi)
                if res is not None:
                    if lo_cap is not None and res[1] < lo_cap:
                        return PRUNED, cap
                    return res
            cur = min(hi_start, cand.wlo - 1)
        W = max(WINDOW0, 2 * k)
        while True:
            if lo_cap is not None and cur < lo_cap:
                return PRUNED, cap
            while cur < sp.grid_start:
                sp._grow_front()
            wlo = max(cur - W + 1, sp.grid_start)
            if lo_cap is not None:
                wlo = max(wlo, lo_cap)
            cand = self._scan(tid, v, k, wlo, cur, peers_fn)
            res = self._consume(cand, v, k, cur)
            if res is not None:
                return res
            if lo_cap is not None and wlo <= lo_cap:
                return PRUNED, cap
            if wlo <= sp.grid_start:
                # the whole grid below the deadline is dry — like
                # latest_fit, expose free space before the origin
                sp._grow_front()
            cur = wlo - 1
            W *= 2
            peers_fn = None

    # ------------------------------------------------------------------
    def _scan(self, tid, v, k, wlo, whi, peers_fn) -> _Cand:
        """Scan starts [wlo, whi] for ``tid`` plus prefetchable peers."""
        sp = self.space
        reverse = self.direction == BACKWARD
        batch = [(tid, v, k)]
        if peers_fn is not None:
            for p in peers_fn():
                if len(batch) > MAX_BATCH:
                    break
                if p.tid == tid or p.tid in self._cands or p.dur_ticks > LONG_K:
                    continue
                # only worth caching when the peer's own walk would start
                # inside this window — a cache that misses the peer's first
                # admissible start is discarded at use (estimates are hints;
                # the walk re-clips against the real pop-time anchor)
                if reverse:
                    start = p.anchor - p.dur_ticks   # highest admissible start
                    usable = wlo <= start <= whi
                else:
                    usable = wlo <= p.anchor <= whi
                if usable:
                    batch.append((p.tid, p.demand, p.dur_ticks))
        Vs = ceil32(np.stack([b[1] for b in batch]))
        ks = np.array([b[2] for b in batch], dtype=np.int64)
        plo, phi = wlo + sp.off, whi + 1 + sp.off
        goods = self._backend.scan_kernel(sp, Vs, ks, plo, phi, reverse)
        out: _Cand | None = None
        ver, edge = sp.version, sp.grid_end
        eager = isinstance(goods, np.ndarray)
        for i, (btid, _bv, _bk) in enumerate(batch):
            if eager:
                c = _Cand(wlo, whi, np.ascontiguousarray(goods[i]), reverse,
                          ver, edge)
            else:   # async backend: rows materialize on first use
                c = _Cand(wlo, whi, None, reverse, ver, edge, load=goods[i])
            if btid == tid:
                out = c
            else:
                self._cands[btid] = c
        assert out is not None
        return out


class BatchedBackend(PlacementBackend):
    name = "batched"
    wants_prescan = True

    def scan_kernel(self, space, Vs, ks, plo, phi, reverse):
        """The feasibility-scan kernel, routed through the kernel-dispatch
        layer (core/engine/kernels.py).  Subclasses (jit) override with a
        device-resident session keyed off the Space — which is why the
        entry point takes the Space, not a bare grid array."""
        return kernels.scan(space.avail, Vs, ks, plo, phi, reverse)

    def session(self, space, direction: str) -> BatchedSession:
        return BatchedSession(space, direction, self)

    def sessions(self, space, specs) -> list[BatchedSession]:
        """Multi-variant entry: stack sibling variants' first feasibility
        scans into one pass per direction over the shared node grid.

        All sibling branches start from exactly this grid state, so one
        (n_variants * n_tasks, m, W) scan is sound for every branch: a
        branch only subtracts capacity from the scanned state, keeping
        each bitmap a superset that the session's stale-walk settles with
        live rechecks (same argument as per-pass peer prefetch — the
        prescan can change cost, never results).
        """
        out = [self.session(space, d) for d, _peers in specs]
        for reverse in (False, True):
            direction = BACKWARD if reverse else FORWARD
            batch: list[tuple[int, np.ndarray, int, int]] = []   # tid, v, k, first start
            owners: list[list[BatchedSession]] = []
            tids: dict[int, int] = {}
            for sess, (d, peers) in zip(out, specs):
                if d != direction:
                    continue
                for p in peers:
                    if p.dur_ticks > LONG_K:
                        continue
                    if p.tid in tids:    # same task in two sibling branches
                        owners[tids[p.tid]].append(sess)
                        continue
                    tids[p.tid] = len(batch)
                    start = p.anchor - p.dur_ticks if reverse else p.anchor
                    batch.append((p.tid, p.demand, p.dur_ticks, start))
                    owners.append([sess])
            if not batch:
                continue
            kmax = max(k for _t, _v, k, _s in batch)
            if reverse:
                whi = max(s for _t, _v, _k, s in batch)
                wlo = max(whi - max(WINDOW0, 2 * kmax) + 1, space.grid_start)
            else:
                wlo = min(s for _t, _v, _k, s in batch)
                whi = min(wlo + max(WINDOW0, 2 * kmax) - 1, space.grid_end - 1)
            if whi < wlo:
                continue
            # keep only peers whose own walk starts inside the window (a
            # cache missing a task's first admissible start is discarded
            # at use, so scanning it would be waste)
            keep = [j for j, (_t, _v, _k, s) in enumerate(batch)
                    if wlo <= s <= whi]
            if not keep:
                continue
            Vs = ceil32(np.stack([batch[j][1] for j in keep]))
            ks = np.array([batch[j][2] for j in keep], dtype=np.int64)
            plo, phi = wlo + space.off, whi + 1 + space.off
            goods = self.scan_kernel(space, Vs, ks, plo, phi, reverse)
            ver, edge = space.version, space.grid_end
            eager = isinstance(goods, np.ndarray)
            for i, j in enumerate(keep):
                if eager:
                    cand = _Cand(wlo, whi, np.ascontiguousarray(goods[i]),
                                 reverse, ver, edge)
                else:
                    cand = _Cand(wlo, whi, None, reverse, ver, edge,
                                 load=goods[i])
                for sess in owners[j]:
                    # the _Cand is read-only; sibling sessions may share it
                    sess._cands[batch[j][0]] = cand
        return out


register_backend("batched", BatchedBackend)
