"""Batched placement backend: candidate-parallel windowed feasibility.

Instead of rescanning the grid per task, a pass-scoped session answers a
whole ready-set at once: one `scan_starts` call lifts the cumsum
run-length trick from (m, T) to (n_tasks, m, W) over an adaptive window
and yields, per task, the bitmap of (start, machine) slots where it fits.

Exactness argument.  Capacity only decreases within a placement pass
(commits subtract; rollbacks happen only between passes), so a bitmap
scanned at grid version V is a sound *superset* of live feasibility at
any later version: a clear bit can never become placeable again.  The
walk therefore:

  * trusts the bitmap outright while the grid version still matches
    (bits are exact — nothing committed since the scan);
  * otherwise verifies the first candidate bit with one O(dur * d) live
    recheck, and on a stale hit settles the whole window with a single
    live (m, W) mini-scan — sound because everything lexicographically
    before that bit was already clear in the superset.

Walking bits in (start, machine) lexicographic order — mirrored for
backward passes — thus reproduces the reference backend's
earliest/latest-fit results tick-for-tick, including the hint fast path,
while doing ~one tensor scan per ready-set instead of one full-grid scan
per task.
"""

from __future__ import annotations

from typing import Callable, Sequence

import numpy as np

from ..space import runs_of_k
from .base import (BACKWARD, FORWARD, HintKey, PeerTask, PlacementBackend,
                   PlacementSession, ceil32, register_backend)

#: first window size in ticks (doubles on every extension); sized so the
#: common case — placing near the packing frontier — resolves in one scan
WINDOW0 = 192
#: max ready-set peers prefetched into one scan
MAX_BATCH = 32
#: durations above this skip the bitmap machinery: a long task's window is
#: duration-dominated, so batching it multiplies large scans that a couple
#: of chunked live probes (Space.fit_first) answer outright.  Long stages
#: are also narrow (few tasks), so there is no cohort to amortize over.
LONG_K = 128


def scan_starts(
    avail: np.ndarray,
    Vs: np.ndarray,
    ks: np.ndarray,
    plo: int,
    phi: int,
    reverse: bool = False,
) -> np.ndarray:
    """Feasible-start bitmaps for a batch of tasks over one window.

    For each task g (demand ``Vs[g]``, duration ``ks[g]`` ticks) and each
    physical start t in [plo, phi), bit (g, t, machine) says whether the
    whole run [t, t + ks[g]) fits on that machine inside the grid.

    Returns bool (g, (phi - plo) * m): rows are flattened over
    (start, machine) with starts ascending, or descending when
    ``reverse`` (the backward-pass walk order).
    """
    m, T, _d = avail.shape
    g = len(ks)
    W = phi - plo
    kmax = int(ks.max())
    hi_read = min(T, phi + kmax - 1)
    win = avail[:, plo:hi_read, :]                              # (m, L, d)
    L = hi_read - plo
    if g == 1:  # window extensions: skip the batched gather machinery
        k = int(ks[0])
        ok = (win >= Vs[0]).all(axis=2)                         # (m, L)
        good = runs_of_k(ok, k)
        full = np.zeros((W, m), dtype=bool)
        n = min(W, good.shape[1])
        full[:n] = good[:, :n].T
        if reverse:
            full = full[::-1]
        return np.ascontiguousarray(full).reshape(1, W * m)
    ok = (win[None, :, :, :] >= Vs[:, None, None, :]).all(axis=3)  # (g, m, L)
    if (ks == ks[0]).all():
        # stage peers usually share one duration: the per-task gather
        # degenerates to a single slice subtraction over the cumsums
        k0 = int(ks[0])
        good = np.zeros((g, m, W), dtype=bool)
        runs = runs_of_k(ok.reshape(g * m, L), k0).reshape(g, m, -1)
        n = min(W, runs.shape[2])
        good[:, :, :n] = runs[:, :, :n]
    else:
        cz = np.zeros((g, m, L + 1), dtype=np.int32)
        np.cumsum(ok, axis=2, out=cz[:, :, 1:])
        ends = np.minimum(np.arange(W, dtype=np.int64)[None, :] + ks[:, None], L)
        idx = np.broadcast_to(ends[:, None, :], (g, m, W))
        run = np.take_along_axis(cz, idx, axis=2) - cz[:, :, :W]
        # a run truncated by the grid edge counts < k and is correctly excluded
        good = run == ks[:, None, None]                         # (g, m, W)
    good = np.ascontiguousarray(np.swapaxes(good, 1, 2))        # (g, W, m)
    if reverse:
        good = good[:, ::-1, :]
    return good.reshape(g, W * m)


class _Cand:
    """One scanned window's bitmap for one task."""

    __slots__ = ("wlo", "whi", "flat", "reverse", "version", "edge")

    def __init__(self, wlo: int, whi: int, flat: np.ndarray, reverse: bool,
                 version: int, edge: int):
        self.wlo = wlo          # lowest logical start covered
        self.whi = whi          # highest logical start covered (inclusive)
        self.flat = flat        # (W * m,) bool in walk order
        self.reverse = reverse
        self.version = version  # grid version at scan time
        # logical grid_end at scan time: starts above edge - dur had their
        # runs truncated by the grid boundary and were cleared UNSOUNDLY
        # with respect to later growth — they are NOT settled by this
        # bitmap and must be rescanned once the grid grows
        self.edge = edge

    def next_bit(self, m: int, bound: int):
        """First set bit in walk order at/after ``bound`` → (machine, t)."""
        j0 = ((self.whi - bound) if self.reverse else (bound - self.wlo)) * m
        flat = self.flat
        if j0 < 0:
            j0 = 0
        elif j0 >= flat.size:
            return None
        j = j0 + int(np.argmax(flat[j0:]))
        if not flat[j]:
            return None
        t = (self.whi - j // m) if self.reverse else (self.wlo + j // m)
        return j % m, t


#: sentinel start returned when every admissible slot is past the prune cap
PRUNED = -1


class BatchedSession(PlacementSession):
    wants_peers = True
    wants_f32 = True

    def __init__(self, space, direction: str, backend: "BatchedBackend"):
        super().__init__(space, direction)
        self._backend = backend
        self._cands: dict[int, _Cand] = {}

    # ------------------------------------------------------------------
    def place(
        self,
        tid: int,
        v: np.ndarray,
        k: int,
        anchor: int,
        key: HintKey,
        peers_fn: Callable[[], Sequence[PeerTask]] | None = None,
        cap: int | None = None,
    ) -> tuple[int, int]:
        sp = self.space
        h = self.hint.get(key)
        # compare in pure float32 (no grid-slice promotion); ceil32 keeps
        # every comparison bit-identical to the reference float64 one
        v = ceil32(v)
        if self.direction == FORWARD:
            lo = int(anchor)
            if cap is not None and lo >= cap:
                return PRUNED, cap  # even the anchor is past the prune bound
            if h is not None and h[1] >= anchor:
                if cap is not None and h[1] >= cap:
                    return PRUNED, cap
                lo = max(lo, h[1])
                mm = sp.check_fit_at(v, k, h[1])
                if mm >= 0:
                    self.hint[key] = (mm, h[1])
                    return mm, h[1]
            # mirror the reference pre-scan growth so grid extents (and the
            # deadline of later unanchored backward tasks) stay identical
            while lo < sp.grid_start:
                sp._grow_front()
            res = self._resolve_fwd(tid, v, k, lo, peers_fn, cap)
        else:
            deadline = int(anchor)
            while deadline > sp.grid_end:
                sp._grow_back()
            hi = deadline
            if cap is not None and hi - k <= cap:
                return PRUNED, cap  # even the deadline slot is past the bound
            if h is not None and h[1] + k <= deadline:
                if cap is not None and h[1] <= cap:
                    return PRUNED, cap
                hi = min(hi, h[1] + k)
                mm = sp.check_fit_at(v, k, h[1])
                if mm >= 0:
                    self.hint[key] = (mm, h[1])
                    return mm, h[1]
            res = self._resolve_bwd(tid, v, k, hi - k, peers_fn, cap)
        if res[0] >= 0:
            self.hint[key] = res
        return res

    # ------------------------------------------------------------------
    def _consume(self, cand: _Cand, v, k, bound):
        """Extreme live slot inside one window, or None if the window is dry.

        ``bound`` clips the walk (lowest admissible start forward, highest
        backward).  At most one live mini-scan per call: the grid is frozen
        during a `place`, so its result is definitive for the window.
        """
        sp = self.space
        nxt = cand.next_bit(sp.m, bound)
        if nxt is None:
            return None
        mm, t = nxt
        if cand.version == sp.version or sp.check_fit_exact(mm, t, k, v):
            return mm, t
        # stale hit: everything in walk order before (t, mm) was clear even
        # in the superset, so one live scan of [t .. window edge] decides.
        if cand.reverse:
            return sp.fit_first(v, k, cand.wlo, t, latest=True)
        return sp.fit_first(v, k, t, cand.whi, latest=False)

    def _resolve_fwd(self, tid, v, k, lo, peers_fn, cap=None):
        sp = self.space
        hi_cap = None if cap is None else cap - 1   # highest admissible start
        if k > LONG_K:
            cur = lo
            while True:
                top = sp.grid_end - k
                if hi_cap is not None:
                    top = min(top, hi_cap)
                res = sp.fit_first(v, k, cur, top)
                if res is not None:
                    return res
                if hi_cap is not None and top >= hi_cap:
                    return PRUNED, cap   # every admissible start proven dry
                nxt = sp.grid_end - k + 1  # everything below is now dry
                sp._grow_back()
                cur = max(lo, nxt)
        cur = lo
        cand = self._cands.pop(tid, None)
        if cand is not None and cand.wlo <= lo:
            res = self._consume(cand, v, k, lo)
            if res is not None:
                # this IS the earliest fit; past the cap it only proves
                # the pass is doomed
                if hi_cap is not None and res[1] > hi_cap:
                    return PRUNED, cap
                return res
            # starts above cand.edge - k had their runs truncated by the
            # then-grid boundary: not settled, resume from there
            cur = max(lo, min(cand.whi, cand.edge - k) + 1)
        W = max(WINDOW0, 2 * k)
        while True:
            if hi_cap is not None and cur > hi_cap:
                return PRUNED, cap
            if cur > sp.grid_end - k:
                sp._grow_back()
            whi = min(cur + W - 1, sp.grid_end - 1)
            if hi_cap is not None:
                whi = min(whi, hi_cap)
            if whi < cur:
                sp._grow_back()
                continue
            cand = self._scan(tid, v, k, cur, whi, peers_fn)
            res = self._consume(cand, v, k, cur)  # fresh scan: bits exact
            if res is not None:
                return res
            # same truncation rule for the window just scanned: anything in
            # (grid_end - k, whi] is only proven dry for the CURRENT grid
            cur = min(whi, sp.grid_end - k) + 1
            W *= 2
            peers_fn = None  # prefetch only on the first window of a call

    def _resolve_bwd(self, tid, v, k, hi_start, peers_fn, cap=None):
        sp = self.space
        lo_cap = None if cap is None else cap + 1   # lowest admissible start
        if k > LONG_K:
            cur = hi_start
            while True:
                bot = sp.grid_start
                if lo_cap is not None:
                    bot = max(bot, lo_cap)
                res = sp.fit_first(v, k, bot, cur, latest=True)
                if res is not None:
                    return res
                if lo_cap is not None and bot <= lo_cap:
                    return PRUNED, cap
                nxt = sp.grid_start - 1
                sp._grow_front()
                cur = min(hi_start, nxt)
        cur = hi_start
        cand = self._cands.pop(tid, None)
        if cand is not None and cand.whi >= hi_start:
            # starts above cand.edge - k had their runs truncated by the
            # then-grid boundary, so their cleared bits are unsound once the
            # deadline has grown the grid (mirror of the forward resume
            # rule): settle that top region with a live scan first
            safe_hi = min(hi_start, cand.edge - k)
            if safe_hi < hi_start:
                res = sp.fit_first(v, k, safe_hi + 1, hi_start, latest=True)
                if res is not None:
                    if lo_cap is not None and res[1] < lo_cap:
                        return PRUNED, cap
                    return res
            if safe_hi >= cand.wlo:
                res = self._consume(cand, v, k, safe_hi)
                if res is not None:
                    if lo_cap is not None and res[1] < lo_cap:
                        return PRUNED, cap
                    return res
            cur = min(hi_start, cand.wlo - 1)
        W = max(WINDOW0, 2 * k)
        while True:
            if lo_cap is not None and cur < lo_cap:
                return PRUNED, cap
            while cur < sp.grid_start:
                sp._grow_front()
            wlo = max(cur - W + 1, sp.grid_start)
            if lo_cap is not None:
                wlo = max(wlo, lo_cap)
            cand = self._scan(tid, v, k, wlo, cur, peers_fn)
            res = self._consume(cand, v, k, cur)
            if res is not None:
                return res
            if lo_cap is not None and wlo <= lo_cap:
                return PRUNED, cap
            if wlo <= sp.grid_start:
                # the whole grid below the deadline is dry — like
                # latest_fit, expose free space before the origin
                sp._grow_front()
            cur = wlo - 1
            W *= 2
            peers_fn = None

    # ------------------------------------------------------------------
    def _scan(self, tid, v, k, wlo, whi, peers_fn) -> _Cand:
        """Scan starts [wlo, whi] for ``tid`` plus prefetchable peers."""
        sp = self.space
        reverse = self.direction == BACKWARD
        batch = [(tid, v, k)]
        if peers_fn is not None:
            for p in peers_fn():
                if len(batch) > MAX_BATCH:
                    break
                if p.tid == tid or p.tid in self._cands or p.dur_ticks > LONG_K:
                    continue
                # only worth caching when the peer's own walk would start
                # inside this window — a cache that misses the peer's first
                # admissible start is discarded at use (estimates are hints;
                # the walk re-clips against the real pop-time anchor)
                if reverse:
                    start = p.anchor - p.dur_ticks   # highest admissible start
                    usable = wlo <= start <= whi
                else:
                    usable = wlo <= p.anchor <= whi
                if usable:
                    batch.append((p.tid, p.demand, p.dur_ticks))
        Vs = ceil32(np.stack([b[1] for b in batch]))
        ks = np.array([b[2] for b in batch], dtype=np.int64)
        plo, phi = wlo + sp.off, whi + 1 + sp.off
        goods = self._backend.scan_kernel(sp.avail, Vs, ks, plo, phi, reverse)
        out: _Cand | None = None
        ver, edge = sp.version, sp.grid_end
        for row, (btid, _bv, _bk) in zip(goods, batch):
            c = _Cand(wlo, whi, np.ascontiguousarray(row), reverse, ver, edge)
            if btid == tid:
                out = c
            else:
                self._cands[btid] = c
        assert out is not None
        return out


class BatchedBackend(PlacementBackend):
    name = "batched"
    wants_prescan = True

    #: the feasibility-scan kernel; subclasses (jit) override this
    @staticmethod
    def scan_kernel(avail, Vs, ks, plo, phi, reverse):
        return scan_starts(avail, Vs, ks, plo, phi, reverse)

    def session(self, space, direction: str) -> BatchedSession:
        return BatchedSession(space, direction, self)

    def sessions(self, space, specs) -> list[BatchedSession]:
        """Multi-variant entry: stack sibling variants' first feasibility
        scans into one pass per direction over the shared node grid.

        All sibling branches start from exactly this grid state, so one
        (n_variants * n_tasks, m, W) scan is sound for every branch: a
        branch only subtracts capacity from the scanned state, keeping
        each bitmap a superset that the session's stale-walk settles with
        live rechecks (same argument as per-pass peer prefetch — the
        prescan can change cost, never results).
        """
        out = [self.session(space, d) for d, _peers in specs]
        for reverse in (False, True):
            direction = BACKWARD if reverse else FORWARD
            batch: list[tuple[int, np.ndarray, int, int]] = []   # tid, v, k, first start
            owners: list[list[BatchedSession]] = []
            tids: dict[int, int] = {}
            for sess, (d, peers) in zip(out, specs):
                if d != direction:
                    continue
                for p in peers:
                    if p.dur_ticks > LONG_K:
                        continue
                    if p.tid in tids:    # same task in two sibling branches
                        owners[tids[p.tid]].append(sess)
                        continue
                    tids[p.tid] = len(batch)
                    start = p.anchor - p.dur_ticks if reverse else p.anchor
                    batch.append((p.tid, p.demand, p.dur_ticks, start))
                    owners.append([sess])
            if not batch:
                continue
            kmax = max(k for _t, _v, k, _s in batch)
            if reverse:
                whi = max(s for _t, _v, _k, s in batch)
                wlo = max(whi - max(WINDOW0, 2 * kmax) + 1, space.grid_start)
            else:
                wlo = min(s for _t, _v, _k, s in batch)
                whi = min(wlo + max(WINDOW0, 2 * kmax) - 1, space.grid_end - 1)
            if whi < wlo:
                continue
            # keep only peers whose own walk starts inside the window (a
            # cache missing a task's first admissible start is discarded
            # at use, so scanning it would be waste)
            keep = [j for j, (_t, _v, _k, s) in enumerate(batch)
                    if wlo <= s <= whi]
            if not keep:
                continue
            Vs = ceil32(np.stack([batch[j][1] for j in keep]))
            ks = np.array([batch[j][2] for j in keep], dtype=np.int64)
            plo, phi = wlo + space.off, whi + 1 + space.off
            goods = self.scan_kernel(space.avail, Vs, ks, plo, phi, reverse)
            ver, edge = space.version, space.grid_end
            for row, j in zip(goods, keep):
                cand = _Cand(wlo, whi, np.ascontiguousarray(row), reverse,
                             ver, edge)
                for sess in owners[j]:
                    # the _Cand is read-only; sibling sessions may share it
                    sess._cands[batch[j][0]] = cand
        return out


register_backend("batched", BatchedBackend)
