"""Reference placement backend: the original per-task numpy grid search.

Each `place` call runs one full cumsum feasibility scan over the remaining
grid (`Space.earliest_fit` / `Space.latest_fit`), seeded by the per-pass
hint table.  This is the semantic oracle the batched backends — and every
implementation in the kernel-dispatch layer (core/engine/kernels.py) —
must match tick-for-tick, and the baseline the construction benchmark
compares against.  It deliberately bypasses the dispatch layer: the
oracle must not share code with what it oracles.
"""

from __future__ import annotations

from typing import Callable, Sequence

import numpy as np

from .base import (FORWARD, HintKey, PeerTask, PlacementBackend,
                   PlacementSession, register_backend)


class ReferenceSession(PlacementSession):
    wants_peers = False

    def place(
        self,
        tid: int,
        v: np.ndarray,
        k: int,
        anchor: int,
        key: HintKey,
        peers_fn: Callable[[], Sequence[PeerTask]] | None = None,
        cap: int | None = None,
    ) -> tuple[int, int]:
        h = self.hint.get(key)
        if self.direction == FORWARD:
            m, t0 = self.space.earliest_fit(v, k, anchor, h)
        else:
            m, t0 = self.space.latest_fit(v, k, anchor, h)
        self.hint[key] = (m, t0)
        return m, t0


class ReferenceBackend(PlacementBackend):
    name = "reference"

    def session(self, space, direction: str) -> ReferenceSession:
        return ReferenceSession(space, direction)


register_backend("reference", ReferenceBackend)
