"""Placement-engine backend protocol (paper §4.2 placement primitives).

A *backend* answers the virtual-space placement queries that the offline
builder (§4) issues while constructing a schedule.  Every backend must
implement the same placement semantics:

  forward  — earliest (machine, start >= ready) fitting the task's demand
             for its whole duration, ties broken by lowest start then lowest
             machine index;
  backward — latest (machine, start) with start + dur <= deadline, ties by
             highest start then lowest machine index;

plus the per-pass *hint* memoization keyed by (stage, anchor, demand): the
slot of a previously placed identical task is a sound bound because the
space only fills up within a pass.

Backends differ in *how* they search.  The reference backend rescans the
grid per task; the batched backend answers a whole ready-set through one
(n_tasks, m, T)-shaped feasibility scan and walks the precomputed
candidates with cheap live rechecks; the jit backend runs the same scan as
a jax.jit-compiled kernel.  All three are tick-identical by construction
(see docs/architecture.md for the monotonicity argument).

Sessions are *per placement pass* (one PlaceTasksF/PlaceTasksB call): the
hint table and any cached feasibility data must not outlive the pass,
because the builder rolls the space back between candidate variants and
cached data is only a sound upper bound while capacity monotonically
decreases.
"""

from __future__ import annotations

import abc
import os
import threading
import typing
from typing import TYPE_CHECKING, Callable, Sequence

import numpy as np

if TYPE_CHECKING:  # avoid a hard cycle: space does not import the engine
    from ..space import Space

FORWARD = "fwd"
BACKWARD = "bwd"

# key of the per-pass hint table: (stage, anchor, demand bytes)
HintKey = tuple


class PeerTask(typing.NamedTuple):
    """A ready-but-not-yet-placed task announced to the session.

    Peers are a *prefetch hint only*: a batched session may scan their
    feasibility alongside the current task to amortize the tensor op, but
    whether peers are announced (and their estimated anchors) can never
    change any placement result.
    """

    tid: int
    anchor: int          # estimated ready tick (fwd) / deadline tick (bwd)
    demand: np.ndarray   # (d,)
    dur_ticks: int


def ceil32(v: np.ndarray) -> np.ndarray:
    """Round float64 demands up to the nearest float32.

    For a float32 grid cell a and float64 demand v, ``a >= v`` iff
    ``a >= ceil32(v)``: comparisons can then run entirely in float32,
    sparing the float64 promotion of every scanned grid slice while
    staying bit-identical to the reference float64 comparison.  This is
    the exactness keystone of every accelerated scan implementation in
    the kernel-dispatch layer (numpy/xla/pallas all compare the same
    float32 pair); a hypothesis property test pins the boundary argument
    (tests/test_placement_kernels.py).
    """
    v = np.asarray(v)
    if v.dtype == np.float32:  # already rounded — passthrough
        return v
    v32 = v.astype(np.float32)
    low = v32.astype(np.float64) < v
    if low.any():
        v32[low] = np.nextafter(v32[low], np.float32(np.inf))
    return v32


class PlacementSession(abc.ABC):
    """One placement pass over a Space in a fixed direction."""

    #: whether the session benefits from PeerTask prefetch announcements
    wants_peers: bool = False

    def __init__(self, space: "Space", direction: str):
        if direction not in (FORWARD, BACKWARD):
            raise ValueError(f"bad direction {direction!r}")
        self.space = space
        self.direction = direction
        self.hint: dict[HintKey, tuple[int, int]] = {}

    #: sessions that compare in float32 may be handed ceil32-rounded demands
    wants_f32: bool = False

    @abc.abstractmethod
    def place(
        self,
        tid: int,
        v: np.ndarray,
        k: int,
        anchor: int,
        key: HintKey,
        peers_fn: Callable[[], Sequence[PeerTask]] | None = None,
        cap: int | None = None,
    ) -> tuple[int, int]:
        """Find the slot for one task; the caller commits it afterwards.

        ``anchor`` is the ready tick (forward) or deadline tick (backward).
        ``peers_fn`` lazily yields PeerTask prefetch hints.  Returns
        (machine, logical start).

        ``cap`` is a prune bound: the caller will discard the whole pass if
        the found start is >= cap (forward) / <= cap (backward).  A session
        MAY therefore stop searching once it has proven every admissible
        slot is past the cap and return the sentinel (-1, cap) instead of
        the exact slot; the reference session ignores it and lets the
        caller prune after the fact — both yield the same pass outcome.
        """


class PlacementBackend(abc.ABC):
    """Factory of placement sessions; stateless and shareable."""

    name: str = "abstract"

    #: whether sessions() actually consumes the per-spec peer hints — lets
    #: callers skip building prefetch hints for backends that ignore them
    wants_prescan: bool = False

    @abc.abstractmethod
    def session(self, space: "Space", direction: str) -> PlacementSession:
        ...

    def sessions(
        self,
        space: "Space",
        specs: Sequence[tuple[str, Sequence[PeerTask]]],
    ) -> list[PlacementSession]:
        """Multi-variant entry point: one session per sibling variant.

        ``specs`` lists the first placement segment of each sibling branch
        off one shared grid state as (direction, initial ready-set peers).
        Backends MAY evaluate all siblings' feasibility scans in one
        stacked (n_variants, n_tasks, m, W) pass and seed each returned
        session with the results; because every sibling starts from
        exactly the scanned grid state and capacity only decreases within
        its pass, a node-level scan is a sound superset for each branch
        (the same monotonicity argument as per-pass prefetch, so results
        are tick-identical with or without the prescan).  Under the
        device-resident jit backend the stacked pass is a single
        asynchronous device launch.

        The default is the degenerate stack: independent unseeded
        sessions, one per spec (the reference backend's behavior).
        """
        return [self.session(space, d) for d, _peers in specs]

    @classmethod
    def available(cls) -> bool:
        return True

    def __repr__(self) -> str:  # pragma: no cover - debugging sugar
        return f"<PlacementBackend {self.name}>"


_REGISTRY: dict[str, Callable[[], PlacementBackend]] = {}
_INSTANCES: dict[str, PlacementBackend] = {}
# backends are stateless-shareable, but the check-then-create below must
# still be atomic so concurrent builds resolve ONE instance per name
_INSTANCES_LOCK = threading.Lock()

#: env var consulted when build_schedule is not given an explicit backend
BACKEND_ENV = "REPRO_PLACEMENT_BACKEND"
DEFAULT_BACKEND = "batched"


def register_backend(name: str, factory: Callable[[], PlacementBackend]) -> None:
    _REGISTRY[name] = factory


def available_backends() -> list[str]:
    return list(_REGISTRY)


def get_backend(which: str | PlacementBackend | None = None) -> PlacementBackend:
    """Resolve a backend instance from a name, instance, or the environment."""
    if isinstance(which, PlacementBackend):
        return which
    name = which or os.environ.get(BACKEND_ENV) or DEFAULT_BACKEND
    if name not in _REGISTRY:
        raise ValueError(f"unknown placement backend {name!r}; "
                         f"have {sorted(_REGISTRY)}")
    with _INSTANCES_LOCK:
        inst = _INSTANCES.get(name)
        if inst is None:
            inst = _INSTANCES[name] = _REGISTRY[name]()
    return inst
