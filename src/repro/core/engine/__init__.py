"""Placement engine: backend-abstracted, candidate-parallel placement core.

Layers:
  base      — PlacementBackend/PlacementSession protocol + registry
  reference — per-task numpy grid search (the semantic oracle)
  batched   — windowed ready-set feasibility scan, (n_tasks, m, W) lift
  jit       — the same scan as a jax.jit-compiled kernel (flag-gated)
  packing   — shared fit/score kernels for the online layers

Select with ``build_schedule(..., backend="batched")`` or the
``REPRO_PLACEMENT_BACKEND`` env var.  See docs/architecture.md.
"""

from .base import (BACKEND_ENV, BACKWARD, DEFAULT_BACKEND, FORWARD, PeerTask,
                   PlacementBackend, PlacementSession, available_backends,
                   get_backend, register_backend)
from .reference import ReferenceBackend
from .batched import BatchedBackend, scan_starts
from .jit import JitBackend
from . import packing

__all__ = [
    "BACKEND_ENV", "BACKWARD", "DEFAULT_BACKEND", "FORWARD", "PeerTask",
    "PlacementBackend", "PlacementSession", "available_backends",
    "get_backend", "register_backend", "ReferenceBackend", "BatchedBackend",
    "JitBackend", "scan_starts", "packing",
]
