"""Placement engine: backend-abstracted, candidate-parallel placement core.

Layers:
  base      — PlacementBackend/PlacementSession protocol + registry
  kernels   — kernel-dispatch layer: numpy / xla / pallas implementations
              of the scan + fit/score/heartbeat ops, selected per-op
  reference — per-task numpy grid search (the semantic oracle)
  batched   — windowed ready-set feasibility scan, (n_tasks, m, W) lift
  jit       — device-resident sessions: persistent grid mirror + bucketed
              donated buffers over the same scan (flag-gated)
  packing   — shared numpy fit/score kernels for the online layers

Select backends with ``build_schedule(..., backend="batched")`` or the
``REPRO_PLACEMENT_BACKEND`` env var; pin kernel implementations with
``REPRO_KERNELS`` (e.g. ``scan=xla``).  See docs/architecture.md.
"""

from .base import (BACKEND_ENV, BACKWARD, DEFAULT_BACKEND, FORWARD, PeerTask,
                   PlacementBackend, PlacementSession, available_backends,
                   get_backend, register_backend)
from . import kernels
from .kernels import scan_starts
from .reference import ReferenceBackend
from .batched import BatchedBackend
from .jit import JitBackend
from . import packing

__all__ = [
    "BACKEND_ENV", "BACKWARD", "DEFAULT_BACKEND", "FORWARD", "PeerTask",
    "PlacementBackend", "PlacementSession", "available_backends",
    "get_backend", "register_backend", "ReferenceBackend", "BatchedBackend",
    "JitBackend", "scan_starts", "packing", "kernels",
]
