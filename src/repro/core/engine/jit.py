"""jax.jit-compiled feasibility scan behind the ``jit`` backend flag.

Same placement semantics as the batched backend — only the window scan
kernel runs as a compiled XLA program.  Shapes are padded to coarse
buckets so the kernel retraces a handful of times per process instead of
once per window.

Exactness note: the grid is float32 while demands are float64, and the
reference scan compares them in float64.  XLA (without global x64) would
silently downcast the demand, which can flip boundary comparisons.  We
instead pre-round each demand *up* to the nearest float32
(``ceil32``): for float32 a and float64 v, ``a >= v`` iff
``a >= ceil32(v)``, so the all-float32 kernel is bit-identical to the
float64 comparison.

jax is a hard dependency of the wider repo but this module degrades
gracefully: ``JitBackend.available()`` is False when jax cannot be
imported, and ``get_backend("jit")`` then raises at session time.
"""

from __future__ import annotations

import functools

import numpy as np

from .base import ceil32, register_backend
from .batched import BatchedBackend, BatchedSession

try:  # gate the dependency: the numpy backends must work without jax
    import jax
    import jax.numpy as jnp

    _HAVE_JAX = True
except Exception:  # pragma: no cover - exercised only on jax-less installs
    jax, jnp = None, None
    _HAVE_JAX = False


def _pad_to(x: int, step: int) -> int:
    return ((x + step - 1) // step) * step


@functools.lru_cache(maxsize=None)
def _kernel():
    """The jitted scan: all-float32, shapes fixed per (g, m, L, W) bucket."""

    def scan(win, Vs, ks, W: int):
        # win (m, L, d) f32, Vs (g, d) f32, ks (g,) i32
        ok = (win[None, :, :, :] >= Vs[:, None, None, :]).all(axis=3)
        c = jnp.cumsum(ok.astype(jnp.int32), axis=2)
        cz = jnp.pad(c, ((0, 0), (0, 0), (1, 0)))
        L = win.shape[1]
        ends = jnp.minimum(jnp.arange(W)[None, :] + ks[:, None], L)
        idx = jnp.broadcast_to(ends[:, None, :], (Vs.shape[0], win.shape[0], W))
        run = jnp.take_along_axis(cz, idx, axis=2) - cz[:, :, :W]
        good = run == ks[:, None, None]          # (g, m, W)
        return jnp.swapaxes(good, 1, 2)          # (g, W, m)

    return jax.jit(scan, static_argnames=("W",))


class JitBackend(BatchedBackend):
    name = "jit"

    #: (m, d) pairs whose base kernel bucket has been compiled this process
    _prewarmed: set[tuple[int, int]] = set()

    @classmethod
    def available(cls) -> bool:
        return _HAVE_JAX

    @classmethod
    def prewarm(cls, m: int, d: int) -> None:
        """Compile the smallest (g, m, L, W) kernel bucket ahead of use.

        The scan shapes are padded to coarse buckets, so the very first
        window of a session otherwise pays XLA compilation plus backend
        dispatch warm-up inside the timed placement path.  Larger buckets
        still compile on demand (they are cheap once the backend is warm);
        this removes the multi-second first-dispatch hit at session start.
        """
        if not _HAVE_JAX or (m, d) in cls._prewarmed:
            return
        cls._prewarmed.add((m, d))
        win = np.full((m, 16, d), -1.0, dtype=np.float32)
        Vs = np.full((8, d), 2.0, dtype=np.float32)
        ks = np.ones(8, dtype=np.int32)
        np.asarray(_kernel()(win, Vs, ks, 16))

    @staticmethod
    def scan_kernel(avail, Vs, ks, plo, phi, reverse):
        if not _HAVE_JAX:  # pragma: no cover
            raise RuntimeError("placement backend 'jit' requires jax")
        m, T, d = avail.shape
        g = len(ks)
        W = phi - plo
        kmax = int(ks.max())
        hi_read = min(T, phi + kmax - 1)
        win = avail[:, plo:hi_read, :]
        # pad to buckets: L/W up to the next power of two, g to multiples
        # of 8.  Pad rows never fit (demand 2 > capacity 1) and pad ticks
        # never satisfy a run (avail -1), so they only produce False bits
        # that are sliced away below.
        Lp = max(16, 1 << int(np.ceil(np.log2(max(win.shape[1], 2)))))
        Wp = min(Lp, max(16, 1 << int(np.ceil(np.log2(max(W, 2))))))
        gp = _pad_to(g, 8)
        win_p = np.full((m, Lp, d), -1.0, dtype=np.float32)
        win_p[:, : win.shape[1], :] = win
        Vs_p = np.full((gp, d), 2.0, dtype=np.float32)
        Vs_p[:g] = ceil32(np.asarray(Vs))
        ks_p = np.ones(gp, dtype=np.int32)
        ks_p[:g] = ks
        good = np.asarray(_kernel()(win_p, Vs_p, ks_p, Wp))     # (gp, Wp, m)
        good = good[:g, :W, :]
        if reverse:
            good = good[:, ::-1, :]
        return np.ascontiguousarray(good).reshape(g, W * m)

    def session(self, space, direction: str) -> BatchedSession:
        if not _HAVE_JAX:
            raise RuntimeError("placement backend 'jit' requires jax; "
                               "use 'batched' or 'reference' instead")
        self.prewarm(space.m, space.d)
        return BatchedSession(space, direction, self)


register_backend("jit", JitBackend)
