"""Device-resident placement sessions behind the ``jit`` backend flag.

Same placement semantics as the batched backend — only the window scan
runs as a compiled XLA program against a *persistent device mirror* of
the Space's grid:

  * the mirror registers as a Space observer, so every ``commit`` and
    ``restore`` marks the touched tick range dirty instead of re-uploading
    a freshly padded window per scan (the pre-refactor behavior);
  * before a scan, only the dirty/unsynced slab is uploaded through a
    donated ``dynamic_update_slice`` — buffers are reused in place;
  * scan shapes are padded to the coarse buckets of
    ``core/engine/kernels.py`` whose compiled-function cache is bounded
    (``REPRO_JIT_BUCKET_CAP``) with retraces counted in
    ``kernels.XLA_STATS``;
  * small batches fall back to the numpy kernel through the dispatch
    layer: below ``MIN_DEVICE_G`` rows the launch overhead exceeds the
    tensor work on CPU hosts, and the fallback is outcome-identical by
    construction (all scan implementations are bit-equal).

Because ``PlacementBackend.sessions`` stacks every sibling variant's
prescan into one ``scan_kernel`` call, the multi-variant node prescan is
a single device launch under this backend (the ROADMAP's "jit-stacked
node prescan").

Exactness note: the grid is float32 while demands are float64, and the
reference scan compares them in float64.  XLA (without global x64) would
silently downcast the demand, which can flip boundary comparisons.  We
instead pre-round each demand *up* to the nearest float32 (``ceil32``):
for float32 a and float64 v, ``a >= v`` iff ``a >= ceil32(v)``, so the
all-float32 kernel is bit-identical to the float64 comparison.

jax is a hard dependency of the wider repo but this module degrades
gracefully: ``JitBackend.available()`` is False when jax cannot be
imported, and ``get_backend("jit")`` then raises at session time.
"""

from __future__ import annotations

import os
import threading
import time

import numpy as np

from . import kernels
from .base import ceil32, register_backend
from .batched import WINDOW0, BatchedBackend, BatchedSession

try:  # gate the dependency: the numpy backends must work without jax
    import jax
    import jax.numpy as jnp
    from jax import lax

    _HAVE_JAX = True
except Exception:  # pragma: no cover - exercised only on jax-less installs
    jax, jnp, lax = None, None, None
    _HAVE_JAX = False


#: batches smaller than this run the numpy scan instead (outcome-identical;
#: on CPU hosts the device launch overhead dominates below ~16 rows, while
#: a real accelerator amortizes a launch over ~4).  ``None`` means "not
#: resolved yet": ``min_device_g()`` consults REPRO_JIT_MIN_BATCH first and
#: otherwise auto-tunes by the detected jax platform.  Tests monkeypatch
#: this module attribute directly with an int.
MIN_DEVICE_G: int | None = None


def _detect_min_batch() -> int:
    """Default device batch floor: ~4 on real accelerators, 16 on CPU."""
    env = os.environ.get("REPRO_JIT_MIN_BATCH")
    if env:
        return int(env)
    if not _HAVE_JAX:
        return 16
    try:
        platform = jax.default_backend()
    except Exception:  # pragma: no cover - backend init failure
        return 16
    return 4 if platform != "cpu" else 16


def min_device_g() -> int:
    """Resolved device batch floor (cached in ``MIN_DEVICE_G``)."""
    global MIN_DEVICE_G
    if MIN_DEVICE_G is None:
        MIN_DEVICE_G = _detect_min_batch()
    return MIN_DEVICE_G


#: profile counters surfaced in the construction bench rows
PROFILE = {"device_calls": 0, "fallback_calls": 0, "sync_cells": 0,
           "scan_seconds": 0.0}

#: guards PROFILE: concurrent builds (core/buildsvc.py thread mode) run
#: device sessions from worker threads and unlocked ``+=`` drops counts
_PROF_LOCK = threading.Lock()


def _prof_add(key: str, n) -> None:
    with _PROF_LOCK:
        PROFILE[key] += n


def reset_profile() -> None:
    with _PROF_LOCK:
        for k in PROFILE:
            PROFILE[k] = 0.0 if k == "scan_seconds" else 0


# eager construct (a lazy ``if X is None`` init races under threads); the
# builder lambda touches jax only when a key is actually built
_UPDATE_FNS = kernels._BucketCache(
    lambda *k: jax.jit(
        lambda buf, slab, idx: lax.dynamic_update_slice(
            buf, slab, (0, idx, 0)),
        donate_argnums=0))


def _update_fn(m: int, Sb: int, d: int, Tb: int):
    """Donated slab writer for one (m, Sb, d, Tb) shape bucket.

    Keyed through the shared bounded cache so these compiles are counted
    in ``kernels.XLA_STATS`` and capped like the scan buckets (the buffer
    length Tb is part of the trace signature — both slab and buffer sit
    on coarse ladders, so the key set stays small).
    """
    return _UPDATE_FNS.get((m, Sb, d, Tb))


class DeviceGrid:
    """Persistent device mirror of one Space's capacity grid.

    Keeps a (m, Tb, d) float32 buffer on the default jax device, addressed
    in *logical* ticks (stable across ``Space._grow_front``, which only
    shifts the physical origin).  ``synced`` is the logical interval where
    device == host; ``dirty`` the sub-interval invalidated by commits or
    restores since the last upload.  ``ensure(lo, hi)`` uploads exactly one
    bridging slab (bucketed length, donated buffer) so both intervals stay
    intervals — worst case the slab spans the occupied grid, typically it
    is the few frontier ticks the last commits touched.
    """

    def __init__(self, space):
        self.space = space
        self.buf = None
        self.base = 0            # logical tick of buffer index 0
        self.Tb = 0
        self.s0 = self.s1 = 0    # synced logical interval [s0, s1)
        self.d0 = self.d1 = 0    # dirty sub-interval [d0, d1)
        space.add_observer(self)

    # -- Space.observer protocol ---------------------------------------
    def on_commit(self, task, machine, start, k, v) -> None:
        self._mark(start, start + k)

    def on_restore(self, n_placed, lo, hi) -> None:
        if lo is not None:
            self._mark(lo, hi)
        # a shrink drops cells; if the grid later regrows there, host
        # content restarts at 1.0 while the mirror kept old values — clamp
        # the synced interval to the live extent so those cells re-upload
        sp = self.space
        self.s0 = max(self.s0, sp.grid_start)
        self.s1 = min(self.s1, sp.grid_end)
        if self.s0 >= self.s1:
            self.s0 = self.s1 = 0
        self.d0 = max(self.d0, self.s0)
        self.d1 = min(self.d1, self.s1)

    def _mark(self, lo: int, hi: int) -> None:
        if self.s0 >= self.s1:
            return                       # nothing synced: nothing to dirty
        lo, hi = max(lo, self.s0), min(hi, self.s1)
        if lo >= hi:
            return
        if self.d0 >= self.d1:
            self.d0, self.d1 = lo, hi
        else:
            self.d0, self.d1 = min(self.d0, lo), max(self.d1, hi)

    # ------------------------------------------------------------------
    @staticmethod
    def alloc_len(span: int) -> int:
        """Buffer-length ladder for a grid span.

        Floored at the largest first-window read (WINDOW0 + LONG_K) so
        that ``prewarm`` — which only knows the Space's grid length — and
        ``_alloc`` — which also sees the first scan's request — land on
        the same bucket, keeping the prewarmed trace the one live
        sessions actually launch.
        """
        return kernels.bucket(2 * max(span, WINDOW0 + kernels.LONG_K),
                              floor=256)

    def _alloc(self, lo: int, hi: int) -> None:
        """(Re)allocate the buffer so logical [lo, hi) maps inside with
        headroom for growth at both ends; invalidates the synced state."""
        sp = self.space
        self.Tb = self.alloc_len(max(hi - lo, sp.T))
        self.base = lo - (self.Tb - (hi - lo)) // 2
        self.buf = jnp.ones((sp.m, self.Tb, sp.d), dtype=jnp.float32)
        self.s0 = self.s1 = 0
        self.d0 = self.d1 = 0

    def ensure(self, lo: int, hi: int, slack: int) -> None:
        """Make device == host over logical [lo, hi); keep ``slack`` ticks
        past ``lo`` inside the buffer (the scan's fixed-size dynamic_slice
        must not get clamped)."""
        if self.buf is None or lo < self.base or lo + slack > self.base + self.Tb:
            self._alloc(lo, max(hi, lo + slack))
        # pieces of [lo, hi) that are not clean on device right now
        pieces = []
        if self.s0 >= self.s1:
            pieces.append((lo, hi))
        else:
            if self.d0 < self.d1:                     # stale synced cells
                p0, p1 = max(self.d0, lo), min(self.d1, hi)
                if p0 < p1:
                    pieces.append((p0, p1))
            if lo < self.s0:                          # never-synced cells
                pieces.append((lo, min(hi, self.s0)))
            if hi > self.s1:
                pieces.append((max(lo, self.s1), hi))
        if not pieces:
            return
        u0 = min(p[0] for p in pieces)
        u1 = max(p[1] for p in pieces)
        if self.s0 < self.s1:
            # bridge to the synced interval so it stays one interval
            if u0 > self.s1:
                u0 = self.s1
            elif u1 < self.s0:
                u1 = self.s0
        sp = self.space
        # bucket the slab length (bounds update-fn retraces); clamp the
        # bucketed extension inside the buffer
        Sb = min(kernels.bucket(u1 - u0), self.Tb)
        if u0 + Sb > self.base + self.Tb:
            u0 = self.base + self.Tb - Sb
        u1 = u0 + Sb
        # cells beyond the live grid upload as 1.0 — exactly the content
        # the host materializes if the grid later grows there; until then
        # the scan's t_live mask keeps them invisible
        slab = np.ones((sp.m, Sb, sp.d), dtype=np.float32)
        h0, h1 = max(u0, sp.grid_start), min(u1, sp.grid_end)
        if h0 < h1:
            slab[:, h0 - u0 : h1 - u0, :] = np.ascontiguousarray(
                sp.avail[:, h0 + sp.off : h1 + sp.off, :])
        fn = _update_fn(sp.m, Sb, sp.d, self.Tb)
        self.buf = fn(self.buf, slab, np.int32(u0 - self.base))
        _prof_add("sync_cells", sp.m * Sb * sp.d)
        if self.s0 >= self.s1:
            self.s0, self.s1 = u0, u1
        else:
            self.s0, self.s1 = min(self.s0, u0), max(self.s1, u1)
        # drop the covered part of the dirty interval; an upload strictly
        # inside it keeps the hull (conservative: re-uploads a few clean
        # cells later rather than ever trusting a stale one)
        if self.d0 < self.d1:
            if u0 <= self.d0 and self.d1 <= u1:
                self.d0 = self.d1 = 0
            elif self.d0 < u0 and self.d1 > u1:
                pass
            elif self.d0 < u0:
                self.d1 = min(self.d1, u0)
            else:
                self.d0 = max(self.d0, u1)

    # ------------------------------------------------------------------
    def launch(self, Vs, ks, plo, phi, reverse) -> "_DeviceRows":
        """Asynchronous device scan: syncs the mirror, launches the kernel,
        returns a lazy row view — no host/device round trip until a row is
        actually walked.  The computation captures the grid state at launch
        (jax arrays are immutable), so later commits/restores cannot leak
        into the result; the session's version/edge logic treats the bitmap
        exactly like a synchronous scan of the same state."""
        t0 = time.perf_counter()
        sp = self.space
        m, T, d = sp.avail.shape
        g = len(ks)
        W = phi - plo
        kmax = int(ks.max())
        hi_read = min(T, phi + kmax - 1)
        lo_l = plo - sp.off                  # logical window start
        hi_l = hi_read - sp.off
        gb, Lb, Wb = kernels.scan_buckets(g, W, kmax)
        self.ensure(lo_l, hi_l, slack=Lb)
        Vs_p = np.full((gb, d), 2.0, dtype=np.float32)
        Vs_p[:g] = ceil32(np.asarray(Vs))
        ks_p = np.ones(gb, dtype=np.int32)
        ks_p[:g] = ks
        kernels.stat_add("scan_calls")
        fn = kernels.scan_fn_for(m, d, gb, Lb, Wb, self.Tb)
        dev = fn(self.buf, np.int32(lo_l - self.base),
                 np.int32(hi_l - lo_l), Vs_p, ks_p)
        _prof_add("device_calls", 1)
        _prof_add("scan_seconds", time.perf_counter() - t0)
        return _DeviceRows(dev, W, m, reverse)


class _DeviceRows:
    """Lazy view over an in-flight device scan's (g, Wb, m) result.

    ``row(i)`` blocks on the computation once (first access) and hands out
    walk-order (W*m,) bitmap rows; unused rows are never transferred."""

    __slots__ = ("dev", "host", "W", "m", "reverse")

    def __init__(self, dev, W: int, m: int, reverse: bool):
        self.dev = dev
        self.host = None
        self.W = W
        self.m = m
        self.reverse = reverse

    def row(self, i: int) -> np.ndarray:
        if self.host is None:
            self.host = np.asarray(self.dev)
            self.dev = None
        row = self.host[i, : self.W, :]
        if self.reverse:
            row = row[::-1]
        return np.ascontiguousarray(row).reshape(self.W * self.m)

    def loader(self, i: int):
        return lambda: self.row(i)


class JitBackend(BatchedBackend):
    name = "jit"

    #: (m, d, buffer-bucket) triples already compiled this process
    _prewarmed: set[tuple[int, int, int]] = set()
    #: held across a prewarm so concurrent sessions (build service) don't
    #: duplicate the compile work — late arrivals wait on the winner
    _prewarm_lock = threading.Lock()

    @classmethod
    def available(cls) -> bool:
        return _HAVE_JAX

    @classmethod
    def prewarm(cls, m: int, d: int, T: int | None = None) -> None:
        """Compile the common kernel buckets ahead of use.

        The scan shapes are padded to coarse buckets, so the very first
        windows of a session otherwise pay XLA compilation plus backend
        dispatch warm-up inside the timed placement path.  Larger buckets
        still compile on demand (they are cheap once the backend is warm);
        this removes the multi-second first-dispatch hit at session start.

        ``T`` (the Space's physical grid length) predicts the device
        mirror's buffer-length bucket, which is part of the scan's trace
        signature; a later reallocation to a different bucket compiles on
        demand (counted in ``kernels.XLA_STATS``).
        """
        if not _HAVE_JAX:
            return
        Tb = DeviceGrid.alloc_len(T if T is not None else 0)
        with cls._prewarm_lock:
            if (m, d, Tb) in cls._prewarmed:
                return
            cls._prewarmed.add((m, d, Tb))
            # compile the buckets real sessions hit: device launches carry
            # the g-1 peer rows of batches >= min_device_g(), so gb starts
            # at pad8(max(min_device_g(), 2) - 1), and the first-window
            # shape is (Wb=WINDOW0, Lb=Wb+{SHORT_K,LONG_K})
            gb0 = kernels.pad8(max(min_device_g(), 2) - 1)
            buf = jnp.ones((m, Tb, d), dtype=jnp.float32)
            for gb in (gb0, gb0 + 8):
                Vs = np.full((gb, d), 2.0, dtype=np.float32)
                ks = np.ones(gb, dtype=np.int32)
                for kmax in (kernels.SHORT_K, kernels.LONG_K):
                    _gb, Lb, Wb = kernels.scan_buckets(gb, WINDOW0, kmax)
                    np.asarray(kernels.scan_fn_for(m, d, gb, Lb, Wb, Tb)(
                        buf, np.int32(0), np.int32(16), Vs, ks))

    @staticmethod
    def mirror(space) -> DeviceGrid:
        """The Space's device mirror, created on first use."""
        dg = getattr(space, "_device_grid", None)
        if dg is None:
            dg = DeviceGrid(space)
            space._device_grid = dg
        return dg

    def scan_kernel(self, space, Vs, ks, plo, phi, reverse):
        if not _HAVE_JAX:  # pragma: no cover
            raise RuntimeError("placement backend 'jit' requires jax")
        g = len(ks)
        if g < max(min_device_g(), 2):
            # outcome-identical numpy fallback: launch overhead beats the
            # tensor work for tiny batches, and the hybrid split below
            # needs at least one peer row (see module docstring)
            _prof_add("fallback_calls", 1)
            return kernels.scan(space.avail, Vs, ks, plo, phi, reverse)
        # hybrid split: row 0 — the task the session walks immediately —
        # runs through the numpy g=1 fast path so the caller never blocks
        # on the device; the peer rows launch asynchronously and
        # materialize when (if ever) their tasks pop, by which time the
        # device compute has finished behind the host-side walk
        row0 = kernels.scan(space.avail, Vs[:1], ks[:1], plo, phi, reverse)
        rows = self.mirror(space).launch(Vs[1:], ks[1:], plo, phi, reverse)
        out = [None] * g
        out[0] = lambda: row0[0]
        for i in range(1, g):
            out[i] = rows.loader(i - 1)
        return out

    def session(self, space, direction: str) -> BatchedSession:
        if not _HAVE_JAX:
            raise RuntimeError("placement backend 'jit' requires jax; "
                               "use 'batched' or 'reference' instead")
        self.prewarm(space.m, space.d, space.T)
        return BatchedSession(space, direction, self)


register_backend("jit", JitBackend)
