"""Shared multi-resource fit/score kernels (the online placement core).

The offline builder places tasks into the virtual resource-time space; the
work-conserving executor (`core.baselines.simulate_execution`), the online
matcher (`core.online.Matcher`) and the cluster simulator
(`sim.cluster.ClusterSim`) all answer the same two questions about *now*:

  * which candidate tasks fit into a machine's remaining capacity, and
  * how well does a task pack there (Tetris dot-product score §5).

These kernels are that shared core, so every layer uses identical epsilon
and dimension-subset semantics.

They are also the ``numpy`` implementations — the exact float64 oracles —
of the corresponding ops in the kernel-dispatch layer
(``core/engine/kernels.py``), which layers xla/pallas variants on top.
Decision-bearing callers (the matcher's bundling loop, speculative-copy
placement) import this module directly on purpose: those must never be
rerouted to an approximate implementation.  Skip-only callers go through
the dispatch wrappers instead.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

EPS = 1e-9


def fits_mask(
    avail: np.ndarray,
    demand: np.ndarray,
    dims: Sequence[int] | np.ndarray | None = None,
    slack: float = 0.0,
    eps: float = EPS,
) -> np.ndarray:
    """Boolean fit test, broadcasting over machines and/or candidates.

    avail  — (d,) one machine, or (m, d) many machines
    demand — (d,) one task, or (n, d) many tasks
    dims   — resource dims the scheduler checks (None = all)
    slack  — extra headroom per checked dim (overbooking allowance)

    Returns the broadcast ``.all``-over-dims result: (), (m,), (n,), or
    (n, m) depending on the inputs.
    """
    avail = np.asarray(avail)
    demand = np.asarray(demand)
    if dims is not None:
        dims = np.asarray(dims, dtype=np.int64)
        if len(dims) == 0:
            if avail.ndim == 2 and demand.ndim == 2:  # (n, m) orientation
                return np.ones((demand.shape[0], avail.shape[0]), dtype=bool)
            shape = np.broadcast_shapes(avail.shape[:-1], demand.shape[:-1])
            return np.ones(shape, dtype=bool)
        avail = avail[..., dims]
        demand = demand[..., dims]
    if avail.ndim == 2 and demand.ndim == 2:
        return (demand[:, None, :] <= avail[None, :, :] + slack + eps).all(axis=2)
    return (demand <= avail + slack + eps).all(axis=-1)


def pack_score(
    avail: np.ndarray,
    demand: np.ndarray,
    clip: bool = False,
) -> np.ndarray:
    """Tetris packing score: dot(demand, available) (§5 pScore).

    avail (d,) or (m, d); demand (d,) or (n, d).  Returns (), (m,), (n,)
    or (n, m).  ``clip`` floors availability at 0 first (overbooked
    machines report negative headroom).
    """
    avail = np.asarray(avail, dtype=np.float64)
    if clip:
        avail = np.clip(avail, 0.0, None)
    demand = np.asarray(demand)
    if avail.ndim == 2 and demand.ndim == 2:
        return demand @ avail.T
    return demand @ np.swapaxes(np.atleast_2d(avail), -1, -2).squeeze()


def heartbeat_masks(
    avail: np.ndarray,
    demands: np.ndarray,
    fit_dims: Sequence[int] | np.ndarray,
    rigid_dims: Sequence[int] | np.ndarray,
    fungible_dims: Sequence[int] | np.ndarray,
    overbook_slack: float = 0.0,
    use_overbooking: bool = True,
) -> tuple[np.ndarray, np.ndarray]:
    """Fit/overbook masks for every (candidate, machine) pair of a heartbeat.

    avail (m, d), demands (n, d).  Returns ``(fits, over)`` as (n, m) bool
    arrays with exactly the matcher's per-machine semantics: ``fits`` is a
    fit on all checked dims; ``over`` marks candidates that do not fit but
    whose rigid dims fit outright and whose fungible dims fit within the
    bounded overbooking allowance.  Pure comparisons — no float arithmetic
    beyond the same ``avail + slack + eps`` adds the scalar path performs —
    so a row of this matrix equals the per-machine masks bit-for-bit.
    """
    fits = fits_mask(np.atleast_2d(avail), np.atleast_2d(demands),
                     dims=np.asarray(fit_dims))
    if not use_overbooking:
        return fits, np.zeros_like(fits)
    over = (~fits
            & fits_mask(np.atleast_2d(avail), np.atleast_2d(demands),
                        dims=np.asarray(rigid_dims))
            & fits_mask(np.atleast_2d(avail), np.atleast_2d(demands),
                        dims=np.asarray(fungible_dims), slack=overbook_slack))
    return fits, over


def machines_with_candidates(
    avail: np.ndarray,
    demands: np.ndarray,
    fit_dims: Sequence[int] | np.ndarray,
    rigid_dims: Sequence[int] | np.ndarray,
    fungible_dims: Sequence[int] | np.ndarray,
    overbook_slack: float = 0.0,
    use_overbooking: bool = True,
) -> tuple[np.ndarray, np.ndarray]:
    """Which machines could start *some* candidate this heartbeat.

    Returns ``(eligible (n, m) bool, machine_any (m,) bool)`` where
    ``eligible = fits | over`` from :func:`heartbeat_masks`.  A machine
    whose column has no True bit cannot pick anything, so a matcher call
    for it is a guaranteed no-op (no picks, no deficit/EMA mutation) and
    may be skipped without changing any scheduling decision.

    A cheap exact pre-filter runs first: the per-dim minimum demand over
    candidates is a lower bound on every candidate, so a machine that
    cannot fit even that minimum on some rigid dim (or some fungible dim
    within slack) hosts nothing; full (n, m) masks are only computed for
    the machines that survive.
    """
    avail = np.atleast_2d(avail)
    demands = np.atleast_2d(demands)
    m = avail.shape[0]
    n = demands.shape[0]
    eligible = np.zeros((n, m), dtype=bool)
    if n == 0:
        return eligible, np.zeros(m, dtype=bool)
    min_dem = demands.min(axis=0)
    rigid = np.asarray(rigid_dims, dtype=np.int64)
    fung = np.asarray(fungible_dims, dtype=np.int64)
    survive = fits_mask(avail, min_dem, dims=rigid)
    if use_overbooking:
        # clamp the prefilter slack at 0 so fitting candidates survive even
        # under a sub-1.0 overbooking cap (fits ⊆ rigid-fit ∧ fung-fit)
        survive &= fits_mask(avail, min_dem, dims=fung,
                             slack=max(overbook_slack, 0.0))
    else:
        survive &= fits_mask(avail, min_dem, dims=np.asarray(fit_dims))
    idx = np.flatnonzero(survive)
    if len(idx):
        fits, over = heartbeat_masks(avail[idx], demands, fit_dims, rigid,
                                     fung, overbook_slack, use_overbooking)
        eligible[:, idx] = fits | over
    return eligible, eligible.any(axis=0)


def best_fit_machines(
    avail: np.ndarray,
    demands: np.ndarray,
    dims: Sequence[int] | np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-candidate best machine by packing score among fitting machines.

    avail (m, d), demands (n, d).  Returns (ok (n, m), best_m (n,),
    best_score (n,)); best entries are -inf where nothing fits.
    """
    dsel = demands if dims is None else demands[:, np.asarray(dims)]
    asel = avail if dims is None else avail[:, np.asarray(dims)]
    ok = (asel[None, :, :] >= dsel[:, None, :] - EPS).all(axis=2)   # (n, m)
    scores = np.where(ok, dsel @ asel.T, -np.inf)
    best_m = np.argmax(scores, axis=1)
    best_s = scores[np.arange(len(demands)), best_m]
    return ok, best_m, best_s
