"""Shared multi-resource fit/score kernels (the online placement core).

The offline builder places tasks into the virtual resource-time space; the
work-conserving executor (`core.baselines.simulate_execution`), the online
matcher (`core.online.Matcher`) and the cluster simulator
(`sim.cluster.ClusterSim`) all answer the same two questions about *now*:

  * which candidate tasks fit into a machine's remaining capacity, and
  * how well does a task pack there (Tetris dot-product score §5).

These kernels are that shared core, so every layer uses identical epsilon
and dimension-subset semantics.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

EPS = 1e-9


def fits_mask(
    avail: np.ndarray,
    demand: np.ndarray,
    dims: Sequence[int] | np.ndarray | None = None,
    slack: float = 0.0,
    eps: float = EPS,
) -> np.ndarray:
    """Boolean fit test, broadcasting over machines and/or candidates.

    avail  — (d,) one machine, or (m, d) many machines
    demand — (d,) one task, or (n, d) many tasks
    dims   — resource dims the scheduler checks (None = all)
    slack  — extra headroom per checked dim (overbooking allowance)

    Returns the broadcast ``.all``-over-dims result: (), (m,), (n,), or
    (n, m) depending on the inputs.
    """
    avail = np.asarray(avail)
    demand = np.asarray(demand)
    if dims is not None:
        dims = np.asarray(dims, dtype=np.int64)
        if len(dims) == 0:
            shape = np.broadcast_shapes(avail.shape[:-1], demand.shape[:-1])
            return np.ones(shape, dtype=bool)
        avail = avail[..., dims]
        demand = demand[..., dims]
    if avail.ndim == 2 and demand.ndim == 2:
        return (demand[:, None, :] <= avail[None, :, :] + slack + eps).all(axis=2)
    return (demand <= avail + slack + eps).all(axis=-1)


def pack_score(
    avail: np.ndarray,
    demand: np.ndarray,
    clip: bool = False,
) -> np.ndarray:
    """Tetris packing score: dot(demand, available) (§5 pScore).

    avail (d,) or (m, d); demand (d,) or (n, d).  Returns (), (m,), (n,)
    or (n, m).  ``clip`` floors availability at 0 first (overbooked
    machines report negative headroom).
    """
    avail = np.asarray(avail, dtype=np.float64)
    if clip:
        avail = np.clip(avail, 0.0, None)
    demand = np.asarray(demand)
    if avail.ndim == 2 and demand.ndim == 2:
        return demand @ avail.T
    return demand @ np.swapaxes(np.atleast_2d(avail), -1, -2).squeeze()


def best_fit_machines(
    avail: np.ndarray,
    demands: np.ndarray,
    dims: Sequence[int] | np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-candidate best machine by packing score among fitting machines.

    avail (m, d), demands (n, d).  Returns (ok (n, m), best_m (n,),
    best_score (n,)); best entries are -inf where nothing fits.
    """
    dsel = demands if dims is None else demands[:, np.asarray(dims)]
    asel = avail if dims is None else avail[:, np.asarray(dims)]
    ok = (asel[None, :, :] >= dsel[:, None, :] - EPS).all(axis=2)   # (n, m)
    scores = np.where(ok, dsel @ asel.T, -np.inf)
    best_m = np.argmax(scores, axis=1)
    best_s = scores[np.arange(len(demands)), best_m]
    return ok, best_m, best_s
