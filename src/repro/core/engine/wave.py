"""Device-resident heartbeat wave: fused eligibility→score→pick.

One wave of the online matcher (`ShardedMatcher.match_wave`) is, on the
host path, a Python loop over machines in descending-capacity order, each
iteration running `Matcher.match_batch` — the last per-heartbeat O(m)
Python loop in the system.  This module turns the whole wave into one
registry op (``match_wave`` in `core/engine/kernels.py`) with three
implementations:

  numpy  — the extracted host loop, bit-for-bit the historical wave
           (now passing the wave's ``active`` mask into ``match_batch``
           instead of compressing the batch per machine).
  xla    — a ``lax.scan`` over the host-computed machine order that fuses
           eligibility, pack scoring, bundling/deficit gating and the
           ``avail[m] -= demand`` update into ONE device launch per wave,
           plus at most one dirty-row upload launch.
  pallas — the same fused walk as a single sequential Pallas program
           (`kernels/placement_scan`), interpret-validated off-TPU.

Exactness.  The matcher's decisions must stay bit-identical to the numpy
oracle, which rules out float32 and *also* rules out letting XLA contract
multiply→add chains into fused-multiply-adds (XLA CPU contracts them
unconditionally; ``--xla_allow_excess_precision=false`` does not stop it).
Two measures make the device arithmetic reproduce numpy float64 exactly:

  * every float op runs in float64 under ``jax.experimental.enable_x64``;
  * every product that feeds an add/sub is *laundered* through
    ``where(p == p, p, 0.0)`` — a bitwise identity XLA cannot see through,
    so the add rounds the already-rounded product exactly like numpy does.

The one numpy op with no portable bit pattern — the BLAS matvec the
matcher used for its packing score — was reformulated in
`core/online.py::seq_dot` as an explicit left-to-right accumulation, which
both the numpy oracle and the device kernels now share.

State residency.  A `DeviceWaveState` (one per `ShardedMatcher`) keeps the
``avail`` matrix, candidate columns, EMA pair and dense deficit ledger on
device across waves.  Host-side shadows detect what actually changed:
``avail`` rows touched by task finishes/failures re-upload (dirty rows
only), candidate columns re-upload only when `TaskPool.refresh` rebuilt
them (array identity), and the EMA/deficit ledgers re-upload only if the
host replay diverged from the device's own update (never, absent external
edits).  Per-wave host↔device traffic is therefore the machine order, the
dirty state, and the picks list — not the O(n×m) eligibility matrix of
the PR 6 path (``match_wave.*.bytes_*`` PROFILE counters quantify it).

Fault seam: the op dispatches through ``kernels._run_op``, so an injected
``kernel_impl`` fault (or a real kernel failure) sticky-demotes the wave
back to the numpy loop mid-run with zero decision drift — the device
impls mutate no matcher state before their launch returns.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Callable

import numpy as np

from . import packing

if TYPE_CHECKING:  # runtime import would cycle: online -> engine -> here
    from ..online import CandidateBatch


@dataclasses.dataclass
class WaveContext:
    """One heartbeat wave's inputs, handed to the ``match_wave`` op.

    ``start_cb(row, machine)`` applies a pick's side effects (including
    the host ``avail[machine] -= demand`` update); rows index ``batch``.
    """

    sm: object                 # the owning core.shard.ShardedMatcher
    avail: np.ndarray          # (m, d) float64, mutated by start_cb
    alive: np.ndarray          # (m,) bool
    batch: CandidateBatch
    start_cb: Callable[[int, int], None]


# ----------------------------------------------------------------------
# numpy implementation — the reference wave loop
# ----------------------------------------------------------------------

def match_wave_numpy(ctx: WaveContext) -> int:
    """The host wave loop (decision oracle for the fused kernels).

    Identical to the historical `ShardedMatcher.match_wave` body except
    that the wave's ``active`` mask is passed straight into
    ``match_batch`` (O(1) per-machine allocations) instead of compressing
    the batch with ``batch.take`` per machine (O(m) copies per wave) —
    decision-identical, see `Matcher.match_batch`.
    """
    sm, avail, alive, batch = ctx.sm, ctx.avail, ctx.alive, ctx.batch
    start_cb = ctx.start_cb
    eligible, machine_any = sm.eligibility(avail, batch.dem)
    active = np.ones(len(batch), dtype=bool)
    n_active = len(batch)
    order = np.argsort(-avail.sum(axis=1))
    # visit only machines that can possibly pick: dead, drained, or
    # candidate-less machines are guaranteed matcher no-ops
    ok = (alive[order] & (avail[order] > 1e-9).any(axis=1)
          & machine_any[order])
    matcher = sm.matcher
    cfg = sm.cfg
    n_picks = 0
    for m in order[ok].tolist():
        if n_active == 0:
            break
        if not (eligible[:, m] & active).any():
            continue
        picks = matcher.match_batch(m, avail[m], batch, active=active)
        if picks:
            ledger = sm.shard_matchers[sm.plan.shard_of(m)].deficits
            for gi, _over in picks:
                start_cb(gi, m)
                active[gi] = False
                ledger.allocated(int(batch.grp[gi]),
                                 cfg.fairness(batch.dem[gi]))
            n_active -= len(picks)
            n_picks += len(picks)
    return n_picks


# ----------------------------------------------------------------------
# fused device kernel (shared by the xla and pallas implementations)
# ----------------------------------------------------------------------

try:
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import enable_x64

    _HAVE_JAX = True
except Exception:  # pragma: no cover - jax-less installs use numpy only
    jax = jnp = lax = enable_x64 = None
    _HAVE_JAX = False

#: consts vector layout (f64 scalars passed per call, not traced into the
#: compiled bucket): eps, overbook slack, remote penalty, eta_m,
#: must-serve threshold (kappa * capacity), EMA step a, 1 - a, srpt floor
_C_EPS, _C_SLACK, _C_RP, _C_ETA_M, _C_THRESH, _C_A, _C_1MA, _C_FLOOR = \
    range(8)
_EMA_A = 0.05
_EMA_1MA = 1.0 - 0.05          # bound host-side once; uploaded, never re-derived
_SRPT_FLOOR = 1e-12


def _launder(p):
    """Bitwise identity that XLA cannot fold away: blocks the (otherwise
    unconditional on CPU) contraction of this product into an FMA with a
    following add/sub, so the sum rounds the product exactly like numpy."""
    return jnp.where(p == p, p, 0.0)


def wave_core(avail, order, dem, pri, srpt, gidx, loc, taken0, ema,
              deficit, share, fd_mask, rd_mask, fg_mask, consts, *,
              bundle_limit: int, use_packing: bool, use_srpt: bool,
              use_overbooking: bool, drf: bool):
    """The fused wave: scan machines in order, bundle picks per machine.

    Pure jnp/f64 — traced both by the jitted xla implementation and
    inside the Pallas kernel, so the two device paths share one set of
    semantics.  Every float op mirrors one numpy op of
    `Matcher.match_batch` in the same order (see module docstring).

    Shapes: avail (m, d) f64 resident; order (m,) i32 (visit order,
    -1-padded after host-side alive/drained filtering — visiting a
    machine the host would have *skipped* is decision-free, it can pick
    nothing and mutates nothing, so the eligibility prefilter needs no
    launch of its own); dem (n, d) f64 with pad rows pre-taken; deficit
    (G,) f64 dense in ledger key order, -inf pads (share pads 0, so
    ``allocated`` leaves them at -inf and ``argmax`` never picks one).

    Returns (avail', ema', deficit', rows, machines, over, obs, count):
    the pick list in pick order plus each pick's observed score
    ``pri*base`` (host-side EMA replay needs it — ``base`` depends on the
    in-kernel local avail the host never sees).
    """
    n = dem.shape[0]
    d = dem.shape[1]
    eps = consts[_C_EPS]
    neg_inf = jnp.float64(-jnp.inf)
    i32 = jnp.int32

    def visit(carry, mid):
        avail, taken, ema_s, ema_r, deficit, rows, mach, overf, obs, cnt \
            = carry
        row0 = avail[mid]
        rp = jnp.where((loc >= 0) & (loc != mid), consts[_C_RP], 1.0)

        def body(st):
            (j, local, row, taken, ema_s, ema_r, deficit, rows, mach,
             overf, obs, cnt, _stop) = st
            # -- eligibility (exact f64; masked-out dims always pass) ---
            thr = local + eps
            fits = jnp.where(fd_mask[None, :], dem <= thr[None, :],
                             True).all(axis=1)
            if use_overbooking:
                thr_g = (local + consts[_C_SLACK]) + eps
                over = (~fits
                        & jnp.where(rd_mask[None, :], dem <= thr[None, :],
                                    True).all(axis=1)
                        & jnp.where(fg_mask[None, :], dem <= thr_g[None, :],
                                    True).all(axis=1))
            else:
                over = jnp.zeros(n, dtype=bool)
            eligible = (fits | over) & ~taken
            # -- deficit gating (dense mirror of DeficitCounters) -------
            gstar = jnp.argmax(deficit).astype(i32)
            forced = eligible & (gidx == gstar)
            use_forced = (deficit[gstar] >= consts[_C_THRESH]) & forced.any()
            eligible = jnp.where(use_forced, forced, eligible)
            any_elig = eligible.any()
            # -- scoring (seq_dot mirror; every product laundered) ------
            if use_packing:
                av = jnp.clip(local, 0.0, None)
                acc = _launder(dem[:, 0] * av[0])
                for k in range(1, d):
                    acc = acc + _launder(dem[:, k] * av[k])
                dot = acc * rp
            else:
                dot = rp
            overshoot = jnp.clip(
                jnp.where(fg_mask[None, :], dem - local[None, :],
                          neg_inf).max(axis=1), 0.0, None)
            base = jnp.where(fits, dot,
                             dot * jnp.maximum(1.0 - overshoot, 0.05))
            if use_srpt:
                eta = (consts[_C_ETA_M] * ema_s
                       / jnp.maximum(ema_r, consts[_C_FLOOR]))
            else:
                eta = jnp.float64(0.0)
            perf = _launder(pri * base) - _launder(eta * srpt)
            pool_fit = eligible & fits
            pool = jnp.where(pool_fit.any(), pool_fit, eligible)
            score = jnp.where(pool, perf, neg_inf)
            i = jnp.argmax(score)
            ok = any_elig & jnp.isfinite(score[i])
            # -- apply the pick (no-ops when ~ok) -----------------------
            obs_i = pri[i] * base[i]
            w = dem[i].max() if drf else jnp.float64(1.0)
            taken = taken.at[i].set(taken[i] | ok)
            ema_s = jnp.where(
                ok, _launder(consts[_C_1MA] * ema_s)
                + _launder(consts[_C_A] * obs_i), ema_s)
            ema_r = jnp.where(
                ok, _launder(consts[_C_1MA] * ema_r)
                + _launder(consts[_C_A]
                           * jnp.maximum(srpt[i], consts[_C_FLOOR])), ema_r)
            deficit = jnp.where(
                ok, (deficit + _launder(share * w)).at[gidx[i]].add(-w),
                deficit)
            local = jnp.where(ok, jnp.clip(local - dem[i], 0.0, None), local)
            row = jnp.where(ok, row - dem[i], row)
            rows = rows.at[cnt].set(jnp.where(ok, i.astype(i32), rows[cnt]))
            mach = mach.at[cnt].set(jnp.where(ok, mid, mach[cnt]))
            overf = overf.at[cnt].set(
                jnp.where(ok, over[i].astype(jnp.int8), overf[cnt]))
            obs = obs.at[cnt].set(jnp.where(ok, obs_i, obs[cnt]))
            cnt = cnt + jnp.where(ok, i32(1), i32(0))
            return (j + 1, local, row, taken, ema_s, ema_r, deficit, rows,
                    mach, overf, obs, cnt, ~ok)

        def do_visit(carry):
            avail, taken, ema_s, ema_r, deficit, rows, mach, overf, obs, \
                cnt = carry
            st = (i32(0), row0, row0, taken, ema_s, ema_r, deficit, rows,
                  mach, overf, obs, cnt, False)
            st = lax.while_loop(
                lambda st: (~st[-1]) & (st[0] < bundle_limit), body, st)
            (_j, _local, row, taken, ema_s, ema_r, deficit, rows, mach,
             overf, obs, cnt, _stop) = st
            return (avail.at[mid].set(row), taken, ema_s, ema_r, deficit,
                    rows, mach, overf, obs, cnt)

        carry = lax.cond(mid >= 0, do_visit, lambda c: c,
                         (avail, taken, ema_s, ema_r, deficit, rows, mach,
                          overf, obs, cnt))
        return carry, None

    rows0 = jnp.zeros(n, dtype=i32)
    mach0 = jnp.zeros(n, dtype=i32)
    over0 = jnp.zeros(n, dtype=jnp.int8)
    obs0 = jnp.zeros(n, dtype=jnp.float64)
    init = (avail, taken0, ema[0], ema[1], deficit, rows0, mach0, over0,
            obs0, i32(0))
    out, _ = lax.scan(visit, init, order)
    avail, _taken, ema_s, ema_r, deficit, rows, mach, overf, obs, cnt = out
    return avail, jnp.stack([ema_s, ema_r]), deficit, rows, mach, overf, \
        obs, cnt


def _build_wave_fn(m, d, n_cap, g_cap, bundle_limit, use_packing,
                   use_srpt, use_overbooking, drf):
    """One compile bucket of the fused wave (donated resident avail)."""
    import functools

    core = functools.partial(wave_core, bundle_limit=bundle_limit,
                             use_packing=use_packing, use_srpt=use_srpt,
                             use_overbooking=use_overbooking, drf=drf)
    return jax.jit(core, donate_argnums=_donate())


def _build_pallas_wave_fn(m, d, n_cap, g_cap, bundle_limit, use_packing,
                          use_srpt, use_overbooking, drf):
    from ...kernels.placement_scan import ops as ps_ops

    import functools
    return functools.partial(
        ps_ops.match_wave_walk, bundle_limit=bundle_limit,
        use_packing=use_packing, use_srpt=use_srpt,
        use_overbooking=use_overbooking, drf=drf)


# built lazily (kernels.py imports this module while itself initializing)
_WAVE_FNS = None
_UPD_FNS = None


def _caches():
    global _WAVE_FNS, _UPD_FNS
    if _WAVE_FNS is None:
        from . import kernels as K

        _WAVE_FNS = K._BucketCache(_build_wave_fn)
        _UPD_FNS = K._BucketCache(_build_row_update_fn)
    return _WAVE_FNS, _UPD_FNS


_PALLAS_FNS = None


def _pallas_cache():
    global _PALLAS_FNS
    if _PALLAS_FNS is None:
        from . import kernels as K

        _PALLAS_FNS = K._BucketCache(_build_pallas_wave_fn)
    return _PALLAS_FNS


def _donate() -> tuple:
    """Donate the resident avail buffer where donation is implemented
    (donating on CPU only earns a warning per compile)."""
    try:
        return () if jax.default_backend() == "cpu" else (0,)
    except Exception:  # pragma: no cover
        return ()


def _build_row_update_fn(r_cap):
    """Dirty-row scatter into the resident avail mirror (donated)."""
    def upd(avail, rows, vals):
        return avail.at[rows].set(vals)

    return jax.jit(upd, donate_argnums=_donate())


def pallas_wave_available() -> bool:
    """The Pallas wave needs f64, which only interpret mode provides."""
    if not _HAVE_JAX:
        return False
    from . import kernels as K

    if not K._have_pallas():
        return False
    try:
        return jax.default_backend() == "cpu"
    except Exception:  # pragma: no cover
        return False


# ----------------------------------------------------------------------
# device-resident wave state (one per ShardedMatcher)
# ----------------------------------------------------------------------

class DeviceWaveState:
    """Host-shadowed device mirrors for the fused wave.

    The shadows are plain numpy copies of what the device currently
    holds; before each wave the host state is diffed against them and
    only the difference is uploaded.  After a wave, the host replay
    applies the same arithmetic the kernel did, so the refreshed shadows
    equal the device buffers bit-for-bit and steady-state waves upload
    nothing but the machine order and whatever the simulator touched.
    """

    def __init__(self):
        self.avail_dev = None
        self.avail_shadow = None
        # candidate-column cache: array identities of the last upload
        self.n_cap = 0
        self.col_ids = None
        self.dem_dev = self.pri_dev = self.srpt_dev = None
        self.gidx_dev = self.loc_dev = self.taken0_dev = None
        self.gidx_np = None
        # ledger mirrors
        self.keys = None               # deficit key order at last upload
        self.gmap_lut = None           # group id -> dense ledger index
        self.g_cap = 0
        self.ema_dev = None
        self.ema_shadow = None
        self.deficit_dev = None
        self.deficit_shadow = None
        self.share_dev = None


def _bstat(impl: str, key: str, n) -> None:
    from . import kernels as K

    K.transfer_add(f"match_wave.{impl}.{key}", int(n))


def _sync_avail(st: DeviceWaveState, avail: np.ndarray, impl: str,
                upd_fns) -> int:
    """Upload only rows that changed since the last wave's replay.

    Returns the number of extra device launches used (0 or 1)."""
    m, d = avail.shape
    if st.avail_shadow is None or st.avail_shadow.shape != avail.shape:
        st.avail_dev = jnp.asarray(avail, dtype=jnp.float64)
        st.avail_shadow = avail.copy()
        _bstat(impl, "bytes_h2d", avail.nbytes)
        return 0
    dirty = np.flatnonzero((st.avail_shadow != avail).any(axis=1))
    if len(dirty) == 0:
        return 0
    if len(dirty) * 2 >= m:
        st.avail_dev = jnp.asarray(avail, dtype=jnp.float64)
        st.avail_shadow = avail.copy()
        _bstat(impl, "bytes_h2d", avail.nbytes)
        return 0
    from . import kernels as K

    r_cap = K.bucket(len(dirty), floor=8)
    rows = np.zeros(r_cap, dtype=np.int32)
    rows[:len(dirty)] = dirty
    rows[len(dirty):] = dirty[0]       # duplicate writes carry equal values
    vals = avail[rows].astype(np.float64)
    st.avail_dev = upd_fns.get((r_cap,))(st.avail_dev, jnp.asarray(rows),
                                         jnp.asarray(vals))
    st.avail_shadow[dirty] = avail[dirty]
    _bstat(impl, "bytes_h2d", rows.nbytes + vals.nbytes)
    return 1


def _sync_batch(st: DeviceWaveState, batch: CandidateBatch, gmap_lut,
                impl: str) -> bool:
    """Upload candidate columns that `TaskPool.refresh` actually rebuilt.

    Column array identity is the dirtiness signal: the pool reuses cached
    rows for clean jobs and swaps only the srpt column on srpt-only
    refreshes, so steady-state waves re-upload nothing.  Returns False
    when a candidate's group is unknown to the ledger (numpy fallback).
    """
    n = len(batch)
    from . import kernels as K

    n_cap = K.bucket(n, floor=64)
    ids = (id(batch.dem), id(batch.pri), id(batch.srpt), id(batch.grp),
           id(batch.loc), n)
    if st.n_cap == n_cap and st.col_ids == ids and st.gidx_np is not None:
        return True
    rebuild = st.n_cap != n_cap or st.col_ids is None \
        or st.col_ids[0] != ids[0] or st.col_ids[5] != n
    if rebuild or st.col_ids[3] != ids[3]:
        if batch.grp.min(initial=0) < 0 \
                or batch.grp.max(initial=-1) >= len(gmap_lut):
            return False
        gidx = gmap_lut[batch.grp]
        if (gidx < 0).any():
            return False
        gp = np.zeros(n_cap, dtype=np.int32)
        gp[:n] = gidx
        st.gidx_np = gidx
        st.gidx_dev = jnp.asarray(gp)
        _bstat(impl, "bytes_h2d", gp.nbytes)
    if rebuild:
        dem = np.full((n_cap, batch.dem.shape[1]), 2.0, dtype=np.float64)
        dem[:n] = batch.dem
        taken0 = np.ones(n_cap, dtype=bool)
        taken0[:n] = False
        st.dem_dev = jnp.asarray(dem)
        st.taken0_dev = jnp.asarray(taken0)
        _bstat(impl, "bytes_h2d", dem.nbytes + taken0.nbytes)
    if rebuild or st.col_ids[1] != ids[1]:
        pri = np.zeros(n_cap, dtype=np.float64)
        pri[:n] = batch.pri
        st.pri_dev = jnp.asarray(pri)
        _bstat(impl, "bytes_h2d", pri.nbytes)
    if rebuild or st.col_ids[2] != ids[2]:
        srpt = np.zeros(n_cap, dtype=np.float64)
        srpt[:n] = batch.srpt
        st.srpt_dev = jnp.asarray(srpt)
        _bstat(impl, "bytes_h2d", srpt.nbytes)
    if rebuild or st.col_ids[4] != ids[4]:
        loc = np.full(n_cap, -1, dtype=np.int32)
        loc[:n] = batch.loc
        st.loc_dev = jnp.asarray(loc)
        _bstat(impl, "bytes_h2d", loc.nbytes)
    st.n_cap = n_cap
    st.col_ids = ids
    return True


def _sync_ledgers(st: DeviceWaveState, matcher, impl: str) -> np.ndarray:
    """EMA pair + dense deficit/share mirrors (key order = dict order).

    Returns the group-id → dense-index lookup table.  Steady state, the
    post-replay shadows match the host exactly and nothing uploads.
    """
    from . import kernels as K

    keys = list(matcher.deficits.deficit.keys())
    g_cap = max(K.pad8(len(keys)), 8)
    dfc = matcher.deficits
    if keys != st.keys or g_cap != st.g_cap:
        st.keys = keys
        st.g_cap = g_cap
        share = np.zeros(g_cap, dtype=np.float64)
        share[:len(keys)] = [dfc.share[g] for g in keys]
        st.share_dev = jnp.asarray(share)
        st.deficit_shadow = None
        _bstat(impl, "bytes_h2d", share.nbytes)
        lut_len = (max(keys) + 1) if keys else 1
        st.gmap_lut = np.full(lut_len, -1, dtype=np.int64)
        for i, g in enumerate(keys):
            st.gmap_lut[g] = i
        st.col_ids = None              # gidx depends on the key order
    deficit = np.full(g_cap, -np.inf, dtype=np.float64)
    deficit[:len(keys)] = [dfc.deficit[g] for g in keys]
    if st.deficit_shadow is None \
            or not np.array_equal(st.deficit_shadow, deficit):
        st.deficit_dev = jnp.asarray(deficit)
        st.deficit_shadow = deficit
        _bstat(impl, "bytes_h2d", deficit.nbytes)
    ema = np.array([matcher._ema_score, matcher._ema_srpt],
                   dtype=np.float64)
    if st.ema_shadow is None or not np.array_equal(st.ema_shadow, ema):
        st.ema_dev = jnp.asarray(ema)
        st.ema_shadow = ema
        _bstat(impl, "bytes_h2d", ema.nbytes)
    return st.gmap_lut


def _device_wave(ctx: WaveContext, impl: str) -> int:
    """Shared xla/pallas driver: sync mirrors, launch, replay the picks."""
    from ..online import drf_fairness, slot_fairness

    sm, avail, alive, batch = ctx.sm, ctx.avail, ctx.alive, ctx.batch
    matcher = sm.matcher
    cfg = sm.cfg
    if cfg.fairness is drf_fairness:
        drf = True
    elif cfg.fairness is slot_fairness:
        drf = False
    else:                              # unportable fairness fn: host loop
        return match_wave_numpy(ctx)
    n = len(batch)
    m, d = avail.shape
    st = getattr(sm, "_wave_state", None)
    if st is None:
        st = sm._wave_state = DeviceWaveState()
    with enable_x64():
        gmap_lut = _sync_ledgers(st, matcher, impl)
        if not _sync_batch(st, batch, gmap_lut, impl):
            return match_wave_numpy(ctx)
        wave_fns, upd_fns = _caches()
        launches = 1 + _sync_avail(st, avail, impl, upd_fns)
        # host-computed visit order (argsort is a host-only sort); the
        # alive/drained prefilter mirrors the numpy wave, machines it
        # would *skip* via eligibility are decision-free in-kernel visits
        order = np.argsort(-avail.sum(axis=1))
        keep = alive[order] & (avail[order] > 1e-9).any(axis=1)
        order_p = np.full(m, -1, dtype=np.int32)
        kept = order[keep]
        order_p[:len(kept)] = kept
        fd, rigid, fung = matcher.fit_dim_split()
        masks = []
        for dims in (fd, rigid, fung):
            mk = np.zeros(d, dtype=bool)
            mk[np.asarray(dims, dtype=np.int64)] = True
            masks.append(jnp.asarray(mk))
        consts = np.zeros(8, dtype=np.float64)
        consts[_C_EPS] = packing.EPS
        consts[_C_SLACK] = cfg.max_overbook - 1.0
        consts[_C_RP] = cfg.remote_penalty
        consts[_C_ETA_M] = cfg.eta_m
        consts[_C_THRESH] = matcher.deficits.kappa \
            * matcher.deficits.capacity
        consts[_C_A] = _EMA_A
        consts[_C_1MA] = _EMA_1MA
        consts[_C_FLOOR] = _SRPT_FLOOR
        key = (m, d, st.n_cap, st.g_cap, cfg.bundle_limit,
               bool(cfg.use_packing), bool(cfg.use_srpt),
               bool(cfg.use_overbooking), drf)
        fns = _pallas_cache() if impl == "pallas" else wave_fns
        fn = fns.get(key)
        pri_dev = st.pri_dev if cfg.use_priority else \
            jnp.asarray(np.concatenate([np.ones(n), np.zeros(st.n_cap - n)]))
        out = fn(st.avail_dev, jnp.asarray(order_p), st.dem_dev, pri_dev,
                 st.srpt_dev, st.gidx_dev, st.loc_dev, st.taken0_dev,
                 st.ema_dev, st.deficit_dev, st.share_dev, *masks,
                 jnp.asarray(consts))
        st.avail_dev, st.ema_dev, st.deficit_dev = out[0], out[1], out[2]
        rows = np.asarray(out[3])
        mach = np.asarray(out[4])
        overf = np.asarray(out[5])
        obs = np.asarray(out[6])
        count = int(out[7])
    _bstat(impl, "bytes_h2d",
           order_p.nbytes + consts.nbytes + 3 * d)
    _bstat(impl, "bytes_d2h",
           rows.nbytes + mach.nbytes + overf.nbytes + obs.nbytes + 4)
    _bstat(impl, "launches", launches)
    _bstat(impl, "waves", 1)
    # -- host replay: apply every pick's side effects in pick order ------
    plan = sm.plan
    fairness = cfg.fairness
    for j in range(count):
        gi = int(rows[j])
        mm = int(mach[j])
        ctx.start_cb(gi, mm)
        matcher._observe(float(obs[j]), float(batch.srpt[gi]))
        w = fairness(batch.dem[gi])
        matcher.deficits.allocated(int(batch.grp[gi]), w)
        sm.shard_matchers[plan.shard_of(mm)].deficits.allocated(
            int(batch.grp[gi]), w)
    # refresh shadows from the replayed host state: the kernel applied
    # identical float64 ops, so these equal the device buffers bit-for-bit
    # and the next wave's diffs only see *external* mutations
    st.avail_shadow = avail.copy()
    st.ema_shadow = np.array([matcher._ema_score, matcher._ema_srpt],
                             dtype=np.float64)
    dfc = matcher.deficits
    sh = np.full(st.g_cap, -np.inf, dtype=np.float64)
    sh[:len(st.keys)] = [dfc.deficit[g] for g in st.keys]
    st.deficit_shadow = sh
    return count


def match_wave_xla(ctx: WaveContext) -> int:
    return _device_wave(ctx, "xla")


def match_wave_pallas(ctx: WaveContext) -> int:
    return _device_wave(ctx, "pallas")
