"""Baseline DAG schedulers + a common work-conserving executor (paper §8.1).

Experimental baselines:
  * bfs      — breadth-first stage order (Tez default)
  * cp       — critical-path-length priority (CPSched)
  * random   — random static priority
  * tetris   — multi-resource packing score (dot product), dependency-blind
  * cg       — Coffman-Graham labeling
  * strippart— level decomposition, levels run as barriers (StripPart [20])
  * dagps    — priScore order from the constructed schedule, softly combined
               with the packing score (the single-job slice of §5)

All of these run through `simulate_execution`, an event-driven,
work-conserving list-scheduling executor over m machines with d-resource
capacity — so comparisons measure the *order quality*, exactly as in Fig. 12.
Fit tests and packing scores go through `engine.packing`, the same kernels
the online matcher and the cluster simulator use.
"""

from __future__ import annotations

import heapq
from typing import Callable, Sequence

import numpy as np

from .dag import DAG
from .engine import packing


# ----------------------------------------------------------------------
# Static orders
# ----------------------------------------------------------------------

def bfs_order(dag: DAG) -> np.ndarray:
    """Breadth-first: by depth from sources, then stage, then id (Tez)."""
    depth = np.zeros(dag.n, dtype=np.int64)
    for i in range(dag.n):
        ps = dag.parents[i]
        depth[i] = (depth[ps].max() + 1) if len(ps) else 0
    return np.lexsort((np.arange(dag.n), dag.stage_of, depth))


def cp_order(dag: DAG) -> np.ndarray:
    """Critical-path scheduling: longest path to a sink first."""
    cp = critical_path_to_sink(dag)
    return np.lexsort((np.arange(dag.n), -cp))


def critical_path_to_sink(dag: DAG) -> np.ndarray:
    cp = np.zeros(dag.n, dtype=np.float64)
    for i in range(dag.n - 1, -1, -1):
        ch = dag.children[i]
        cp[i] = dag.duration[i] + (cp[ch].max() if len(ch) else 0.0)
    return cp


def random_order(dag: DAG, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.permutation(dag.n)


def cg_order(dag: DAG) -> np.ndarray:
    """Coffman-Graham labeling, generalized to arbitrary DAGs.

    Labels are assigned from 1 upward to tasks whose successors are all
    labeled, choosing the task whose decreasing sequence of successor labels
    is lexicographically smallest.  Execution priority = label descending.
    """
    n = dag.n
    label = np.zeros(n, dtype=np.int64)
    unlabeled_children = np.array([len(dag.children[i]) for i in range(n)])
    ready = [i for i in range(n) if unlabeled_children[i] == 0]
    next_label = 1
    while ready:
        def key(i: int):
            ls = sorted((int(label[c]) for c in dag.children[i]), reverse=True)
            return (ls, i)
        ready.sort(key=key)
        t = ready.pop(0)
        label[t] = next_label
        next_label += 1
        for p in dag.parents[t]:
            unlabeled_children[p] -= 1
            if unlabeled_children[p] == 0:
                ready.append(int(p))
    return np.lexsort((np.arange(n), -label))


# ----------------------------------------------------------------------
# Work-conserving executor
# ----------------------------------------------------------------------

def simulate_execution(
    dag: DAG,
    m: int,
    order: Sequence[int] | None = None,
    policy: str = "priority",
    pri_score: np.ndarray | None = None,
    fit_dims: Sequence[int] | None = None,
    barrier_levels: np.ndarray | None = None,
) -> float:
    """Event-driven list scheduling of one DAG on m machines.

    policy:
      * "priority" — start runnable tasks in static `order`, skipping tasks
        that do not fit (work-conserving).
      * "tetris"   — dynamic: among runnable+fitting tasks pick the max
        dot(demand, available) (Tetris's packing score).
      * "dagps"    — score = priScore * dot(demand, avail): softly follow
        the constructed schedule while packing (§5 single-job slice).
    barrier_levels: if given, a task may start only when all tasks of lower
      levels have finished (StripPart semantics — not work-conserving).
    """
    n = dag.n
    if n == 0:
        return 0.0
    fit = np.asarray(fit_dims if fit_dims is not None else range(dag.d))
    avail = np.ones((m, dag.d), dtype=np.float64)
    pending_parents = np.array([len(dag.parents[i]) for i in range(n)])
    runnable: set[int] = {i for i in range(n) if pending_parents[i] == 0}
    prio = np.zeros(n)
    if order is not None:
        prio[np.asarray(order)] = np.arange(n)
    done = np.zeros(n, dtype=bool)
    n_done = 0
    level_remaining = None
    cur_level = 0
    if barrier_levels is not None:
        level_remaining = np.bincount(barrier_levels)
    events: list[tuple[float, int, int]] = []  # (end_time, task, machine)
    t_now = 0.0

    def start_tasks() -> None:
        """Vectorized work-conserving allocation pass."""
        while True:
            if barrier_levels is not None:
                cands = np.array([i for i in runnable if barrier_levels[i] == cur_level],
                                 dtype=np.int64)
            else:
                cands = np.fromiter(runnable, dtype=np.int64, count=len(runnable))
            if len(cands) == 0:
                return
            ok, best_m, best_s = packing.best_fit_machines(avail, dag.demand[cands],
                                                           dims=fit)
            fit_any = ok.any(axis=1)
            if not fit_any.any():
                return
            if policy == "priority":
                pr = np.where(fit_any, prio[cands], np.inf)
                ci = int(np.argmin(pr))
            elif policy == "dagps":
                ps = pri_score[cands] if pri_score is not None else np.ones(len(cands))
                ci = int(np.argmax(np.where(fit_any, ps * (best_s + 1e-9), -np.inf)))
            else:  # tetris
                ci = int(np.argmax(np.where(fit_any, best_s, -np.inf)))
            chosen = int(cands[ci])
            mach = int(best_m[ci])
            runnable.discard(chosen)
            avail[mach] -= dag.demand[chosen]
            heapq.heappush(events, (t_now + dag.duration[chosen], chosen, mach))

    start_tasks()
    while events:
        t_now, i, mach = heapq.heappop(events)
        avail[mach] += dag.demand[i]
        done[i] = True
        n_done += 1
        for c in dag.children[i]:
            pending_parents[c] -= 1
            if pending_parents[c] == 0:
                runnable.add(int(c))
        if level_remaining is not None:
            level_remaining[barrier_levels[i]] -= 1
            while cur_level < len(level_remaining) - 1 and level_remaining[cur_level] == 0:
                cur_level += 1
        # batch-drain simultaneous completions before reallocating
        while events and events[0][0] <= t_now + 1e-12:
            t2, i2, m2 = heapq.heappop(events)
            avail[m2] += dag.demand[i2]
            done[i2] = True
            n_done += 1
            for c in dag.children[i2]:
                pending_parents[c] -= 1
                if pending_parents[c] == 0:
                    runnable.add(int(c))
            if level_remaining is not None:
                level_remaining[barrier_levels[i2]] -= 1
                while cur_level < len(level_remaining) - 1 and level_remaining[cur_level] == 0:
                    cur_level += 1
        start_tasks()
    assert n_done == n, f"executor finished {n_done}/{n} tasks"
    return float(t_now)


def strip_levels(dag: DAG) -> np.ndarray:
    """Longest-path level of each task (all edges cross levels)."""
    lev = np.zeros(dag.n, dtype=np.int64)
    for i in range(dag.n):
        ps = dag.parents[i]
        lev[i] = (lev[ps].max() + 1) if len(ps) else 0
    return lev


# ----------------------------------------------------------------------
# One-call comparisons
# ----------------------------------------------------------------------

def run_baseline(dag: DAG, m: int, scheme: str, seed: int = 0,
                 fit_dims: Sequence[int] | None = None,
                 pri_score: np.ndarray | None = None,
                 backend: str | None = None) -> float:
    """Makespan of `scheme` on dag with m machines."""
    if scheme == "bfs":
        return simulate_execution(dag, m, order=bfs_order(dag), fit_dims=fit_dims)
    if scheme == "cp":
        return simulate_execution(dag, m, order=cp_order(dag), fit_dims=fit_dims)
    if scheme == "random":
        return simulate_execution(dag, m, order=random_order(dag, seed), fit_dims=fit_dims)
    if scheme == "tetris":
        return simulate_execution(dag, m, policy="tetris", fit_dims=fit_dims)
    if scheme == "cg":
        return simulate_execution(dag, m, order=cg_order(dag), fit_dims=fit_dims)
    if scheme == "strippart":
        return simulate_execution(
            dag, m, policy="tetris", fit_dims=fit_dims, barrier_levels=strip_levels(dag)
        )
    if scheme == "dagps":
        from .builder import build_schedule

        sched = build_schedule(dag, m, backend=backend)
        return simulate_execution(
            dag, m, policy="dagps", pri_score=pri_score if pri_score is not None else sched.pri_score,
            fit_dims=fit_dims,
        )
    raise ValueError(f"unknown scheme {scheme!r}")
