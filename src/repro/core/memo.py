"""Cross-candidate construction memoization (ROADMAP "memoization lever").

The offline search (paper Fig. 7) evaluates many (T-set, order, direction)
variants that share long placement prefixes: the same tasks get placed at
the same anchors onto grids that agree wherever it matters.  This module
memoizes that work at two granularities, both *outcome-exact* — a memo hit
returns precisely what the live search would have returned:

Pass level ("segment replay").  A whole PlaceTasksF/PlaceTasksB pass is a
deterministic function of (the id *set*, the direction, the grid content,
the grid extents): the heap pops tasks in a canonical order and every
anchor derives from already-committed placements.  Successful passes are
recorded as (final span, commit plan) under the key
(ids-digest, direction, space-digest, grid extent); re-reaching the key on
another branch replays the commits with zero searches.  The ids-digest is
the *sorted* id bytes — permuted-but-equal id sets are the same set and
must hit (place_pass heapifies, so its outcome is order-independent);
tests/test_memo.py locks that down.  Pass entries are scoped to one
sub-build: plans store task ids, and ids map to different demands in
different partitions, so ``attach`` clears them.

Place level ("windowed memo").  A single placement query is even more
reusable: an earliest-fit of demand v for k ticks from anchor a depends
only on the grid cells in [a, t0 + k) — every start it examined lives
there (mirrored for latest-fit).  Keying on a digest of just the
placements overlapping that window lets a query hit even when the grids
have long since diverged elsewhere (e.g. two candidate T-sets whose
placement traces share a prefix but end differently).  Entries store the
window bounds and its digest at record time; a lookup recomputes the
digest over the *current* placements and only trusts a bit-equal match.

Digests are 64-bit *additive* multiset hashes (sum mod 2^64) over
(machine, start, k, demand-bytes) quadruples — order-independent, O(1)
incremental under commit, O(dropped) under restore.  Addition, not XOR:
hashing the *demand* instead of the task id makes two identical tasks
legally sharing one (machine, start) slot hash-equal, and XOR would
cancel the pair into "empty window" (a real bug class caught by the
periodic workload); a sum accumulates multiplicity.  Dropping the task
id is what makes the digest a pure function of grid content: identical
window content yields identical digests no matter which tasks produced
it.
That is what lets one memo serve every partitioned sub-build of a DAG
(``build_schedule`` rebinds the memo to each partition's Space via
``attach``): task ids are partition-local, but a window whose content
digest matches is the same tick-space search problem regardless of
partition or even tick quantization, so cross-partition hits are exact.
The memo mirrors the Space's placement list through the Space observer
hook, so snapshot/restore keeps the digest exact.  A stale digest can
never validate: any content difference inside the window changes the sum
by a nonzero multiset of pseudo-random 64-bit terms (up to collision
odds, ~2^-64 per lookup pair).
"""

from __future__ import annotations

import threading

import numpy as np

from . import faults


class Counters:
    """Thread-safe accounting counters (``d[k]`` reads, ``add`` writes).

    The build service (core/buildsvc.py) runs builds concurrently, and a
    bare ``dict[k] += 1`` is a read-modify-write that drops increments
    under threads.  ``add`` is the one mutation path and takes the lock;
    plain ``[]`` reads stay lock-free (a torn read of an int cannot
    happen under CPython, and the benches only ever read quiescent or
    monotone values).
    """

    def __init__(self, names):
        self._lock = threading.Lock()
        self._d = dict.fromkeys(names, 0)

    def add(self, key: str, n: int = 1) -> None:
        with self._lock:
            self._d[key] += n

    def __getitem__(self, key: str) -> int:
        return self._d[key]

    def __contains__(self, key: str) -> bool:
        return key in self._d

    def reset(self) -> None:
        with self._lock:
            for k in self._d:
                self._d[k] = 0

    def snapshot(self) -> dict[str, int]:
        with self._lock:
            return dict(self._d)


# counters threaded into benchmarks/bench_scheduling.py: the bench JSON
# reports placements-evaluated vs placements-memoized per scenario.
COUNTERS = Counters((
    "places_evaluated",      # live backend searches
    "places_memoized",       # windowed place-memo hits
    "places_memoized_xpart",  # ...of which hit an entry recorded by an
                              # earlier partition of the same DAG
    "passes_run",            # live place_pass executions
    "passes_replayed",       # pass-memo plan replays (incl. fail shortcuts)
    "variants_bound_skipped",  # order-variant subtrees pruned by bound
    "candidates_lb_skipped",   # candidates skipped at the tick LB
    "parts_reused",          # delta rebuild: partitions replayed from a
                             # previous build's content-keyed parts map
    "placements_reused",     # ...task placements those partitions carried
    "memo_discarded",        # entries failing their self-checksum on get
                             # (corruption -> treated as a miss, evicted)
))


def reset_counters() -> None:
    COUNTERS.reset()


def counters_snapshot() -> dict[str, int]:
    return COUNTERS.snapshot()


_M1 = 0x9E3779B97F4A7C15
_M2 = 0xC2B2AE3D27D4EB4F
_M3 = 0x165667B19E3779F9
_MASK = (1 << 64) - 1


def item_hash(a: int, b: int, c: int, salt: int = 0) -> int:
    """64-bit mix of one placement tuple (xorshift-multiply finalizer).

    The memo feeds it (machine, start, k) with the demand-row hash as
    ``salt``; every component perturbs the result.
    """
    h = (a * _M1 ^ (b + 7) * _M2 ^ (c & _MASK) * _M3 ^ (salt & _MASK)) & _MASK
    h ^= h >> 29
    h = (h * _M1) & _MASK
    h ^= h >> 32
    return h


def _place_chk(b0: int, b1: int, dig: int, m: int, t0: int,
               epoch: int) -> int:
    """Self-checksum of one place-memo entry (fault hardening: a stored
    entry whose fields no longer hash to this is discarded on get)."""
    return item_hash(b0 * 1000003 + b1, m * 1000003 + t0, epoch, salt=dig)


def _pass_chk(span: int, plan: list) -> int:
    """Self-checksum of one pass-memo entry (order-sensitive over the
    replay plan: any mutated commit flips it)."""
    h = item_hash(span, len(plan), 0)
    for i, (t, m, t0) in enumerate(plan):
        h = (h + item_hash(t * 1000003 + m, t0, i, salt=h)) & _MASK
    return h


#: per (direction, demand, k, anchor) key, how many distinct grid-window
#: contexts to remember before dropping the oldest
PLACE_ENTRY_CAP = 8


class ConstructionMemo:
    """Placement memo for one builder DAG (see module docstring).

    ``attach`` binds it to a Space (registering as a Space observer so
    commits/restores keep the mirrored (start, end, hash) arrays and the
    whole-content digest exact) and may be called again for each
    partitioned sub-build: the windowed place memo persists across
    partitions (content-addressed, see module docstring), the pass memo
    and the placement mirror reset.
    """

    def __init__(self, space=None):
        self.space = None
        cap = 256
        self._start = np.zeros(cap, dtype=np.int64)
        self._end = np.zeros(cap, dtype=np.int64)
        self._hash = np.zeros(cap, dtype=np.uint64)
        self._n = 0
        self.ckey = 0                       # sum (mod 2^64) over live placements
        self._place: dict[tuple, list] = {}
        self._pass: dict[tuple, tuple] = {}
        self._epoch = 0                     # bumped per attach (partition)
        if space is not None:
            self.attach(space)

    def attach(self, space) -> None:
        """(Re)bind to a Space: fresh mirror + pass memo, kept place memo."""
        if self.space is not None:
            self.space.remove_observer(self)
        self.space = space
        space.add_observer(self)
        self._n = 0
        self.ckey = 0
        self._pass.clear()
        self._epoch += 1

    # -- Space.observer protocol ---------------------------------------
    def on_commit(self, task: int, machine: int, start: int, k: int,
                  v: np.ndarray) -> None:
        n = self._n
        if n == len(self._start):
            grow = 2 * n
            self._start = np.resize(self._start, grow)
            self._end = np.resize(self._end, grow)
            self._hash = np.resize(self._hash, grow)
        # item_hash inlined: this runs once per grid commit.  The hash
        # covers (machine, start, k, demand) — task ids are NOT part of it,
        # so the digest identifies grid *content* (what commit subtracts),
        # which is what makes cross-partition place-memo hits sound.
        salt = hash(v.tobytes())
        h = (machine * _M1 ^ (start + 7) * _M2 ^ (k & _MASK) * _M3
             ^ (salt & _MASK)) & _MASK
        h ^= h >> 29
        h = (h * _M1) & _MASK
        h ^= h >> 32
        self._start[n] = start
        self._end[n] = start + k
        self._hash[n] = h
        self._n = n + 1
        self.ckey = (self.ckey + h) & _MASK

    def on_restore(self, n_placed: int, lo=None, hi=None) -> None:
        if n_placed < self._n:
            dropped = int(np.sum(self._hash[n_placed:self._n],
                                 dtype=np.uint64))
            self.ckey = (self.ckey - dropped) & _MASK
        self._n = n_placed

    # -- windowed place memo -------------------------------------------
    def _window_digest(self, a: int, b: int) -> int:
        """Sum (mod 2^64) over placements intersecting logical [a, b)."""
        n = self._n
        if n == 0:
            return 0
        mask = (self._end[:n] > a) & (self._start[:n] < b)
        if not mask.any():
            return 0
        return int(np.sum(self._hash[:n][mask], dtype=np.uint64))

    def place_get(self, direction: str, vb: bytes, k: int,
                  anchor: int) -> tuple[int, int] | None:
        lst = self._place.get((direction, vb, k, anchor))
        if not lst:
            return None
        fault = faults.query("memo", op="place", k=int(k), anchor=int(anchor))
        if fault is not None and lst:
            if fault.kind == "drop":          # eviction: whole key gone
                lst.clear()
                return None
            if fault.kind == "corrupt":       # flip a stored field, not chk
                b0, b1, dig, m, t0, epoch, chk = lst[-1]
                lst[-1] = (b0, b1, dig, m, t0 + 1, epoch, chk)
        live = []
        hit = None
        for e in lst:
            b0, b1, dig, m, t0, epoch, chk = e
            if chk != _place_chk(b0, b1, dig, m, t0, epoch):
                # bit-rot / injected corruption: the entry is evicted and
                # the lookup falls through to the live search — a bad
                # entry can cost a rebuild, never a mis-placement
                COUNTERS.add("memo_discarded")
                continue
            live.append(e)
            if hit is None and self._window_digest(b0, b1) == dig:
                COUNTERS.add("places_memoized")
                if epoch != self._epoch:
                    COUNTERS.add("places_memoized_xpart")
                hit = (m, t0)
        if len(live) != len(lst):
            lst[:] = live
        return hit

    def place_put(self, direction: str, vb: bytes, k: int, anchor: int,
                  forward: bool, m: int, t0: int) -> None:
        # the cells the live search examined: every candidate start it
        # rejected plus the slot it took (see module docstring)
        b0, b1 = (anchor, t0 + k) if forward else (t0, anchor)
        lst = self._place.setdefault((direction, vb, k, anchor), [])
        dig = self._window_digest(b0, b1)
        lst.append((b0, b1, dig, m, t0, self._epoch,
                    _place_chk(b0, b1, dig, m, t0, self._epoch)))
        if len(lst) > PLACE_ENTRY_CAP:
            del lst[0]

    # -- pass-level segment memo ---------------------------------------
    def pass_key(self, ids: np.ndarray, direction: str) -> tuple:
        sp = self.space
        return (np.sort(ids).tobytes(), direction, self.ckey, sp.T, sp.off)

    def pass_get(self, key: tuple):
        ent = self._pass.get(key)
        if ent is None:
            return None
        span, plan, chk = ent
        fault = faults.query("memo", op="pass", n=len(plan))
        if fault is not None:
            if fault.kind == "drop":
                del self._pass[key]
                return None
            if fault.kind == "corrupt" and plan:
                t, m, t0 = plan[0]
                plan = [(t, m + 1, t0)] + plan[1:]
                self._pass[key] = (span, plan, chk)
        if chk != _pass_chk(span, plan):
            COUNTERS.add("memo_discarded")
            del self._pass[key]
            return None
        return span, plan

    def pass_put(self, key: tuple, span: int, plan: list) -> None:
        self._pass[key] = (span, plan, _pass_chk(span, plan))
