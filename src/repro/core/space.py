"""Virtual resource-time space (paper §3, §4.2).

The space has d+1 dimensions: d resources x time.  We discretize time into
ticks and model `m` machines each with capacity 1.0 per resource, so a task
placement is (machine, start_tick) with its demand subtracted over
[start, start + dur_ticks).

Coordinates handed to callers are *logical* ticks and may be negative
(backward placement packs tasks before the anchor).  Physically the grid is
a finite array with an origin offset; it grows on demand at either end —
which is what makes placement dead-end-free (§4.3 then only has to argue
about dependency consistency, never about running out of room).

Placement primitives:
  * earliest_fit(v, k, ready)  — forward placement (§4.2)
  * latest_fit(v, k, deadline) — backward placement (§4.2)

Both use a cumulative-sum trick to find runs of >=k feasible ticks in
O(m*T) numpy work.  The `hint` of a previous placement of an identical task
is a sound floor/ceiling for the search (the space only fills up within a
pass), which makes placing a whole stage ~O(T) amortized.

The engine layer (core/engine/) supplies alternative search strategies
over this grid; `snapshot`/`restore` give the builder copy-on-write-style
variant evaluation: a snapshot costs O(1), a restore costs O(cells
written since), never O(grid).
"""

from __future__ import annotations

import dataclasses

import numpy as np


def runs_of_k(ok: np.ndarray, k: int) -> np.ndarray:
    """Per row of boolean `ok` (m, L): positions starting a run of >= k Trues.

    Returns (m, L - k + 1) for k > 1 (positions whose run would overflow L
    are dropped), `ok` itself for k == 1.  The cumulative-sum trick shared
    by every feasibility scan in the repo — reference, chunked, batched.
    """
    if k <= 1:
        return ok
    if ok.shape[1] < k:     # window shorter than the run: nothing can start
        return np.zeros((ok.shape[0], 0), dtype=bool)
    c = np.cumsum(ok, axis=1, dtype=np.int32)
    runs = c[:, k - 1 :].copy()
    runs[:, 1:] -= c[:, : runs.shape[1] - 1]
    return runs == k


@dataclasses.dataclass
class Placement:
    task: int
    machine: int
    start: int   # logical tick
    end: int     # logical tick (exclusive)


@dataclasses.dataclass(frozen=True)
class SpaceSnapshot:
    """O(1) checkpoint of a Space; see Space.snapshot/restore."""

    n_undo: int
    n_placed: int
    min_start: int | None
    max_end: int | None
    T: int
    off: int


class Space:
    def __init__(self, m: int, d: int, horizon: int, tick: float = 1.0):
        self.m = int(m)
        self.d = int(d)
        self.tick = float(tick)
        self.T = int(max(horizon, 8))        # physical grid length
        self.off = 0                          # physical = logical + off
        self.avail = np.ones((self.m, self.T, self.d), dtype=np.float32)
        self.placements: list[Placement] = []
        self._min_start: int | None = None   # logical
        self._max_end: int | None = None     # logical
        # bumped whenever capacity changes; engine sessions use it to decide
        # whether a cached feasibility bitmap is still exact or merely a
        # sound upper bound needing a live recheck.
        self.version = 0
        # undo log for snapshot/restore: (machine, logical start, pre-commit
        # copy of the overwritten cells) — restoring copies the exact bits
        # back, so rollback is float-exact (no subtract/re-add drift).
        self._undo: list[tuple[int, int, np.ndarray]] = []
        # optional mirrors of the grid/placement state.  Each observer gets
        #   on_commit(task, machine, start, k, v)    after every commit
        #   on_restore(n_placed, lo, hi)             after every restore,
        # where [lo, hi) is the logical tick range whose cell values were
        # rewritten (None/None when nothing was undone).  core/memo.py keeps
        # its content digests exact through these; the jit engine keeps a
        # device-resident grid mirror in sync the same way.
        self.observers: list = []

    # ------------------------------------------------------------------
    def add_observer(self, obs) -> None:
        if obs not in self.observers:
            self.observers.append(obs)

    def remove_observer(self, obs) -> None:
        if obs in self.observers:
            self.observers.remove(obs)

    # ------------------------------------------------------------------
    def clone(self) -> "Space":
        s = Space.__new__(Space)
        s.observers = []       # mirrors track ONE space; clones start fresh
        s.version = self.version
        s.m, s.d, s.tick, s.T, s.off = self.m, self.d, self.tick, self.T, self.off
        s.avail = self.avail.copy()
        s.placements = list(self.placements)
        s._min_start = self._min_start
        s._max_end = self._max_end
        s._undo = list(self._undo)
        return s

    # -- logical extent --------------------------------------------------
    @property
    def grid_start(self) -> int:
        """Lowest logical tick inside the physical grid."""
        return -self.off

    @property
    def grid_end(self) -> int:
        """One past the highest logical tick inside the physical grid."""
        return self.T - self.off

    # -- copy-on-write-style variant evaluation --------------------------
    def snapshot(self) -> SpaceSnapshot:
        """O(1) checkpoint; restore() rolls back everything committed since."""
        return SpaceSnapshot(len(self._undo), len(self.placements),
                             self._min_start, self._max_end, self.T, self.off)

    def restore(self, snap: SpaceSnapshot, keep_extent: bool = False) -> None:
        """Roll back to `snap`: O(cells written since), plus one grid slice
        if the grid grew (what a clone would have paid anyway).

        Shrinking back matters: a kept-grown grid would push the empty-grid
        backward deadline (grid_end) further out on every candidate variant,
        snowballing the grid and the scans over it.  ``keep_extent`` skips
        the shrink — needed when commits recorded after the snapshot will be
        replayed into the (possibly grown) region right away.
        """
        lo = hi = None   # logical range of rewritten cells, for observers
        for machine, start, vals in reversed(self._undo[snap.n_undo:]):
            ps = start + self.off
            self.avail[machine, ps : ps + len(vals), :] = vals
            if lo is None or start < lo:
                lo = start
            if hi is None or start + len(vals) > hi:
                hi = start + len(vals)
        del self._undo[snap.n_undo:]
        del self.placements[snap.n_placed:]
        self.version += 1
        if not keep_extent and (self.T != snap.T or self.off != snap.off):
            shift = self.off - snap.off  # growth only ever extends, off >= snap.off
            self.avail = np.ascontiguousarray(self.avail[:, shift : shift + snap.T, :])
            self.T, self.off = snap.T, snap.off
        for obs in self.observers:
            obs.on_restore(snap.n_placed, lo, hi)
        self._min_start = snap.min_start
        self._max_end = snap.max_end

    def _grow_back(self) -> None:
        extra = np.ones((self.m, self.T, self.d), dtype=np.float32)
        self.avail = np.concatenate([self.avail, extra], axis=1)
        self.T = self.avail.shape[1]

    def _grow_front(self) -> None:
        grow = self.T
        extra = np.ones((self.m, grow, self.d), dtype=np.float32)
        self.avail = np.concatenate([extra, self.avail], axis=1)
        self.off += grow
        self.T = self.avail.shape[1]

    # ------------------------------------------------------------------
    def _fit_starts(self, v: np.ndarray, k: int, lo: int, hi: int):
        """All (machine, logical t) fitting v over [t, t+k), lo <= t <= hi-k.

        lo/hi are logical; caller guarantees they map inside the grid.
        """
        plo, phi = lo + self.off, hi + self.off
        ok = (self.avail[:, plo:phi, :] >= v).all(axis=2)  # (m, phi-plo)
        ms, ts = np.nonzero(runs_of_k(ok, k))
        return ms, ts + lo

    def fit_first(self, v: np.ndarray, k: int, lo: int, hi: int,
                  latest: bool = False) -> tuple[int, int] | None:
        """Extreme (machine, t) fitting v over [t, t+k) with lo <= t <= hi.

        Like one `_fit_starts` query restricted to starts in [lo, hi], but
        scanned in chunks from the near end with early exit — the engine's
        live searches almost always hit within the first chunk.  Returns
        the lexicographic extreme the full scan would return: (min t, min
        machine), or (max t, min machine) when ``latest``.
        """
        if hi < lo:
            return None
        chunk = max(64, k)

        def _scan_chunk(c0: int, c1: int):
            # starts c0..c1 need avail rows [c0, c1 + k); slicing clips at
            # the grid edge, which correctly truncates (and excludes) runs
            # that would overflow it
            ok = (self.avail[:, c0 + self.off : c1 + k + self.off, :] >= v).all(axis=2)
            good = runs_of_k(ok, k)[:, : c1 - c0 + 1]
            if not good.any():
                return None
            ms, ts = np.nonzero(good)
            tx = int(ts.max()) if latest else int(ts.min())
            return int(ms[ts == tx].min()), tx + c0

        if latest:
            c1 = hi
            while c1 >= lo:
                c0 = max(lo, c1 - chunk + 1)
                res = _scan_chunk(c0, c1)
                if res is not None:
                    return res
                c1 = c0 - 1
        else:
            c0 = lo
            while c0 <= hi:
                c1 = min(hi, c0 + chunk - 1)
                res = _scan_chunk(c0, c1)
                if res is not None:
                    return res
                c0 = c1 + 1
        return None

    def check_fit_at(self, v: np.ndarray, k: int, t: int) -> int:
        """Lowest machine fitting v over [t, t+k) at logical t, else -1."""
        pt = t + self.off
        if pt < 0 or pt + k > self.T:
            return -1
        ok = (self.avail[:, pt : pt + k, :] >= v).all(axis=(1, 2))
        return int(np.argmax(ok)) if ok.any() else -1

    _check_at = check_fit_at

    def check_fit_exact(self, machine: int, t: int, k: int, v: np.ndarray) -> bool:
        """Does v fit on `machine` over logical [t, t+k) right now?"""
        pt = t + self.off
        if pt < 0 or pt + k > self.T:
            return False
        return bool((self.avail[machine, pt : pt + k, :] >= v).all())

    def earliest_fit(self, v: np.ndarray, k: int, ready: int,
                     hint: tuple[int, int] | None = None) -> tuple[int, int]:
        """Earliest (machine, logical start >= ready) fitting v for k ticks."""
        k = max(int(k), 1)
        lo = int(ready)
        if hint is not None and hint[1] >= ready:
            lo = max(lo, hint[1])
            m = self._check_at(v, k, hint[1])
            if m >= 0:
                return m, hint[1]
        while True:
            if lo + self.off < 0:
                self._grow_front()
                continue
            if lo + self.off + k > self.T:
                self._grow_back()
                continue
            hi = self.T - self.off  # logical end of grid
            ms, ts = self._fit_starts(v, k, lo, hi)
            if len(ts):
                j = int(np.argmin(ts))
                return int(ms[j]), int(ts[j])
            self._grow_back()

    def latest_fit(self, v: np.ndarray, k: int, deadline: int,
                   hint: tuple[int, int] | None = None) -> tuple[int, int]:
        """Latest (machine, logical start) with start + k <= deadline fitting v."""
        k = max(int(k), 1)
        hi = int(deadline)
        if hint is not None and hint[1] + k <= deadline:
            hi = min(hi, hint[1] + k)
            m = self._check_at(v, k, hint[1])
            if m >= 0:
                return m, hint[1]
        while True:
            if hi + self.off > self.T:
                self._grow_back()
                continue
            lo = -self.off  # logical start of grid
            if hi - k < lo:
                self._grow_front()
                continue
            ms, ts = self._fit_starts(v, k, lo, hi)
            if len(ts):
                j = int(np.argmax(ts))
                return int(ms[j]), int(ts[j])
            self._grow_front()

    # ------------------------------------------------------------------
    def commit(self, task: int, machine: int, start: int, k: int, v: np.ndarray,
               check: bool = True) -> Placement:
        """Subtract v over [start, start+k) on `machine` and log the undo.

        ``check=False`` skips the over-commit guard: replay paths (memo
        plan replays, place_best winner replays) re-commit placements that
        already passed the guard against bit-identical window content.
        """
        k = max(int(k), 1)
        ps = start + self.off
        assert 0 <= ps and ps + k <= self.T, "commit outside grid"
        win = self.avail[machine, ps : ps + k, :]   # one view, three uses
        self._undo.append((machine, start, win.copy()))
        win -= v
        self.version += 1
        if check and win.min() < -1e-5:
            raise RuntimeError("over-committed space")
        p = Placement(task, machine, start, start + k)
        self.placements.append(p)
        for obs in self.observers:
            obs.on_commit(task, machine, start, k, v)
        self._min_start = start if self._min_start is None else min(self._min_start, start)
        self._max_end = start + k if self._max_end is None else max(self._max_end, start + k)
        return p

    # ------------------------------------------------------------------
    @property
    def makespan_ticks(self) -> int:
        if self._min_start is None:
            return 0
        return self._max_end - self._min_start

    @property
    def makespan(self) -> float:
        return self.makespan_ticks * self.tick

    def utilization(self) -> float:
        """Fraction of resource-time used inside the occupied span."""
        if self._min_start is None:
            return 0.0
        window = self.avail[:, self._min_start + self.off : self._max_end + self.off, :]
        return float(1.0 - window.mean())
