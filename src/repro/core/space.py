"""Virtual resource-time space (paper §3, §4.2).

The space has d+1 dimensions: d resources x time.  We discretize time into
ticks and model `m` machines each with capacity 1.0 per resource, so a task
placement is (machine, start_tick) with its demand subtracted over
[start, start + dur_ticks).

Coordinates handed to callers are *logical* ticks and may be negative
(backward placement packs tasks before the anchor).  Physically the grid is
a finite array with an origin offset; it grows on demand at either end —
which is what makes placement dead-end-free (§4.3 then only has to argue
about dependency consistency, never about running out of room).

Placement primitives:
  * earliest_fit(v, k, ready)  — forward placement (§4.2)
  * latest_fit(v, k, deadline) — backward placement (§4.2)

Both use a cumulative-sum trick to find runs of >=k feasible ticks in
O(m*T) numpy work.  The `hint` of a previous placement of an identical task
is a sound floor/ceiling for the search (the space only fills up within a
pass), which makes placing a whole stage ~O(T) amortized.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Placement:
    task: int
    machine: int
    start: int   # logical tick
    end: int     # logical tick (exclusive)


class Space:
    def __init__(self, m: int, d: int, horizon: int, tick: float = 1.0):
        self.m = int(m)
        self.d = int(d)
        self.tick = float(tick)
        self.T = int(max(horizon, 8))        # physical grid length
        self.off = 0                          # physical = logical + off
        self.avail = np.ones((self.m, self.T, self.d), dtype=np.float32)
        self.placements: list[Placement] = []
        self._min_start: int | None = None   # logical
        self._max_end: int | None = None     # logical

    # ------------------------------------------------------------------
    def clone(self) -> "Space":
        s = Space.__new__(Space)
        s.m, s.d, s.tick, s.T, s.off = self.m, self.d, self.tick, self.T, self.off
        s.avail = self.avail.copy()
        s.placements = list(self.placements)
        s._min_start = self._min_start
        s._max_end = self._max_end
        return s

    def _grow_back(self) -> None:
        extra = np.ones((self.m, self.T, self.d), dtype=np.float32)
        self.avail = np.concatenate([self.avail, extra], axis=1)
        self.T = self.avail.shape[1]

    def _grow_front(self) -> None:
        grow = self.T
        extra = np.ones((self.m, grow, self.d), dtype=np.float32)
        self.avail = np.concatenate([extra, self.avail], axis=1)
        self.off += grow
        self.T = self.avail.shape[1]

    # ------------------------------------------------------------------
    def _fit_starts(self, v: np.ndarray, k: int, lo: int, hi: int):
        """All (machine, logical t) fitting v over [t, t+k), lo <= t <= hi-k.

        lo/hi are logical; caller guarantees they map inside the grid.
        """
        plo, phi = lo + self.off, hi + self.off
        ok = (self.avail[:, plo:phi, :] >= v).all(axis=2)  # (m, phi-plo)
        if k > 1:
            c = np.cumsum(ok, axis=1, dtype=np.int32)
            runs = c[:, k - 1 :].copy()
            runs[:, 1:] -= c[:, : runs.shape[1] - 1]
            good = runs == k
        else:
            good = ok
        ms, ts = np.nonzero(good)
        return ms, ts + lo

    def _check_at(self, v: np.ndarray, k: int, t: int) -> int:
        """Any machine fitting v at logical t, else -1."""
        pt = t + self.off
        if pt < 0 or pt + k > self.T:
            return -1
        ok = (self.avail[:, pt : pt + k, :] >= v).all(axis=(1, 2))
        return int(np.argmax(ok)) if ok.any() else -1

    def earliest_fit(self, v: np.ndarray, k: int, ready: int,
                     hint: tuple[int, int] | None = None) -> tuple[int, int]:
        """Earliest (machine, logical start >= ready) fitting v for k ticks."""
        k = max(int(k), 1)
        lo = int(ready)
        if hint is not None and hint[1] >= ready:
            lo = max(lo, hint[1])
            m = self._check_at(v, k, hint[1])
            if m >= 0:
                return m, hint[1]
        while True:
            if lo + self.off < 0:
                self._grow_front()
                continue
            if lo + self.off + k > self.T:
                self._grow_back()
                continue
            hi = self.T - self.off  # logical end of grid
            ms, ts = self._fit_starts(v, k, lo, hi)
            if len(ts):
                j = int(np.argmin(ts))
                return int(ms[j]), int(ts[j])
            self._grow_back()

    def latest_fit(self, v: np.ndarray, k: int, deadline: int,
                   hint: tuple[int, int] | None = None) -> tuple[int, int]:
        """Latest (machine, logical start) with start + k <= deadline fitting v."""
        k = max(int(k), 1)
        hi = int(deadline)
        if hint is not None and hint[1] + k <= deadline:
            hi = min(hi, hint[1] + k)
            m = self._check_at(v, k, hint[1])
            if m >= 0:
                return m, hint[1]
        while True:
            if hi + self.off > self.T:
                self._grow_back()
                continue
            lo = -self.off  # logical start of grid
            if hi - k < lo:
                self._grow_front()
                continue
            ms, ts = self._fit_starts(v, k, lo, hi)
            if len(ts):
                j = int(np.argmax(ts))
                return int(ms[j]), int(ts[j])
            self._grow_front()

    # ------------------------------------------------------------------
    def commit(self, task: int, machine: int, start: int, k: int, v: np.ndarray) -> Placement:
        k = max(int(k), 1)
        ps = start + self.off
        assert 0 <= ps and ps + k <= self.T, "commit outside grid"
        self.avail[machine, ps : ps + k, :] -= v
        if (self.avail[machine, ps : ps + k, :] < -1e-5).any():
            raise RuntimeError("over-committed space")
        p = Placement(task, machine, start, start + k)
        self.placements.append(p)
        self._min_start = start if self._min_start is None else min(self._min_start, start)
        self._max_end = start + k if self._max_end is None else max(self._max_end, start + k)
        return p

    # ------------------------------------------------------------------
    @property
    def makespan_ticks(self) -> int:
        if self._min_start is None:
            return 0
        return self._max_end - self._min_start

    @property
    def makespan(self) -> float:
        return self.makespan_ticks * self.tick

    def utilization(self) -> float:
        """Fraction of resource-time used inside the occupied span."""
        if self._min_start is None:
            return 0.0
        window = self.avail[:, self._min_start + self.off : self._max_end + self.off, :]
        return float(1.0 - window.mean())
