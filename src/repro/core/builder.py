"""Offline schedule construction for one DAG (paper §4, Figs. 5-7).

BuildSchedule:
  1. CandidateTroublesomeTasks (§4.1): score tasks by LongScore (duration /
     max duration) and stages by FragScore (TWork / greedy execution time);
     sweep discriminative (l, f) thresholds; take the closure of each chosen
     set; split the DAG into subsets T (troublesome), P (parents), C
     (children), O (other).
  2. Place T first onto the virtual space, forward or backward, keep the
     more compact (§4.2).
  3. TrySubsetOrders (§4.3): the four dead-end-free orders
     T-OPC, T-OCP, T-COP, T-POC with the direction restrictions proved in
     Lemma 4 (P only backward, C only forward, O either).
  4. Keep the most compact schedule across all candidates; OrderTasks
     returns tasks sorted by start time, which the online component (§5)
     consumes as priScore.

All candidate (T-set, order, direction) variants are evaluated against ONE
shared Space through snapshot/restore (an undo log — O(cells written) per
variant, never a full grid clone), and the per-task fit queries go through
a pluggable placement backend (core/engine/): "reference" rescans the grid
per task, "batched" (default) answers whole ready-sets with one
(n_tasks, m, W) feasibility scan, "jit" runs that scan via jax.jit.

Cross-candidate reductions (all outcome-exact; parity suite locks them):

  * the order variants around a placed T run as a shared-prefix tree
    (``_try_orders``): each common (ids-prefix, direction) segment is
    placed once and branched via the Space undo log;
  * placement work is memoized across variants at pass and single-slot
    granularity (core/memo.py) — a segment or query re-reached on another
    branch replays its recorded outcome instead of searching; the
    windowed slot memo is content-addressed, so one memo serves every
    partitioned sub-build of a DAG (recurring pipelines hit across
    partitions);
  * candidate evaluation stops at a sound tick lower bound, and order
    subtrees whose dependency-chain bound already reaches the incumbent
    are skipped before any placement.

Disable the memo with ``build_schedule(..., memoize=False)`` or
``REPRO_BUILDER_MEMO=0`` (the parity tests diff both modes).
"""

from __future__ import annotations

import dataclasses
import heapq
import math
import os
from typing import Iterable

import numpy as np

from .dag import DAG, dag_digest
from .engine import FORWARD, BACKWARD, PeerTask, PlacementBackend, get_backend
from .engine.base import ceil32
from .memo import COUNTERS, ConstructionMemo
from .space import Space

#: env var consulted when build_schedule is not given an explicit memoize
MEMO_ENV = "REPRO_BUILDER_MEMO"


def _memo_enabled(memoize: bool | None) -> bool:
    if memoize is not None:
        return memoize
    return os.environ.get(MEMO_ENV, "1") != "0"


@dataclasses.dataclass
class BuildInfo:
    """Provenance of one build: the inputs that parameterized it plus the
    per-partition results, content-keyed for delta rebuilds.

    ``parts`` maps (partition content digest, m, knobs) to the partition's
    relative build output.  ``_build_one`` derives its tick quantization
    from the *sub-DAG* horizon, so a digest-equal partition produces a
    bit-identical relative schedule no matter which enclosing DAG it came
    from — replaying a stored entry is exact, which is what
    ``build_schedule(..., reuse=prev)`` leans on after a graph mutation:
    untouched partitions replay, only dirty ones re-search.
    """

    m: int
    knobs: tuple             # (ticks, n_long, n_frag, max_candidates, use_partitions)
    parts: dict              # key -> (rel start, machine, tmask, makespan, tick)
    reused_parts: int = 0    # partitions replayed from ``reuse`` this build
    reused_tasks: int = 0    # task placements those partitions carried
    total_parts: int = 0


@dataclasses.dataclass
class Schedule:
    """A constructed schedule: placement of every task in the virtual space."""

    dag: DAG
    order: np.ndarray        # task ids sorted by start time
    start: np.ndarray        # (n,) start seconds (shifted so min == 0)
    machine: np.ndarray      # (n,) machine index in the virtual space
    makespan: float
    tick: float
    trouble_mask: np.ndarray | None = None
    label: str = "dagps"
    build_info: BuildInfo | None = None

    @property
    def pri_score(self) -> np.ndarray:
        """priScore in [0, 1]: 1 for the first task, ->0 for the last (§5)."""
        n = self.dag.n
        rank = np.empty(n, dtype=np.float64)
        rank[self.order] = np.arange(n)
        return 1.0 - rank / max(n, 1)

    def validate(self) -> None:
        """Dependencies respected and no over-commitment (test hook)."""
        d = self.dag
        end = self.start + d.duration
        for i in range(d.n):
            for p in d.parents[i]:
                if self.start[i] < end[p] - 1e-6 - self.tick:
                    raise AssertionError(f"dependency violated: {p} -> {i}")


# ----------------------------------------------------------------------
# Subset placement (Fig. 7)
# ----------------------------------------------------------------------

class _Placer:
    def __init__(self, dag: DAG, space: Space, dur_ticks: np.ndarray,
                 backend: PlacementBackend,
                 memo: ConstructionMemo | None = None):
        self.dag = dag
        self.space = space
        self.k = dur_ticks
        self.backend = backend
        self.memo = memo
        # structural tie-break: among equal durations, place tasks that
        # enable the most downstream work first (resolves Fig. 17's "red"
        # tasks, which are identical to their siblings except structurally).
        self.n_desc = np.array([len(dag.children[i]) for i in range(dag.n)])
        self.n_par = np.array([len(dag.parents[i]) for i in range(dag.n)])
        self.demand32 = ceil32(dag.demand)   # for float32-comparing sessions
        # demand rows as bytes, precomputed once: hint keys and memo keys
        # need them on every single placement
        self.vb64 = [row.tobytes() for row in dag.demand]
        self.vb32 = [row.tobytes() for row in self.demand32]
        # flat edge arrays: pending counts per pass become one bincount
        self.edge_child = np.concatenate(
            [np.full(len(p), i) for i, p in enumerate(dag.parents)]
        ).astype(np.int64) if dag.n else np.empty(0, np.int64)
        self.edge_parent = (np.concatenate(dag.parents).astype(np.int64)
                            if dag.n and len(self.edge_child) else np.empty(0, np.int64))
        self.placed_start = np.zeros(dag.n, dtype=np.int64)
        self.placed_end = np.zeros(dag.n, dtype=np.int64)
        self.machine = np.full(dag.n, -1, dtype=np.int64)
        self.is_placed = np.zeros(dag.n, dtype=bool)

    def branch(self) -> "_Placer":
        """Cheap variant copy: own task arrays, SHARED space (snapshot it)."""
        p = _Placer.__new__(_Placer)
        p.dag, p.k, p.backend = self.dag, self.k, self.backend
        p.memo = self.memo
        p.n_desc, p.n_par = self.n_desc, self.n_par
        p.demand32 = self.demand32
        p.vb64, p.vb32 = self.vb64, self.vb32
        p.edge_child, p.edge_parent = self.edge_child, self.edge_parent
        p.space = self.space
        p.placed_start = self.placed_start.copy()
        p.placed_end = self.placed_end.copy()
        p.machine = self.machine.copy()
        p.is_placed = self.is_placed.copy()
        return p

    def _save(self):
        return (self.placed_start.copy(), self.placed_end.copy(),
                self.machine.copy(), self.is_placed.copy())

    def _load(self, saved) -> None:
        self.placed_start, self.placed_end, self.machine, self.is_placed = (
            saved[0].copy(), saved[1].copy(), saved[2].copy(), saved[3].copy())

    def _commit(self, t: int, m: int, t0: int, check: bool = True) -> None:
        self.space.commit(t, m, t0, self.k[t], self.dag.demand[t], check)
        self.placed_start[t] = t0
        self.placed_end[t] = t0 + self.k[t]
        self.machine[t] = m
        self.is_placed[t] = True

    def _replay_commit(self, t: int, m: int, t0: int) -> None:
        """Re-commit a memoized placement: grow the grid to cover the slot,
        skip the over-commit guard (the original commit passed it against
        bit-identical window content)."""
        sp = self.space
        k = int(self.k[t])
        while t0 < sp.grid_start:
            sp._grow_front()
        while t0 + k > sp.grid_end:
            sp._grow_back()
        self._commit(t, m, t0, check=False)

    def _anchor(self, t: int, forward: bool) -> int:
        """Ready tick (forward) / deadline tick (backward) for one task.

        Unplaced parents *within the subset* gate readiness; parents outside
        the subset constrain the start only if already placed (see §4.3
        discussion of inter-subset dependencies).  Mirrored for backward.

        Scalar python loop on purpose: adjacency rows are short and this
        runs twice per commit, where numpy fancy-indexing overhead on
        10-element arrays dominates the actual work.
        """
        sp = self.space
        placed = self.is_placed
        if forward:
            best = None     # logical ticks may be negative: no -1 sentinel
            pe = self.placed_end
            for p in self.dag.parents[t]:
                if placed[p] and (best is None or pe[p] > best):
                    best = pe[p]
            if best is not None:
                return int(best)
            return sp._min_start if sp._min_start is not None else 0
        best = None
        ps = self.placed_start
        for c in self.dag.children[t]:
            if placed[c] and (best is None or ps[c] < best):
                best = ps[c]
        if best is not None:
            return int(best)
        if sp._max_end is not None:
            # unanchored task: pack against the occupied region instead of
            # drifting to the far end of the grid.
            return int(sp._max_end)
        return sp.grid_end  # logical end of the empty grid

    def ready_peers(self, ids: np.ndarray, direction: str,
                    cap: int = 24) -> list[PeerTask]:
        """Initial ready set of a pass as PeerTask prefetch hints.

        Used by the multi-variant node prescan (``PlacementBackend.
        sessions``): anchors are the same estimates ``place_pass`` itself
        announces, so prescanned bitmaps are hints only and can never
        change a placement result.
        """
        dag = self.dag
        forward = direction == FORWARD
        if len(ids) == 0:
            return []
        in_subset = np.zeros(dag.n, dtype=bool)
        in_subset[ids] = True
        adj_gate = dag.parents if forward else dag.children
        ready = [int(i) for i in ids if not in_subset[adj_gate[i]].any()]
        ready.sort(key=lambda i: (-dag.duration[i],
                                  -(self.n_desc if forward else self.n_par)[i], i))
        demand = self.demand32
        return [PeerTask(i, self._anchor(i, forward), demand[i], int(self.k[i]))
                for i in ready[:cap]]

    def place_pass(self, ids: np.ndarray, direction: str,
                   limit: int | None = None, sess=None) -> bool:
        """PlaceTasksF / PlaceTasksB: dependency order within the subset,
        longest task first, each task at its extreme feasible slot.

        ``limit`` prunes exactly: the occupied span only ever grows, so
        once it reaches the incumbent best the variant can never win and
        the pass aborts (the caller rolls the space back either way).  The
        derived per-placement ``cap`` lets a session stop searching early
        once every admissible slot is provably past the bound (see
        PlacementSession.place).

        ``sess`` injects a pre-seeded session (multi-variant node prescan);
        the memo layers consult core/memo.py before touching the session —
        a whole-segment hit replays the recorded plan with zero searches,
        a single-slot hit skips just that query.
        """
        dag, sp = self.dag, self.space
        memo = self.memo
        pass_key = None
        if memo is not None:
            pass_key = memo.pass_key(ids, direction)
            hit = memo.pass_get(pass_key)
            if hit is not None:
                span, plan = hit
                COUNTERS.add("passes_replayed")
                if limit is not None and span >= limit:
                    return False   # the live pass would abort mid-way
                for t, m, t0 in plan:   # replay is commit-only: no searches
                    self._replay_commit(t, m, t0)
                return True
        COUNTERS.add("passes_run")
        n_before = len(sp.placements)
        forward = direction == FORWARD
        in_subset = np.zeros(dag.n, dtype=bool)
        in_subset[ids] = True
        adj_open = dag.children if forward else dag.parents
        # pending in-subset gate-neighbors per task, as one bincount over
        # the flat edge list (parents gate forward passes, children gate
        # backward ones)
        ev, ew = ((self.edge_parent, self.edge_child) if forward
                  else (self.edge_child, self.edge_parent))
        pending = np.bincount(ew[in_subset[ev]], minlength=dag.n) \
            if len(ev) else np.zeros(dag.n, dtype=np.int64)
        tie = self.n_desc if forward else self.n_par
        dur = dag.duration
        # min-heap pops in the same (-duration, -tie, id) order the sorted
        # ready list did
        heap = [(-dur[i], -tie[i], int(i)) for i in ids if pending[i] == 0]
        heapq.heapify(heap)
        remaining = len(ids)
        if sess is None or sess.direction != direction:
            sess = self.backend.session(sp, direction)
        if sess.wants_f32:
            demand, vbytes = self.demand32, self.vb32
        else:
            demand, vbytes = dag.demand, self.vb64
        peers_fn = None
        est: dict[int, int] = {}
        if sess.wants_peers:
            # estimated anchors for prefetch (exact for anchored tasks; the
            # session re-clips against the real pop-time anchor regardless)
            est = {e[2]: self._anchor(e[2], forward) for e in heap}

            def peers_fn():
                return [PeerTask(e[2], est[e[2]], demand[e[2]], int(self.k[e[2]]))
                        for e in heap[:24]]
        while remaining:
            if not heap:
                return False  # cycle — cannot happen on a valid DAG
            t = heapq.heappop(heap)[2]
            anchor = self._anchor(t, forward)
            k = int(self.k[t])
            cap = None
            if limit is not None:
                # the exact start bound past which the new span >= limit
                if forward and sp._min_start is not None:
                    cap = limit + sp._min_start - k
                elif not forward and sp._max_end is not None:
                    cap = sp._max_end - limit
            vb = vbytes[t]
            hit = memo.place_get(direction, vb, k, anchor) if memo is not None else None
            if hit is not None:
                m, t0 = hit
                self._replay_commit(t, m, t0)
            else:
                COUNTERS.add("places_evaluated")
                key = (int(dag.stage_of[t]), float(anchor), self.vb64[t])
                m, t0 = sess.place(t, demand[t], k, anchor, key, peers_fn, cap)
                if memo is not None and m >= 0:
                    memo.place_put(direction, vb, k, anchor, forward, m, t0)
                if m < 0:
                    return False  # session proved the variant cannot win
                self._commit(t, m, t0)
            if limit is not None and sp.makespan_ticks >= limit:
                return False  # span is monotone: this variant cannot win
            remaining -= 1
            for c in adj_open[t]:
                if in_subset[c]:
                    pending[c] -= 1
                    if pending[c] == 0:
                        c = int(c)
                        if sess.wants_peers:
                            est[c] = self._anchor(c, forward)
                        heapq.heappush(heap, (-dur[c], -tie[c], c))
        if memo is not None:
            plan = [(p.task, p.machine, p.start)
                    for p in sp.placements[n_before:]]
            memo.pass_put(pass_key, sp.makespan_ticks, plan)
        return True

    # kept as thin aliases for readability at call sites / tests
    def place_forward(self, ids: np.ndarray, limit: int | None = None,
                      sess=None) -> bool:
        return self.place_pass(ids, FORWARD, limit, sess)

    def place_backward(self, ids: np.ndarray, limit: int | None = None,
                       sess=None) -> bool:
        return self.place_pass(ids, BACKWARD, limit, sess)

    def place_best(self, ids: np.ndarray, limit: int | None = None,
                   sess=None) -> bool:
        """PlaceTasks: min(forward, backward) by resulting span (Fig. 7 l.13).

        Tries both directions against the shared space (rolling back in
        between) and replays the winner's commits — no grid clone.  An
        aborted direction's true span provably exceeds ``limit``, so a
        completed direction always beats it and pruning stays exact.  The
        backward attempt runs under min(limit, forward span): forward wins
        ties, so backward only matters when strictly more compact.
        """
        if len(ids) == 0:
            return True
        sp = self.space
        snap = sp.snapshot()
        saved = self._save()
        okf = self.place_forward(ids, limit, sess)
        span_f = sp.makespan_ticks
        plan_f = [(p.task, p.machine, p.start)
                  for p in sp.placements[snap.n_placed:]] if okf else []
        # keep any growth: the forward plan may be replayed into it below
        sp.restore(snap, keep_extent=True)
        self._load(saved)
        blim = limit if not okf else \
            (span_f if limit is None else min(limit, span_f))
        okb = self.place_backward(ids, blim)
        if okf and (not okb or span_f <= sp.makespan_ticks):
            sp.restore(snap, keep_extent=True)
            self._load(saved)
            for t, m, t0 in plan_f:  # replay is commit-only: no searches
                self._commit(t, m, t0, check=False)
            return True
        return okb


# ----------------------------------------------------------------------
# Troublesome-task search (Fig. 6)
# ----------------------------------------------------------------------

def frag_scores(dag: DAG, m: int) -> np.ndarray:
    """FragScore per stage = TWork(s) / ExecutionTime(s) under greedy packing.

    Tasks of one stage are identical-ish and independent, so greedy packing
    runs them in waves: per machine, c = how many copies fit side by side.
    """
    out = np.ones(dag.n_stages, dtype=np.float64)
    for s, ids in enumerate(dag.stages):
        if len(ids) == 0:
            continue
        dur = float(dag.duration[ids].mean())
        dem = dag.demand[ids].mean(axis=0)
        peak = float(dem.max())
        if peak <= 0 or dur <= 0:
            continue
        per_machine = max(int(1.0 / max(peak, 1e-9) + 1e-9), 1)
        waves = math.ceil(len(ids) / (m * per_machine))
        exec_time = waves * dur
        twork = len(ids) * dur * peak / m
        out[s] = min(twork / exec_time, 1.0)
    return out


def candidate_troublesome(
    dag: DAG,
    m: int,
    n_long: int = 8,
    n_frag: int = 6,
    max_candidates: int = 24,
) -> list[np.ndarray]:
    """Enumerate closed candidate sets T (deduplicated, |candidates| capped)."""
    long_score = dag.duration / max(float(dag.duration.max()), 1e-12)
    frag = frag_scores(dag, m)[dag.stage_of]

    def _levels(vals: np.ndarray, k: int) -> np.ndarray:
        u = np.unique(vals)
        if len(u) <= k:
            return u
        qs = np.quantile(u, np.linspace(0, 1, k))
        return np.unique(qs)

    ls = _levels(long_score, n_long)
    fs = _levels(frag, n_frag)
    seen: set[bytes] = set()
    cands: list[np.ndarray] = []
    # T = empty => plain greedy packing of the whole DAG; always considered
    # so DAGPS can never lose to its own packer.
    empty = np.zeros(dag.n, dtype=bool)
    seen.add(empty.tobytes())
    cands.append(empty)
    pairs = [(l, f) for l in ls[::-1] for f in fs]
    # also pure-long and pure-frag sweeps
    pairs += [(l, -1.0) for l in ls[::-1]] + [(2.0, f) for f in fs]
    for l, f in pairs:
        t_mask = (long_score >= l) | (frag <= f)
        if not t_mask.any():
            continue
        t_mask = dag.closure_mask(t_mask)
        key = t_mask.tobytes()
        if key in seen:
            continue
        seen.add(key)
        cands.append(t_mask)
    if len(cands) > max_candidates:
        # keep a spread of candidate sizes (plus the empty set)
        sizes = np.array([c.sum() for c in cands])
        order = np.argsort(sizes, kind="stable")
        picks = order[np.unique(np.linspace(0, len(order) - 1, max_candidates).astype(int))]
        cands = [cands[i] for i in sorted(picks)]
    return cands


# ----------------------------------------------------------------------
# BuildSchedule (Fig. 5)
# ----------------------------------------------------------------------

def build_schedule(
    dag: DAG,
    m: int,
    ticks: int = 256,
    n_long: int = 8,
    n_frag: int = 6,
    max_candidates: int = 24,
    use_partitions: bool = True,
    backend: str | PlacementBackend | None = None,
    memoize: bool | None = None,
    reuse: "Schedule | dict | None" = None,
) -> Schedule:
    """Construct DAGPS's preferred schedule for one DAG on m machines.

    `backend` selects the placement engine ("reference" | "batched" |
    "jit"); None resolves REPRO_PLACEMENT_BACKEND, defaulting to "batched".
    All backends produce tick-identical schedules.  `memoize` toggles the
    cross-candidate construction memo (None resolves REPRO_BUILDER_MEMO,
    default on), which is shared across the partitioned sub-builds of the
    DAG; memoized and plain builds are bit-identical.

    `reuse` seeds a *delta rebuild*: pass a previous `Schedule` (or its
    ``build_info.parts`` map) and any partition whose content digest,
    machine count and knobs match a stored entry replays that entry
    instead of re-searching — bit-identical to a full build, because a
    partition's relative schedule is a pure function of exactly that key
    (see `BuildInfo`).  After a graph mutation only the partitions the
    edit touched miss; `rebuild_schedule` wraps this for the common case.
    """
    if dag.n == 0:
        return Schedule(dag, np.empty(0, np.int64), np.empty(0), np.empty(0, np.int64), 0.0, 1.0)
    be = get_backend(backend)
    # one memo for the whole build: the windowed place memo is content-
    # addressed (core/memo.py), so it carries across the partitioned
    # sub-builds of one DAG — each partition re-attaches it to its Space.
    memo = ConstructionMemo() if _memo_enabled(memoize) else None
    knobs = (int(ticks), int(n_long), int(n_frag), int(max_candidates),
             bool(use_partitions))
    prev_parts = reuse.build_info.parts if isinstance(reuse, Schedule) \
        and reuse.build_info is not None else (reuse if isinstance(reuse, dict)
                                               else None)
    if use_partitions:
        parts = partition_totally_ordered(dag)
        if len(parts) > 1 or prev_parts:
            return _concat_partition_schedules(dag, parts, m, knobs, be,
                                               memo, prev_parts)
    sched = _build_one(dag, m, ticks, n_long, n_frag, max_candidates, be,
                       memo)
    key = _part_key(_subdag(dag, np.arange(dag.n)), m, knobs)
    sched.build_info = BuildInfo(
        m, knobs, {key: _part_entry(sched)}, 0, 0, 1)
    return sched


def _part_key(sub: DAG, m: int, knobs: tuple) -> tuple:
    """Content key of one partition build: digest + machine count + knobs.

    The placement backend and the memo toggle are deliberately NOT part
    of the key — all backends are tick-identical and memoized builds are
    bit-identical to plain ones (both invariants locked by the parity
    suites), so entries recorded under any configuration replay exactly.
    """
    return (dag_digest(sub), int(m)) + knobs


def _part_entry(sched: Schedule) -> tuple:
    return (sched.start, sched.machine, sched.trouble_mask, sched.makespan,
            sched.tick)


def rebuild_schedule(
    prev: Schedule,
    dag: DAG,
    backend: str | PlacementBackend | None = None,
    memoize: bool | None = None,
    check_parity: bool = False,
    **overrides,
) -> Schedule:
    """Delta rebuild after a graph mutation: same knobs as ``prev``,
    replaying every partition the edit did not touch.

    ``check_parity=True`` (or env REPRO_DELTA_PARITY=1) runs the bit-
    parity oracle: a from-scratch build of the mutated DAG must agree
    with the delta rebuild on every array, bit for bit.
    """
    info = prev.build_info
    if info is None:
        raise ValueError("previous schedule has no build_info to reuse")
    kw = dict(ticks=info.knobs[0], n_long=info.knobs[1], n_frag=info.knobs[2],
              max_candidates=info.knobs[3], use_partitions=info.knobs[4])
    m = overrides.pop("m", info.m)
    kw.update(overrides)
    sched = build_schedule(dag, m, backend=backend, memoize=memoize,
                           reuse=prev, **kw)
    if check_parity or os.environ.get("REPRO_DELTA_PARITY", "0") == "1":
        full = build_schedule(dag, m, backend=backend, memoize=memoize, **kw)
        assert_schedules_equal(sched, full)
    return sched


def assert_schedules_equal(a: Schedule, b: Schedule) -> None:
    """Bit-parity oracle: every decision array identical, not just close."""
    assert (a.order == b.order).all(), "order diverged"
    assert (a.start == b.start).all(), "start times diverged"
    assert (a.machine == b.machine).all(), "machine assignment diverged"
    assert repr(a.makespan) == repr(b.makespan), (a.makespan, b.makespan)
    assert repr(a.tick) == repr(b.tick), (a.tick, b.tick)


def _span_lb_ticks(dag: DAG, m: int, dur_ticks: np.ndarray) -> int:
    """Sound tick lower bound on ANY schedule the builder can construct.

    Critical path in *rounded* ticks (chain tasks occupy disjoint tick
    ranges in the space) and per-dim total work over m unit-capacity
    machines.  Once the incumbent reaches this bound no later candidate
    can be strictly more compact, so the search may stop (``consider``
    replaces on strict < only, which also keeps tie-breaking exact).
    """
    n = dag.n
    finish = np.zeros(n, dtype=np.int64)
    for i in range(n):   # DAG guarantees topological index order
        ps = dag.parents[i]
        finish[i] = (finish[ps].max() if len(ps) else 0) + dur_ticks[i]
    cp = int(finish.max()) if n else 0
    work = (dur_ticks[:, None] * dag.demand).sum(axis=0)
    wb = int(np.ceil(work.max() / max(m, 1) - 1e-12)) if n else 0
    return max(cp, wb)


_INF = 1 << 60


def _span_bound(pl: _Placer) -> int:
    """Sound LB on the final span of any COMPLETED variant continuing
    from ``pl``'s partial placement.

    Every completed variant places all remaining tasks with dependencies
    holding as tick inequalities (parent end <= child start — the §4.3
    dead-end-free invariant), so chains rooted at already-placed tasks
    bound the final extent: a placed parent's end plus the longest k-chain
    below it must fit under max_end', and min_start' can only decrease.
    Unrooted chains bound the span directly.  If the bound reaches the
    incumbent, the whole subtree of order variants under this prefix is
    skipped — outcome-exact, since a completed variant's span would be
    >= the incumbent and ``consider`` replaces on strict < only (a live
    evaluation would have aborted on the monotone-span limit instead).
    """
    dag, sp = pl.dag, pl.space
    placed = pl.is_placed
    span_cur = sp.makespan_ticks
    if placed.all():
        return span_cur
    k = pl.k
    pe, ps_ = pl.placed_end, pl.placed_start
    parents, children = dag.parents, dag.children
    n = dag.n
    # forward sweep: finish-time LBs; rooted[i] iff the chain passes
    # through a placed task (only rooted chains bound absolute extents)
    fin = np.zeros(n, dtype=np.int64)
    rooted = np.zeros(n, dtype=bool)
    for i in range(n):
        if placed[i]:
            continue
        b, r = 0, False
        for p in parents[i]:
            v = pe[p] if placed[p] else fin[p]
            if v > b:
                b, r = v, bool(placed[p] or rooted[p])
            elif v == b and (placed[p] or rooted[p]):
                r = True
        fin[i] = b + k[i]
        rooted[i] = r
    top_rooted = 0
    pure = 0
    for i in range(n):
        if placed[i]:
            continue
        if rooted[i]:
            if fin[i] > top_rooted:
                top_rooted = fin[i]
        elif fin[i] > pure:
            pure = fin[i]
    bound = max(span_cur, pure)
    mn, mx = sp._min_start, sp._max_end
    if top_rooted and mn is not None:
        bound = max(bound, top_rooted - mn)
    # backward sweep: start-time UBs rooted at placed children
    if mx is not None:
        start_ub = np.full(n, _INF, dtype=np.int64)
        low = _INF
        for i in range(n - 1, -1, -1):
            if placed[i]:
                continue
            b = _INF
            for c in children[i]:
                v = ps_[c] if placed[c] else start_ub[c]
                if v < b:
                    b = v
            if b < _INF:
                start_ub[i] = b - k[i]
                if start_ub[i] < low:
                    low = start_ub[i]
        if low < _INF:
            bound = max(bound, mx - low)
    return bound


def _build_one(dag, m, ticks, n_long, n_frag, max_candidates, backend,
               memo=None) -> Schedule:
    from .bounds import cp_length, t_work  # local import, no cycle at module load

    horizon = max(cp_length(dag), t_work(dag, m))
    tick = max(horizon / ticks, 1e-9)
    dur_ticks = np.maximum(np.ceil(dag.duration / tick - 1e-9).astype(np.int64), 1)
    grid = int(dur_ticks.sum() / max(m, 1) + dur_ticks.max()) + 4
    grid = max(grid, int(1.25 * horizon / tick) + 4)

    # one shared space for every candidate variant: each (T-set, order,
    # direction) evaluation runs against a snapshot and is rolled back,
    # so variant cost is O(cells written), never O(grid) cloning.
    space = Space(m, dag.d, grid, tick)
    if memo is not None:
        memo.attach(space)
    lb = _span_lb_ticks(dag, m, dur_ticks)
    best_span: int | None = None
    best_state: tuple[np.ndarray, np.ndarray] | None = None
    best_mask: np.ndarray | None = None
    # adaptive gate for the chain-bound pruner: on work-dominated DAGs the
    # bound never reaches the incumbent, so after a few dry candidates the
    # O(n + e) sweeps stop (a perf-only choice — skipping a *computation*
    # of an exact pruner cannot change the search outcome)
    bound_gate = {"tries": 0, "hits": 0}
    cands = candidate_troublesome(dag, m, n_long, n_frag, max_candidates)
    for ci, t_mask in enumerate(cands):
        if best_span is not None and best_span <= lb:
            # the incumbent is provably unbeatable (strict-< consider)
            COUNTERS.add("candidates_lb_skipped", len(cands) - ci)
            break
        t_mask, o_mask, p_mask, c_mask = dag.split_subsets(t_mask)
        t_ids, o_ids = np.nonzero(t_mask)[0], np.nonzero(o_mask)[0]
        p_ids, c_ids = np.nonzero(p_mask)[0], np.nonzero(c_mask)[0]

        snap_cand = space.snapshot()
        base = _Placer(dag, space, dur_ticks, backend, memo)
        if base.place_best(t_ids, best_span):  # trouble goes first (Fig. 5 l.7)
            best_span, best_state, best_mask = _try_orders(
                space, base, o_ids, p_ids, c_ids, t_mask,
                best_span, best_state, best_mask, lb, bound_gate)
        space.restore(snap_cand)
    assert best_state is not None
    return _to_schedule(dag, best_state[0], best_state[1], tick, best_mask,
                        label="dagps")


#: segment ops: how one prefix-tree edge places its id set
_SEG_BEST, _SEG_FWD, _SEG_BWD = "best", "fwd", "bwd"


def _variant_tree(o_ids, p_ids, c_ids):
    """The four dead-end-free orders (Fig. 7 l.19-23) as a shared-prefix
    tree of (op, ids) placement segments.

    Exact-outcome normalizations before the trie is built:
      * empty segments are dropped (placing nothing is the identity);
      * sequences that coincide after dropping are deduplicated — e.g.
        with P empty, T-OPC and T-OCP are the same variant;
      * with P and C both empty every order degenerates to placing O, and
        place_best(O) already covers both directions, so one sequence
        remains.
    The trie preserves the paper's enumeration order, which ``consider``'s
    strict-< tie-breaking depends on.
    """
    segs = {
        "O": (_SEG_BEST, o_ids),       # either direction (Lemma 4)
        "P": (_SEG_BWD, p_ids),        # parents only backward
        "C": (_SEG_FWD, c_ids),        # children only forward
        "Ob": (_SEG_BWD, o_ids),
        "Of": (_SEG_FWD, o_ids),
    }
    orders = [
        ["O", "P", "C"],               # T-OPC (l.20)
        ["O", "C", "P"],               # T-OCP (l.21)
        ["C", "Ob", "P"],              # T-COP (l.22)
        ["P", "Of", "C"],              # T-POC (l.23)
    ]
    if len(p_ids) == 0 and len(c_ids) == 0:
        orders = [["O"]]
    seen: list[tuple] = []
    tree: dict = {}
    for order in orders:
        seq = tuple(s for s in order if len(segs[s][1]))
        if seq in seen:
            continue
        seen.append(seq)
        node = tree
        for s in seq:
            node = node.setdefault(s, {})
    return segs, tree


def _try_orders(space, base, o_ids, p_ids, c_ids, t_mask,
                best_span, best_state, best_mask, lb=None, bound_gate=None):
    """TrySubsetOrders around a placed T, as a shared-prefix-tree DFS.

    Each trie edge places one (ids, direction) segment; shared prefixes
    (e.g. the place_best(O) prefix of T-OPC/T-OCP) are placed once and
    branched through the Space undo log.  At every branch node:

      * the subtree is skipped when the dependency-chain bound of the
        prefix already reaches the incumbent (``_span_bound``) or the
        incumbent sits at the tick lower bound — both outcome-exact;
      * sibling segments' initial feasibility scans are stacked into one
        multi-variant backend pass (``PlacementBackend.sessions``).
    """
    def consider(pl):
        nonlocal best_span, best_state, best_mask
        span = space.makespan_ticks
        if best_span is None or span < best_span:
            best_span = span
            best_state = (pl.placed_start.copy(), pl.machine.copy())
            best_mask = t_mask

    segs, tree = _variant_tree(o_ids, p_ids, c_ids)

    def descend(pl, node):
        nonlocal best_span
        if not node:
            consider(pl)
            return
        bound = None
        kids = list(node.items())
        sessions = [None] * len(kids)
        if len(kids) > 1 and pl.backend.wants_prescan:
            # one stacked (n_variants, n_tasks, m, W) prescan for all
            # sibling first-segments off this node's shared grid state
            specs = []
            for name, _child in kids:
                op, ids = segs[name]
                d = BACKWARD if op == _SEG_BWD else FORWARD
                specs.append((d, pl.ready_peers(ids, d)))
            sessions = pl.backend.sessions(space, specs)
        gate_open = bound_gate is None or bound_gate["hits"] > 0 \
            or bound_gate["tries"] < 6
        for j, (name, child) in enumerate(kids):
            if best_span is not None:
                if lb is not None and best_span <= lb:
                    break
                if bound is None and gate_open:
                    bound = _span_bound(pl)
                    if bound_gate is not None:
                        bound_gate["tries"] += 1
                if bound is not None and bound >= best_span:
                    if bound_gate is not None:
                        bound_gate["hits"] += 1
                    # every remaining sibling subtree is abandoned (same
                    # all-skipped semantics as candidates_lb_skipped)
                    COUNTERS.add("variants_bound_skipped", len(kids) - j)
                    break
            op, ids = segs[name]
            snap = space.snapshot()
            pl2 = pl.branch()
            if op == _SEG_BEST:
                ok = pl2.place_best(ids, best_span, sessions[j])
            elif op == _SEG_FWD:
                ok = pl2.place_forward(ids, best_span, sessions[j])
            else:
                ok = pl2.place_backward(ids, best_span, sessions[j])
            if ok:
                descend(pl2, child)
            space.restore(snap)

    descend(base, tree)
    return best_span, best_state, best_mask


def _to_schedule(dag: DAG, placed_start: np.ndarray, machine: np.ndarray,
                 tick: float, t_mask, label: str) -> Schedule:
    start_ticks = placed_start.astype(np.float64)
    start_ticks -= start_ticks.min()
    start = start_ticks * tick
    order = np.lexsort((np.arange(dag.n), start))
    makespan = float((start + dag.duration).max() - start.min())
    return Schedule(
        dag=dag, order=order, start=start, machine=machine,
        makespan=makespan, tick=tick, trouble_mask=t_mask, label=label,
    )


# ----------------------------------------------------------------------
# §4.4 enhancement: split at barriers into totally ordered parts
# ----------------------------------------------------------------------

def partition_totally_ordered(dag: DAG) -> list[np.ndarray]:
    """Split V into V1..Vk where every task of Vi precedes all of Vi+1.

    A cut after topological prefix [0..i] is valid iff [0..i] ⊆ anc(j) for
    every j > i, i.e. the suffix-AND of ancestor bitsets from i+1 on contains
    the full prefix.  Computed vectorized in O(n * n/8) bytes.
    """
    n = dag.n
    if n <= 1:
        return [np.arange(n)]
    anc = np.unpackbits(dag.anc_bits.view(np.uint8), axis=1, bitorder="little")[:, :n]
    # suffix_and[i] = AND of anc rows i+1..n-1
    suffix = np.minimum.accumulate(anc[::-1], axis=0)[::-1]
    cuts = []
    for i in range(n - 1):
        if suffix[i + 1, : i + 1].all():
            cuts.append(i)
    parts = []
    prev = 0
    for c in cuts:
        parts.append(np.arange(prev, c + 1))
        prev = c + 1
    parts.append(np.arange(prev, n))
    return parts


def _concat_partition_schedules(dag, parts, m, knobs, backend,
                                memo=None, prev_parts=None) -> Schedule:
    ticks, n_long, n_frag, max_candidates, _ = knobs
    start = np.zeros(dag.n, dtype=np.float64)
    machine = np.zeros(dag.n, dtype=np.int64)
    offset = 0.0
    tick = None
    tmask = np.zeros(dag.n, dtype=bool)
    out_parts: dict = {}
    reused_parts = reused_tasks = 0
    for ids in parts:
        sub = _subdag(dag, ids)
        key = _part_key(sub, m, knobs)
        entry = prev_parts.get(key) if prev_parts else None
        if entry is None:
            sched = _build_one(sub, m, ticks, n_long, n_frag,
                               max_candidates, backend, memo)
            entry = _part_entry(sched)
        else:
            # untouched partition: replay the stored relative schedule
            reused_parts += 1
            reused_tasks += len(ids)
            COUNTERS.add("parts_reused")
            COUNTERS.add("placements_reused", len(ids))
        p_start, p_machine, p_tmask, p_makespan, p_tick = entry
        start[ids] = p_start + offset
        machine[ids] = p_machine
        if p_tmask is not None:
            tmask[ids] = p_tmask
        offset += p_makespan
        tick = p_tick if tick is None else max(tick, p_tick)
        out_parts[key] = entry
    order = np.lexsort((np.arange(dag.n), start))
    makespan = float((start + dag.duration).max() - start.min())
    out = Schedule(dag, order, start, machine, makespan, tick or 1.0,
                   trouble_mask=tmask, label="dagps")
    out.build_info = BuildInfo(m, knobs, out_parts, reused_parts,
                               reused_tasks, len(parts))
    return out


def _subdag(dag: DAG, ids: np.ndarray) -> DAG:
    remap = {int(t): k for k, t in enumerate(ids)}
    idset = set(remap)
    parents = [
        np.asarray(sorted(remap[int(p)] for p in dag.parents[int(t)] if int(p) in idset),
                   dtype=np.int64)
        for t in ids
    ]
    stages = dag.stage_of[ids]
    _, stage_renum = np.unique(stages, return_inverse=True)
    return DAG(
        duration=dag.duration[ids].copy(),
        demand=dag.demand[ids].copy(),
        stage_of=stage_renum,
        parents=parents,
        name=f"{dag.name}[part]",
    )
