"""Offline schedule construction for one DAG (paper §4, Figs. 5-7).

BuildSchedule:
  1. CandidateTroublesomeTasks (§4.1): score tasks by LongScore (duration /
     max duration) and stages by FragScore (TWork / greedy execution time);
     sweep discriminative (l, f) thresholds; take the closure of each chosen
     set; split the DAG into subsets T (troublesome), P (parents), C
     (children), O (other).
  2. Place T first onto the virtual space, forward or backward, keep the
     more compact (§4.2).
  3. TrySubsetOrders (§4.3): the four dead-end-free orders
     T-OPC, T-OCP, T-COP, T-POC with the direction restrictions proved in
     Lemma 4 (P only backward, C only forward, O either).
  4. Keep the most compact schedule across all candidates; OrderTasks
     returns tasks sorted by start time, which the online component (§5)
     consumes as priScore.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

import numpy as np

from .dag import DAG
from .space import Space


@dataclasses.dataclass
class Schedule:
    """A constructed schedule: placement of every task in the virtual space."""

    dag: DAG
    order: np.ndarray        # task ids sorted by start time
    start: np.ndarray        # (n,) start seconds (shifted so min == 0)
    machine: np.ndarray      # (n,) machine index in the virtual space
    makespan: float
    tick: float
    trouble_mask: np.ndarray | None = None
    label: str = "dagps"

    @property
    def pri_score(self) -> np.ndarray:
        """priScore in [0, 1]: 1 for the first task, ->0 for the last (§5)."""
        n = self.dag.n
        rank = np.empty(n, dtype=np.float64)
        rank[self.order] = np.arange(n)
        return 1.0 - rank / max(n, 1)

    def validate(self) -> None:
        """Dependencies respected and no over-commitment (test hook)."""
        d = self.dag
        end = self.start + d.duration
        for i in range(d.n):
            for p in d.parents[i]:
                if self.start[i] < end[p] - 1e-6 - self.tick:
                    raise AssertionError(f"dependency violated: {p} -> {i}")


# ----------------------------------------------------------------------
# Subset placement (Fig. 7)
# ----------------------------------------------------------------------

class _Placer:
    def __init__(self, dag: DAG, space: Space, dur_ticks: np.ndarray):
        self.dag = dag
        self.space = space
        self.k = dur_ticks
        # structural tie-break: among equal durations, place tasks that
        # enable the most downstream work first (resolves Fig. 17's "red"
        # tasks, which are identical to their siblings except structurally).
        self.n_desc = np.array([len(dag.children[i]) for i in range(dag.n)])
        self.placed_start = np.zeros(dag.n, dtype=np.int64)
        self.placed_end = np.zeros(dag.n, dtype=np.int64)
        self.machine = np.full(dag.n, -1, dtype=np.int64)
        self.is_placed = np.zeros(dag.n, dtype=bool)

    def clone(self, space: Space) -> "_Placer":
        p = _Placer.__new__(_Placer)
        p.dag, p.k = self.dag, self.k
        p.n_desc = self.n_desc
        p.space = space
        p.placed_start = self.placed_start.copy()
        p.placed_end = self.placed_end.copy()
        p.machine = self.machine.copy()
        p.is_placed = self.is_placed.copy()
        return p

    def _commit(self, t: int, m: int, t0: int) -> None:
        self.space.commit(t, m, t0, self.k[t], self.dag.demand[t])
        self.placed_start[t] = t0
        self.placed_end[t] = t0 + self.k[t]
        self.machine[t] = m
        self.is_placed[t] = True

    def place_forward(self, ids: np.ndarray) -> bool:
        """PlaceTasksF: dependency-order within subset, longest task first."""
        dag, sp = self.dag, self.space
        in_subset = np.zeros(dag.n, dtype=bool)
        in_subset[ids] = True
        # unplaced parents *within the subset* gate readiness; parents outside
        # the subset constrain the start only if already placed (see §4.3
        # discussion of inter-subset dependencies).
        pending_parents = np.array(
            [int(in_subset[dag.parents[i]].sum()) for i in range(dag.n)]
        )
        key_fn = lambda i: (-dag.duration[i], -self.n_desc[i], i)
        ready = [i for i in ids if pending_parents[i] == 0]
        ready.sort(key=key_fn)
        remaining = len(ids)
        hint: dict[tuple[int, float, bytes], tuple[int, int]] = {}
        while remaining:
            if not ready:
                return False  # cycle — cannot happen on a valid DAG
            t = ready.pop(0)
            par = dag.parents[t]
            pl = par[self.is_placed[par]] if len(par) else par
            if len(pl):
                r = int(self.placed_end[pl].max())
            else:
                r = sp._min_start if sp._min_start is not None else 0
            key = (int(dag.stage_of[t]), float(r), dag.demand[t].tobytes())
            m, t0 = sp.earliest_fit(dag.demand[t], self.k[t], r, hint.get(key))
            self._commit(t, m, t0)
            hint[key] = (m, t0)
            remaining -= 1
            newly = []
            for c in dag.children[t]:
                if in_subset[c]:
                    pending_parents[c] -= 1
                    if pending_parents[c] == 0:
                        newly.append(int(c))
            if newly:
                ready.extend(newly)
                ready.sort(key=key_fn)
        return True

    def place_backward(self, ids: np.ndarray) -> bool:
        """PlaceTasksB: mirror image — children first, latest feasible slot."""
        dag, sp = self.dag, self.space
        in_subset = np.zeros(dag.n, dtype=bool)
        in_subset[ids] = True
        pending_children = np.array(
            [int(in_subset[dag.children[i]].sum()) for i in range(dag.n)]
        )
        key_fn = lambda i: (-dag.duration[i], -len(dag.parents[i]), i)
        ready = [i for i in ids if pending_children[i] == 0]
        ready.sort(key=key_fn)
        remaining = len(ids)
        hint: dict[tuple[int, float, bytes], tuple[int, int]] = {}
        while remaining:
            if not ready:
                return False
            t = ready.pop(0)
            ch = dag.children[t]
            pl = ch[self.is_placed[ch]] if len(ch) else ch
            if len(pl):
                deadline = int(self.placed_start[pl].min())
            elif sp._max_end is not None:
                # unanchored task: pack against the occupied region instead of
                # drifting to the far end of the grid.
                deadline = int(sp._max_end)
            else:
                deadline = sp.T - sp.off  # logical end of the empty grid
            key = (int(dag.stage_of[t]), float(deadline), dag.demand[t].tobytes())
            m, t0 = sp.latest_fit(dag.demand[t], self.k[t], deadline, hint.get(key))
            self._commit(t, m, t0)
            hint[key] = (m, t0)
            remaining -= 1
            newly = []
            for p in dag.parents[t]:
                if in_subset[p]:
                    pending_children[p] -= 1
                    if pending_children[p] == 0:
                        newly.append(int(p))
            if newly:
                ready.extend(newly)
                ready.sort(key=key_fn)
        return True

    def place_best(self, ids: np.ndarray) -> "_Placer":
        """PlaceTasks: min(forward, backward) by resulting span (Fig. 7 l.13)."""
        if len(ids) == 0:
            return self
        fwd = self.clone(self.space.clone())
        okf = fwd.place_forward(ids)
        bwd = self.clone(self.space.clone())
        okb = bwd.place_backward(ids)
        if okf and (not okb or fwd.space.makespan_ticks <= bwd.space.makespan_ticks):
            return fwd
        return bwd


# ----------------------------------------------------------------------
# Troublesome-task search (Fig. 6)
# ----------------------------------------------------------------------

def frag_scores(dag: DAG, m: int) -> np.ndarray:
    """FragScore per stage = TWork(s) / ExecutionTime(s) under greedy packing.

    Tasks of one stage are identical-ish and independent, so greedy packing
    runs them in waves: per machine, c = how many copies fit side by side.
    """
    out = np.ones(dag.n_stages, dtype=np.float64)
    for s, ids in enumerate(dag.stages):
        if len(ids) == 0:
            continue
        dur = float(dag.duration[ids].mean())
        dem = dag.demand[ids].mean(axis=0)
        peak = float(dem.max())
        if peak <= 0 or dur <= 0:
            continue
        per_machine = max(int(1.0 / max(peak, 1e-9) + 1e-9), 1)
        waves = math.ceil(len(ids) / (m * per_machine))
        exec_time = waves * dur
        twork = len(ids) * dur * peak / m
        out[s] = min(twork / exec_time, 1.0)
    return out


def candidate_troublesome(
    dag: DAG,
    m: int,
    n_long: int = 8,
    n_frag: int = 6,
    max_candidates: int = 24,
) -> list[np.ndarray]:
    """Enumerate closed candidate sets T (deduplicated, |candidates| capped)."""
    long_score = dag.duration / max(float(dag.duration.max()), 1e-12)
    frag = frag_scores(dag, m)[dag.stage_of]

    def _levels(vals: np.ndarray, k: int) -> np.ndarray:
        u = np.unique(vals)
        if len(u) <= k:
            return u
        qs = np.quantile(u, np.linspace(0, 1, k))
        return np.unique(qs)

    ls = _levels(long_score, n_long)
    fs = _levels(frag, n_frag)
    seen: set[bytes] = set()
    cands: list[np.ndarray] = []
    # T = empty => plain greedy packing of the whole DAG; always considered
    # so DAGPS can never lose to its own packer.
    empty = np.zeros(dag.n, dtype=bool)
    seen.add(empty.tobytes())
    cands.append(empty)
    pairs = [(l, f) for l in ls[::-1] for f in fs]
    # also pure-long and pure-frag sweeps
    pairs += [(l, -1.0) for l in ls[::-1]] + [(2.0, f) for f in fs]
    for l, f in pairs:
        t_mask = (long_score >= l) | (frag <= f)
        if not t_mask.any():
            continue
        t_mask = dag.closure_mask(t_mask)
        key = t_mask.tobytes()
        if key in seen:
            continue
        seen.add(key)
        cands.append(t_mask)
    if len(cands) > max_candidates:
        # keep a spread of candidate sizes (plus the empty set)
        sizes = np.array([c.sum() for c in cands])
        order = np.argsort(sizes, kind="stable")
        picks = order[np.unique(np.linspace(0, len(order) - 1, max_candidates).astype(int))]
        cands = [cands[i] for i in sorted(picks)]
    return cands


# ----------------------------------------------------------------------
# BuildSchedule (Fig. 5)
# ----------------------------------------------------------------------

def build_schedule(
    dag: DAG,
    m: int,
    ticks: int = 256,
    n_long: int = 8,
    n_frag: int = 6,
    max_candidates: int = 24,
    use_partitions: bool = True,
) -> Schedule:
    """Construct DAGPS's preferred schedule for one DAG on m machines."""
    if dag.n == 0:
        return Schedule(dag, np.empty(0, np.int64), np.empty(0), np.empty(0, np.int64), 0.0, 1.0)
    if use_partitions:
        parts = partition_totally_ordered(dag)
        if len(parts) > 1:
            return _concat_partition_schedules(dag, parts, m, ticks, n_long, n_frag, max_candidates)
    return _build_one(dag, m, ticks, n_long, n_frag, max_candidates)


def _build_one(dag, m, ticks, n_long, n_frag, max_candidates) -> Schedule:
    from .bounds import cp_length, t_work  # local import, no cycle at module load

    horizon = max(cp_length(dag), t_work(dag, m))
    tick = max(horizon / ticks, 1e-9)
    dur_ticks = np.maximum(np.ceil(dag.duration / tick - 1e-9).astype(np.int64), 1)
    grid = int(dur_ticks.sum() / max(m, 1) + dur_ticks.max()) + 4
    grid = max(grid, int(1.25 * horizon / tick) + 4)

    best: tuple[int, _Placer] | None = None
    best_mask: np.ndarray | None = None
    for t_mask in candidate_troublesome(dag, m, n_long, n_frag, max_candidates):
        t_mask, o_mask, p_mask, c_mask = dag.split_subsets(t_mask)
        t_ids, o_ids = np.nonzero(t_mask)[0], np.nonzero(o_mask)[0]
        p_ids, c_ids = np.nonzero(p_mask)[0], np.nonzero(c_mask)[0]

        base = _Placer(dag, Space(m, dag.d, grid, tick), dur_ticks)
        base = base.place_best(t_ids)  # trouble goes first (Fig. 5 l.7)

        for order_fn in (_order_opc, _order_ocp, _order_cop, _order_poc):
            pl = base.clone(base.space.clone())
            if not order_fn(pl, o_ids, p_ids, c_ids):
                continue
            span = pl.space.makespan_ticks
            if best is None or span < best[0]:
                best = (span, pl)
                best_mask = t_mask
    assert best is not None
    return _to_schedule(dag, best[1], best_mask, label="dagps")


def _order_opc(pl: _Placer, o, p, c) -> bool:   # T OPC (Fig. 7 l.20)
    pl2 = pl.place_best(o)
    pl.__dict__.update(pl2.__dict__)
    return pl.place_backward(p) and pl.place_forward(c)


def _order_ocp(pl: _Placer, o, p, c) -> bool:   # T OCP (l.21)
    pl2 = pl.place_best(o)
    pl.__dict__.update(pl2.__dict__)
    return pl.place_forward(c) and pl.place_backward(p)


def _order_cop(pl: _Placer, o, p, c) -> bool:   # T COP (l.22)
    return pl.place_forward(c) and pl.place_backward(o) and pl.place_backward(p)


def _order_poc(pl: _Placer, o, p, c) -> bool:   # T POC (l.23)
    return pl.place_backward(p) and pl.place_forward(o) and pl.place_forward(c)


def _to_schedule(dag: DAG, pl: _Placer, t_mask, label: str) -> Schedule:
    start_ticks = pl.placed_start.astype(np.float64)
    start_ticks -= start_ticks.min()
    start = start_ticks * pl.space.tick
    order = np.lexsort((np.arange(dag.n), start))
    makespan = float((start + dag.duration).max() - start.min())
    return Schedule(
        dag=dag, order=order, start=start, machine=pl.machine,
        makespan=makespan, tick=pl.space.tick, trouble_mask=t_mask, label=label,
    )


# ----------------------------------------------------------------------
# §4.4 enhancement: split at barriers into totally ordered parts
# ----------------------------------------------------------------------

def partition_totally_ordered(dag: DAG) -> list[np.ndarray]:
    """Split V into V1..Vk where every task of Vi precedes all of Vi+1.

    A cut after topological prefix [0..i] is valid iff [0..i] ⊆ anc(j) for
    every j > i, i.e. the suffix-AND of ancestor bitsets from i+1 on contains
    the full prefix.  Computed vectorized in O(n * n/8) bytes.
    """
    n = dag.n
    if n <= 1:
        return [np.arange(n)]
    anc = np.unpackbits(dag.anc_bits.view(np.uint8), axis=1, bitorder="little")[:, :n]
    # suffix_and[i] = AND of anc rows i+1..n-1
    suffix = np.minimum.accumulate(anc[::-1], axis=0)[::-1]
    cuts = []
    for i in range(n - 1):
        if suffix[i + 1, : i + 1].all():
            cuts.append(i)
    parts = []
    prev = 0
    for c in cuts:
        parts.append(np.arange(prev, c + 1))
        prev = c + 1
    parts.append(np.arange(prev, n))
    return parts


def _concat_partition_schedules(dag, parts, m, ticks, n_long, n_frag, max_candidates) -> Schedule:
    start = np.zeros(dag.n, dtype=np.float64)
    machine = np.zeros(dag.n, dtype=np.int64)
    offset = 0.0
    tick = None
    tmask = np.zeros(dag.n, dtype=bool)
    for ids in parts:
        sub = _subdag(dag, ids)
        sched = _build_one(sub, m, ticks, n_long, n_frag, max_candidates)
        start[ids] = sched.start + offset
        machine[ids] = sched.machine
        if sched.trouble_mask is not None:
            tmask[ids] = sched.trouble_mask
        offset += sched.makespan
        tick = sched.tick if tick is None else max(tick, sched.tick)
    order = np.lexsort((np.arange(dag.n), start))
    makespan = float((start + dag.duration).max() - start.min())
    return Schedule(dag, order, start, machine, makespan, tick or 1.0,
                    trouble_mask=tmask, label="dagps")


def _subdag(dag: DAG, ids: np.ndarray) -> DAG:
    remap = {int(t): k for k, t in enumerate(ids)}
    idset = set(remap)
    parents = [
        np.asarray(sorted(remap[int(p)] for p in dag.parents[int(t)] if int(p) in idset),
                   dtype=np.int64)
        for t in ids
    ]
    stages = dag.stage_of[ids]
    _, stage_renum = np.unique(stages, return_inverse=True)
    return DAG(
        duration=dag.duration[ids].copy(),
        demand=dag.demand[ids].copy(),
        stage_of=stage_renum,
        parents=parents,
        name=f"{dag.name}[part]",
    )
