"""Offline schedule construction for one DAG (paper §4, Figs. 5-7).

BuildSchedule:
  1. CandidateTroublesomeTasks (§4.1): score tasks by LongScore (duration /
     max duration) and stages by FragScore (TWork / greedy execution time);
     sweep discriminative (l, f) thresholds; take the closure of each chosen
     set; split the DAG into subsets T (troublesome), P (parents), C
     (children), O (other).
  2. Place T first onto the virtual space, forward or backward, keep the
     more compact (§4.2).
  3. TrySubsetOrders (§4.3): the four dead-end-free orders
     T-OPC, T-OCP, T-COP, T-POC with the direction restrictions proved in
     Lemma 4 (P only backward, C only forward, O either).
  4. Keep the most compact schedule across all candidates; OrderTasks
     returns tasks sorted by start time, which the online component (§5)
     consumes as priScore.

All candidate (T-set, order, direction) variants are evaluated against ONE
shared Space through snapshot/restore (an undo log — O(cells written) per
variant, never a full grid clone), and the per-task fit queries go through
a pluggable placement backend (core/engine/): "reference" rescans the grid
per task, "batched" (default) answers whole ready-sets with one
(n_tasks, m, W) feasibility scan, "jit" runs that scan via jax.jit.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Iterable

import numpy as np

from .dag import DAG
from .engine import FORWARD, BACKWARD, PeerTask, PlacementBackend, get_backend
from .engine.base import ceil32
from .space import Space


@dataclasses.dataclass
class Schedule:
    """A constructed schedule: placement of every task in the virtual space."""

    dag: DAG
    order: np.ndarray        # task ids sorted by start time
    start: np.ndarray        # (n,) start seconds (shifted so min == 0)
    machine: np.ndarray      # (n,) machine index in the virtual space
    makespan: float
    tick: float
    trouble_mask: np.ndarray | None = None
    label: str = "dagps"

    @property
    def pri_score(self) -> np.ndarray:
        """priScore in [0, 1]: 1 for the first task, ->0 for the last (§5)."""
        n = self.dag.n
        rank = np.empty(n, dtype=np.float64)
        rank[self.order] = np.arange(n)
        return 1.0 - rank / max(n, 1)

    def validate(self) -> None:
        """Dependencies respected and no over-commitment (test hook)."""
        d = self.dag
        end = self.start + d.duration
        for i in range(d.n):
            for p in d.parents[i]:
                if self.start[i] < end[p] - 1e-6 - self.tick:
                    raise AssertionError(f"dependency violated: {p} -> {i}")


# ----------------------------------------------------------------------
# Subset placement (Fig. 7)
# ----------------------------------------------------------------------

class _Placer:
    def __init__(self, dag: DAG, space: Space, dur_ticks: np.ndarray,
                 backend: PlacementBackend):
        self.dag = dag
        self.space = space
        self.k = dur_ticks
        self.backend = backend
        # structural tie-break: among equal durations, place tasks that
        # enable the most downstream work first (resolves Fig. 17's "red"
        # tasks, which are identical to their siblings except structurally).
        self.n_desc = np.array([len(dag.children[i]) for i in range(dag.n)])
        self.n_par = np.array([len(dag.parents[i]) for i in range(dag.n)])
        self.demand32 = ceil32(dag.demand)   # for float32-comparing sessions
        self.placed_start = np.zeros(dag.n, dtype=np.int64)
        self.placed_end = np.zeros(dag.n, dtype=np.int64)
        self.machine = np.full(dag.n, -1, dtype=np.int64)
        self.is_placed = np.zeros(dag.n, dtype=bool)

    def branch(self) -> "_Placer":
        """Cheap variant copy: own task arrays, SHARED space (snapshot it)."""
        p = _Placer.__new__(_Placer)
        p.dag, p.k, p.backend = self.dag, self.k, self.backend
        p.n_desc, p.n_par = self.n_desc, self.n_par
        p.demand32 = self.demand32
        p.space = self.space
        p.placed_start = self.placed_start.copy()
        p.placed_end = self.placed_end.copy()
        p.machine = self.machine.copy()
        p.is_placed = self.is_placed.copy()
        return p

    def _save(self):
        return (self.placed_start.copy(), self.placed_end.copy(),
                self.machine.copy(), self.is_placed.copy())

    def _load(self, saved) -> None:
        self.placed_start, self.placed_end, self.machine, self.is_placed = (
            saved[0].copy(), saved[1].copy(), saved[2].copy(), saved[3].copy())

    def _commit(self, t: int, m: int, t0: int) -> None:
        self.space.commit(t, m, t0, self.k[t], self.dag.demand[t])
        self.placed_start[t] = t0
        self.placed_end[t] = t0 + self.k[t]
        self.machine[t] = m
        self.is_placed[t] = True

    def _anchor(self, t: int, forward: bool) -> int:
        """Ready tick (forward) / deadline tick (backward) for one task.

        Unplaced parents *within the subset* gate readiness; parents outside
        the subset constrain the start only if already placed (see §4.3
        discussion of inter-subset dependencies).  Mirrored for backward.
        """
        dag, sp = self.dag, self.space
        if forward:
            par = dag.parents[t]
            pl = par[self.is_placed[par]] if len(par) else par
            if len(pl):
                return int(self.placed_end[pl].max())
            return sp._min_start if sp._min_start is not None else 0
        ch = dag.children[t]
        pl = ch[self.is_placed[ch]] if len(ch) else ch
        if len(pl):
            return int(self.placed_start[pl].min())
        if sp._max_end is not None:
            # unanchored task: pack against the occupied region instead of
            # drifting to the far end of the grid.
            return int(sp._max_end)
        return sp.grid_end  # logical end of the empty grid

    def place_pass(self, ids: np.ndarray, direction: str,
                   limit: int | None = None) -> bool:
        """PlaceTasksF / PlaceTasksB: dependency order within the subset,
        longest task first, each task at its extreme feasible slot.

        ``limit`` prunes exactly: the occupied span only ever grows, so
        once it reaches the incumbent best the variant can never win and
        the pass aborts (the caller rolls the space back either way).  The
        derived per-placement ``cap`` lets a session stop searching early
        once every admissible slot is provably past the bound (see
        PlacementSession.place).
        """
        dag, sp = self.dag, self.space
        forward = direction == FORWARD
        in_subset = np.zeros(dag.n, dtype=bool)
        in_subset[ids] = True
        adj_gate = dag.parents if forward else dag.children
        adj_open = dag.children if forward else dag.parents
        pending = np.array([int(in_subset[adj_gate[i]].sum()) for i in range(dag.n)])
        tie = self.n_desc if forward else self.n_par
        dur = dag.duration
        # min-heap pops in the same (-duration, -tie, id) order the sorted
        # ready list did
        heap = [(-dur[i], -tie[i], int(i)) for i in ids if pending[i] == 0]
        heapq.heapify(heap)
        remaining = len(ids)
        sess = self.backend.session(sp, direction)
        demand = self.demand32 if sess.wants_f32 else dag.demand
        peers_fn = None
        est: dict[int, int] = {}
        if sess.wants_peers:
            # estimated anchors for prefetch (exact for anchored tasks; the
            # session re-clips against the real pop-time anchor regardless)
            est = {e[2]: self._anchor(e[2], forward) for e in heap}

            def peers_fn():
                return [PeerTask(e[2], est[e[2]], demand[e[2]], int(self.k[e[2]]))
                        for e in heap[:24]]
        while remaining:
            if not heap:
                return False  # cycle — cannot happen on a valid DAG
            t = heapq.heappop(heap)[2]
            anchor = self._anchor(t, forward)
            key = (int(dag.stage_of[t]), float(anchor), dag.demand[t].tobytes())
            k = int(self.k[t])
            cap = None
            if limit is not None:
                # the exact start bound past which the new span >= limit
                if forward and sp._min_start is not None:
                    cap = limit + sp._min_start - k
                elif not forward and sp._max_end is not None:
                    cap = sp._max_end - limit
            m, t0 = sess.place(t, demand[t], k, anchor, key, peers_fn, cap)
            if m < 0:
                return False  # session proved the variant cannot win
            self._commit(t, m, t0)
            if limit is not None and sp.makespan_ticks >= limit:
                return False  # span is monotone: this variant cannot win
            remaining -= 1
            for c in adj_open[t]:
                if in_subset[c]:
                    pending[c] -= 1
                    if pending[c] == 0:
                        c = int(c)
                        if sess.wants_peers:
                            est[c] = self._anchor(c, forward)
                        heapq.heappush(heap, (-dur[c], -tie[c], c))
        return True

    # kept as thin aliases for readability at call sites / tests
    def place_forward(self, ids: np.ndarray, limit: int | None = None) -> bool:
        return self.place_pass(ids, FORWARD, limit)

    def place_backward(self, ids: np.ndarray, limit: int | None = None) -> bool:
        return self.place_pass(ids, BACKWARD, limit)

    def place_best(self, ids: np.ndarray, limit: int | None = None) -> bool:
        """PlaceTasks: min(forward, backward) by resulting span (Fig. 7 l.13).

        Tries both directions against the shared space (rolling back in
        between) and replays the winner's commits — no grid clone.  An
        aborted direction's true span provably exceeds ``limit``, so a
        completed direction always beats it and pruning stays exact.
        """
        if len(ids) == 0:
            return True
        sp = self.space
        snap = sp.snapshot()
        saved = self._save()
        okf = self.place_forward(ids, limit)
        span_f = sp.makespan_ticks
        plan_f = [(p.task, p.machine, p.start)
                  for p in sp.placements[snap.n_placed:]] if okf else []
        # keep any growth: the forward plan may be replayed into it below
        sp.restore(snap, keep_extent=True)
        self._load(saved)
        okb = self.place_backward(ids, limit)
        if okf and (not okb or span_f <= sp.makespan_ticks):
            sp.restore(snap, keep_extent=True)
            self._load(saved)
            for t, m, t0 in plan_f:  # replay is commit-only: no searches
                self._commit(t, m, t0)
            return True
        return okb


# ----------------------------------------------------------------------
# Troublesome-task search (Fig. 6)
# ----------------------------------------------------------------------

def frag_scores(dag: DAG, m: int) -> np.ndarray:
    """FragScore per stage = TWork(s) / ExecutionTime(s) under greedy packing.

    Tasks of one stage are identical-ish and independent, so greedy packing
    runs them in waves: per machine, c = how many copies fit side by side.
    """
    out = np.ones(dag.n_stages, dtype=np.float64)
    for s, ids in enumerate(dag.stages):
        if len(ids) == 0:
            continue
        dur = float(dag.duration[ids].mean())
        dem = dag.demand[ids].mean(axis=0)
        peak = float(dem.max())
        if peak <= 0 or dur <= 0:
            continue
        per_machine = max(int(1.0 / max(peak, 1e-9) + 1e-9), 1)
        waves = math.ceil(len(ids) / (m * per_machine))
        exec_time = waves * dur
        twork = len(ids) * dur * peak / m
        out[s] = min(twork / exec_time, 1.0)
    return out


def candidate_troublesome(
    dag: DAG,
    m: int,
    n_long: int = 8,
    n_frag: int = 6,
    max_candidates: int = 24,
) -> list[np.ndarray]:
    """Enumerate closed candidate sets T (deduplicated, |candidates| capped)."""
    long_score = dag.duration / max(float(dag.duration.max()), 1e-12)
    frag = frag_scores(dag, m)[dag.stage_of]

    def _levels(vals: np.ndarray, k: int) -> np.ndarray:
        u = np.unique(vals)
        if len(u) <= k:
            return u
        qs = np.quantile(u, np.linspace(0, 1, k))
        return np.unique(qs)

    ls = _levels(long_score, n_long)
    fs = _levels(frag, n_frag)
    seen: set[bytes] = set()
    cands: list[np.ndarray] = []
    # T = empty => plain greedy packing of the whole DAG; always considered
    # so DAGPS can never lose to its own packer.
    empty = np.zeros(dag.n, dtype=bool)
    seen.add(empty.tobytes())
    cands.append(empty)
    pairs = [(l, f) for l in ls[::-1] for f in fs]
    # also pure-long and pure-frag sweeps
    pairs += [(l, -1.0) for l in ls[::-1]] + [(2.0, f) for f in fs]
    for l, f in pairs:
        t_mask = (long_score >= l) | (frag <= f)
        if not t_mask.any():
            continue
        t_mask = dag.closure_mask(t_mask)
        key = t_mask.tobytes()
        if key in seen:
            continue
        seen.add(key)
        cands.append(t_mask)
    if len(cands) > max_candidates:
        # keep a spread of candidate sizes (plus the empty set)
        sizes = np.array([c.sum() for c in cands])
        order = np.argsort(sizes, kind="stable")
        picks = order[np.unique(np.linspace(0, len(order) - 1, max_candidates).astype(int))]
        cands = [cands[i] for i in sorted(picks)]
    return cands


# ----------------------------------------------------------------------
# BuildSchedule (Fig. 5)
# ----------------------------------------------------------------------

def build_schedule(
    dag: DAG,
    m: int,
    ticks: int = 256,
    n_long: int = 8,
    n_frag: int = 6,
    max_candidates: int = 24,
    use_partitions: bool = True,
    backend: str | PlacementBackend | None = None,
) -> Schedule:
    """Construct DAGPS's preferred schedule for one DAG on m machines.

    `backend` selects the placement engine ("reference" | "batched" |
    "jit"); None resolves REPRO_PLACEMENT_BACKEND, defaulting to "batched".
    All backends produce tick-identical schedules.
    """
    if dag.n == 0:
        return Schedule(dag, np.empty(0, np.int64), np.empty(0), np.empty(0, np.int64), 0.0, 1.0)
    be = get_backend(backend)
    if use_partitions:
        parts = partition_totally_ordered(dag)
        if len(parts) > 1:
            return _concat_partition_schedules(dag, parts, m, ticks, n_long,
                                               n_frag, max_candidates, be)
    return _build_one(dag, m, ticks, n_long, n_frag, max_candidates, be)


def _build_one(dag, m, ticks, n_long, n_frag, max_candidates, backend) -> Schedule:
    from .bounds import cp_length, t_work  # local import, no cycle at module load

    horizon = max(cp_length(dag), t_work(dag, m))
    tick = max(horizon / ticks, 1e-9)
    dur_ticks = np.maximum(np.ceil(dag.duration / tick - 1e-9).astype(np.int64), 1)
    grid = int(dur_ticks.sum() / max(m, 1) + dur_ticks.max()) + 4
    grid = max(grid, int(1.25 * horizon / tick) + 4)

    # one shared space for every candidate variant: each (T-set, order,
    # direction) evaluation runs against a snapshot and is rolled back,
    # so variant cost is O(cells written), never O(grid) cloning.
    space = Space(m, dag.d, grid, tick)
    best_span: int | None = None
    best_state: tuple[np.ndarray, np.ndarray] | None = None
    best_mask: np.ndarray | None = None
    for t_mask in candidate_troublesome(dag, m, n_long, n_frag, max_candidates):
        t_mask, o_mask, p_mask, c_mask = dag.split_subsets(t_mask)
        t_ids, o_ids = np.nonzero(t_mask)[0], np.nonzero(o_mask)[0]
        p_ids, c_ids = np.nonzero(p_mask)[0], np.nonzero(c_mask)[0]

        snap_cand = space.snapshot()
        base = _Placer(dag, space, dur_ticks, backend)
        if base.place_best(t_ids, best_span):  # trouble goes first (Fig. 5 l.7)
            best_span, best_state, best_mask = _try_orders(
                space, base, o_ids, p_ids, c_ids, t_mask,
                best_span, best_state, best_mask)
        space.restore(snap_cand)
    assert best_state is not None
    return _to_schedule(dag, best_state[0], best_state[1], tick, best_mask,
                        label="dagps")


def _try_orders(space, base, o_ids, p_ids, c_ids, t_mask,
                best_span, best_state, best_mask):
    """TrySubsetOrders (Fig. 7 l.19-23) around a placed T.

    Exact-outcome reductions on the original four orders:
      * T-OPC and T-OCP share the identical place_best(O) prefix (same
        pre-state => same placements), computed once; when P or C is empty
        their tails coincide and only one runs.
      * With P and C both empty every order degenerates to placing O, and
        place_best(O) already covers both directions — COP/POC are skipped.
      * Every pass prunes against the incumbent best span (see place_pass).
    """
    def consider(pl, ok):
        nonlocal best_span, best_state, best_mask
        if ok:
            span = space.makespan_ticks
            if best_span is None or span < best_span:
                best_span = span
                best_state = (pl.placed_start.copy(), pl.machine.copy())
                best_mask = t_mask
    snap_t = space.snapshot()
    pl_o = base.branch()
    if pl_o.place_best(o_ids, best_span):        # shared T-O... prefix
        tails = (_tail_pc,) if (len(p_ids) == 0 or len(c_ids) == 0) \
            else (_tail_pc, _tail_cp)
        for tail in tails:
            snap_o = space.snapshot()
            pl = pl_o.branch()
            consider(pl, tail(pl, p_ids, c_ids, best_span))
            space.restore(snap_o)
    space.restore(snap_t)
    if len(p_ids) == 0 and len(c_ids) == 0:
        return best_span, best_state, best_mask
    for order_fn in (_order_cop, _order_poc):
        snap_order = space.snapshot()
        pl = base.branch()
        consider(pl, order_fn(pl, o_ids, p_ids, c_ids, best_span))
        space.restore(snap_order)
    return best_span, best_state, best_mask


def _tail_pc(pl: _Placer, p, c, lim) -> bool:        # T OPC (Fig. 7 l.20)
    return pl.place_backward(p, lim) and pl.place_forward(c, lim)


def _tail_cp(pl: _Placer, p, c, lim) -> bool:        # T OCP (l.21)
    return pl.place_forward(c, lim) and pl.place_backward(p, lim)


def _order_cop(pl: _Placer, o, p, c, lim) -> bool:   # T COP (l.22)
    return (pl.place_forward(c, lim) and pl.place_backward(o, lim)
            and pl.place_backward(p, lim))


def _order_poc(pl: _Placer, o, p, c, lim) -> bool:   # T POC (l.23)
    return (pl.place_backward(p, lim) and pl.place_forward(o, lim)
            and pl.place_forward(c, lim))


def _to_schedule(dag: DAG, placed_start: np.ndarray, machine: np.ndarray,
                 tick: float, t_mask, label: str) -> Schedule:
    start_ticks = placed_start.astype(np.float64)
    start_ticks -= start_ticks.min()
    start = start_ticks * tick
    order = np.lexsort((np.arange(dag.n), start))
    makespan = float((start + dag.duration).max() - start.min())
    return Schedule(
        dag=dag, order=order, start=start, machine=machine,
        makespan=makespan, tick=tick, trouble_mask=t_mask, label=label,
    )


# ----------------------------------------------------------------------
# §4.4 enhancement: split at barriers into totally ordered parts
# ----------------------------------------------------------------------

def partition_totally_ordered(dag: DAG) -> list[np.ndarray]:
    """Split V into V1..Vk where every task of Vi precedes all of Vi+1.

    A cut after topological prefix [0..i] is valid iff [0..i] ⊆ anc(j) for
    every j > i, i.e. the suffix-AND of ancestor bitsets from i+1 on contains
    the full prefix.  Computed vectorized in O(n * n/8) bytes.
    """
    n = dag.n
    if n <= 1:
        return [np.arange(n)]
    anc = np.unpackbits(dag.anc_bits.view(np.uint8), axis=1, bitorder="little")[:, :n]
    # suffix_and[i] = AND of anc rows i+1..n-1
    suffix = np.minimum.accumulate(anc[::-1], axis=0)[::-1]
    cuts = []
    for i in range(n - 1):
        if suffix[i + 1, : i + 1].all():
            cuts.append(i)
    parts = []
    prev = 0
    for c in cuts:
        parts.append(np.arange(prev, c + 1))
        prev = c + 1
    parts.append(np.arange(prev, n))
    return parts


def _concat_partition_schedules(dag, parts, m, ticks, n_long, n_frag,
                                max_candidates, backend) -> Schedule:
    start = np.zeros(dag.n, dtype=np.float64)
    machine = np.zeros(dag.n, dtype=np.int64)
    offset = 0.0
    tick = None
    tmask = np.zeros(dag.n, dtype=bool)
    for ids in parts:
        sub = _subdag(dag, ids)
        sched = _build_one(sub, m, ticks, n_long, n_frag, max_candidates, backend)
        start[ids] = sched.start + offset
        machine[ids] = sched.machine
        if sched.trouble_mask is not None:
            tmask[ids] = sched.trouble_mask
        offset += sched.makespan
        tick = sched.tick if tick is None else max(tick, sched.tick)
    order = np.lexsort((np.arange(dag.n), start))
    makespan = float((start + dag.duration).max() - start.min())
    return Schedule(dag, order, start, machine, makespan, tick or 1.0,
                    trouble_mask=tmask, label="dagps")


def _subdag(dag: DAG, ids: np.ndarray) -> DAG:
    remap = {int(t): k for k, t in enumerate(ids)}
    idset = set(remap)
    parents = [
        np.asarray(sorted(remap[int(p)] for p in dag.parents[int(t)] if int(p) in idset),
                   dtype=np.int64)
        for t in ids
    ]
    stages = dag.stage_of[ids]
    _, stage_renum = np.unique(stages, return_inverse=True)
    return DAG(
        duration=dag.duration[ids].copy(),
        demand=dag.demand[ids].copy(),
        stage_of=stage_renum,
        parents=parents,
        name=f"{dag.name}[part]",
    )
