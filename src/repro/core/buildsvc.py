"""Concurrent multi-job schedule-construction service (ROADMAP scale lever).

The paper's online component (§5) enforces schedule orders computed per
job at submission.  After PR 1-4 optimized ONE build (batched engine,
construction memo, device kernels), the remaining order of magnitude at
cluster scale sits ACROSS builds: every arrival's construction is
independent — each job owns its own DAG, ``Space`` and
``ConstructionMemo`` — yet the simulator used to run them strictly
serially inside the arrival event (95% of s8 end-to-end wall time).
This module overlaps them.

Three pieces:

  * a **content-digest dedup front** — submissions are keyed by the
    canonical ``core.dag.dag_digest`` plus every ``build_schedule`` knob,
    so equal-content jobs (recurring pipelines, replayed populations)
    share one construction, and completed entries double as a bounded
    result cache;
  * a **worker pool** — ``mode="process"`` (default) fans builds out to
    forked workers; ``mode="thread"`` shares the interpreter;
    ``mode="serial"`` degenerates to an inline loop.  Processes are the
    default because the builder is *Python-bound* at online grid sizes
    (m≈4): the numpy calls release the GIL, but the heap walk, anchors
    and memo bookkeeping around them dominate — measured ~0.66x with two
    threads on a 2-core host vs ~1.5x with two forked workers.  Thread
    mode remains the right choice for jax-heavy builds (XLA launches
    release the GIL for real compute) and is what the concurrency tests
    hammer;
  * a **submit/future API** — ``submit`` returns a ``BuildHandle``
    immediately; ``build_many`` is the gather form.  The cluster
    simulator submits every arrival's DAG at run start and the event
    loop consumes completed orders as jobs arrive.

Determinism: ``build_schedule`` is a pure function of (DAG content, m,
knobs) — the pool changes *when and where* a schedule is computed, never
its bits, so scheduling decisions downstream are bit-identical to a
serial loop (locked by tests/test_builder_parity.py).  Virtual-time
semantics are untouched: the simulator already treats construction as
instantaneous in sim time, so only wall-clock overlap changes.

Process workers ship a slim result tuple (order/start/machine/span —
not the Schedule, whose ``dag`` back-reference would re-pickle the whole
DAG); the parent rebinds it to its own DAG object.
"""

from __future__ import annotations

import multiprocessing
import os
import sys
import threading
from concurrent.futures import (BrokenExecutor, Future, ProcessPoolExecutor,
                                ThreadPoolExecutor)
from typing import Sequence

import numpy as np

from . import faults
from .builder import Schedule, build_schedule
from .dag import DAG, dag_digest
from .engine import get_backend

#: env defaults: worker count and pool mode (serial | thread | process)
WORKERS_ENV = "REPRO_BUILD_WORKERS"
MODE_ENV = "REPRO_BUILD_MODE"
#: multiprocessing start method for process mode (fork | forkserver | spawn)
MP_ENV = "REPRO_BUILD_MP"

MODES = ("serial", "thread", "process")


def default_workers() -> int:
    """REPRO_BUILD_WORKERS, else the host's CPU count."""
    env = os.environ.get(WORKERS_ENV)
    if env:
        return max(int(env), 1)
    return max(os.cpu_count() or 1, 1)


def _main_survives_reimport() -> bool:
    """Whether forkserver/spawn children can re-prepare ``__main__``.

    Their preparation step re-imports the parent's main module (as
    ``__mp_main__``); a heredoc/stdin parent has ``__file__ == '<stdin>'``
    which no child can open, so such parents must stay on fork.
    """
    main = sys.modules.get("__main__")
    if main is None or getattr(main, "__spec__", None) is not None:
        return True                      # python -m ...: import by name
    f = getattr(main, "__file__", None)
    return f is None or os.path.exists(f)


def _default_mp_context():
    """Start method for process-mode workers (REPRO_BUILD_MP overrides).

    Preference: **forkserver** — workers fork from a clean, exec'd server
    process, so a parent whose jax/XLA runtime threads are already up
    (e.g. benches that ran jit builds first) cannot hand a torn lock to a
    child; the server preloads the builder stack once, so per-worker
    startup stays fork-cheap.  **fork** where forkserver cannot re-import
    the parent's main module; **spawn** as the portable fallback.
    """
    name = os.environ.get(MP_ENV)
    if not name:
        methods = multiprocessing.get_all_start_methods()
        if "forkserver" in methods and _main_survives_reimport():
            name = "forkserver"
        elif "fork" in methods:
            name = "fork"
        else:  # pragma: no cover - non-posix platforms
            name = "spawn"
    if name == "forkserver":
        multiprocessing.set_forkserver_preload(
            ["repro.core.builder", "repro.core.buildsvc"])
    return multiprocessing.get_context(name)


# ----------------------------------------------------------------------
# worker side
# ----------------------------------------------------------------------

def _build_slim(dag: DAG, m: int, kw: dict,
                fault_key: tuple | None = None) -> tuple:
    """One build, returned as the slim array tuple BuildHandle rebinds.

    Module-level so process pools can pickle it; also the single code
    path for every mode (serial/thread pools call it too), keeping the
    three modes trivially output-identical.

    ``fault_key = (digest_hex, attempt)`` is set only on *pool* attempts:
    it arms the ``build_worker`` injection seam (process workers inherit
    a plan through REPRO_FAULTS).  Inline/serial builds never inject —
    they are the trusted final resort of the retry policy.
    """
    if fault_key is not None:
        faults.maybe_fail("build_worker", digest=fault_key[0],
                          attempt=fault_key[1])
    s = build_schedule(dag, m, **kw)
    return (s.order, s.start, s.machine, float(s.makespan), float(s.tick),
            s.trouble_mask, s.label, s.build_info)


class BuildHandle:
    """Future-like view of one submitted build.

    Deduplicated submissions share the underlying future but keep their
    own DAG object, so ``result()`` hands every caller a ``Schedule``
    bound to the DAG instance it submitted.
    """

    __slots__ = ("_future", "_dag", "key")

    def __init__(self, future: Future, dag: DAG, key: tuple):
        self._future = future
        self._dag = dag
        self.key = key

    def done(self) -> bool:
        return self._future.done()

    def result(self, timeout: float | None = None) -> Schedule:
        order, start, machine, makespan, tick, tmask, label, info = \
            self._future.result(timeout)
        return Schedule(dag=self._dag, order=order, start=start,
                        machine=machine, makespan=makespan, tick=tick,
                        trouble_mask=tmask, label=label, build_info=info)


# knobs of build_schedule that participate in the dedup key, with the
# defaults mirrored from its signature
_KNOB_DEFAULTS = {
    "ticks": 256,
    "n_long": 8,
    "n_frag": 6,
    "max_candidates": 24,
    "use_partitions": True,
}


def _complete(out: Future, result=None, exc=None) -> None:
    """Complete a supervised future, tolerating a lost shutdown race
    (already cancelled / already completed)."""
    try:
        if exc is not None:
            out.set_exception(exc)
        elif not out.done():
            out.set_result(result)
    except Exception:
        pass


class BuildService:
    """A worker pool + digest-dedup front over ``build_schedule``.

    ``workers=None`` resolves REPRO_BUILD_WORKERS, else the CPU count;
    ``mode=None`` resolves REPRO_BUILD_MODE, else "process" when more
    than one worker is requested and "serial" otherwise.  Usable as a
    context manager; ``shutdown`` is idempotent.
    """

    def __init__(self, workers: int | None = None, mode: str | None = None,
                 cache_cap: int = 1024,
                 recovery: faults.RecoveryPolicy | None = None):
        self.workers = workers if workers is not None else default_workers()
        if self.workers < 1:
            raise ValueError("workers must be >= 1")
        mode = mode or os.environ.get(MODE_ENV) \
            or ("process" if self.workers > 1 else "serial")
        if mode not in MODES:
            raise ValueError(f"unknown build-service mode {mode!r}; "
                             f"have {MODES}")
        self.mode = mode
        self.recovery = recovery or faults.RecoveryPolicy()
        self._cache_cap = max(cache_cap, 1)
        self._lock = threading.Lock()
        self._futures: dict[tuple, Future] = {}   # dedup front + result cache
        self._pool = None
        self._closed = False
        #: digest_hex -> worker-crash count; quarantined digests build inline
        self._crashes: dict[str, int] = {}
        self._poison: set[str] = set()
        #: pending retry timers -> their re-dispatch args (drained on shutdown)
        self._timers: dict[threading.Timer, tuple] = {}
        #: (old submission key, delta digest) -> content key of the
        #: resulting delta build: recurring-pipeline edits dedup without
        #: re-hashing the mutated DAG
        self._rekeys: dict[tuple, tuple] = {}
        self.stats = {"submitted": 0, "built": 0, "deduped": 0,
                      "resubmits": 0, "resubmit_deduped": 0,
                      "retries": 0, "worker_crashes": 0,
                      "quarantined_digests": 0, "inline_fallbacks": 0,
                      "recovery_secs": 0.0}

    # ------------------------------------------------------------------
    def _ensure_pool(self):
        with self._lock:
            if self._pool is None and self.mode != "serial":
                if self.mode == "thread":
                    self._pool = ThreadPoolExecutor(
                        max_workers=self.workers,
                        thread_name_prefix="buildsvc")
                else:
                    self._pool = ProcessPoolExecutor(
                        max_workers=self.workers,
                        mp_context=_default_mp_context())
            return self._pool

    def key_for(self, dag: DAG, m: int, backend=None,
                memoize: bool | None = None, **knobs) -> tuple:
        """The dedup/cache key of one submission (digest + every knob)."""
        from .builder import _memo_enabled

        items = dict(_KNOB_DEFAULTS)
        unknown = set(knobs) - set(items)
        if unknown:
            raise TypeError(f"unknown build_schedule knobs: {sorted(unknown)}")
        items.update(knobs)
        return (dag_digest(dag), int(m), get_backend(backend).name,
                bool(_memo_enabled(memoize)),
                tuple(sorted(items.items())))

    def submit(self, dag: DAG, m: int, backend=None,
               memoize: bool | None = None, **knobs) -> BuildHandle:
        """Queue one construction; returns immediately.

        Accepts the ``build_schedule`` keyword knobs (ticks, n_long,
        n_frag, max_candidates, use_partitions) plus backend/memoize.
        Equal-content submissions (same digest, same knobs) share one
        build — including ones already completed (bounded LRU cache).
        """
        key = self.key_for(dag, m, backend=backend, memoize=memoize, **knobs)
        kw = dict(knobs)
        if backend is not None:
            # resolve to the *name*: backend instances are shareable in
            # threads but must not cross a process boundary
            kw["backend"] = get_backend(backend).name
        if memoize is not None:
            kw["memoize"] = memoize
        return self._submit_keyed(key, dag, m, kw)

    def resubmit(self, handle: BuildHandle, dag: DAG,
                 delta=None) -> BuildHandle:
        """Delta resubmission: build the mutated ``dag`` with the same
        machine count and knobs as ``handle``'s submission, replaying
        every partition the edit left untouched (``build_schedule``'s
        ``reuse``; bit-identical to a fresh submit of ``dag``).

        ``delta`` is the `core.dag.DagDelta` of the edit; when given,
        (old submission key, delta digest) keys a dedup front of its own,
        so a recurring pipeline resubmitting the same edit repeatedly
        neither re-hashes the DAG nor rebuilds.  The previous build's
        parts map is only consulted if the old future already completed —
        otherwise the resubmission degrades to a full (still exact) build.
        """
        old = handle.key
        _, m, backend, memoize, knob_items = old
        knobs = dict(knob_items)
        rekey = (old, delta.digest) if delta is not None else None
        with self._lock:
            self.stats["resubmits"] += 1
            if rekey is not None:
                key = self._rekeys.get(rekey)
                fut = self._futures.get(key) if key is not None else None
                if fut is not None and not fut.cancelled() and not (
                        fut.done() and fut.exception() is not None):
                    self.stats["resubmit_deduped"] += 1
                    self.stats["submitted"] += 1
                    self.stats["deduped"] += 1
                    self._futures[key] = self._futures.pop(key)  # MRU
                    return BuildHandle(fut, dag, key)
        kw = dict(knobs)
        kw["backend"] = backend
        kw["memoize"] = memoize
        prev = handle._future
        if prev.done() and not prev.cancelled() and prev.exception() is None:
            info = prev.result()[7]
            if info is not None:
                kw["reuse"] = info.parts
        key = self.key_for(dag, m, backend=backend, memoize=memoize, **knobs)
        if rekey is not None:
            with self._lock:
                if len(self._rekeys) >= self._cache_cap:
                    self._rekeys.pop(next(iter(self._rekeys)))
                self._rekeys[rekey] = key
        return self._submit_keyed(key, dag, m, kw)

    def _submit_keyed(self, key: tuple, dag: DAG, m: int,
                      kw: dict) -> BuildHandle:
        with self._lock:
            if self._closed:
                raise RuntimeError("BuildService is shut down")
            self.stats["submitted"] += 1
            fut = self._futures.pop(key, None)
            if fut is not None and not fut.cancelled() and not (
                    fut.done() and fut.exception() is not None):
                # dedup hit — a *failed* entry is dropped instead, so a
                # deterministic build error never poisons its key: the
                # next submit retries the build
                self.stats["deduped"] += 1
                self._futures[key] = fut     # re-append = most recently used
                return BuildHandle(fut, dag, key)
            self.stats["built"] += 1
            # supervised future: pool attempts complete it indirectly, so
            # every dedup sharer survives worker crashes and retries — the
            # caller-visible future only ever fails on a deterministic
            # build error (reproduced by the inline fallback)
            fut = Future()
            if len(self._futures) >= self._cache_cap:
                self._futures.pop(next(iter(self._futures)))
            self._futures[key] = fut
        self._dispatch(key, fut, dag, m, kw, attempt=0)
        return BuildHandle(fut, dag, key)

    # -- supervised dispatch (retry / quarantine / inline fallback) ----

    def _dispatch(self, key: tuple, out: Future, dag: DAG, m: int,
                  kw: dict, attempt: int) -> None:
        """Route one build attempt: pool while the retry budget and the
        digest's crash record allow, guaranteed inline otherwise."""
        digest = key[0].hex()
        with self._lock:
            inline = (self.mode == "serial" or self._closed
                      or digest in self._poison
                      or attempt > self.recovery.build_retries)
            fallback = inline and self.mode != "serial"
        if inline:
            self._finish_inline(out, dag, m, kw, fallback=fallback)
            return
        try:
            pool = self._ensure_pool()
            wfut = pool.submit(_build_slim, dag, m, kw, (digest, attempt))
        except BrokenExecutor:
            self._note_worker_crash(digest, None)
            self._retry_later(key, out, dag, m, kw, attempt + 1)
            return
        except RuntimeError:
            # pool shut down under us — the fallback still owes a result
            self._finish_inline(out, dag, m, kw, fallback=True)
            return

        def _done(f: Future) -> None:
            if f.cancelled():               # pool torn down mid-attempt
                self._finish_inline(out, dag, m, kw, fallback=True)
                return
            exc = f.exception()
            if exc is None:
                _complete(out, result=f.result())
                return
            if isinstance(exc, BrokenExecutor):
                # worker died (os._exit, OOM kill): every in-flight
                # attempt on the pool fails with it; dispose the pool
                # once and let each attempt retry with backoff
                self._note_worker_crash(digest, f)
            with self._lock:
                self.stats["retries"] += 1
            self._retry_later(key, out, dag, m, kw, attempt + 1)

        wfut.add_done_callback(_done)

    def _note_worker_crash(self, digest: str, wfut: Future | None) -> None:
        """Record one crash against a digest; quarantine crash-loopers.

        Attribution is conservative: a broken pool fails every in-flight
        digest, so innocents sharing the pool with a poison DAG may also
        accumulate counts — they just fall back inline (still exact).
        """
        with self._lock:
            self.stats["worker_crashes"] += 1
            n = self._crashes[digest] = self._crashes.get(digest, 0) + 1
            if (n >= max(self.recovery.quarantine_after, 1)
                    and digest not in self._poison):
                self._poison.add(digest)
                self.stats["quarantined_digests"] += 1
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=False)

    def _retry_later(self, key: tuple, out: Future, dag: DAG, m: int,
                     kw: dict, attempt: int) -> None:
        rec = self.recovery
        delay = min(rec.backoff * (2.0 ** (attempt - 1)), rec.backoff_cap)
        with self._lock:
            closed = self._closed
            if not closed and delay > 0:
                self.stats["recovery_secs"] += delay
        if closed or delay <= 0:
            self._dispatch(key, out, dag, m, kw, attempt)
            return
        timer = threading.Timer(delay, self._fire_retry)
        timer.args = (timer,)
        timer.daemon = True
        with self._lock:
            if self._closed:                # raced with shutdown: no timer
                self._finish_inline(out, dag, m, kw, fallback=True)
                return
            self._timers[timer] = (key, out, dag, m, kw, attempt)
        timer.start()

    def _fire_retry(self, timer: threading.Timer) -> None:
        with self._lock:
            args = self._timers.pop(timer, None)
        if args is not None:
            self._dispatch(*args)

    def _finish_inline(self, out: Future, dag: DAG, m: int, kw: dict,
                       fallback: bool = False) -> None:
        """The guaranteed last resort: build on the calling thread.

        Never injected (no fault_key), so every submission eventually
        resolves — with the schedule, or with the build's own
        deterministic error.
        """
        if fallback:
            with self._lock:
                self.stats["inline_fallbacks"] += 1
        try:
            res = _build_slim(dag, m, kw)
        except Exception as exc:
            _complete(out, exc=exc)
        except BaseException as exc:  # KeyboardInterrupt/SystemExit:
            _complete(out, exc=exc)   # unblock any dedup sharer ...
            raise                     # ... but never swallow the cancel
        else:
            _complete(out, result=res)

    def build(self, dag: DAG, m: int, **kw) -> Schedule:
        return self.submit(dag, m, **kw).result()

    def build_many(self, dags: Sequence[DAG], m: int, **kw) -> list[Schedule]:
        """All DAGs through the pool; results in input order.

        Bit-identical to ``[build_schedule(d, m, **kw) for d in dags]``
        (the parity suite diffs them), just overlapped and deduplicated.
        """
        handles = [self.submit(d, m, **kw) for d in dags]
        return [h.result() for h in handles]

    # ------------------------------------------------------------------
    def clear_cache(self) -> None:
        with self._lock:
            self._futures.clear()

    def shutdown(self, wait: bool = True) -> None:
        with self._lock:
            self._closed = True
            pool, self._pool = self._pool, None
            timers = list(self._timers.items())
            self._timers.clear()
        # drain pending retries so no supervised future is left dangling:
        # finish inline when waiting, cancel outright otherwise
        for timer, (key, out, dag, m, kw, attempt) in timers:
            timer.cancel()
            if out.done():
                continue
            if wait:
                self._finish_inline(out, dag, m, kw, fallback=True)
            elif not out.cancel():
                _complete(out, exc=RuntimeError(
                    "BuildService shut down before retry"))
        if pool is not None:
            pool.shutdown(wait=wait, cancel_futures=not wait)

    def __enter__(self) -> "BuildService":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown(wait=exc[0] is None)


def build_many(dags: Sequence[DAG], m: int, workers: int | None = None,
               mode: str | None = None, **kw) -> list[Schedule]:
    """One-shot convenience: a scoped service around ``build_many``."""
    with BuildService(workers=workers, mode=mode) as svc:
        return svc.build_many(dags, m, **kw)
