"""Sharded online matcher: heartbeat matching at 10k+ machines.

The single flat matcher loop (`sim/cluster.py` heartbeats over one global
candidate pool) tops out around a thousand machines: every wave pays one
Python pass over all machines and one eligibility evaluation whose cost
grows with m.  This module partitions the machine axis across N scheduler
shards while keeping the paper's §5 guarantees:

  * **Eligibility fan-out** — a wave's machine-eligibility test runs as
    one batched kernel launch *per shard* (`core/engine/kernels.py`
    heartbeat ops), fanned out over a thread pool.  The launches release
    the GIL (BLAS/XLA), so shards overlap on multicore hosts, and each
    shard's launch auto-selects the accelerated sound-superset impl by
    its own slice size (`kernels.resolve_heartbeat`).  Eligibility
    columns are per-machine independent, so the block-concatenated
    result is exactly what one global launch would produce — the sharded
    wave stays **bit-identical** to the single-shard path for any shard
    count (tests/test_online_parity.py).

  * **Exposure routing** — `route_exposure` splits a `CandidateBatch`
    into disjoint per-shard slices: each job's exposed candidates are
    divided across shards proportionally to shard capacity (largest
    remainder, deterministic), so a job spanning shards offers every
    shard a proportional slice of its work.  `match_wave_routed` is the
    fully distributed mode built on it: each shard's own `Matcher`
    serves only its machine slice from its routed candidates.  That mode
    trades decision identity for locality (documented, opt-in); the
    simulator default is the decision-exact `match_wave`.

  * **Deficit handoff** — bounded unfairness composes across shards
    because deficit counters are additive: for *any* routing of
    allocations to shards, the per-group sum of shard deficits equals
    the deficit a single global counter would hold (``allocated`` adds
    ``share_g * w`` to every group and subtracts ``w`` from the served
    group — both terms route with the allocation).  `deficit_handoff`
    merges the per-group deficits and rebalances them proportionally to
    shard capacity (``d_sg = merged_g * C_s / C``), which makes each
    shard's local ``must_serve`` trigger (threshold ``kappa * C_s``)
    fire exactly when the global trigger (``kappa * C``) would at
    handoff points, and nets out opposite-sign shard deficits that
    would otherwise fire spurious must-serves.  Property-tested against
    the single-shard oracle in tests/test_shard.py.
"""

from __future__ import annotations

import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Sequence

import numpy as np

from . import faults
from .engine import kernels
from .engine import wave as wave_mod
from .online import CandidateBatch, Matcher, MatcherConfig

#: env var overriding the target machines-per-shard used by `auto_shards`
SHARD_MACHINES_ENV = "REPRO_SHARD_MACHINES"
_DEFAULT_SHARD_MACHINES = 2048


def shard_machines() -> int:
    """Target machines per shard for automatic shard-count selection."""
    raw = os.environ.get(SHARD_MACHINES_ENV, "")
    if raw:
        return max(int(raw), 1)
    return _DEFAULT_SHARD_MACHINES


def auto_shards(n_machines: int) -> int:
    """Shard count for a cluster size: ceil(m / shard_machines())."""
    per = shard_machines()
    return max((int(n_machines) + per - 1) // per, 1)


class ShardPlan:
    """Contiguous balanced partition of the machine axis into N shards.

    Shard s owns machines [offsets[s], offsets[s + 1]); the first
    ``m % n_shards`` shards are one machine larger.  Contiguity keeps a
    shard's avail rows a view (no gather) and makes the concatenation of
    per-shard eligibility columns line up with global machine ids.
    """

    def __init__(self, n_machines: int, n_shards: int | None = None):
        if n_machines < 1:
            raise ValueError("need at least one machine")
        if n_shards is None:
            n_shards = auto_shards(n_machines)
        n_shards = max(min(int(n_shards), n_machines), 1)
        base, extra = divmod(n_machines, n_shards)
        sizes = np.full(n_shards, base, dtype=np.int64)
        sizes[:extra] += 1
        self.n_machines = int(n_machines)
        self.n_shards = int(n_shards)
        self.sizes = sizes
        self.offsets = np.concatenate(([0], np.cumsum(sizes)))
        self.fracs = sizes / float(n_machines)

    def slices(self) -> list[slice]:
        return [slice(int(self.offsets[s]), int(self.offsets[s + 1]))
                for s in range(self.n_shards)]

    def shard_of(self, machine: int) -> int:
        """Owning shard of a global machine id."""
        return int(np.searchsorted(self.offsets, machine, side="right") - 1)


def route_exposure(batch: CandidateBatch, plan: ShardPlan) -> list[np.ndarray]:
    """Disjoint per-shard row indices: proportional slices per job.

    Walks contiguous runs of equal job id (the order `TaskPool.refresh`
    emits) and splits each run across shards proportionally to shard
    capacity via largest remainder (ties broken toward lower shard
    index), preserving within-job candidate order inside each slice.
    The result is a partition of ``range(len(batch))``: every candidate
    lands on exactly one shard, and a job spanning shards offers each a
    slice sized to that shard's capacity share.
    """
    n = len(batch)
    if plan.n_shards == 1:
        return [np.arange(n, dtype=np.int64)]
    rows: list[list[np.ndarray]] = [[] for _ in range(plan.n_shards)]
    job = batch.job
    fracs = plan.fracs
    start = 0
    while start < n:
        end = start + 1
        while end < n and job[end] == job[start]:
            end += 1
        r = end - start
        exact = fracs * r
        quota = np.floor(exact).astype(np.int64)
        short = r - int(quota.sum())
        if short:
            order = np.argsort(-(exact - quota), kind="stable")
            quota[order[:short]] += 1
        pos = start
        for s in range(plan.n_shards):
            q = int(quota[s])
            if q:
                rows[s].append(np.arange(pos, pos + q, dtype=np.int64))
                pos += q
        start = end
    return [np.concatenate(r) if r else np.empty(0, dtype=np.int64)
            for r in rows]


class ShardedMatcher:
    """Per-shard matchers + deficit ledgers behind one wave interface.

    ``match_wave`` is the simulator's heartbeat path: eligibility fans
    out one batched kernel launch per shard (thread pool), decisions run
    through a single global `Matcher` so picks, EMA observations and
    deficit updates stay bit-identical to the unsharded loop, and every
    pick is mirrored into the owning shard's ledger; the wave ends with
    a `deficit_handoff`.  ``match_wave_routed`` is the fully distributed
    variant (per-shard matchers over routed candidate slices).
    """

    def __init__(self, cfg: MatcherConfig, n_machines: int,
                 shares: dict[int, float], n_shards: int | None = None,
                 capacity: float | None = None,
                 recovery: faults.RecoveryPolicy | None = None):
        self.plan = ShardPlan(n_machines, n_shards)
        self.cfg = cfg
        self.recovery = recovery or faults.RecoveryPolicy()
        capacity = float(n_machines) if capacity is None else float(capacity)
        self.capacity = capacity
        #: global decision matcher — the single source of pick order
        self.matcher = Matcher(cfg, capacity=capacity, shares=shares)
        #: per-shard matchers: ledgers for the exact path, full matchers
        #: for the distributed path (capacity = this shard's slice of C)
        self.shard_matchers = [
            Matcher(cfg, capacity=capacity * float(f), shares=shares)
            for f in self.plan.fracs
        ]
        self.waves = 0
        self.handoffs = 0
        self.picks = 0
        #: per-shard seconds inside the heartbeat eligibility kernels
        self.kernel_secs = [0.0] * self.plan.n_shards
        self._pool: ThreadPoolExecutor | None = None
        # -- degraded-mode state (core/faults.py): per-shard launch health
        n = self.plan.n_shards
        self.quarantined = [False] * n
        self._consec_fail = [0] * n
        self._probe_wait = [0] * n
        #: wall-clock of the last probe (or quarantine entry) per shard —
        #: the probe_secs trigger, so long waves cannot starve probes
        self._probe_stamp = [0.0] * n
        self._any_quarantined = False
        self.launch_retries = 0      # retried attempts that got another try
        self.launch_failures = 0     # launches that exhausted every attempt
        self.quarantine_events = 0
        self.quarantined_launches = 0  # waves served by the all-eligible mask
        self.probe_recoveries = 0
        #: wall-seconds in failed attempts + backoff sleeps + probes
        #: (phase_recovery in the simulator, not phase_match)
        self.recovery_secs = 0.0

    # -- lifecycle ------------------------------------------------------

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def __enter__(self) -> "ShardedMatcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _executor(self) -> ThreadPoolExecutor:
        if self._pool is None:
            workers = min(self.plan.n_shards,
                          max(os.cpu_count() or 1, 2))
            self._pool = ThreadPoolExecutor(
                max_workers=workers, thread_name_prefix="shard-elig")
        return self._pool

    # -- eligibility fan-out --------------------------------------------

    def _launch(self, s: int, avail_rows: np.ndarray, dem: np.ndarray,
                attempt: int = 0) -> tuple[np.ndarray, np.ndarray]:
        """One shard's batched eligibility launch (timed per shard)."""
        faults.maybe_fail("shard_launch", shard=s, wave=self.waves,
                          attempt=attempt)
        cfg = self.cfg
        fd, rigid, fung = self.matcher.fit_dim_split()
        t0 = time.perf_counter()
        out = kernels.machines_with_candidates(
            avail_rows, dem, fd, rigid, fung, cfg.max_overbook - 1.0,
            cfg.use_overbooking)
        self.kernel_secs[s] += time.perf_counter() - t0
        return out

    @staticmethod
    def _conservative(avail_rows: np.ndarray,
                      dem: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """The all-eligible fallback mask for one shard's machine slice.

        A sound superset of any real eligibility result — the masks only
        ever *skip* machines that provably cannot pick (see the kernels
        exactness contract), so all-True makes the wave visit every
        machine in the slice and decide identically, just slower.
        """
        n = np.atleast_2d(np.asarray(dem)).shape[0]
        m = avail_rows.shape[0]
        eligible = np.ones((n, m), dtype=bool)
        return eligible, eligible.any(axis=0)

    def _timed_attempt(self, s: int, avail_rows: np.ndarray,
                       dem: np.ndarray, attempt: int):
        """One guarded attempt, bounded by the policy's launch timeout.

        Runs on the shard executor so a hung launch (thread stuck in a
        kernel) is abandoned by timeout instead of blocking the wave; an
        abandoned thread eventually finishes or permanently occupies one
        pool slot — either way later attempts/waves keep moving, and
        repeated timeouts land the shard in quarantine.
        """
        timeout = self.recovery.launch_timeout
        if timeout is None:
            return self._launch(s, avail_rows, dem, attempt)
        fut = self._executor().submit(self._launch, s, avail_rows, dem,
                                      attempt)
        return fut.result(timeout=timeout)

    def _guarded_launch(self, s: int, avail_rows: np.ndarray,
                        dem: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Degraded-mode launch: retry w/ capped backoff, quarantine after
        repeated failure, probe-recover on a wave-count OR wall-clock
        cadence (probe_every waves / probe_secs seconds, whichever trips
        first; at least one wave always passes between probes)."""
        rec = self.recovery
        if self.quarantined[s]:
            self._probe_wait[s] += 1
            due = self._probe_wait[s] >= max(rec.probe_every, 1)
            if not due and rec.probe_secs is not None:
                due = (time.monotonic() - self._probe_stamp[s]
                       >= rec.probe_secs)
            if due:
                self._probe_wait[s] = 0
                self._probe_stamp[s] = time.monotonic()
                t0 = time.perf_counter()
                try:
                    out = self._timed_attempt(s, avail_rows, dem, attempt=0)
                except Exception:
                    out = None
                self.recovery_secs += time.perf_counter() - t0
                if out is not None:
                    self.quarantined[s] = False
                    self._consec_fail[s] = 0
                    self.probe_recoveries += 1
                    self._any_quarantined = any(self.quarantined)
                    return out
            self.quarantined_launches += 1
            return self._conservative(avail_rows, dem)
        for attempt in range(rec.launch_retries + 1):
            t0 = time.perf_counter()
            try:
                out = self._timed_attempt(s, avail_rows, dem, attempt)
            except Exception:
                self.recovery_secs += time.perf_counter() - t0
                if attempt < rec.launch_retries:
                    self.launch_retries += 1
                    delay = min(rec.backoff * (2.0 ** attempt),
                                rec.backoff_cap)
                    if delay > 0:
                        time.sleep(delay)
                        self.recovery_secs += delay
            else:
                self._consec_fail[s] = 0
                return out
        self.launch_failures += 1
        self._consec_fail[s] += 1
        if self._consec_fail[s] >= max(rec.quarantine_after, 1):
            self.quarantined[s] = True
            self._probe_wait[s] = 0
            self._probe_stamp[s] = time.monotonic()
            self.quarantine_events += 1
            self._any_quarantined = True
        self.quarantined_launches += 1
        return self._conservative(avail_rows, dem)

    def eligibility(self, avail: np.ndarray,
                    dem: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Sound-superset (eligible (n, m), machine_any (m,)) for a wave.

        One kernel launch per shard, fanned out over the thread pool when
        there is more than one shard.  Columns are per-machine
        independent, so concatenating the per-shard blocks reproduces a
        single global launch exactly.

        With a fault plan active (core/faults.py) or any shard in
        quarantine, launches route through the guarded path — per-attempt
        timeout, capped-backoff retry, quarantine to the conservative
        all-eligible mask — which is decision-exact by the superset
        argument.  Without either, the healthy fast path below runs
        unchanged.
        """
        plan = self.plan
        slices = plan.slices()
        if faults.active_plan() is not None or self._any_quarantined:
            parts = [self._guarded_launch(s, avail[slices[s]], dem)
                     for s in range(plan.n_shards)]
        elif plan.n_shards == 1:
            return self._launch(0, avail, dem)
        else:
            parts = list(self._executor().map(
                lambda s: self._launch(s, avail[slices[s]], dem),
                range(plan.n_shards)))
        if plan.n_shards == 1:
            return parts[0]
        eligible = np.concatenate([p[0] for p in parts], axis=1)
        machine_any = np.concatenate([p[1] for p in parts])
        return eligible, machine_any

    # -- deficit bookkeeping --------------------------------------------

    def record_allocation(self, machine: int, group: int,
                          weight: float) -> None:
        """Mirror one allocation into the owning shard's ledger."""
        s = self.plan.shard_of(machine)
        self.shard_matchers[s].deficits.allocated(group, weight)

    def merged_deficits(self) -> dict[int, float]:
        """Per-group sum of shard deficits (== the global counter)."""
        merged: dict[int, float] = {}
        for sm in self.shard_matchers:
            for g, v in sm.deficits.deficit.items():
                merged[g] = merged.get(g, 0.0) + v
        return merged

    def deficit_handoff(self) -> dict[int, float]:
        """Merge per-group deficits and rebalance by shard capacity.

        After the handoff shard s holds ``merged_g * C_s / C`` for every
        group, so its local ``must_serve`` trigger (``kappa * C_s``) is
        equivalent to the global one (``kappa * C``), and opposite-sign
        deficits accumulated on different shards cancel instead of
        firing spurious must-serves.  Returns the merged deficits.
        """
        merged = self.merged_deficits()
        for sm, frac in zip(self.shard_matchers, self.plan.fracs):
            led = sm.deficits.deficit
            for g in led:
                led[g] = merged.get(g, 0.0) * float(frac)
        self.handoffs += 1
        return merged

    # -- decision-exact wave (simulator path) ---------------------------

    def match_wave(self, avail: np.ndarray, alive: np.ndarray,
                   batch: CandidateBatch,
                   start_cb: Callable[[int, int], None]) -> int:
        """One heartbeat wave, bit-identical to the unsharded loop.

        ``start_cb(row, machine)`` is invoked for every pick (in pick
        order) and must apply the start's side effects — including the
        ``avail[machine] -= demand`` update the next machine's matcher
        call observes.  Returns the number of tasks started.

        The wave body dispatches through the ``match_wave`` kernel op
        (`engine/wave.py`): the numpy impl is the historical host loop;
        at scale the fused xla/pallas kernels run the whole wave in one
        device launch over the resident matcher state, replaying the pick
        stream through ``start_cb`` — bit-identical on every path.
        """
        ctx = wave_mod.WaveContext(sm=self, avail=avail, alive=alive,
                                   batch=batch, start_cb=start_cb)
        n_picks = kernels.match_wave(ctx)
        self.waves += 1
        self.picks += n_picks
        if self.plan.n_shards > 1:
            self.deficit_handoff()
        return n_picks

    # -- distributed wave (routed exposure, per-shard decisions) --------

    def match_wave_routed(self, avail: np.ndarray, alive: np.ndarray,
                          batch: CandidateBatch,
                          start_cb: Callable[[int, int], None]) -> int:
        """Fully distributed wave: shard-local matchers, routed slices.

        Each shard serves only its machine slice from its proportional
        candidate slice (`route_exposure`), using its own `Matcher` (own
        EMA + deficit state).  Eligibility is still one batched launch
        per shard; the Python pick loops run sequentially because
        ``start_cb`` mutates shared simulator state.  Decisions are NOT
        identical to the global wave (candidate visibility differs) —
        bounded unfairness is preserved by the wave-end handoff instead
        (property-tested).  Returns the number of tasks started.
        """
        routed = route_exposure(batch, self.plan)
        n_picks = 0
        for s, sl in enumerate(self.plan.slices()):
            idx = routed[s]
            if len(idx) == 0:
                continue
            sub = batch.take(idx)
            eligible, machine_any = self._launch(s, avail[sl], sub.dem)
            active = np.ones(len(sub), dtype=bool)
            n_active = len(sub)
            lo = int(self.plan.offsets[s])
            local = np.argsort(-avail[sl].sum(axis=1))
            ok = (alive[sl][local] & (avail[sl][local] > 1e-9).any(axis=1)
                  & machine_any[local])
            shard_matcher = self.shard_matchers[s]
            for lm in local[ok].tolist():
                if n_active == 0:
                    break
                if not (eligible[:, lm] & active).any():
                    continue
                picks = shard_matcher.match_batch(
                    lo + lm, avail[lo + lm], sub, active=active)
                for i, _over in picks:
                    start_cb(int(idx[i]), lo + lm)
                    active[i] = False
                n_active -= len(picks)
                n_picks += len(picks)
        self.waves += 1
        self.picks += n_picks
        if self.plan.n_shards > 1:
            self.deficit_handoff()
        return n_picks

    # -- accounting -----------------------------------------------------

    def stats(self) -> dict:
        """Wave/handoff/kernel accounting for bench rows."""
        return {
            "n_shards": self.plan.n_shards,
            "waves": self.waves,
            "picks": self.picks,
            "handoffs": self.handoffs,
            "kernel_secs": [round(s, 6) for s in self.kernel_secs],
            "launch_retries": self.launch_retries,
            "launch_failures": self.launch_failures,
            "quarantines": self.quarantine_events,
            "quarantined_shards": [i for i, q in enumerate(self.quarantined)
                                   if q],
            "quarantined_launches": self.quarantined_launches,
            "probe_recoveries": self.probe_recoveries,
            "recovery_secs": round(self.recovery_secs, 6),
        }
