"""The paper's primary contribution: DAGPS scheduling (offline §4 + online §5 + bounds §6)."""
from .dag import DAG, dag_digest, from_stage_graph
from .faults import FaultPlan, FaultSpec, InjectedFault, RecoveryPolicy
from .space import Space, SpaceSnapshot
from .engine import (BatchedBackend, JitBackend, PlacementBackend,
                     ReferenceBackend, available_backends, get_backend)
from .builder import Schedule, build_schedule, partition_totally_ordered
from .buildsvc import BuildHandle, BuildService, build_many
from .memo import ConstructionMemo, counters_snapshot, reset_counters
from .bounds import all_bounds, cp_length, mod_cp, new_lb, t_work
from .baselines import (
    bfs_order, cp_order, cg_order, random_order, run_baseline,
    simulate_execution, strip_levels,
)
from .online import (
    CandidateBatch, DeficitCounters, JobView, Matcher, MatcherConfig,
    PendingTask, TaskPool, drf_fairness, slot_fairness,
)
from .shard import ShardPlan, ShardedMatcher, auto_shards, route_exposure
