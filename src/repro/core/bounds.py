"""Lower bounds on DAG completion time (paper §6, Eq. 1a-1d).

  CPLen  — critical path length (1a)
  TWork  — total work / capacity, maxed over resources (1b)
  ModCP  — a path bound where exactly one stage on the path is upgraded from
           "one task must run" to "the whole stage must complete"
           (max(TWork_s, CPLen_s)), all other stages contribute their
           minimum task duration (1c)
  NewLB  — split the DAG into totally ordered partitions (§4.4) and sum the
           per-partition max(CPLen, TWork, ModCP) (1d)

All bounds are per-job, normalized to the job's capacity share of
m machines x 1.0 capacity per resource.
"""

from __future__ import annotations

import numpy as np

from .dag import DAG


def cp_length(dag: DAG) -> float:
    """Eq. 1a: longest path by task duration."""
    n = dag.n
    if n == 0:
        return 0.0
    finish = np.zeros(n, dtype=np.float64)
    for i in range(n):  # topological order
        ps = dag.parents[i]
        base = finish[ps].max() if len(ps) else 0.0
        finish[i] = base + dag.duration[i]
    return float(finish.max())


def t_work(dag: DAG, m: int) -> float:
    """Eq. 1b: total work normalized by capacity, maxed over resources."""
    if dag.n == 0:
        return 0.0
    per_res = (dag.duration[:, None] * dag.demand).sum(axis=0)  # (d,)
    return float(per_res.max() / m)


def _stage_quantities(dag: DAG, m: int):
    """Per stage: (min task duration, max task duration=CPLen_s, TWork_s)."""
    mins = np.zeros(dag.n_stages)
    maxs = np.zeros(dag.n_stages)
    works = np.zeros(dag.n_stages)
    for s, ids in enumerate(dag.stages):
        if len(ids) == 0:
            continue
        mins[s] = dag.duration[ids].min()
        maxs[s] = dag.duration[ids].max()
        works[s] = float(
            (dag.duration[ids, None] * dag.demand[ids]).sum(axis=0).max() / m
        )
    return mins, maxs, works


def mod_cp(dag: DAG, m: int) -> float:
    """Eq. 1c over the stage DAG.

    max over stage-paths p, max over s in p of
      max(TWork_s, CPLen_s) + sum_{s' in p - s} min-duration(s').
    Computed by a 2-state longest-path DP (upgrade used / not used).
    """
    if dag.n == 0:
        return 0.0
    mins, maxs, works = _stage_quantities(dag, m)
    upgraded = np.maximum(works, maxs)
    sp = dag.stage_parents()
    n_s = dag.n_stages
    order = _topo_stages(sp, n_s)
    best0 = np.full(n_s, -np.inf)  # path ending at s, no stage upgraded yet
    best1 = np.full(n_s, -np.inf)  # path ending at s, one stage upgraded
    for s in order:
        p0 = max((best0[p] for p in sp[s]), default=0.0)
        p1 = max((best1[p] for p in sp[s]), default=-np.inf)
        best0[s] = p0 + mins[s]
        best1[s] = max(p0 + upgraded[s], (p1 + mins[s]) if p1 > -np.inf else -np.inf)
    return float(max(best1.max(), best0.max()))


def _topo_stages(stage_parents, n_s: int) -> list[int]:
    state = [0] * n_s
    out: list[int] = []

    def visit(s: int):
        if state[s] == 2:
            return
        if state[s] == 1:
            raise ValueError("stage cycle")
        state[s] = 1
        for p in stage_parents[s]:
            visit(p)
        state[s] = 2
        out.append(s)

    for s in range(n_s):
        visit(s)
    return out


def new_lb(dag: DAG, m: int) -> float:
    """Eq. 1d: sum over totally ordered partitions of the best bound."""
    from .builder import partition_totally_ordered, _subdag

    if dag.n == 0:
        return 0.0
    parts = partition_totally_ordered(dag)
    total = 0.0
    for ids in parts:
        sub = _subdag(dag, ids) if len(parts) > 1 else dag
        total += max(cp_length(sub), t_work(sub, m), mod_cp(sub, m))
    return float(total)


def all_bounds(dag: DAG, m: int) -> dict[str, float]:
    return {
        "cplen": cp_length(dag),
        "twork": t_work(dag, m),
        "modcp": mod_cp(dag, m),
        "newlb": new_lb(dag, m),
    }
