"""Checkpoint save/restore with mesh-reshape-aware restore (elasticity).

Flat-key .npz shards + a JSON manifest.  Restore targets any mesh: arrays
are loaded host-side and re-placed under the *current* sharding rules, so
a run checkpointed on 512 chips restarts on 256 (or 1 — CPU debugging)
unchanged: that, plus the deterministic data pipeline, is the
checkpoint/restart story for node failures and elastic resizes.

Leaves are saved in full (gathered) form: simple and correct; for
multi-host deployments swap the np.savez for per-shard writes keyed by
process index (the manifest format already carries the tree structure).
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict[str, Any]) -> Any:
    root: dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if not isinstance(node, dict):
            return node
        keys = list(node)
        if keys and all(k.isdigit() for k in keys):
            return [fix(node[str(i)]) for i in range(len(keys))]
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


def save(path: str, step: int, params: Any, opt_state: Any | None = None,
         extra: dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    tree = {"params": params}
    if opt_state is not None:
        tree["opt"] = opt_state
    flat = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    tmp = os.path.join(path, f"tmp_step_{step:08d}.npz")
    final = os.path.join(path, f"step_{step:08d}.npz")
    np.savez(tmp, **arrays)
    os.replace(tmp, final)
    manifest = {
        "step": step,
        "keys": sorted(arrays),
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "extra": extra or {},
    }
    mtmp = os.path.join(path, "manifest.json.tmp")
    with open(mtmp, "w") as f:
        json.dump(manifest, f)
    os.replace(mtmp, os.path.join(path, "manifest.json"))


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [int(f[5:13]) for f in os.listdir(path)
             if f.startswith("step_") and f.endswith(".npz")]
    return max(steps) if steps else None


def restore(path: str, step: int | None = None, shardings: Any | None = None):
    """Returns (step, tree).  `shardings` (a matching pytree of
    NamedSharding, or None) re-places every leaf for the current mesh."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    with np.load(os.path.join(path, f"step_{step:08d}.npz")) as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten(flat)
    if shardings is not None:
        flat_t, treedef = jax.tree.flatten(tree)
        flat_s, _ = jax.tree.flatten(shardings)
        placed = [jax.device_put(a, s) for a, s in zip(flat_t, flat_s)]
        tree = jax.tree.unflatten(treedef, placed)
    return step, tree
