"""AdamW with fp32 master weights and sharded state (ZeRO-friendly).

State mirrors the param pytree leaf-for-leaf, so the launch layer's
sharding rules apply to optimizer state exactly as to params (m/v/master
are sharded like the weight they track — optimizer-state sharding comes
for free from pjit).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    master_weights: bool = True
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def init_state(cfg: AdamWConfig, params: Any) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    state = {
        "m": zeros,
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.master_weights:
        # explicit copy: astype(f32) on f32 params is a no-op alias, which
        # breaks buffer donation (same buffer donated via params and master)
        state["master"] = jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
    return state


def apply_updates(cfg: AdamWConfig, params: Any, grads: Any, state: dict):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    # global-norm clip in fp32
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    masters = state.get("master", params)

    def upd(p, g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        mf = master.astype(jnp.float32)
        new_master = mf - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * mf)
        return new_master.astype(p.dtype), m, v, new_master

    out = jax.tree.map(upd, params, grads, state["m"], state["v"], masters)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    if cfg.master_weights:
        new_state["master"] = jax.tree.map(lambda t: t[3], out,
                                           is_leaf=lambda t: isinstance(t, tuple))
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
