"""Gradient compression for cross-replica reduction (distributed-opt trick).

`compressed_psum_tree` quantizes each gradient leaf to int8 with a
per-leaf fp32 scale, sums int32 across the named axes inside shard_map,
and dequantizes — 4x less ICI traffic than bf16 all-reduce at <1% relative
error on typical gradients (tested).  Used by the shard_map training path;
the pure-pjit path leaves reduction to XLA (exact).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name) -> jax.Array:
    """int8-quantized psum (call inside shard_map).

    Each replica quantizes with its own scale; scales are maxed across the
    axis first so the int8 grids align, then int32-summed.
    """
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0 + 1e-30
    scale = lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int32)
    total = lax.psum(q, axis_name)
    return total.astype(jnp.float32) * scale


def compressed_psum_tree(grads, axis_name):
    return jax.tree.map(lambda g: compressed_psum(g, axis_name).astype(g.dtype), grads)
