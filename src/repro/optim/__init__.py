from .adamw import AdamWConfig, apply_updates, init_state, schedule
from .compression import compressed_psum, compressed_psum_tree, quantize_int8, dequantize
