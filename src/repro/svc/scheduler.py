"""The central scheduler process.

`SchedulerCore` is a synchronous, deterministic state machine over the
existing scheduling stack — `build_schedule`/`BuildService` for offline
construction, `TaskPool` + `ShardedMatcher` for online waves, and
`JobState` (shared with `ClusterSim`) for per-job DAG progress.  It
never reads a wall clock: every transition takes the caller's ``now``,
so a virtual-time driver replaying a simulator workload through it
produces **bit-identical placements and JCTs** to `ClusterSim`
(tests/test_service.py parity suite).  The parity-critical rules it
mirrors from the simulator's event loop:

  * the scheduler owns ``avail`` — agents never report float resource
    state over the wire, so there is nothing to drift;
  * a lease's effective duration is computed at grant time with the
    simulator's exact overload formula (`core.online.overload_factor`);
  * waves fire only when job/cluster state changed (submit, settle,
    requeue, rejoin) — exactly the simulator's match_all trigger set —
    and one pump settles every already-delivered completion before it
    waves, the simulator's drain-simultaneous-finishes rule.

Placements are **leases**: a grant is owed either a `task_done` or a
reclaim.  A machine silent past ``hb_lost_after`` is declared lost, its
leases are reclaimed and requeued (the PR 7 suspicion/lost/rejoin
ladder, now driven by real agent heartbeats through the same
``heartbeat`` seam), and a `task_done` for a reclaimed lease is a
counted no-op — so every task has exactly one *effective* placement no
matter how the chaos plan interleaves crashes, partitions and
retransmissions.

`SchedulerService` is the process wrapper: it owns the listener, one
reliable `Channel` per connection, and routes wire messages into the
core.  ``pump`` is one synchronous step (drain every connection ->
apply -> check silence -> wave -> push new leases), callable either
from a virtual-time driver or from ``serve_in_thread`` on the wall
clock.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time

import numpy as np

from ..core import faults
from ..core.baselines import bfs_order, cp_order, random_order
from ..core.builder import build_schedule
from ..core.buildsvc import BuildService
from ..core.dag import DAG, dag_digest
from ..core.engine import get_backend, kernels
from ..core.online import JobState, TaskPool, overload_factor
from ..core.shard import ShardedMatcher
from . import wire
from .comm import Channel, listen


@dataclasses.dataclass
class ServiceConfig:
    """Scheduler-service knobs (the SimConfig analogue)."""

    n_machines: int = 8
    d: int = 4
    seed: int = 0
    expose_per_job: int = 8
    build_machines: int | None = None
    placement_backend: str | None = None
    build_workers: int | None = 1   # >1/None -> BuildService worker pool
    matcher_shards: int | None = None
    schedule_cache: bool = True
    #: fairness groups known up front (the simulator derives them from
    #: the whole arrival list; a streaming service must be told)
    groups: tuple = (0,)
    #: agent heartbeat cadence and the silence ladder (sim defaults:
    #: suspected after 2.5 periods, declared lost after 5)
    heartbeat_period: float = 1.0
    hb_suspect_after: float | None = None
    hb_lost_after: float | None = None
    recovery: faults.RecoveryPolicy | None = None

    @property
    def suspect_after(self) -> float:
        return self.hb_suspect_after or 2.5 * self.heartbeat_period

    @property
    def lost_after(self) -> float:
        return self.hb_lost_after or 5.0 * self.heartbeat_period


@dataclasses.dataclass
class Lease:
    """One granted placement, owed a task_done or a reclaim."""

    lease_id: int
    job: int
    task: int
    machine: int
    t: float           # grant time
    expected: float    # effective duration at grant (overload-adjusted)


class SchedulerCore:
    """Deterministic scheduler state machine (no clock, no I/O)."""

    def __init__(self, cfg: ServiceConfig, spec):
        self.cfg = cfg
        self.spec = spec   # sim.cluster.SchemeSpec
        M, d = cfg.n_machines, cfg.d
        self.avail = np.ones((M, d), dtype=np.float64)
        self.registered = np.zeros(M, dtype=bool)
        self.suspected = np.zeros(M, dtype=bool)
        self.lost = np.zeros(M, dtype=bool)
        self.last_seen = np.zeros(M, dtype=np.float64)
        self.pool = TaskPool(d=d, expose=cfg.expose_per_job)
        shares = {g: 1.0 for g in cfg.groups}
        self.smatcher = ShardedMatcher(spec.matcher, M, shares,
                                       n_shards=cfg.matcher_shards,
                                       capacity=float(M),
                                       recovery=cfg.recovery)
        self.jobs: dict[int, JobState] = {}
        self.leases: dict[int, Lease] = {}
        self._lease_ids = itertools.count(1)
        self._job_ids = itertools.count()
        self._rng = np.random.default_rng(cfg.seed)
        self._dirty = False
        self.incomplete = 0
        self.placements: list[tuple[float, int, int, int]] = []
        #: (job, task) -> effective completions; the exactly-once
        #: invariant chaos tests assert (every value is exactly 1)
        self.effective: dict[tuple[int, int], int] = {}
        self.stats = {"submits": 0, "placements": 0, "completions": 0,
                      "lease_reclaims": 0, "stale_done": 0, "beats": 0,
                      "suspects": 0, "losses": 0, "rejoins": 0}
        self._pri_cache: dict[tuple, np.ndarray] = {}
        self._buildsvc: BuildService | None = None
        if spec.order_fn == "dagps" and (
                cfg.build_workers is None or cfg.build_workers > 1):
            self._buildsvc = BuildService(workers=cfg.build_workers,
                                          recovery=cfg.recovery)
        # degraded-mode accounting baselines (mirrors ClusterSim._run)
        ap = faults.active_plan()
        self._inj0 = ap.snapshot() if ap is not None else {}
        self._dem0 = kernels.demotions_snapshot()

    # -- offline construction (mirrors ClusterSim._make_pri) -----------

    def _build_m(self) -> int:
        return self.cfg.build_machines or max(self.cfg.n_machines // 10, 4)

    def _make_pri(self, dag: DAG) -> np.ndarray:
        kind = self.spec.order_fn
        if kind == "dagps":
            key = (dag_digest(dag), self._build_m(),
                   get_backend(self.cfg.placement_backend).name)
            if self.cfg.schedule_cache and key in self._pri_cache:
                return self._pri_cache[key]
            if self._buildsvc is not None:
                pri = self._buildsvc.submit(
                    dag, self._build_m(),
                    backend=self.cfg.placement_backend).result().pri_score
            else:
                pri = build_schedule(
                    dag, self._build_m(),
                    backend=self.cfg.placement_backend).pri_score
            if self.cfg.schedule_cache:
                self._pri_cache[key] = pri
            return pri
        if kind == "bfs":
            order = bfs_order(dag)
        elif kind == "cp":
            order = cp_order(dag)
        else:
            order = random_order(dag, int(self._rng.integers(1 << 31)))
        rank = np.empty(dag.n)
        rank[order] = np.arange(dag.n)
        return 1.0 - rank / max(dag.n, 1)

    # -- transitions ----------------------------------------------------

    def register(self, machine: int, now: float) -> None:
        m = int(machine)
        self.registered[m] = True
        self.last_seen[m] = now
        self._dirty = True

    def submit(self, dag: DAG, group: int, now: float) -> int:
        job_id = next(self._job_ids)
        pri = self._make_pri(dag)
        job = JobState(job_id, dag, now, group, pri)
        self.jobs[job_id] = job
        self.pool.add_job(job_id, group, dag.demand, pri, job.runnable,
                          job.srpt)
        if not job.complete:
            self.incomplete += 1
        self.stats["submits"] += 1
        self._dirty = True
        return job_id

    def heartbeat(self, machine: int, t: float) -> None:
        """One beat reaches the scheduler (mirrors the sim's hb_arrive:
        stale/duplicate beats — retransmits, reorders — are no-ops via
        the monotone last_seen guard)."""
        m = int(machine)
        if not self.registered[m] or t <= self.last_seen[m]:
            return
        self.stats["beats"] += 1
        self.last_seen[m] = t
        if self.lost[m]:
            # rejoin on flap: fresh capacity again (its reclaimed tasks
            # may already run elsewhere under new leases)
            self.lost[m] = False
            self.suspected[m] = False
            self.avail[m] = 1.0
            self.stats["rejoins"] += 1
            self._dirty = True
        elif self.suspected[m]:
            self.suspected[m] = False
            self._dirty = True

    def task_done(self, lease_id: int, t: float) -> list[JobState]:
        """Settle one completion; returns jobs it retired.

        Exactly-once by construction: the channel's SeqGate already
        collapsed retransmits, and a reclaimed (requeued) lease is gone
        from the table — its late completion is a counted no-op, never a
        second effective placement.
        """
        lease = self.leases.pop(int(lease_id), None)
        if lease is None:
            self.stats["stale_done"] += 1
            return []
        key = (lease.job, lease.task)
        self.effective[key] = self.effective.get(key, 0) + 1
        job = self.jobs[lease.job]
        self.avail[lease.machine] += job.dag.demand[lease.task]
        was_runnable = lease.task in job.runnable
        if job.task_done(lease.task) or was_runnable:
            self.pool.mark_dirty(job.job_id)
        self.pool.set_srpt(job.job_id, job.srpt)
        self.stats["completions"] += 1
        self._dirty = True
        if job.complete and job.finish is None:
            job.finish = t
            self.pool.remove_job(job.job_id)
            self.incomplete -= 1
            return [job]
        return []

    def check_silence(self, now: float) -> list[Lease]:
        """Advance the suspicion/lost ladder; returns reclaimed leases
        (the service notifies their agents with revoke messages)."""
        reclaimed: list[Lease] = []
        lost_after, suspect_after = self.cfg.lost_after, self.cfg.suspect_after
        for m in np.flatnonzero(self.registered & ~self.lost):
            silent = now - self.last_seen[m]
            if silent + 1e-9 >= lost_after:
                self.lost[m] = True
                self.suspected[m] = True
                self.avail[m] = 0.0
                self.stats["losses"] += 1
                for lid, lease in list(self.leases.items()):
                    if lease.machine == m:
                        del self.leases[lid]
                        job = self.jobs[lease.job]
                        job.task_requeued(lease.task)
                        self.pool.mark_dirty(job.job_id)
                        self.stats["lease_reclaims"] += 1
                        reclaimed.append(lease)
                if reclaimed:
                    self._dirty = True
            elif silent + 1e-9 >= suspect_after and not self.suspected[m]:
                self.suspected[m] = True
                self.stats["suspects"] += 1
        return reclaimed

    def wave(self, now: float) -> list[Lease]:
        """One heartbeat wave, iff state changed since the last one —
        exactly the simulator's match_all trigger set, so healthy runs
        wave at identical times with identical pool/avail state."""
        if not self._dirty:
            return []
        self._dirty = False
        batch = self.pool.refresh()
        if batch is None or len(batch) == 0:
            return []
        matchable = self.registered & ~self.suspected & ~self.lost
        granted: list[Lease] = []

        def start_cb(gi: int, m: int) -> None:
            job = self.jobs[int(batch.job[gi])]
            tid = int(batch.tid[gi])
            self.avail[m] -= job.dag.demand[tid]
            expected = float(job.dag.duration[tid]) \
                * overload_factor(self.avail[m])
            lease = Lease(next(self._lease_ids), job.job_id, tid, int(m),
                          now, expected)
            self.leases[lease.lease_id] = lease
            job.task_started(tid)
            self.pool.mark_dirty(job.job_id)
            self.placements.append((now, job.job_id, tid, int(m)))
            self.stats["placements"] += 1
            granted.append(lease)

        self.smatcher.match_wave(self.avail, matchable, batch, start_cb)
        return granted

    # -- accounting -----------------------------------------------------

    def fault_stats(self) -> dict:
        """SimResult.fault_stats-shaped accounting (satellite of the
        PR 7 follow-up: these now exist behind the service API too)."""
        ap = faults.active_plan()
        inj1 = ap.snapshot() if ap is not None else {}
        dem1 = kernels.demotions_snapshot()
        sstats = self.smatcher.stats()
        return {
            "injections": {k: v - self._inj0.get(k, 0) for k, v in
                           inj1.items() if v - self._inj0.get(k, 0)},
            "shard": {k: sstats[k] for k in
                      ("launch_retries", "launch_failures", "quarantines",
                       "quarantined_shards", "quarantined_launches",
                       "probe_recoveries")},
            "build": {k: self._buildsvc.stats[k] for k in
                      ("retries", "worker_crashes", "quarantined_digests",
                       "inline_fallbacks", "resubmits", "resubmit_deduped")}
            if self._buildsvc is not None else {},
            "kernel_demotions": {k: v - self._dem0.get(k, 0)
                                 for k, v in dem1.items()
                                 if v - self._dem0.get(k, 0)},
            "heartbeat": {k: self.stats[k] for k in
                          ("beats", "suspects", "losses", "rejoins")},
            "service": {k: self.stats[k] for k in
                        ("submits", "placements", "completions",
                         "lease_reclaims", "stale_done")},
            "recovery_secs": round(
                self.smatcher.recovery_secs
                + (float(self._buildsvc.stats["recovery_secs"])
                   if self._buildsvc is not None else 0.0), 6),
        }

    def close(self) -> None:
        if self._buildsvc is not None:
            self._buildsvc.shutdown(wait=False)
        self.smatcher.close()


class SchedulerService:
    """Process wrapper: listener + per-connection reliable channels."""

    def __init__(self, core: SchedulerCore, addr: str = "inproc://sched",
                 clock=time.monotonic):
        self.core = core
        self._clock = clock
        self._lock = threading.Lock()
        self._conns: list[Channel] = []
        self._agents: dict[int, Channel] = {}
        #: job_id -> (client channel, client-side submission id)
        self._job_src: dict[int, tuple[Channel, int]] = {}
        self.listener = listen(addr, self._on_connect)
        self.addr = getattr(self.listener, "addr", addr)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def _on_connect(self, comm) -> None:
        ch = Channel(comm, "sched", self.core.cfg.recovery, self._clock)
        with self._lock:
            self._conns.append(ch)

    # -- one synchronous step -------------------------------------------

    def pump(self, now: float | None = None) -> None:
        """Drain every connection, apply, check silence, wave, push."""
        now = self._clock() if now is None else now
        with self._lock:
            conns = list(self._conns)
        dones: list[tuple[int, float]] = []
        for ch in conns:
            for msg in ch.poll(now):
                if msg.kind == wire.TASK_DONE:
                    dones.append((int(msg.payload["lease"]),
                                  float(msg.payload["t"])))
                else:
                    self._handle(ch, msg, now)
        # settle completions in lease-grant order, not delivery order:
        # lease ids increase in grant (= simulator start) order, so float
        # accumulation (avail rows, per-job srpt) runs in the simulator's
        # finish-heap order no matter how connections/retransmits
        # interleaved the task_done messages — a parity requirement and
        # what makes settle order a pure function of the message *set*
        for lease_id, t in sorted(dones):
            for job in self.core.task_done(lease_id, t):
                src = self._job_src.get(job.job_id)
                if src is not None:
                    src[0].send(wire.JOB_DONE, sub=src[1], job=job.job_id,
                                group=job.group, arrival=job.arrival,
                                t=job.finish, n_tasks=job.dag.n)
        for lease in self.core.check_silence(now):
            ch = self._agents.get(lease.machine)
            if ch is not None:
                ch.send(wire.REVOKE, lease=lease.lease_id)
        for lease in self.core.wave(now):
            ch = self._agents.get(lease.machine)
            if ch is not None:
                ch.send(wire.PLACE, lease=lease.lease_id, job=lease.job,
                        task=lease.task, machine=lease.machine,
                        t=lease.t, expected=lease.expected)

    def _handle(self, ch: Channel, msg: wire.Msg, now: float) -> None:
        p = msg.payload
        if msg.kind == wire.HEARTBEAT:
            self.core.heartbeat(p["machine"], float(p["t"]))
        elif msg.kind == wire.REGISTER:
            m = int(p["machine"])
            self._agents[m] = ch
            self.core.register(m, float(p.get("t", now)))
        elif msg.kind == wire.SUBMIT:
            job_id = self.core.submit(p["dag"], int(p.get("group", 0)),
                                      float(p.get("t", now)))
            self._job_src[job_id] = (ch, int(p["sub"]))
        elif msg.kind == wire.STATS_REQ:
            fs = self.core.fault_stats()
            fs["comm"] = self.comm_stats()
            ch.cast(wire.STATS, fault_stats=fs, mutation_stats=None)

    # -- accounting -----------------------------------------------------

    def comm_stats(self) -> dict:
        """Comm/channel reliability counters, summed over connections."""
        agg = {"retransmits": 0, "acked": 0, "dups": 0, "reorders": 0,
               "sent": 0, "dropped": 0, "duped": 0, "delayed": 0}
        with self._lock:
            conns = list(self._conns)
        for ch in conns:
            agg["retransmits"] += ch.stats["retransmits"]
            agg["acked"] += ch.stats["acked"]
            agg["dups"] += ch.gate.stats["dups"]
            agg["reorders"] += ch.gate.stats["reorders"]
            for k in ("sent", "dropped", "duped", "delayed"):
                agg[k] += ch.comm.stats[k]
        return agg

    # -- wall-clock serving ---------------------------------------------

    def serve_in_thread(self, poll_interval: float = 0.005) -> None:
        def _loop():
            while not self._stop.is_set():
                self.pump()
                self._stop.wait(poll_interval)

        self._thread = threading.Thread(target=_loop, name="repro-sched",
                                        daemon=True)
        self._thread.start()

    def close(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        self.listener.close()
        with self._lock:
            conns = list(self._conns)
        for ch in conns:
            ch.close()
        self.core.close()
