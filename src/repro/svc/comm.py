"""Comm layer: listener/connector transports + reliable channels.

Modeled on the dask.distributed ``comm/{core,inproc}`` split: an address
scheme picks the transport —

  * ``inproc://<name>``  in-process queue pairs.  Sends are synchronous
    and single-threaded callers see fully deterministic delivery order,
    which is what the decision-parity suite needs.
  * ``tcp://host:port``  length-prefixed frames over asyncio streams,
    run on a private background event loop so the rest of the stack
    stays synchronous.  Port 0 binds an ephemeral port (read it back
    from ``listener.addr``).

Every physical ``Comm.send`` consults the ``comm_send`` fault seam
(core/faults.py) with a per-comm send counter in the context, so
retransmissions of one logical message get independent (but seeded,
deterministic) drop/delay/dup decisions:

  * ``drop``  the frame vanishes
  * ``dup``   the frame is delivered twice
  * ``delay`` inproc: parked until the receiver's next poll cycle (a
    deterministic reorder); tcp: written ``spec.delay`` seconds late

`Channel` stacks the reliability protocol on a raw comm: outbound
sequencing + ack-gated retransmit with capped exponential backoff
(RecoveryPolicy.rpc_timeout/backoff_cap), inbound auto-ack + `SeqGate`
exactly-once admission.  See docs/architecture.md ("Scheduler service &
comm fault model").
"""

from __future__ import annotations

import asyncio
import itertools
import threading
import time
from collections import deque

from ..core import faults
from . import wire
from .wire import ACK, Msg, SeqGate

COMM_STATS_KEYS = ("sent", "delivered", "dropped", "duped", "delayed")


class CommClosed(Exception):
    """The peer is gone (connection refused, reset, or closed)."""


class Comm:
    """One bidirectional message pipe.  Subclasses implement
    ``_deliver`` (push one encoded frame toward the peer) and expose a
    thread-safe inbound queue via ``recv_nowait``."""

    def __init__(self, label: str = "?"):
        self.label = label
        self.closed = False
        self._sent = 0
        self.stats = dict.fromkeys(COMM_STATS_KEYS, 0)

    # -- outbound ------------------------------------------------------

    def send(self, msg: Msg) -> None:
        """Send through the ``comm_send`` fault seam."""
        if self.closed:
            raise CommClosed(f"comm {self.label} is closed")
        self._sent += 1
        self.stats["sent"] += 1
        sp = faults.query("comm_send", src=msg.sender, kind=msg.kind,
                          seq=msg.seq or msg.payload.get("ack", 0),
                          n=self._sent)
        if sp is not None and sp.kind == "drop":
            self.stats["dropped"] += 1
            return
        if sp is not None and sp.kind == "delay":
            self.stats["delayed"] += 1
            self._deliver(msg, delay=max(sp.delay, 0.0))
            return
        self._deliver(msg)
        if sp is not None and sp.kind == "dup":
            self.stats["duped"] += 1
            self._deliver(msg)

    def _deliver(self, msg: Msg, delay: float = 0.0) -> None:
        raise NotImplementedError

    # -- inbound -------------------------------------------------------

    def recv_nowait(self) -> Msg | None:
        raise NotImplementedError

    def flush_delayed(self) -> None:
        """Release delay-parked inbound messages into the live queue
        (transports without parking override as a no-op)."""

    def close(self) -> None:
        self.closed = True


# ----------------------------------------------------------------------
# inproc transport
# ----------------------------------------------------------------------

_INPROC_LISTENERS: dict[str, "InprocListener"] = {}
_INPROC_LOCK = threading.Lock()
_INPROC_IDS = itertools.count()


class InprocComm(Comm):
    """One side of an in-process pipe.  ``_q`` is this side's inbound
    queue; sends append to the peer's.  A ``delay``-kind injection parks
    the frame on the peer's delayed list until its next poll cycle —
    time-free, so virtual-clock runs stay deterministic."""

    def __init__(self, label: str):
        super().__init__(label)
        self._q: deque = deque()
        self._delayed: list = []
        self._lock = threading.Lock()
        self.peer: InprocComm | None = None

    def _deliver(self, msg: Msg, delay: float = 0.0) -> None:
        peer = self.peer
        if peer is None or peer.closed:
            return                       # peer gone: frames fall on the floor
        # encode/decode round-trip even in-process: the transports must
        # not differ in what object graph the receiver observes
        copy = wire.decode(wire.encode(msg))
        with peer._lock:
            (peer._delayed if delay > 0.0 else peer._q).append(copy)
        if delay <= 0.0:
            self.stats["delivered"] += 1

    def recv_nowait(self) -> Msg | None:
        with self._lock:
            return self._q.popleft() if self._q else None

    def flush_delayed(self) -> None:
        with self._lock:
            if self._delayed:
                self._q.extend(self._delayed)
                self.stats["delivered"] += len(self._delayed)
                self._delayed.clear()

    def close(self) -> None:
        super().close()
        peer = self.peer
        if peer is not None:
            peer.closed = True


class InprocListener:
    def __init__(self, addr: str, on_connect):
        self.addr = addr
        self.on_connect = on_connect
        self.closed = False

    def close(self) -> None:
        self.closed = True
        with _INPROC_LOCK:
            if _INPROC_LISTENERS.get(self.addr) is self:
                del _INPROC_LISTENERS[self.addr]


def _inproc_connect(addr: str) -> Comm:
    with _INPROC_LOCK:
        lst = _INPROC_LISTENERS.get(addr)
    if lst is None or lst.closed:
        raise CommClosed(f"no inproc listener at {addr}")
    cid = next(_INPROC_IDS)
    a = InprocComm(f"{addr}#c{cid}")
    b = InprocComm(f"{addr}#s{cid}")
    a.peer, b.peer = b, a
    lst.on_connect(b)
    return a


# ----------------------------------------------------------------------
# tcp transport (asyncio streams on a private background loop)
# ----------------------------------------------------------------------

_LOOP: asyncio.AbstractEventLoop | None = None
_LOOP_LOCK = threading.Lock()


def _loop() -> asyncio.AbstractEventLoop:
    global _LOOP
    with _LOOP_LOCK:
        if _LOOP is None or _LOOP.is_closed():
            _LOOP = asyncio.new_event_loop()
            t = threading.Thread(target=_LOOP.run_forever,
                                 name="repro-svc-io", daemon=True)
            t.start()
        return _LOOP


class TcpComm(Comm):
    """Frames are 4-byte big-endian length + wire.encode payload."""

    def __init__(self, label: str, reader: asyncio.StreamReader,
                 writer: asyncio.StreamWriter):
        super().__init__(label)
        self._reader = reader
        self._writer = writer
        self._q: deque = deque()
        self._lock = threading.Lock()
        self._read_task = asyncio.run_coroutine_threadsafe(
            self._read_loop(), _loop())

    async def _read_loop(self) -> None:
        try:
            while True:
                head = await self._reader.readexactly(4)
                raw = await self._reader.readexactly(
                    int.from_bytes(head, "big"))
                msg = wire.decode(raw)
                with self._lock:
                    self._q.append(msg)
                self.stats["delivered"] += 1
        except (asyncio.IncompleteReadError, ConnectionError, OSError):
            pass
        finally:
            self.closed = True

    def _write(self, frame: bytes) -> None:
        if not self._writer.is_closing():
            self._writer.write(frame)

    def _deliver(self, msg: Msg, delay: float = 0.0) -> None:
        raw = wire.encode(msg)
        frame = len(raw).to_bytes(4, "big") + raw
        loop = _loop()
        if delay > 0.0:
            loop.call_soon_threadsafe(loop.call_later, delay,
                                      self._write, frame)
        else:
            loop.call_soon_threadsafe(self._write, frame)

    def recv_nowait(self) -> Msg | None:
        with self._lock:
            return self._q.popleft() if self._q else None

    def close(self) -> None:
        super().close()
        self._read_task.cancel()
        _loop().call_soon_threadsafe(self._writer.close)


class TcpListener:
    def __init__(self, host: str, port: int, on_connect):
        self.on_connect = on_connect
        self.closed = False

        async def _serve():
            return await asyncio.start_server(self._accept, host, port)

        self._server = asyncio.run_coroutine_threadsafe(
            _serve(), _loop()).result(timeout=10.0)
        sock = self._server.sockets[0].getsockname()
        self.addr = f"tcp://{sock[0]}:{sock[1]}"

    async def _accept(self, reader, writer) -> None:
        peer = writer.get_extra_info("peername")
        self.on_connect(TcpComm(f"tcp-srv{peer}", reader, writer))

    def close(self) -> None:
        self.closed = True
        _loop().call_soon_threadsafe(self._server.close)


def _tcp_connect(addr: str, timeout: float) -> Comm:
    host, _, port = addr[len("tcp://"):].rpartition(":")

    async def _open():
        return await asyncio.open_connection(host, int(port))

    fut = asyncio.run_coroutine_threadsafe(_open(), _loop())
    try:
        reader, writer = fut.result(timeout=timeout)
    except (ConnectionError, OSError, asyncio.TimeoutError, TimeoutError) \
            as e:
        fut.cancel()
        raise CommClosed(f"connect to {addr} failed: {e}") from e
    return TcpComm(f"tcp-cli{addr}", reader, writer)


# ----------------------------------------------------------------------
# address-dispatched entry points
# ----------------------------------------------------------------------

def listen(addr: str, on_connect):
    """Start a listener; ``on_connect(comm)`` fires per inbound
    connection (synchronously for inproc, on the io thread for tcp —
    keep it cheap and thread-safe)."""
    if addr.startswith("inproc://"):
        lst = InprocListener(addr, on_connect)
        with _INPROC_LOCK:
            _INPROC_LISTENERS[addr] = lst
        return lst
    if addr.startswith("tcp://"):
        host, _, port = addr[len("tcp://"):].rpartition(":")
        return TcpListener(host or "127.0.0.1", int(port), on_connect)
    raise ValueError(f"unknown comm scheme in {addr!r}")


def connect(addr: str, timeout: float = 5.0) -> Comm:
    """Open a connection to a listener (raises `CommClosed` on failure)."""
    if addr.startswith("inproc://"):
        return _inproc_connect(addr)
    if addr.startswith("tcp://"):
        return _tcp_connect(addr, timeout)
    raise ValueError(f"unknown comm scheme in {addr!r}")


# ----------------------------------------------------------------------
# reliable channel
# ----------------------------------------------------------------------

class _Pending:
    __slots__ = ("msg", "attempt", "due")

    def __init__(self, msg: Msg, due: float):
        self.msg = msg
        self.attempt = 0
        self.due = due


class Channel:
    """Reliable conversation over one comm.

    ``send`` sequences and records the message for retransmission until
    the peer acks it; ``cast`` is fire-and-forget for the unsequenced
    kinds.  ``poll`` drains the comm: acks clear pending state, every
    sequenced inbound message is (re-)acked — the peer may have missed
    the first ack — and admitted through the `SeqGate`, so the caller
    sees each logical message exactly once, in the sender's order.
    Unacked messages are retransmitted on a capped exponential backoff
    (`RecoveryPolicy.rpc_timeout` base, ``backoff_cap`` ceiling), each
    retransmission drawing fresh ``comm_send`` seam decisions.
    """

    def __init__(self, comm: Comm, name: str,
                 recovery: faults.RecoveryPolicy | None = None,
                 clock=time.monotonic):
        self.comm = comm
        self.name = name
        rec = recovery or faults.RecoveryPolicy()
        self._t0 = rec.rpc_timeout
        self._cap = rec.backoff_cap
        self._clock = clock
        self._next_seq = itertools.count(1)
        self._pending: dict[int, _Pending] = {}
        self.gate = SeqGate()
        self.stats = {"retransmits": 0, "acked": 0}

    def send(self, kind: str, **payload) -> int:
        seq = next(self._next_seq)
        msg = Msg(kind, self.name, seq, payload)
        self._pending[seq] = _Pending(msg, self._clock() + self._t0)
        self.comm.send(msg)
        return seq

    def cast(self, kind: str, **payload) -> None:
        self.comm.send(Msg(kind, self.name, 0, payload))

    @property
    def unacked(self) -> int:
        return len(self._pending)

    def poll(self, now: float | None = None) -> list[Msg]:
        now = self._clock() if now is None else now
        self.comm.flush_delayed()
        out: list[Msg] = []
        while (m := self.comm.recv_nowait()) is not None:
            if m.kind == ACK:
                if self._pending.pop(int(m.payload["ack"]), None) is not None:
                    self.stats["acked"] += 1
                continue
            if m.seq:
                try:
                    self.comm.send(Msg(ACK, self.name, 0, {"ack": m.seq}))
                except CommClosed:
                    pass
            out.extend(self.gate.admit(m))
        for ent in self._pending.values():
            if now >= ent.due and not self.comm.closed:
                ent.attempt += 1
                self.stats["retransmits"] += 1
                self.comm.send(ent.msg)
                # exponent clamp: a peer that never acks (crashed agent)
                # drives attempt unboundedly; past ~2^32 the cap rules
                ent.due = now + min(
                    self._t0 * 2.0 ** min(ent.attempt, 32), self._cap)
        return out

    def close(self) -> None:
        self.comm.close()
