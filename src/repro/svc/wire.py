"""Wire protocol: message schema, codec, and idempotency machinery.

Every message is ``Msg(kind, sender, seq, payload)``.  Senders number
the messages of each reliable conversation 1, 2, 3, ... and retransmit
until acked; receivers push every incoming message through a `SeqGate`
that admits each (sender, seq) exactly once and in order.  Together
those two halves make the RPC layer *idempotent by construction*:
duplicated, reordered or dropped-then-retried deliveries all collapse
to the clean-delivery schedule (property-tested in
tests/test_service.py against a clean oracle).

The codec is tagged JSON — self-describing, endian-stable and safe to
decode from an untrusted peer (no pickle).  numpy arrays ride as
(dtype, shape, base64(tobytes)) triples; DAGs as their five constructor
fields, rebuilt through `core.dag.DAG` on decode so derived state
(children, stages, reachability) is recomputed, never trusted.
"""

from __future__ import annotations

import base64
import dataclasses
import json

import numpy as np

from ..core.dag import DAG

# -- message kinds -----------------------------------------------------
# agent -> scheduler
REGISTER = "register"        # machine joins: {machine}
HEARTBEAT = "heartbeat"      # {machine, t, beat}; unsequenced, superseded
TASK_DONE = "task_done"      # {lease, t}; reliable, exactly-once
# scheduler -> agent
PLACE = "place"              # lease grant: {lease, job, task, machine,
                             #  demand, t, expected}
REVOKE = "revoke"            # lease reclaimed after silence: {lease}
# client -> scheduler
SUBMIT = "submit"            # {sub, dag, group}; reliable
STATS_REQ = "stats_req"      # {}; unsequenced read
# scheduler -> client
JOB_DONE = "job_done"        # {sub, job, t, arrival, n_tasks}; reliable
STATS = "stats"              # {fault_stats, mutation_stats, core}
# both directions
ACK = "ack"                  # {ack: seq}; unsequenced by definition

#: kinds outside the reliable conversation: never sequenced, never acked,
#: never retransmitted.  Heartbeats are superseded by the next beat;
#: acks of acks would regress infinitely; stats are idempotent reads.
UNSEQUENCED = frozenset({ACK, HEARTBEAT, STATS_REQ, STATS})


@dataclasses.dataclass
class Msg:
    """One wire message.  ``seq`` is 0 for unsequenced kinds, else the
    sender's 1-based position in this conversation."""

    kind: str
    sender: str
    seq: int = 0
    payload: dict = dataclasses.field(default_factory=dict)


# -- codec -------------------------------------------------------------

def _enc(obj):
    if isinstance(obj, dict):
        return {k: _enc(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_enc(v) for v in obj]
    if isinstance(obj, DAG):
        return {"__dag__": {
            "duration": obj.duration.tolist(),
            "demand": obj.demand.tolist(),
            "stage_of": obj.stage_of.tolist(),
            "parents": [p.tolist() for p in obj.parents],
            "name": obj.name,
        }}
    if isinstance(obj, np.ndarray):
        return {"__nd__": [obj.dtype.str, list(obj.shape),
                           base64.b64encode(np.ascontiguousarray(obj)
                                            .tobytes()).decode("ascii")]}
    if isinstance(obj, (bytes, bytearray)):
        return {"__bytes__": base64.b64encode(bytes(obj)).decode("ascii")}
    if isinstance(obj, np.generic):
        return obj.item()
    return obj


def _dec(obj):
    if isinstance(obj, dict):
        if "__dag__" in obj:
            d = obj["__dag__"]
            return DAG(duration=np.asarray(d["duration"], dtype=np.float64),
                       demand=np.asarray(d["demand"], dtype=np.float64),
                       stage_of=np.asarray(d["stage_of"], dtype=np.int64),
                       parents=[np.asarray(p, dtype=np.int64)
                                for p in d["parents"]],
                       name=d["name"])
        if "__nd__" in obj:
            dt, shape, b64 = obj["__nd__"]
            return np.frombuffer(base64.b64decode(b64),
                                 dtype=np.dtype(dt)).reshape(shape).copy()
        if "__bytes__" in obj:
            return base64.b64decode(obj["__bytes__"])
        return {k: _dec(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_dec(v) for v in obj]
    return obj


def encode(msg: Msg) -> bytes:
    return json.dumps({"kind": msg.kind, "sender": msg.sender,
                       "seq": msg.seq, "payload": _enc(msg.payload)},
                      separators=(",", ":")).encode("utf-8")


def decode(raw: bytes) -> Msg:
    obj = json.loads(raw.decode("utf-8"))
    return Msg(kind=obj["kind"], sender=obj["sender"],
               seq=int(obj["seq"]), payload=_dec(obj["payload"]))


# -- receiver-side idempotency -----------------------------------------

class SeqGate:
    """Exactly-once, in-order admission of sequenced messages.

    Per sender: the next expected seq starts at 1; an already-seen seq
    is a counted no-op (duplicate), a future seq is parked until the gap
    fills (reorder), and admitting a seq releases any parked successors.
    Unsequenced kinds pass straight through.
    """

    def __init__(self):
        self._next: dict[str, int] = {}
        self._held: dict[str, dict[int, Msg]] = {}
        self.stats = {"admitted": 0, "dups": 0, "reorders": 0}

    def admit(self, msg: Msg) -> list[Msg]:
        """Messages now applicable, in order (possibly empty)."""
        if msg.kind in UNSEQUENCED:
            return [msg]
        nxt = self._next.get(msg.sender, 1)
        if msg.seq < nxt:
            self.stats["dups"] += 1
            return []
        if msg.seq > nxt:
            held = self._held.setdefault(msg.sender, {})
            if msg.seq in held:
                self.stats["dups"] += 1
            else:
                held[msg.seq] = msg
                self.stats["reorders"] += 1
            return []
        out = [msg]
        nxt += 1
        held = self._held.get(msg.sender, {})
        while nxt in held:
            out.append(held.pop(nxt))
            nxt += 1
        self._next[msg.sender] = nxt
        self.stats["admitted"] += len(out)
        return out
