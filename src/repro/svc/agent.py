"""Worker agents: heartbeats out, leases in.

Two shapes over one protocol:

  * `VirtualAgent` — driver-stepped and clockless, for the virtual-time
    parity/chaos suites.  The driver owns the event heap and calls
    ``heartbeat``/``poll``/``complete`` at simulated times; the agent
    only decides *whether* (fault seams) and *what* to send.
  * `Agent` — a wall-clock thread for real (tcp) deployment: connects
    with capped-backoff retry, registers, beats on a clock-derived
    schedule (a slow poll loop cannot starve beats), executes leases
    and reports completions.

Both emit heartbeats through the simulator's existing ``heartbeat``
fault seam with the same ``(machine, beat)`` context, so one chaos plan
drives the sim and the service identically — the PR 7 fold promised in
the ROADMAP.  The ``agent`` seam adds process-level failure: ``crash``
silences the agent forever (its leases get reclaimed after
``hb_lost_after``), ``partition`` pauses all sends *and* receives for
``delay`` simulated seconds — queued traffic, retransmits and the
rejoin ladder then play out on heal.

Reconnect backoff (satellite of the PR 8 quarantine-probe fix): each
failed connect waits ``min(backoff * 2^attempt, backoff_cap)``, further
capped by ``RecoveryPolicy.probe_secs`` — a scheduler stuck in a long
wave can delay acceptance, but never push the agent's next attempt past
the probe cadence, so rejoin latency is bounded by policy, not by
backoff history.
"""

from __future__ import annotations

import threading
import time
from collections import deque

from ..core import faults
from . import wire
from .comm import Channel, CommClosed, connect


class VirtualAgent:
    """Driver-stepped agent for virtual-time runs."""

    def __init__(self, machine: int, comm,
                 recovery: faults.RecoveryPolicy | None = None,
                 clock=None):
        self.machine = int(machine)
        self.ch = Channel(comm, f"agent-{self.machine}", recovery,
                          clock or (lambda: 0.0))
        self.beat_no = 0
        self.crashed = False
        self.partition_until = -float("inf")
        #: lease_id -> (job, task, t_done) of accepted, unrevoked leases
        self.active: dict[int, tuple[int, int, float]] = {}
        self._deferred: list[tuple[int, float]] = []  # partition backlog

    def register(self, t: float) -> None:
        self.ch.send(wire.REGISTER, machine=self.machine, t=t)

    def _partitioned(self, t: float) -> bool:
        return t < self.partition_until

    def heartbeat(self, t: float) -> tuple[str, float] | None:
        """One beat tick.  Returns ``("delay", t_arrive)`` when the
        heartbeat seam delays this beat — the driver schedules a
        `send_beat` then — else None (sent, dropped, or agent down)."""
        beat = self.beat_no
        self.beat_no += 1
        if self.crashed:
            return None
        sp = faults.query("agent", machine=self.machine, beat=beat)
        if sp is not None:
            if sp.kind == "crash":
                self.crashed = True
                self.active.clear()
                self._deferred.clear()
                return None
            if sp.kind == "partition":
                self.partition_until = t + max(sp.delay, 0.0)
        if self._partitioned(t):
            return None
        self._flush_deferred()
        sp = faults.query("heartbeat", machine=self.machine, beat=beat)
        if sp is not None:
            if sp.kind == "delay":
                return ("delay", t + max(sp.delay, 0.0))
            return None                                      # drop
        self.send_beat(t)
        return None

    def send_beat(self, t: float) -> None:
        """Emit one beat unconditionally (delayed-beat arrivals)."""
        if self.crashed or self._partitioned(t):
            return
        self.ch.cast(wire.HEARTBEAT, machine=self.machine, t=t)

    def poll(self, t: float) -> list[tuple[float, int]]:
        """Drain placements; returns new ``(t_done, lease_id)`` events
        for the driver to schedule."""
        if self.crashed or self._partitioned(t):
            return []
        self._flush_deferred()
        due: list[tuple[float, int]] = []
        for msg in self.ch.poll(t):
            p = msg.payload
            if msg.kind == wire.PLACE:
                t_done = float(p["t"]) + float(p["expected"])
                self.active[int(p["lease"])] = (int(p["job"]),
                                                int(p["task"]), t_done)
                due.append((t_done, int(p["lease"])))
            elif msg.kind == wire.REVOKE:
                self.active.pop(int(p["lease"]), None)
        return due

    def complete(self, lease_id: int, t: float) -> None:
        """The lease's work finished locally: report it (or queue the
        report until a partition heals)."""
        if self.crashed or lease_id not in self.active:
            return
        del self.active[lease_id]
        if self._partitioned(t):
            self._deferred.append((lease_id, t))
            return
        self.ch.send(wire.TASK_DONE, lease=lease_id, t=t)

    def _flush_deferred(self) -> None:
        for lease_id, t in self._deferred:
            self.ch.send(wire.TASK_DONE, lease=lease_id, t=t)
        self._deferred.clear()


class Agent:
    """Wall-clock worker agent (tcp deployment shape).

    ``clock``/``sleep``/``connector`` are injectable for the
    monkeypatched-clock regression tests; ``time_scale`` compresses
    lease durations (a lease for 30 simulated seconds occupies the
    agent for ``30 * time_scale`` wall seconds before it reports
    completion at the *simulated* finish time).
    """

    def __init__(self, addr: str, machine: int, period: float = 0.5,
                 recovery: faults.RecoveryPolicy | None = None,
                 time_scale: float = 0.0, clock=time.monotonic,
                 sleep=time.sleep, connector=connect):
        self.addr = addr
        self.machine = int(machine)
        self.period = period
        self.recovery = recovery or faults.RecoveryPolicy()
        self.time_scale = time_scale
        self._clock = clock
        self._sleep = sleep
        self._connector = connector
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._done_q: deque = deque()       # (lease, t_done) from timers
        self.reconnect_delays: list[float] = []
        self.beats: list[float] = []
        self.completed: list[int] = []

    # -- reconnect backoff (testable in isolation) ----------------------

    def backoff_delay(self, attempt: int) -> float:
        rec = self.recovery
        delay = min(rec.backoff * (2.0 ** attempt), rec.backoff_cap)
        if rec.probe_secs is not None:
            delay = min(delay, rec.probe_secs)
        return delay

    def connect_with_retry(self, max_attempts: int | None = None):
        attempt = 0
        while not self._stop.is_set():
            try:
                return self._connector(self.addr)
            except (CommClosed, OSError):
                if max_attempts is not None and attempt + 1 >= max_attempts:
                    raise
                delay = self.backoff_delay(attempt)
                self.reconnect_delays.append(delay)
                self._sleep(delay)
                attempt += 1
        return None

    # -- the serving loop -----------------------------------------------

    def start(self) -> None:
        self._thread = threading.Thread(target=self.run,
                                        name=f"repro-agent-{self.machine}",
                                        daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)

    def run(self) -> None:
        while not self._stop.is_set():
            comm = self.connect_with_retry()
            if comm is None:
                return
            ch = Channel(comm, f"agent-{self.machine}", self.recovery,
                         self._clock)
            ch.send(wire.REGISTER, machine=self.machine, t=self._clock())
            next_beat = self._clock()
            while not self._stop.is_set() and not comm.closed:
                next_beat = self.step(ch, next_beat)
                self._sleep(min(self.period / 4.0, 0.02))
            comm.close()
            # connection gone: fall through to the reconnect loop

    def step(self, ch: Channel, next_beat: float) -> float:
        """One poll-loop iteration; returns the updated beat deadline.

        The deadline advances off the *clock*, not the iteration count:
        however long a poll (or a scheduler wave on the other side)
        takes, the next beat is due ``period`` after the last one fired.
        """
        now = self._clock()
        if now >= next_beat:
            beat = len(self.beats)
            self.beats.append(now)
            if faults.query("heartbeat", machine=self.machine,
                            beat=beat) is None:
                ch.cast(wire.HEARTBEAT, machine=self.machine, t=now)
            next_beat = now + self.period
        for msg in ch.poll(now):
            p = msg.payload
            if msg.kind == wire.PLACE:
                self._execute(int(p["lease"]), float(p["expected"]),
                              float(p["t"]) + float(p["expected"]))
            elif msg.kind == wire.REVOKE:
                self._done_q = deque((lz, tz) for lz, tz in self._done_q
                                     if lz != int(p["lease"]))
        while self._done_q:
            lease, t_done = self._done_q.popleft()
            ch.send(wire.TASK_DONE, lease=lease, t=t_done)
            self.completed.append(lease)
        return next_beat

    def _execute(self, lease: int, expected: float, t_done: float) -> None:
        """Run one lease: occupy ``expected * time_scale`` wall seconds,
        then report completion at the simulated finish time."""
        if self.time_scale > 0.0:
            timer = threading.Timer(expected * self.time_scale,
                                    self._done_q.append,
                                    args=((lease, t_done),))
            timer.daemon = True
            timer.start()
        else:
            self._done_q.append((lease, t_done))
