"""Scheduler-as-a-service: the distributed deployment mode.

The simulator (`sim/cluster.py`) and a real deployment are two clients
of one scheduler core:

  * `wire`      — message schema, codec, and the sequence-gating that
                  makes every RPC idempotent (dups/reorders are no-ops)
  * `comm`      — listener/connector transport abstraction: an
                  in-process transport for deterministic tests plus an
                  asyncio-socket transport for real deployment, with the
                  ``comm_send`` fault seam injected at every send
  * `scheduler` — the central scheduler process: streaming
                  ``submit(dag) -> handle``, lease-based placements,
                  heartbeat-silence lease reclaim
  * `agent`     — worker agents: real heartbeats, lease execution,
                  wall-clock-aware reconnect backoff
  * `client`    — client API + the virtual-time driver that replays a
                  simulator workload through the service for the
                  decision-parity suite
"""

from .client import Client, ServiceResult, run_service_workload
from .comm import Channel, Comm, CommClosed, connect, listen
from .scheduler import SchedulerCore, SchedulerService, ServiceConfig
from .wire import Msg, SeqGate, decode, encode

__all__ = [
    "Channel", "Client", "Comm", "CommClosed", "Msg", "SchedulerCore",
    "SchedulerService", "SeqGate", "ServiceConfig", "ServiceResult",
    "connect", "decode", "encode", "listen", "run_service_workload",
]
