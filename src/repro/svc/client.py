"""Client API + the virtual-time workload driver.

`Client` is the streaming submission handle: ``submit(dag) -> handle``
over a reliable channel, completions delivered as ``job_done`` messages,
and ``fault_stats``/``mutation_stats`` fetchable over the wire (the
PR 7 ROADMAP follow-up: they used to exist only on `SimResult`).

`run_service_workload` replays a simulator workload — the same
``(t, dag, group)`` arrival list `ClusterSim.run` takes — through a real
inproc service: one `SchedulerService`, one `VirtualAgent` per machine,
one `Client`, all stepped by a single virtual-time event heap that
mirrors the simulator's (arrival events first, then per-machine
heartbeat clocks; simultaneous completions drain as one batch before the
wave).  On a healthy run the resulting placements and JCTs are
bit-identical to `ClusterSim` (tests/test_service.py locks this with a
golden); under a chaos plan the run instead asserts liveness — every
job completes, each task exactly once.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import time

import numpy as np

from ..core import faults
from ..sim.cluster import JobResult
from . import wire
from .agent import VirtualAgent
from .comm import Channel, connect
from .scheduler import SchedulerCore, SchedulerService, ServiceConfig

_RUN_IDS = itertools.count()


class JobHandle:
    """One submission: filled in when its job_done arrives."""

    def __init__(self, sub: int):
        self.sub = sub
        self.job_id: int | None = None
        self.result: JobResult | None = None

    @property
    def done(self) -> bool:
        return self.result is not None


class Client:
    """Streaming submission client over one comm."""

    def __init__(self, comm, name: str = "client",
                 recovery: faults.RecoveryPolicy | None = None,
                 clock=time.monotonic):
        self.ch = Channel(comm, name, recovery, clock)
        self._sub_ids = itertools.count()
        self._subs: dict[int, JobHandle] = {}
        self._stats: list[dict] = []

    def submit(self, dag, group: int = 0, t: float = 0.0) -> JobHandle:
        handle = JobHandle(next(self._sub_ids))
        self._subs[handle.sub] = handle
        self.ch.send(wire.SUBMIT, sub=handle.sub, dag=dag, group=group, t=t)
        return handle

    def poll(self, now: float | None = None) -> list[JobHandle]:
        """Drain the channel; returns handles that just completed."""
        finished = []
        for msg in self.ch.poll(now):
            p = msg.payload
            if msg.kind == wire.JOB_DONE:
                handle = self._subs[int(p["sub"])]
                handle.job_id = int(p["job"])
                handle.result = JobResult(int(p["job"]), int(p["group"]),
                                          float(p["arrival"]), float(p["t"]),
                                          int(p["n_tasks"]))
                finished.append(handle)
            elif msg.kind == wire.STATS:
                self._stats.append(p)
        return finished

    @property
    def pending(self) -> int:
        return sum(1 for h in self._subs.values() if not h.done)

    def request_stats(self) -> None:
        self.ch.cast(wire.STATS_REQ)

    def take_stats(self) -> dict | None:
        return self._stats.pop() if self._stats else None

    def stats(self, timeout: float = 5.0, poll_interval: float = 0.01,
              sleep=time.sleep) -> dict:
        """Blocking wall-clock stats fetch (service must be serving)."""
        self.request_stats()
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            self.poll()
            got = self.take_stats()
            if got is not None:
                return got
            sleep(poll_interval)
        raise TimeoutError("no stats reply from scheduler service")


@dataclasses.dataclass
class ServiceResult:
    """`SimResult`'s service twin (same jcts()/jobs shape the bench and
    example harnesses consume)."""

    jobs: list[JobResult]
    makespan: float
    placements: list[tuple[float, int, int, int]]
    fault_stats: dict | None = None
    mutation_stats: dict | None = None
    #: (job, task) -> effective completion count (chaos invariant: all 1)
    effective: dict | None = None
    phase_times: dict | None = None   # parity with SimResult consumers

    def jcts(self) -> np.ndarray:
        return np.array([j.jct for j in self.jobs])


class _VClock:
    """Mutable virtual clock shared by every channel in a driven run."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


# driver event codes, mirroring the simulator's heap discipline:
# arrivals pushed first at init (so they outrank same-time runtime
# events), completions drain as a batch, heartbeats tick per machine
_ARR, _DONE, _HB, _HBA = range(4)


def run_service_workload(arrivals, cfg: ServiceConfig, spec,
                         fault_plan=None, addr: str | None = None,
                         max_steps: int = 2_000_000) -> ServiceResult:
    """Replay a simulator arrival list through an inproc service run.

    ``spec`` is a `sim.cluster.SchemeSpec` (use `sim.cluster.scheme`).
    Healthy runs are decision-parity territory: configure the matching
    `SimConfig` with ``speculate=False`` and the same seed/machines/
    shards, and placements + JCTs match `ClusterSim` bit-for-bit.
    """
    plan = faults.coerce(fault_plan)
    if plan is None:
        return _run(arrivals, cfg, spec, addr, max_steps)
    with faults.scope(plan):
        return _run(arrivals, cfg, spec, addr, max_steps)


def _run(arrivals, cfg: ServiceConfig, spec, addr, max_steps):
    arrivals = list(arrivals)
    groups = tuple(sorted({g for (_, _, g) in arrivals})) or (0,)
    if tuple(cfg.groups) != groups:
        cfg = dataclasses.replace(cfg, groups=groups)
    vt = _VClock()
    addr = addr or f"inproc://svc-run-{next(_RUN_IDS)}"
    core = SchedulerCore(cfg, spec)
    svc = SchedulerService(core, addr, clock=vt)
    try:
        agents = [VirtualAgent(m, connect(addr), cfg.recovery, clock=vt)
                  for m in range(cfg.n_machines)]
        for a in agents:
            a.register(0.0)
        client = Client(connect(addr), recovery=cfg.recovery, clock=vt)
        svc.pump(0.0)

        counter = itertools.count()
        events: list[tuple[float, int, int, object]] = []
        for k, (t, _dag, _g) in enumerate(arrivals):
            heapq.heappush(events, (float(t), next(counter), _ARR, k))
        period = cfg.heartbeat_period
        for m in range(cfg.n_machines):
            heapq.heappush(events, (period, next(counter), _HB, m))

        handles: dict[int, JobHandle] = {}
        results: list[JobResult] = []
        n_jobs = len(arrivals)
        steps = 0
        while events and len(results) < n_jobs:
            steps += 1
            if steps > max_steps:
                raise RuntimeError(
                    f"service workload did not complete in {max_steps} "
                    f"steps ({len(results)}/{n_jobs} jobs done)")
            t_now, _, code, arg = heapq.heappop(events)
            vt.t = t_now
            if code == _ARR:
                t_a, dag, g = arrivals[arg]
                handles[arg] = client.submit(dag, group=g, t=t_now)
            elif code == _DONE:
                m, lease = arg
                agents[m].complete(lease, t_now)
                # drain simultaneous completions before the wave — the
                # simulator's finish-drain rule (stop at the first
                # non-completion event, exactly like its heap scan)
                while events and events[0][2] == _DONE \
                        and events[0][0] <= t_now + 1e-9:
                    _, _, _, arg2 = heapq.heappop(events)
                    m2, lease2 = arg2
                    agents[m2].complete(lease2, t_now)
            elif code == _HB:
                delayed = agents[arg].heartbeat(t_now)
                if delayed is not None:
                    heapq.heappush(events, (delayed[1], next(counter),
                                            _HBA, arg))
                heapq.heappush(events, (t_now + period, next(counter),
                                        _HB, arg))
            elif code == _HBA:
                agents[arg].send_beat(t_now)
            svc.pump(t_now)
            for a in agents:
                for t_done, lease in a.poll(t_now):
                    heapq.heappush(events, (t_done, next(counter), _DONE,
                                            (a.machine, lease)))
            for handle in client.poll(t_now):
                results.append(handle.result)

        # fetch the final accounting over the wire (the service client
        # API surface for fault_stats — not a core peek)
        client.request_stats()
        svc.pump(vt.t)
        client.poll(vt.t)
        stats = client.take_stats() or {}
        return ServiceResult(
            jobs=results,
            makespan=max((j.finish for j in results), default=0.0),
            placements=list(core.placements),
            fault_stats=stats.get("fault_stats"),
            mutation_stats=stats.get("mutation_stats"),
            effective=dict(core.effective),
        )
    finally:
        svc.close()
