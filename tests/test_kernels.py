"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import kernel as fak, ref as far
from repro.kernels.rg_lru import kernel as rgk, ref as rgr
from repro.kernels.rwkv6 import kernel as wkk, ref as wkr

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    # (B, Sq, Sk, H, KV, hd)
    (1, 128, 128, 2, 2, 64),
    (2, 256, 256, 4, 2, 64),
    (1, 128, 256, 4, 1, 128),   # MQA, rectangular
])
@pytest.mark.parametrize("opts", [
    dict(causal=True),
    dict(causal=True, window=64),
    dict(causal=True, cap=30.0),
    dict(causal=False),
])
def test_flash_attention_matches_ref(dtype, shape, opts):
    B, Sq, Sk, H, KV, hd = shape
    q = jax.random.normal(KEY, (B, Sq, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, Sk, KV, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Sk, KV, hd), dtype)
    got = fak.flash_attention(q, k, v, block_q=64, block_k=64,
                              interpret=True, **opts)
    want = far.attention(q, k, v, **opts)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(1, 32, 2, 16), (2, 64, 3, 32)])
@pytest.mark.parametrize("chunk", [16, 32])
def test_wkv6_matches_ref(dtype, shape, chunk):
    B, S, H, N = shape
    r = (jax.random.normal(KEY, (B, S, H, N)) * 0.5).astype(dtype)
    k = (jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, N)) * 0.5).astype(dtype)
    v = (jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, H, N)) * 0.5).astype(dtype)
    w = (jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, H, N)))
         * 0.5 + 0.45).astype(dtype)
    u = (jax.random.normal(jax.random.fold_in(KEY, 4), (H, N)) * 0.3).astype(jnp.float32)
    s0 = jax.random.normal(jax.random.fold_in(KEY, 5), (B, H, N, N)) * 0.1
    y1, sT1 = wkk.wkv6(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    y2, sT2 = wkr.wkv6(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(sT1), np.asarray(sT2), rtol=1e-3, atol=1e-3)


def test_wkv6_chunk_invariance():
    B, S, H, N = 1, 64, 2, 16
    r = jax.random.normal(KEY, (B, S, H, N)) * 0.5
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, N)) * 0.5
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, H, N)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, H, N))) * 0.5 + 0.45
    u = jax.random.normal(jax.random.fold_in(KEY, 4), (H, N)) * 0.3
    s0 = jnp.zeros((B, H, N, N))
    outs = [wkk.wkv6(r, k, v, w, u, s0, chunk=c, interpret=True)[0]
            for c in (8, 16, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(1, 64, 128), (2, 128, 256)])
def test_rglru_matches_ref(dtype, shape):
    B, S, D = shape
    x = (jax.random.normal(KEY, (B, S, D)) * 0.5).astype(dtype)
    a = (jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, D)))
         * 0.4 + 0.5).astype(dtype)
    h0 = jax.random.normal(jax.random.fold_in(KEY, 2), (B, D)) * 0.2
    h1, hT1 = rgk.rglru_scan(x, a, h0, chunk=32, d_block=128, interpret=True)
    h2, hT2 = rgr.rglru_scan(x, a, h0)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(hT1), np.asarray(hT2), rtol=1e-3, atol=1e-3)


def test_rglru_state_chaining():
    """Running two half-sequences with carried state == one full run."""
    B, S, D = 1, 64, 128
    x = jax.random.normal(KEY, (B, S, D)) * 0.5
    a = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, D))) * 0.4 + 0.5
    h0 = jnp.zeros((B, D))
    full, _ = rgk.rglru_scan(x, a, h0, chunk=16, interpret=True)
    h1, hT = rgk.rglru_scan(x[:, :32], a[:, :32], h0, chunk=16, interpret=True)
    h2, _ = rgk.rglru_scan(x[:, 32:], a[:, 32:], hT, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate([h1, h2], axis=1)),
                               rtol=1e-5, atol=1e-5)
