"""Launch-layer tests: sharding rules (pure logic) + a tiny-mesh pjit
compile in a subprocess (the main test process keeps 1 device)."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_spec_guards_divisibility():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from jax.sharding import PartitionSpec as P
from repro.launch.sharding import spec_for_path, _guard
mesh = jax.make_mesh((2, 4), ("data", "model"))


class K:
    def __init__(self, key):
        self.key = key


# wk with kv heads = 2 < model axis 4 -> TP dropped
s = spec_for_path((K("body"), K("attn"), K("wk")), (8, 64, 2, 16), mesh)
assert s == P(None, "data", None, None), s
# mlp weight: both axes shard
s = spec_for_path((K("mlp"), K("wg")), (64, 128), mesh)
assert s == P("data", "model"), s
# inference: fsdp off
s = spec_for_path((K("mlp"), K("wg")), (64, 128), mesh, fsdp=False)
assert s == P(None, "model"), s
# moe with many experts: expert-parallel
s = spec_for_path((K("moe"), K("wg")), (8, 64, 128), mesh)
assert s == P("model", "data", None), s
# moe with few experts: expert-TP on d_ff
s = spec_for_path((K("moe"), K("wg")), (2, 64, 128), mesh)
assert s == P(None, "data", "model"), s
print("SPEC_OK")
"""
    out = _run(code)
    assert "SPEC_OK" in out


def test_tiny_mesh_train_compiles():
    """End-to-end pjit train-step compile on a 2x2 debug mesh."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro import configs
from repro.data import batch_specs
from repro.models import model as M
from repro.models.sharding import activation_sharding
from repro.optim import AdamWConfig, init_state
from repro.train import TrainConfig, make_train_step
from repro.launch.sharding import activation_rules, batch_shardings, tree_shardings

mesh = jax.make_mesh((2, 2), ("data", "model"))
cfg = configs.get_smoke("gemma2_2b")
tcfg = TrainConfig(optimizer=AdamWConfig())
with mesh, activation_sharding(mesh, activation_rules(mesh, 4, n_kv=cfg.n_kv_heads)):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_s = jax.eval_shape(lambda k: M.init_params(cfg, k), key)
    pspec = tree_shardings(params_s, mesh)
    opt_s = jax.eval_shape(lambda p: init_state(tcfg.optimizer, p), params_s)
    ospec = tree_shardings(opt_s, mesh)
    bsd = batch_specs(cfg, 4, 32)
    bspec = batch_shardings(bsd, mesh)
    step = make_train_step(cfg, tcfg)
    compiled = jax.jit(step, in_shardings=(pspec, ospec, bspec),
                       out_shardings=(pspec, ospec, None)).lower(
        params_s, opt_s, bsd).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # jax 0.4.x: list of per-computation dicts
        ca = ca[0]
    assert ca["flops"] > 0
print("COMPILE_OK")
"""
    out = _run(code)
    assert "COMPILE_OK" in out


def test_collective_parser():
    from repro.launch.roofline import parse_collectives
    hlo = '''
  %all-gather = f32[4096,128]{1,0} all-gather(%x), channel_id=1, replica_groups=[16,16]<=[16,16]T(1,0), dimensions={0}
  %all-reduce.1 = bf16[128,64]{1,0} all-reduce(%y), replica_groups=[32,8]<=[256], to_apply=%add
  %ars = (f32[64]{0}, f32[64]{0}) all-reduce-start(%z), replica_groups={{0,1,2,3}}
  %ard = f32[64]{0} all-reduce-done(%ars)
  %cp = bf16[32,32]{1,0} collective-permute(%w), source_target_pairs={{0,1},{1,0}}
'''
    st = parse_collectives(hlo, 256)
    assert st.count_by_kind == {"all-gather": 1, "all-reduce": 2,
                                "collective-permute": 1}
    ag = 4096 * 128 * 4 * 15 / 16
    ar = 128 * 64 * 2 * 2 * 7 / 8
    ars = 2 * (64 * 4) * 3 / 4   # start counted once, group of 4
    cp = 32 * 32 * 2
    assert st.bytes_by_kind["all-gather"] == pytest.approx(ag)
    assert st.bytes_by_kind["all-reduce"] == pytest.approx(ar + ars)
    assert st.bytes_by_kind["collective-permute"] == pytest.approx(cp)


def test_roofline_terms_math():
    from repro.launch.roofline import CollectiveStats, roofline_terms
    coll = CollectiveStats({"all-reduce": 1e9}, 1e9, {"all-reduce": 3})
    rl = roofline_terms({"flops": 197e12, "bytes accessed": 819e9}, coll,
                        n_devices=2, model_flops=2 * 197e12)
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.memory_s == pytest.approx(1.0)
    assert rl.collective_s == pytest.approx(1e9 / 50e9)
    assert rl.dominant in ("compute", "memory")
    assert rl.useful_ratio == pytest.approx(1.0)


def _run(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, timeout=420)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout
