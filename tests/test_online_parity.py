"""Decision parity: batched SoA matcher ≡ the pre-refactor object matcher.

The vectorized online path (CandidateBatch/TaskPool + `Matcher.match_batch`)
must make bit-identical decisions to the historical per-machine object-list
matcher: same picks in the same order, same overbook flags, same EMA
observations and deficit updates.  `ReferenceMatcher` below is a verbatim
copy of the pre-refactor `find_tasks_for_machine`; randomized heartbeats
(including score ties, overbooking boundaries, deficit forcing, and
carried-over matcher state) assert equality against the new path.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.engine import packing
from repro.core.online import (CandidateBatch, DeficitCounters, JobView,
                               Matcher, MatcherConfig, PendingTask, TaskPool,
                               drf_fairness, slot_fairness)

FUNGIBLE = (2, 3)
RIGID = (0, 1)


class ReferenceMatcher:
    """Pre-refactor FindAppropriateTasksForMachine, kept verbatim as the
    parity oracle for the batched path."""

    def __init__(self, cfg: MatcherConfig, capacity: float, shares: dict[int, float]):
        self.cfg = cfg
        self.deficits = DeficitCounters(shares, capacity, cfg.kappa)
        self._ema_score = 1.0
        self._ema_srpt = 1.0

    @property
    def eta(self) -> float:
        if not self.cfg.use_srpt:
            return 0.0
        return self.cfg.eta_m * self._ema_score / max(self._ema_srpt, 1e-12)

    def _observe(self, score: float, srpt: float) -> None:
        a = 0.05
        self._ema_score = (1 - a) * self._ema_score + a * score
        self._ema_srpt = (1 - a) * self._ema_srpt + a * max(srpt, 1e-12)

    def find_tasks_for_machine(self, machine_id, avail, tasks, jobs):
        cfg = self.cfg
        if not tasks:
            return []
        avail = avail.astype(np.float64).copy()
        dem = np.stack([t.demand for t in tasks])           # (n, d)
        pri = (np.array([t.pri_score for t in tasks])
               if cfg.use_priority else np.ones(len(tasks)))
        srpt = np.array([jobs[t.job_id].srpt for t in tasks])
        grp = np.array([jobs[t.job_id].group for t in tasks])
        rp = np.array([
            cfg.remote_penalty if (t.locality >= 0 and t.locality != machine_id) else 1.0
            for t in tasks
        ])
        fd = np.asarray(cfg.fit_dims)
        rigid = np.asarray([r for r in RIGID if r in cfg.fit_dims], dtype=int)
        fung = np.asarray([f for f in FUNGIBLE if f in cfg.fit_dims], dtype=int)
        taken = np.zeros(len(tasks), dtype=bool)
        picked = []
        while len(picked) < cfg.bundle_limit:
            fits = packing.fits_mask(avail, dem, dims=fd)
            if cfg.use_overbooking:
                over = (~fits
                        & packing.fits_mask(avail, dem, dims=rigid)
                        & packing.fits_mask(avail, dem, dims=fung,
                                            slack=cfg.max_overbook - 1.0))
            else:
                over = np.zeros(len(tasks), dtype=bool)
            eligible = (fits | over) & ~taken
            must_group = self.deficits.must_serve()
            if must_group is not None and (eligible & (grp == must_group)).any():
                eligible &= grp == must_group
            if not eligible.any():
                break
            if cfg.use_packing:
                # lockstep with Matcher.match_batch: the packing score is
                # the explicit left-to-right accumulation (seq_dot), not a
                # BLAS matvec — see online.seq_dot for why
                av = np.clip(avail, 0.0, None)
                acc = dem[:, 0] * av[0]
                for k in range(1, dem.shape[1]):
                    acc = acc + dem[:, k] * av[k]
                dot = acc * rp
            else:
                dot = rp.copy()
            if len(fung):
                overshoot = np.clip((dem[:, fung] - avail[fung]).max(axis=1), 0.0, None)
            else:
                overshoot = np.zeros(len(tasks))
            base = np.where(fits, dot, dot * np.maximum(1.0 - overshoot, 0.05))
            perf = pri * base - self.eta * srpt
            pool = eligible & fits if (eligible & fits).any() else eligible
            score = np.where(pool, perf, -np.inf)
            i = int(np.argmax(score))
            if not np.isfinite(score[i]):
                break
            t = tasks[i]
            taken[i] = True
            picked.append((t, bool(over[i])))
            self._observe(float(pri[i] * base[i]), float(srpt[i]))
            avail -= t.demand
            np.clip(avail, 0.0, None, out=avail)
            self.deficits.allocated(jobs[t.job_id].group, cfg.fairness(t.demand))
        return picked


def _random_heartbeat(rng: np.random.Generator):
    """One randomized heartbeat config: tasks, job views, cfg, machines."""
    d = 4
    n_jobs = int(rng.integers(1, 6))
    jobs = {j: JobView(j, int(rng.integers(0, 3)),
                       float(rng.uniform(0.5, 50.0))) for j in range(n_jobs)}
    n = int(rng.integers(1, 40))
    quant = rng.random() < 0.5       # coarse demands/pri force score ties
    tasks = []
    for i in range(n):
        dem = rng.uniform(0.02, 0.95, d)
        pri = float(rng.uniform(0.0, 1.0))
        if quant:
            dem = np.round(dem * 5) / 5 + 0.01
            pri = round(pri, 1)
        tasks.append(PendingTask(
            job_id=int(rng.integers(0, n_jobs)), task_id=i, demand=dem,
            duration=float(rng.uniform(0.5, 20.0)), pri_score=pri,
            locality=int(rng.integers(-1, 4)) if rng.random() < 0.3 else -1))
    cfg = MatcherConfig(
        eta_m=float(rng.choice([0.05, 0.2, 0.5])),
        remote_penalty=float(rng.choice([0.5, 0.8, 1.0])),
        kappa=float(rng.choice([0.02, 0.1, 10.0])),
        max_overbook=float(rng.choice([1.0, 1.25, 1.5])),
        fairness=drf_fairness if rng.random() < 0.5 else slot_fairness,
        use_priority=bool(rng.random() < 0.8),
        use_packing=bool(rng.random() < 0.8),
        use_srpt=bool(rng.random() < 0.8),
        use_overbooking=bool(rng.random() < 0.7),
        bundle_limit=int(rng.choice([2, 8, 64])),
        fit_dims=tuple(rng.choice([0, 1, 2, 3],
                                  size=int(rng.integers(1, 5)),
                                  replace=False).tolist()),
    )
    shares = {g: 1.0 for g in sorted({v.group for v in jobs.values()})}
    machines = [(int(m), rng.uniform(0.0, 1.2, d))
                for m in rng.integers(0, 5, size=int(rng.integers(1, 4)))]
    return tasks, jobs, cfg, shares, machines


def _batch_from(tasks, jobs) -> CandidateBatch:
    return CandidateBatch(
        dem=np.stack([t.demand for t in tasks]),
        pri=np.array([t.pri_score for t in tasks]),
        srpt=np.array([jobs[t.job_id].srpt for t in tasks]),
        grp=np.array([jobs[t.job_id].group for t in tasks]),
        loc=np.array([t.locality for t in tasks], dtype=np.int64),
        job=np.array([t.job_id for t in tasks], dtype=np.int64),
        tid=np.array([t.task_id for t in tasks], dtype=np.int64),
    )


def _assert_parity_one(seed: int) -> None:
    rng = np.random.default_rng(seed)
    tasks, jobs, cfg, shares, machines = _random_heartbeat(rng)
    ref = ReferenceMatcher(cfg, capacity=10.0, shares=shares)
    new = Matcher(cfg, capacity=10.0, shares=shares)
    # pre-load deficit state identically on both (forces must_serve paths)
    for _ in range(int(rng.integers(0, 8))):
        g = int(rng.choice(list(shares)))
        w = float(rng.uniform(0.5, 2.0))
        ref.deficits.allocated(g, w)
        new.deficits.allocated(g, w)
    # several heartbeats against the same matcher state (EMA/deficit carry)
    for m, avail in machines:
        want = ref.find_tasks_for_machine(m, avail, tasks, jobs)
        got_rows = new.match_batch(m, avail, _batch_from(tasks, jobs))
        got = [(tasks[i], ob) for i, ob in got_rows]
        assert [(t.job_id, t.task_id, ob) for t, ob in want] == \
               [(t.job_id, t.task_id, ob) for t, ob in got]
        assert new._ema_score == ref._ema_score
        assert new._ema_srpt == ref._ema_srpt
        assert new.deficits.deficit == ref.deficits.deficit


def test_decision_parity_seeded():
    """≥20 randomized heartbeat configurations, exact decision parity."""
    for seed in range(30):
        _assert_parity_one(seed)


def test_wrapper_matches_batch_core():
    """find_tasks_for_machine (object wrapper) ≡ match_batch decisions."""
    rng = np.random.default_rng(1234)
    for _ in range(10):
        tasks, jobs, cfg, shares, machines = _random_heartbeat(rng)
        a = Matcher(cfg, capacity=10.0, shares=shares)
        b = Matcher(cfg, capacity=10.0, shares=shares)
        for m, avail in machines:
            via_wrap = a.find_tasks_for_machine(m, avail, tasks, jobs)
            via_core = b.match_batch(m, avail, _batch_from(tasks, jobs))
            assert [(t.task_id, ob) for t, ob in via_wrap] == \
                   [(tasks[i].task_id, ob) for i, ob in via_core]
            assert a._ema_score == b._ema_score
            assert a.deficits.deficit == b.deficits.deficit


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_decision_parity_hypothesis(seed):
        _assert_parity_one(seed)
except ImportError:  # pragma: no cover - hypothesis ships with .[test]
    pass


def test_machine_skip_layer_is_exact():
    """machines_with_candidates ≡ the matcher's first-iteration eligibility.

    The simulator skips machines whose eligibility column is empty; that is
    only decision-free if the batched masks match what `match_batch` would
    compute on its first bundling iteration for every machine — including
    restricted fit_dims, disabled overbooking, and sub-1.0 overbook caps.
    """
    rng = np.random.default_rng(99)
    for trial in range(40):
        n, m, d = int(rng.integers(1, 30)), int(rng.integers(1, 20)), 4
        dem = rng.uniform(0.02, 0.95, (n, d))
        avail = rng.uniform(0.0, 1.1, (m, d))
        fit_dims = tuple(sorted(rng.choice(4, size=int(rng.integers(1, 5)),
                                           replace=False).tolist()))
        use_ob = bool(rng.random() < 0.6)
        max_ob = float(rng.choice([0.9, 1.0, 1.25, 1.5]))
        fd = np.asarray(fit_dims)
        rigid = np.asarray([r for r in RIGID if r in fit_dims], dtype=int)
        fung = np.asarray([f for f in FUNGIBLE if f in fit_dims], dtype=int)
        eligible, machine_any = packing.machines_with_candidates(
            avail, dem, fd, rigid, fung, max_ob - 1.0, use_ob)
        for mi in range(m):
            fits = packing.fits_mask(avail[mi], dem, dims=fd)
            if use_ob:
                over = (~fits
                        & packing.fits_mask(avail[mi], dem, dims=rigid)
                        & packing.fits_mask(avail[mi], dem, dims=fung,
                                            slack=max_ob - 1.0))
            else:
                over = np.zeros(n, dtype=bool)
            want = fits | over
            np.testing.assert_array_equal(eligible[:, mi], want,
                                          err_msg=f"trial {trial} machine {mi}")
            assert machine_any[mi] == want.any()


def _wave_oracle(matcher, avail, alive, batch):
    """Verbatim pre-shard `sim/cluster.py` match_all loop (one wave)."""
    cfg = matcher.cfg
    fd, rigid, fung = matcher.fit_dim_split()
    eligible, machine_any = packing.machines_with_candidates(
        avail, batch.dem, fd, rigid, fung, cfg.max_overbook - 1.0,
        cfg.use_overbooking)
    active = np.ones(len(batch), dtype=bool)
    n_active = len(batch)
    order = np.argsort(-avail.sum(axis=1))
    ok = (alive[order] & (avail[order] > 1e-9).any(axis=1)
          & machine_any[order])
    started = []
    for m in order[ok].tolist():
        if n_active == 0:
            break
        if not (eligible[:, m] & active).any():
            continue
        idx = np.flatnonzero(active)
        picks = matcher.match_batch(m, avail[m], batch.take(idx))
        for i, _over in picks:
            gi = int(idx[i])
            started.append((gi, m))
            avail[m] -= batch.dem[gi]
            active[gi] = False
        n_active -= len(picks)
    return started


def test_sharded_wave_parity_all_shard_counts():
    """ShardedMatcher.match_wave ≡ the legacy inline wave, for 1/2/4 shards.

    Several consecutive waves against carried-over matcher state (EMA +
    deficits + mutated avail): the sharded wave must produce the same
    (candidate, machine) starts in the same order, leave the global
    matcher in the same state, and keep the merged shard ledgers equal
    to the global deficit counters.
    """
    from repro.core.shard import ShardedMatcher

    for seed in range(8):
        rng = np.random.default_rng(1000 + seed)
        tasks, jobs, cfg, shares, _ = _random_heartbeat(rng)
        batch = _batch_from(tasks, jobs)
        M = int(rng.integers(5, 40))
        avail0 = rng.uniform(0.0, 1.2, (M, 4))
        alive = rng.random(M) < 0.9
        oracle = Matcher(cfg, capacity=float(M), shares=shares)
        o_avail = avail0.copy()
        want = [_wave_oracle(oracle, o_avail, alive, batch)
                for _ in range(3)]
        for n_shards in (1, 2, 4):
            sm = ShardedMatcher(cfg, M, shares, n_shards=n_shards,
                                capacity=float(M))
            s_avail = avail0.copy()
            with sm:
                for wave in range(3):
                    got = []

                    def cb(gi, m):
                        got.append((gi, m))
                        s_avail[m] -= batch.dem[gi]

                    sm.match_wave(s_avail, alive, batch, cb)
                    assert got == want[wave], (seed, n_shards, wave)
            np.testing.assert_array_equal(s_avail, o_avail)
            assert sm.matcher._ema_score == oracle._ema_score
            assert sm.matcher._ema_srpt == oracle._ema_srpt
            assert sm.matcher.deficits.deficit == oracle.deficits.deficit
            merged = sm.merged_deficits()
            for g, v in oracle.deficits.deficit.items():
                assert merged.get(g, 0.0) == pytest.approx(v, abs=1e-9)


def test_taskpool_matches_fresh_rebuild():
    """Incremental TaskPool refresh ≡ rebuilding candidates from scratch."""
    rng = np.random.default_rng(7)
    pool = TaskPool(d=4, expose=4)
    jobs = {}
    for j in range(5):
        n = int(rng.integers(3, 12))
        demand = rng.uniform(0.05, 0.9, (n, 4))
        pri = np.round(rng.uniform(0, 1, n), 1)   # ties likely
        runnable = set(range(n))
        jobs[j] = dict(demand=demand, pri=pri, runnable=runnable,
                       srpt=float(rng.uniform(1, 20)), group=j % 2)
        pool.add_job(j, j % 2, demand, pri, runnable, jobs[j]["srpt"])

    def fresh():
        dem, prs, tids, jids = [], [], [], []
        for j, jd in jobs.items():
            top = sorted(jd["runnable"], key=lambda t: -jd["pri"][t])[:4]
            for t in top:
                dem.append(jd["demand"][t])
                prs.append(float(jd["pri"][t]))
                tids.append(t)
                jids.append(j)
        return dem, prs, tids, jids

    for step in range(40):
        batch = pool.refresh()
        dem, prs, tids, jids = fresh()
        assert batch is not None and len(batch) == len(tids)
        np.testing.assert_array_equal(batch.dem, np.stack(dem))
        np.testing.assert_array_equal(batch.pri, np.array(prs))
        assert batch.tid.tolist() == tids
        assert batch.job.tolist() == jids
        # mutate a random job's runnable set like the simulator would
        j = int(rng.integers(0, 5))
        jd = jobs[j]
        if jd["runnable"] and rng.random() < 0.6:
            victim = sorted(jd["runnable"])[int(rng.integers(0, len(jd["runnable"])))]
            jd["runnable"].discard(victim)
        else:
            jd["runnable"].add(int(rng.integers(0, len(jd["pri"]))))
        pool.mark_dirty(j)
        jd["srpt"] *= 0.9
        pool.set_srpt(j, jd["srpt"])
