import os
import sys

# tests must see 1 CPU device (the dry-run sets its own flags in-process);
# keep any user XLA_FLAGS out of the way.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
