"""Cluster-simulator behaviors: completion, fairness, stragglers, failures."""

import numpy as np
import pytest

from repro.sim import make_workload, run_workload
from repro.sim.cluster import ClusterSim, SimConfig, scheme


def _small_workload(n=6, seed=0):
    return make_workload("production", n, seed=seed)


def test_all_jobs_complete():
    res = run_workload(_small_workload(), "dagps", n_machines=12,
                       interarrival=5.0, seed=1)
    assert len(res.jobs) == 6
    assert res.makespan > 0


def test_dagps_not_worse_than_tez():
    dags = _small_workload(8, seed=3)
    tez = run_workload(dags, "tez", n_machines=10, interarrival=10.0, seed=3)
    dg = run_workload(dags, "dagps", n_machines=10, interarrival=10.0, seed=3)
    assert np.median(dg.jcts()) <= np.median(tez.jcts()) * 1.05


def test_bounded_unfairness_two_queues():
    dags = _small_workload(8, seed=5)
    res = run_workload(dags, "dagps", n_machines=10, interarrival=5.0,
                       n_groups=2, seed=5)
    shares = {0: 1.0, 1: 1.0}
    # long-window fairness approaches 1 (Table 4's pattern)
    j_long = res.jain_index(240.0, shares)
    assert j_long > 0.5
    assert len(res.jobs) == 8


def test_speculation_mitigates_stragglers():
    dags = _small_workload(5, seed=7)
    base = dict(n_machines=10, interarrival=5.0, seed=7,
                straggle_prob=0.08, straggle_factor=(4.0, 8.0))
    slow = run_workload(dags, "dagps", speculate=False, **base)
    fast = run_workload(dags, "dagps", speculate=True, **base)
    assert fast.speculative_launches > 0
    assert np.mean(fast.jcts()) <= np.mean(slow.jcts()) * 1.02


def test_machine_failures_requeue_and_complete():
    dags = _small_workload(5, seed=9)
    res = run_workload(dags, "dagps", n_machines=10, interarrival=5.0, seed=9,
                       failure_rate=1 / 150.0, repair_time=60.0)
    assert len(res.jobs) == 5          # everything still finishes
    assert res.failed_tasks_requeued >= 0


def test_workload_generators_valid():
    for bench in ("production", "tpch", "tpcds", "bigbench", "ehive",
                  "build", "workflow", "mixed"):
        for dag in make_workload(bench, 3, seed=11):
            assert dag.n > 0
            assert (dag.demand <= 0.9 + 1e-9).all()
            assert (dag.duration > 0).all()
            # topological order by construction
            for i in range(dag.n):
                assert all(p < i for p in dag.parents[i])


def test_ckpt_roundtrip(tmp_path):
    import jax, jax.numpy as jnp
    from repro import configs
    from repro.ckpt import restore, save, latest_step
    from repro.models import model as M
    cfg = configs.get_smoke("granite3_8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    save(str(tmp_path), 7, params, extra={"arch": "granite"})
    assert latest_step(str(tmp_path)) == 7
    step, tree = restore(str(tmp_path))
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(tree["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pipeline_schedule_quality():
    from repro.train import (gpipe_makespan, ideal_makespan,
                             one_f_one_b_makespan, schedule_pipeline)
    plan = schedule_pipeline(4, 8, 1.0)
    gp = gpipe_makespan(4, 8, 1.0)
    fb = one_f_one_b_makespan(4, 8, 1.0)
    # DAGPS discovers a schedule within quantization fuzz of 1F1B's optimum
    assert plan.makespan <= fb * 1.06
    assert plan.makespan <= gp * 1.06
    assert sorted(plan.microbatch_order) == list(range(8))
    # order is a valid topological execution (validated inside build)
