"""Cluster-simulator behaviors: completion, fairness, stragglers, failures."""

import json
import os

import numpy as np
import pytest

from repro.sim import make_workload, run_workload
from repro.sim.cluster import ClusterSim, SimConfig, scheme

GOLDEN = os.path.join(os.path.dirname(__file__), "data", "golden_sim.json")


def _small_workload(n=6, seed=0):
    return make_workload("production", n, seed=seed)


def test_all_jobs_complete():
    res = run_workload(_small_workload(), "dagps", n_machines=12,
                       interarrival=5.0, seed=1)
    assert len(res.jobs) == 6
    assert res.makespan > 0


def test_dagps_not_worse_than_tez():
    dags = _small_workload(8, seed=3)
    tez = run_workload(dags, "tez", n_machines=10, interarrival=10.0, seed=3)
    dg = run_workload(dags, "dagps", n_machines=10, interarrival=10.0, seed=3)
    assert np.median(dg.jcts()) <= np.median(tez.jcts()) * 1.05


def test_bounded_unfairness_two_queues():
    dags = _small_workload(8, seed=5)
    res = run_workload(dags, "dagps", n_machines=10, interarrival=5.0,
                       n_groups=2, seed=5)
    shares = {0: 1.0, 1: 1.0}
    # long-window fairness approaches 1 (Table 4's pattern)
    j_long = res.jain_index(240.0, shares)
    assert j_long > 0.5
    assert len(res.jobs) == 8


def test_speculation_mitigates_stragglers():
    dags = _small_workload(5, seed=7)
    base = dict(n_machines=10, interarrival=5.0, seed=7,
                straggle_prob=0.08, straggle_factor=(4.0, 8.0))
    slow = run_workload(dags, "dagps", speculate=False, **base)
    fast = run_workload(dags, "dagps", speculate=True, **base)
    assert fast.speculative_launches > 0
    assert np.mean(fast.jcts()) <= np.mean(slow.jcts()) * 1.02


def test_machine_failures_requeue_and_complete():
    dags = _small_workload(5, seed=9)
    res = run_workload(dags, "dagps", n_machines=10, interarrival=5.0, seed=9,
                       failure_rate=1 / 150.0, repair_time=60.0)
    assert len(res.jobs) == 5          # everything still finishes
    assert res.failed_tasks_requeued >= 0


@pytest.mark.parametrize("name,bench,n,sch,kw", [
    ("tpch_tez", "tpch", 8, "tez",
     dict(n_machines=10, interarrival=8.0, seed=3)),
    ("prod_tetris", "production", 6, "tez+tetris",
     dict(n_machines=10, interarrival=6.0, seed=5)),
    ("tpcds_drf", "tpcds", 6, "tez+drf",
     dict(n_machines=8, interarrival=10.0, seed=21, n_groups=2)),
    ("prod_churn", "production", 5, "dagps",
     dict(n_machines=10, interarrival=5.0, seed=9, failure_rate=1 / 150.0,
          repair_time=60.0, straggle_prob=0.08, straggle_factor=(4.0, 8.0))),
])
def test_golden_bit_identical(name, bench, n, sch, kw):
    """The vectorized online path reproduces pre-refactor outputs exactly.

    tests/data/golden_sim.json holds full-precision (repr) JCT / makespan /
    Jain values captured from the object-list simulator before the SoA
    task-pool refactor; any drift in matching decisions, event ordering, or
    rng consumption shows up here as a bit-level mismatch.
    """
    golden = json.load(open(GOLDEN))[name]
    dags = make_workload(bench, n, seed=kw["seed"])
    res = run_workload(dags, sch, **kw)
    assert {str(j.job_id): repr(j.jct) for j in res.jobs} == golden["jcts"]
    assert repr(res.makespan) == golden["makespan"]
    assert repr(res.jain_index(60.0, {0: 1.0, 1: 1.0})) == golden["jain_60"]
    assert res.speculative_launches == golden["spec"]
    assert res.failed_tasks_requeued == golden["requeued"]


def test_profile_phase_times():
    from repro.core import FaultPlan

    # the empty plan pins a fault-free run even under an ambient
    # REPRO_FAULTS smoke plan (CI), where recovery would be nonzero
    dags = make_workload("tpch", 3, seed=2)
    res = run_workload(dags, "dagps", n_machines=8, interarrival=5.0, seed=2,
                       profile=True, fault_plan=FaultPlan())
    pt = res.phase_times
    assert pt is not None
    assert set(pt) == {"build", "match", "event", "total", "heartbeat",
                       "recovery"}
    # no faults injected -> no recovery time spent
    assert pt["recovery"] == 0.0
    assert pt["total"] >= pt["build"] + pt["match"] - 1e-6
    # the heartbeat kernel runs inside the match phase
    assert pt["heartbeat"] <= pt["match"] + 1e-6
    assert all(v >= 0.0 for v in pt.values())
    # profiling must not perturb outputs
    plain = run_workload(dags, "dagps", n_machines=8, interarrival=5.0, seed=2,
                         fault_plan=FaultPlan())
    assert plain.phase_times is None
    np.testing.assert_array_equal(plain.jcts(), res.jcts())


def test_zero_task_job_does_not_hang_failure_loop():
    """A zero-task DAG is born complete: it must not keep the failure
    process rescheduling forever (regression for the work-remaining
    counters that replaced the per-event job scan)."""
    from repro.core.dag import DAG

    empty = DAG(duration=np.empty(0), demand=np.empty((0, 4)),
                stage_of=np.empty(0, int), parents=[])
    res = run_workload([empty], "tez", n_machines=4, interarrival=1.0,
                       seed=0, failure_rate=0.5, repair_time=5.0)
    assert res.jobs == [] and res.makespan == 0.0
    # mixed with a real job everything still completes
    dags = [empty] + make_workload("tpch", 2, seed=4)
    res = run_workload(dags, "tez", n_machines=6, interarrival=1.0, seed=4,
                       failure_rate=1 / 50.0, repair_time=10.0)
    assert len(res.jobs) == 2


def test_no_restart_of_done_tasks_under_churn(monkeypatch):
    """A task requeued by a machine failure whose speculative copy then
    finishes must leave the pool's cached exposure — the matcher may never
    start a task that is already done (regression for a stale-exposure bug
    in the incremental TaskPool dirty marking)."""
    from repro.sim import cluster as C

    orig = C._Job.task_started

    def checked(self, t):
        assert t not in self.done, f"done task {t} restarted"
        orig(self, t)

    monkeypatch.setattr(C._Job, "task_started", checked)
    dags = make_workload("production", 4, seed=13)
    # seeds 3/12/13/20 deterministically hit the failure->speculative-finish
    # race under these churn parameters (verified against the buggy variant)
    for seed in (3, 12, 13, 20):
        res = run_workload(dags, "tez+tetris", n_machines=6, interarrival=3.0,
                           seed=seed, failure_rate=1 / 10.0, repair_time=8.0,
                           straggle_prob=0.5, straggle_factor=(5.0, 12.0),
                           speculate=True, spec_threshold=1.1)
        assert len(res.jobs) == 4


def test_workload_generators_valid():
    for bench in ("production", "tpch", "tpcds", "bigbench", "ehive",
                  "build", "workflow", "mixed"):
        for dag in make_workload(bench, 3, seed=11):
            assert dag.n > 0
            assert (dag.demand <= 0.9 + 1e-9).all()
            assert (dag.duration > 0).all()
            # topological order by construction
            for i in range(dag.n):
                assert all(p < i for p in dag.parents[i])


def test_ckpt_roundtrip(tmp_path):
    import jax, jax.numpy as jnp
    from repro import configs
    from repro.ckpt import restore, save, latest_step
    from repro.models import model as M
    cfg = configs.get_smoke("granite3_8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    save(str(tmp_path), 7, params, extra={"arch": "granite"})
    assert latest_step(str(tmp_path)) == 7
    step, tree = restore(str(tmp_path))
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(tree["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------------------
# heartbeat-loss semantics (core/faults.py "heartbeat" seam — the lossy one)
# ----------------------------------------------------------------------

def _decisions(res):
    return ([(j.job_id, repr(j.jct)) for j in
             sorted(res.jobs, key=lambda j: j.job_id)],
            repr(res.makespan))


def test_heartbeat_healthy_decision_parity():
    """Enabling heartbeats without any faults must not perturb a single
    decision: beats ride their own event lane and consume no workload
    rng, so healthy-hb == no-hb bit-for-bit."""
    from repro.core import FaultPlan

    dags = _small_workload(5, seed=17)
    base = dict(n_machines=8, interarrival=6.0, seed=17,
                fault_plan=FaultPlan())
    plain = run_workload(dags, "dagps", **base)
    hb = run_workload(dags, "dagps", heartbeat_period=7.3, **base)
    assert _decisions(hb) == _decisions(plain)
    stats = hb.fault_stats["heartbeat"]
    assert stats["beats"] > 0
    assert stats["suspects"] == stats["losses"] == 0
    assert plain.fault_stats["heartbeat"]["beats"] == 0


def test_heartbeat_loss_requeues_and_completes():
    """One machine's beats all drop: it is suspected, declared lost, its
    tasks requeue, and the workload completes on the survivors."""
    res = run_workload(_small_workload(5, seed=19), "dagps", n_machines=8,
                       interarrival=6.0, seed=19, heartbeat_period=5.0,
                       fault_plan="seed=2;heartbeat:drop@1.0,machine=2")
    assert len(res.jobs) == 5
    stats = res.fault_stats["heartbeat"]
    assert stats["dropped"] > 0
    assert stats["suspects"] >= 1
    assert stats["losses"] >= 1
    assert res.fault_stats["injections"].get("heartbeat.drop", 0) > 0


def test_heartbeat_full_partition_terminates():
    """Every machine silent forever: the sticky forced-rejoin valve (the
    operator-intervention analogue) must keep the sim terminating with
    every job complete instead of livelocking on requeues."""
    res = run_workload(_small_workload(4, seed=23), "dagps", n_machines=4,
                       interarrival=4.0, seed=23, heartbeat_period=5.0,
                       fault_plan="seed=5;heartbeat:drop@1.0")
    assert len(res.jobs) == 4
    assert res.fault_stats["heartbeat"]["forced_rejoins"] >= 1


def test_heartbeat_delay_below_suspicion_is_harmless():
    """Delayed (but delivered) beats below the suspicion threshold must
    not suspect or lose anyone."""
    res = run_workload(_small_workload(4, seed=29), "dagps", n_machines=6,
                       interarrival=5.0, seed=29, heartbeat_period=5.0,
                       fault_plan="seed=3;heartbeat:delay@0.5,delay=2.0")
    assert len(res.jobs) == 4
    stats = res.fault_stats["heartbeat"]
    assert stats["delayed"] > 0
    assert stats["suspects"] == stats["losses"] == 0


def test_pipeline_schedule_quality():
    from repro.train import (gpipe_makespan, ideal_makespan,
                             one_f_one_b_makespan, schedule_pipeline)
    plan = schedule_pipeline(4, 8, 1.0)
    gp = gpipe_makespan(4, 8, 1.0)
    fb = one_f_one_b_makespan(4, 8, 1.0)
    # DAGPS discovers a schedule within quantization fuzz of 1F1B's optimum
    assert plan.makespan <= fb * 1.06
    assert plan.makespan <= gp * 1.06
    assert sorted(plan.microbatch_order) == list(range(8))
    # order is a valid topological execution (validated inside build)
