"""Sharded online matcher (core/shard.py): routing, handoff, parity.

Three layers of coverage:

  * structural properties of the machine partition and exposure routing,
  * deficit-handoff algebra against the single-shard oracle — seeded
    deterministic versions always run; hypothesis versions ride along
    when the plugin is installed (repo convention, see test_property.py),
  * end-to-end shard-count invariance of simulator decisions (the
    acceptance bar: 1 vs 2 vs 4 shards bit-identical JCT/makespan),
    including under churn and with the accelerated eligibility kernels
    force-promoted at every machine count.
"""

import numpy as np
import pytest

from repro.core.engine import kernels, packing
from repro.core.online import (
    CandidateBatch,
    DeficitCounters,
    Matcher,
    MatcherConfig,
)
from repro.core.shard import (
    ShardPlan,
    ShardedMatcher,
    auto_shards,
    route_exposure,
)
from repro.sim.cluster import run_workload
from repro.sim.workload import online_mix_workload


def _random_batch(rng, n_jobs=6, per_job=(1, 9), d=4):
    """CandidateBatch with contiguous per-job runs, TaskPool-style."""
    dem, pri, srpt, grp, job, tid = [], [], [], [], [], []
    for j in range(n_jobs):
        r = int(rng.integers(*per_job))
        dem.append(rng.uniform(0.05, 0.45, size=(r, d)))
        pri.append(rng.uniform(0.1, 1.0, size=r))
        srpt.append(np.full(r, float(rng.uniform(1.0, 50.0))))
        grp.append(np.full(r, int(rng.integers(0, 3)), dtype=np.int64))
        job.append(np.full(r, j, dtype=np.int64))
        tid.append(np.arange(r, dtype=np.int64))
    n = sum(len(p) for p in pri)
    return CandidateBatch(
        dem=np.concatenate(dem), pri=np.concatenate(pri),
        srpt=np.concatenate(srpt), grp=np.concatenate(grp),
        loc=np.full(n, -1, dtype=np.int64), job=np.concatenate(job),
        tid=np.concatenate(tid))


# ----------------------------------------------------------------------
# partition + routing structure
# ----------------------------------------------------------------------

def test_shard_plan_partitions_machines():
    for m, n in [(1, 1), (7, 3), (64, 4), (100, 7), (5, 9)]:
        plan = ShardPlan(m, n)
        assert plan.n_shards == min(n, m)
        assert int(plan.sizes.sum()) == m
        assert plan.sizes.max() - plan.sizes.min() <= 1
        # slices tile [0, m) and shard_of agrees with them
        seen = []
        for s, sl in enumerate(plan.slices()):
            seen.extend(range(sl.start, sl.stop))
            for mm in (sl.start, sl.stop - 1):
                assert plan.shard_of(mm) == s
        assert seen == list(range(m))
        assert np.isclose(plan.fracs.sum(), 1.0)


def test_auto_shards_scales_with_machine_count(monkeypatch):
    monkeypatch.setenv("REPRO_SHARD_MACHINES", "2048")
    assert auto_shards(64) == 1
    assert auto_shards(2048) == 1
    assert auto_shards(2049) == 2
    assert auto_shards(10240) == 5
    monkeypatch.setenv("REPRO_SHARD_MACHINES", "512")
    assert auto_shards(2048) == 4


def test_route_exposure_partitions_proportionally():
    rng = np.random.default_rng(7)
    for trial in range(20):
        batch = _random_batch(rng, n_jobs=int(rng.integers(1, 8)))
        plan = ShardPlan(int(rng.integers(4, 65)), int(rng.integers(1, 5)))
        routed = route_exposure(batch, plan)
        assert len(routed) == plan.n_shards
        # exact partition of all rows
        allr = np.concatenate(routed)
        assert sorted(allr.tolist()) == list(range(len(batch)))
        # within each shard, candidate order is preserved
        for r in routed:
            assert (np.diff(r) > 0).all() if len(r) > 1 else True
        # per-job quotas match largest-remainder proportionality
        for j in np.unique(batch.job):
            r = int((batch.job == j).sum())
            counts = np.array([int((batch.job[ri] == j).sum())
                               for ri in routed])
            assert counts.sum() == r
            exact = plan.fracs * r
            # floor quota respected, and each shard within 1 of exact
            assert (counts >= np.floor(exact).astype(int)).all()
            assert (np.abs(counts - exact) < 1.0 + 1e-9).all()


def test_route_exposure_spanning_job_slices_every_shard():
    # one big job across 4 equal shards: every shard gets exactly 1/4
    rng = np.random.default_rng(3)
    batch = _random_batch(rng, n_jobs=1, per_job=(16, 17))
    plan = ShardPlan(64, 4)
    routed = route_exposure(batch, plan)
    assert [len(r) for r in routed] == [4, 4, 4, 4]


# ----------------------------------------------------------------------
# deficit handoff vs the single-shard oracle
# ----------------------------------------------------------------------

def _mk_sharded(n_machines, n_shards, shares, kappa=0.1):
    cfg = MatcherConfig(kappa=kappa)
    return ShardedMatcher(cfg, n_machines, shares, n_shards=n_shards)


def _merged_trace_case(seed, n_shards, n_groups, n_steps, handoff_every):
    """Route one allocation trace to shards; merged must track the oracle."""
    rng = np.random.default_rng(seed)
    shares = {g: float(rng.uniform(0.5, 2.0)) for g in range(n_groups)}
    C = 40.0
    sm = _mk_sharded(40, n_shards, shares)
    oracle = DeficitCounters(shares, capacity=C, kappa=sm.cfg.kappa)
    for step in range(n_steps):
        g = int(rng.integers(n_groups))
        w = float(rng.uniform(0.1, 1.5))
        s = int(rng.integers(sm.plan.n_shards))
        sm.shard_matchers[s].deficits.allocated(g, w)
        oracle.allocated(g, w)
        if handoff_every and step % handoff_every == 0:
            before = sm.merged_deficits()
            sm.deficit_handoff()
            after = sm.merged_deficits()
            # handoff redistributes, never creates/destroys deficit
            for g2 in shares:
                assert after[g2] == pytest.approx(before[g2], abs=1e-9)
            # post-handoff: shard ledgers are capacity-proportional slices
            for shard, frac in zip(sm.shard_matchers, sm.plan.fracs):
                for g2, v in shard.deficits.deficit.items():
                    assert v == pytest.approx(after[g2] * frac, abs=1e-9)
        merged = sm.merged_deficits()
        for g2 in shares:
            assert merged[g2] == pytest.approx(oracle.deficit[g2], abs=1e-8)
    # trigger equivalence at handoff points: each shard's local must_serve
    # agrees with the global counter once ledgers are rebalanced
    sm.deficit_handoff()
    oracle_d = {g: sm.merged_deficits()[g] for g in shares}
    glob = DeficitCounters(shares, capacity=C, kappa=sm.cfg.kappa)
    glob.deficit.update(oracle_d)
    for shard in sm.shard_matchers:
        assert shard.deficits.must_serve() == glob.must_serve()


def test_merged_deficits_track_single_shard_oracle():
    for seed in range(12):
        rng = np.random.default_rng(100 + seed)
        _merged_trace_case(seed, n_shards=int(rng.integers(1, 5)),
                           n_groups=int(rng.integers(1, 5)),
                           n_steps=60, handoff_every=int(rng.integers(0, 9)))


def test_sharded_bound_with_enforcement():
    """Serve-on-trigger keeps merged deficits within the composed bound.

    Single-shard bound (test_property.py): kappa*C + one allocation
    quantum.  Across N shards with per-wave handoff, local views go
    stale by at most one wave of allocations, so the composition slack
    is (N * allocs_per_wave + 1) * w_max on top of kappa*C.
    """
    for seed in range(8):
        rng = np.random.default_rng(500 + seed)
        n_shards = int(rng.integers(1, 5))
        n_groups = int(rng.integers(2, 5))
        shares = {g: 1.0 for g in range(n_groups)}
        kappa, C, w_max, per_wave = 0.1, 40.0, 0.8, 2
        sm = _mk_sharded(40, n_shards, shares, kappa=kappa)
        peak = 0.0
        for _wave in range(80):
            for s, shard in enumerate(sm.shard_matchers):
                for _ in range(per_wave):
                    g = shard.deficits.must_serve()
                    if g is None:
                        g = int(rng.integers(n_groups))
                    shard.deficits.allocated(g, float(rng.uniform(0.1, w_max)))
                    peak = max(peak, max(sm.merged_deficits().values()))
            sm.deficit_handoff()
        slack = (n_shards * per_wave + 1) * w_max
        assert peak <= kappa * C + slack + 1e-9


def test_handoff_nets_out_opposite_sign_deficits():
    # shard A over-serves group 0, shard B under-serves it: merged is 0,
    # so after handoff neither shard spuriously fires must_serve
    shares = {0: 1.0, 1: 1.0}
    sm = _mk_sharded(20, 2, shares, kappa=0.05)
    a, b = (m.deficits for m in sm.shard_matchers)
    for _ in range(40):
        a.allocated(0, 1.0)   # A serves only group 0 -> deficit[1] grows on A
        b.allocated(1, 1.0)   # B serves only group 1 -> deficit[0] grows on B
    assert a.must_serve() is not None and b.must_serve() is not None
    sm.deficit_handoff()
    merged = sm.merged_deficits()
    assert all(abs(v) < 1e-9 for v in merged.values())
    assert all(m.deficits.must_serve() is None for m in sm.shard_matchers)


# hypothesis variants (skip cleanly when the plugin is absent) ----------

try:
    from hypothesis import given, settings, strategies as st
    _HYP = True
except ImportError:          # pragma: no cover - plugin-less envs
    _HYP = False

if _HYP:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 4), st.integers(1, 4),
           st.integers(0, 8))
    def test_hypothesis_merged_deficits_track_oracle(seed, n_shards,
                                                     n_groups, handoff_every):
        _merged_trace_case(seed, n_shards, n_groups, n_steps=40,
                           handoff_every=handoff_every)


# ----------------------------------------------------------------------
# eligibility fan-out
# ----------------------------------------------------------------------

def test_sharded_eligibility_equals_global_launch():
    rng = np.random.default_rng(11)
    for n_shards in (1, 2, 4):
        batch = _random_batch(rng, n_jobs=5)
        avail = rng.uniform(0.0, 1.0, size=(37, 4))
        avail[:10] *= 0.05
        sm = _mk_sharded(37, n_shards, {0: 1.0, 1: 1.0, 2: 1.0})
        with sm:
            elig, any_ = sm.eligibility(avail, batch.dem)
        fd, rigid, fung = sm.matcher.fit_dim_split()
        ref_e, ref_a = packing.machines_with_candidates(
            avail, batch.dem, fd, rigid, fung,
            sm.cfg.max_overbook - 1.0, sm.cfg.use_overbooking)
        assert (elig == ref_e).all()
        assert (any_ == ref_a).all()


@pytest.mark.skipif(not kernels.have_jax(), reason="needs jax")
def test_sharded_eligibility_superset_under_forced_xla(monkeypatch):
    # promote the accelerated impls at every machine count: the sharded
    # launch must stay a sound superset of the exact oracle per column
    monkeypatch.setenv(kernels.HEARTBEAT_MIN_M_ENV, "1")
    rng = np.random.default_rng(13)
    batch = _random_batch(rng, n_jobs=5)
    avail = rng.uniform(0.0, 1.0, size=(48, 4))
    sm = _mk_sharded(48, 3, {0: 1.0, 1: 1.0, 2: 1.0})
    with sm:
        elig, any_ = sm.eligibility(avail, batch.dem)
    fd, rigid, fung = sm.matcher.fit_dim_split()
    ref_e, ref_a = packing.machines_with_candidates(
        avail, batch.dem, fd, rigid, fung,
        sm.cfg.max_overbook - 1.0, sm.cfg.use_overbooking)
    assert not (ref_e & ~elig).any()       # superset of exact eligibility
    assert not (ref_a & ~any_).any()


# ----------------------------------------------------------------------
# kernel auto-promotion (satellite: PR 4 follow-up)
# ----------------------------------------------------------------------

@pytest.mark.skipif(not kernels.have_jax(), reason="needs jax")
def test_heartbeat_auto_promotes_above_threshold(monkeypatch):
    monkeypatch.delenv(kernels.KERNELS_ENV, raising=False)
    monkeypatch.setenv(kernels.HEARTBEAT_MIN_M_ENV, "64")
    for op in kernels.HEARTBEAT_AUTO_OPS:
        assert kernels.heartbeat_impl(op, 63) == "numpy"
        assert kernels.heartbeat_impl(op, 64) == "xla"
        assert kernels.heartbeat_impl(op, 10240) == "xla"


@pytest.mark.skipif(not kernels.have_jax(), reason="needs jax")
def test_heartbeat_env_pin_beats_auto_promotion(monkeypatch):
    monkeypatch.setenv(kernels.HEARTBEAT_MIN_M_ENV, "1")
    monkeypatch.setenv(kernels.KERNELS_ENV,
                       "machines_with_candidates=numpy")
    assert kernels.heartbeat_impl("machines_with_candidates", 10240) == "numpy"
    # un-pinned op still auto-promotes
    assert kernels.heartbeat_impl("heartbeat_masks", 10240) == "xla"


@pytest.mark.skipif(not kernels.have_jax(), reason="needs jax")
def test_heartbeat_dispatch_profiles_promoted_impl(monkeypatch):
    monkeypatch.setenv(kernels.HEARTBEAT_MIN_M_ENV, "8")
    kernels.reset_profile()
    rng = np.random.default_rng(0)
    avail = rng.uniform(0.2, 1.0, size=(16, 4))
    dem = rng.uniform(0.05, 0.3, size=(5, 4))
    fd = np.arange(4)
    kernels.machines_with_candidates(avail, dem, fd, np.array([0, 1]),
                                     np.array([2, 3]), 0.25, True)
    kernels.machines_with_candidates(avail[:4], dem, fd, np.array([0, 1]),
                                     np.array([2, 3]), 0.25, True)
    prof = kernels.profile_snapshot()
    assert prof["machines_with_candidates.xla"][0] == 1
    assert prof["machines_with_candidates.numpy"][0] == 1


def test_active_reports_small_m_selection(monkeypatch):
    monkeypatch.delenv(kernels.KERNELS_ENV, raising=False)
    # the m-agnostic view stays the exact oracle for the heartbeat ops
    assert kernels.active()["machines_with_candidates"] == "numpy"
    assert kernels.active()["heartbeat_masks"] == "numpy"


# ----------------------------------------------------------------------
# end-to-end shard-count invariance (acceptance bar)
# ----------------------------------------------------------------------

def _decision_key(res):
    return ([(j.job_id, repr(j.jct)) for j in
             sorted(res.jobs, key=lambda j: j.job_id)],
            repr(res.makespan))


def test_sim_decisions_invariant_across_shard_counts():
    dags = online_mix_workload(10, seed=4)
    keys = {}
    for shards in (1, 2, 4):
        res = run_workload(dags, "dagps", n_machines=64, interarrival=1.0,
                           n_groups=3, seed=4, build_machines=4,
                           matcher_shards=shards)
        assert res.shard_stats["n_shards"] == shards
        keys[shards] = _decision_key(res)
    assert keys[1] == keys[2] == keys[4]


def test_sim_decisions_invariant_under_churn():
    # failures + stragglers + speculation exercise the _JOIN single-machine
    # rematch path and the requeue bookkeeping under sharding
    dags = online_mix_workload(8, seed=9)
    keys = {}
    for shards in (1, 3):
        res = run_workload(dags, "dagps", n_machines=48, interarrival=2.0,
                           n_groups=2, seed=9, build_machines=4,
                           matcher_shards=shards, straggle_prob=0.1,
                           failure_rate=0.002, repair_time=30.0)
        keys[shards] = _decision_key(res)
    assert keys[1] == keys[3]


def test_sim_decisions_invariant_under_churn_with_faults():
    """Churn (failures + stragglers) composed with an exact-recoverable
    fault plan (core/faults.py): retries, timeouts and quarantine masks
    must reproduce the fault-free decisions bit-for-bit."""
    from repro.core import FaultPlan, RecoveryPolicy

    dags = online_mix_workload(8, seed=9)
    kw = dict(n_machines=48, interarrival=2.0, n_groups=2, seed=9,
              build_machines=4, matcher_shards=3, straggle_prob=0.1,
              failure_rate=0.002, repair_time=30.0)
    base = run_workload(dags, "dagps", fault_plan=FaultPlan(), **kw)
    plan = FaultPlan.parse("seed=13;shard_launch:raise@0.4;"
                           "shard_launch:hang@0.1,delay=0.005")
    rec = RecoveryPolicy(launch_timeout=5.0, launch_retries=1, backoff=0.001,
                         backoff_cap=0.002, quarantine_after=2, probe_every=4)
    faulty = run_workload(dags, "dagps", fault_plan=plan, recovery=rec, **kw)
    assert _decision_key(base) == _decision_key(faulty)
    assert faulty.fault_stats["injections"]
    shard = faulty.fault_stats["shard"]
    assert shard["launch_retries"] + shard["quarantined_launches"] > 0


@pytest.mark.skipif(not kernels.have_jax(), reason="needs jax")
def test_sim_decisions_exact_under_kernel_faults(monkeypatch):
    """An injected accelerated-kernel failure demotes dispatch to the
    numpy oracle mid-run without changing a single decision."""
    from repro.core import FaultPlan

    monkeypatch.setenv(kernels.HEARTBEAT_MIN_M_ENV, "1")
    kernels.reset_demotions()
    try:
        dags = online_mix_workload(6, seed=2)
        kw = dict(n_machines=32, interarrival=1.5, n_groups=2, seed=2,
                  build_machines=4, matcher_shards=2)
        base = run_workload(dags, "dagps", fault_plan=FaultPlan(), **kw)
        faulty = run_workload(dags, "dagps",
                              fault_plan="seed=4;kernel_impl:raise@1,count=1",
                              **kw)
        assert _decision_key(base) == _decision_key(faulty)
        assert faulty.fault_stats["kernel_demotions"]
    finally:
        kernels.reset_demotions()


@pytest.mark.skipif(not kernels.have_jax(), reason="needs jax")
def test_sim_decisions_invariant_under_forced_xla(monkeypatch):
    # sound-superset eligibility end-to-end: promoting the accelerated
    # kernels at every machine count must not change a single decision
    dags = online_mix_workload(6, seed=2)
    base = run_workload(dags, "dagps", n_machines=32, interarrival=1.5,
                        n_groups=2, seed=2, build_machines=4,
                        matcher_shards=2)
    monkeypatch.setenv(kernels.HEARTBEAT_MIN_M_ENV, "1")
    forced = run_workload(dags, "dagps", n_machines=32, interarrival=1.5,
                          n_groups=2, seed=2, build_machines=4,
                          matcher_shards=2)
    assert _decision_key(base) == _decision_key(forced)


def test_routed_wave_starts_valid_disjoint_tasks():
    # distributed mode smoke: picks are disjoint rows, machines stay in
    # the owning shard, avail never goes rigid-negative
    rng = np.random.default_rng(21)
    batch = _random_batch(rng, n_jobs=8, per_job=(2, 7))
    avail = rng.uniform(0.3, 1.0, size=(40, 4))
    alive = np.ones(40, dtype=bool)
    sm = _mk_sharded(40, 4, {g: 1.0 for g in range(3)})
    started = []

    def cb(row, machine):
        started.append((row, machine))
        avail[machine] -= batch.dem[row]
        np.clip(avail[machine], 0.0, None, out=avail[machine])

    with sm:
        n = sm.match_wave_routed(avail, alive, batch, cb)
    assert n == len(started) > 0
    rows = [r for r, _m in started]
    assert len(rows) == len(set(rows))
    routed = route_exposure(batch, sm.plan)
    for row, machine in started:
        assert sm.plan.shard_of(machine) == next(
            s for s, ri in enumerate(routed) if row in ri)
    assert sm.handoffs == 1
