"""Parity suite for the memoized schedule builder.

The cross-candidate construction memo (core/memo.py) and every search
reduction around it (prefix-tree order variants, tick-LB stop, chain-bound
subtree skips) must be invisible in the output: a memoized build is
bit-identical to a no-memo build, on every backend, on every DAG of the
engine-parity corpus.  A committed golden file additionally pins the
full-precision start/machine arrays of a fixed corpus, so a regression
that changes *both* modes the same way still gets caught.

Regenerate the golden after an intentional semantic change with:

    PYTHONPATH=src python tests/test_builder_parity.py --regen
"""

import json
import os

import numpy as np
import pytest

from repro.core import build_schedule
from repro.core.engine import JitBackend
from repro.core.memo import COUNTERS
from repro.sim.workload import production_dag, query_dag

GOLDEN = os.path.join(os.path.dirname(__file__), "data", "golden_builder.json")


def _corpus():
    """The engine-parity corpus: every production + TPC-DS DAG that
    tests/test_engine.py checks for backend parity."""
    out = []
    for seed in range(20):
        dag = production_dag(np.random.default_rng(seed), scale=0.35, share=3)
        out.append((f"production-{seed}", dag, 3, 96))
    for seed in range(4):
        dag = query_dag(np.random.default_rng(seed), preset="tpcds")
        out.append((f"tpcds-{seed}", dag, 4, 128))
    return out


def _assert_same(a, b, ctx):
    assert a.makespan == b.makespan, f"makespan differs {ctx}"
    assert np.array_equal(a.start, b.start), f"starts differ {ctx}"
    assert np.array_equal(a.machine, b.machine), f"machines differ {ctx}"
    assert np.array_equal(a.order, b.order), f"order differs {ctx}"


class TestMemoParity:
    def test_memoized_equals_plain_full_corpus(self):
        """Every corpus DAG: memo on == memo off, bit for bit (default
        backend), and the memo actually did something."""
        before = COUNTERS["places_memoized"]
        for name, dag, m, ticks in _corpus():
            memo = build_schedule(dag, m, ticks=ticks, memoize=True)
            plain = build_schedule(dag, m, ticks=ticks, memoize=False)
            _assert_same(memo, plain, f"({name})")
        assert COUNTERS["places_memoized"] > before, \
            "memo never hit on the whole corpus — the lever is dead"

    def test_memoized_equals_plain_all_backends(self):
        """Memo/no-memo parity holds per backend AND across backends."""
        backends = ["reference", "batched"]
        if JitBackend.available():
            backends.append("jit")
        for name, dag, m, ticks in _corpus()[:3] + _corpus()[-2:]:
            builds = {}
            for be in backends:
                memo = build_schedule(dag, m, ticks=ticks, backend=be,
                                      memoize=True)
                plain = build_schedule(dag, m, ticks=ticks, backend=be,
                                       memoize=False)
                _assert_same(memo, plain, f"({name}, backend={be})")
                builds[be] = memo
            for be in backends[1:]:
                _assert_same(builds[backends[0]], builds[be],
                             f"({name}, {backends[0]} vs {be})")

    def test_env_var_disables_memo(self, monkeypatch):
        from repro.core import builder
        monkeypatch.setenv(builder.MEMO_ENV, "0")
        assert builder._memo_enabled(None) is False
        monkeypatch.setenv(builder.MEMO_ENV, "1")
        assert builder._memo_enabled(None) is True
        assert builder._memo_enabled(False) is False  # explicit arg wins


class TestBuildServiceParity:
    """The concurrent build service (core/buildsvc.py) must be invisible
    in the output: pooled + deduplicated construction is bit-identical to
    a serial build_schedule loop, across backends and memo modes."""

    def test_build_many_equals_serial_loop(self):
        from repro.core.buildsvc import BuildService

        backends = ["reference", "batched"]
        if JitBackend.available():
            backends.append("jit")
        corpus = _corpus()[:2] + _corpus()[-1:]
        for be in backends:
            for memoize in (True, False):
                serial = [build_schedule(dag, m, ticks=ticks, backend=be,
                                         memoize=memoize)
                          for _n, dag, m, ticks in corpus]
                with BuildService(workers=2, mode="thread") as svc:
                    handles = [svc.submit(dag, m, ticks=ticks, backend=be,
                                          memoize=memoize)
                               for _n, dag, m, ticks in corpus]
                    pooled = [h.result() for h in handles]
                for (name, *_), s, p in zip(corpus, serial, pooled):
                    _assert_same(s, p,
                                 f"({name}, backend={be}, memo={memoize})")

    def test_process_mode_equals_serial_loop(self):
        """Process workers rebuild the Schedule from the slim wire tuple —
        diff it against the in-process build bit for bit."""
        from repro.core.buildsvc import build_many

        corpus = _corpus()[:3]
        dags = [dag for _n, dag, _m, _t in corpus]
        serial = [build_schedule(dag, 3, ticks=96) for dag in dags]
        pooled = build_many(dags, 3, workers=2, mode="process", ticks=96)
        for (name, *_), s, p in zip(corpus, serial, pooled):
            _assert_same(s, p, f"({name}, mode=process)")


def _golden_corpus():
    """Smaller fixed corpus for the committed golden arrays."""
    out = []
    for seed in (0, 3, 7, 11):
        dag = production_dag(np.random.default_rng(seed), scale=0.35, share=3)
        out.append((f"production-{seed}", dag, 3, 96))
    for seed in (0, 2):
        dag = query_dag(np.random.default_rng(seed), preset="tpcds")
        out.append((f"tpcds-{seed}", dag, 4, 128))
    return out


def _build_golden():
    entries = []
    for name, dag, m, ticks in _golden_corpus():
        s = build_schedule(dag, m, ticks=ticks)
        entries.append({
            "name": name, "m": m, "ticks": ticks, "n": int(dag.n),
            # full precision: json round-trips python floats exactly
            "tick": s.tick,
            "start": [float(x) for x in s.start],
            "machine": [int(x) for x in s.machine],
        })
    return {"entries": entries}


class TestGoldenBuilder:
    def test_matches_committed_golden(self):
        """Start/machine arrays equal the committed full-precision golden."""
        with open(GOLDEN) as f:
            golden = json.load(f)
        built = _build_golden()
        assert len(built["entries"]) == len(golden["entries"])
        for g, b in zip(golden["entries"], built["entries"]):
            assert g["name"] == b["name"]
            assert g["tick"] == b["tick"], f"tick drifted ({g['name']})"
            assert g["start"] == b["start"], f"starts drifted ({g['name']})"
            assert g["machine"] == b["machine"], \
                f"machines drifted ({g['name']})"


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        with open(GOLDEN, "w") as f:
            json.dump(_build_golden(), f, indent=1)
        print(f"wrote {GOLDEN}")
