"""Scheduler-as-a-service suite (svc/): comm seams, reliability
protocol, and the scheduler/agent split.

Five layers of coverage:

  * the wire codec — tagged-JSON round-trips for every payload type that
    crosses the service boundary (DAGs, ndarrays, bytes, floats whose
    repr must survive exactly for decision parity),
  * the idempotency machinery — `SeqGate` exactly-once in-order
    admission, and the `Channel` reliability property: any interleaving
    of dropped / duplicated / delayed-then-retransmitted deliveries
    collapses to the clean-delivery schedule (seeded deterministic
    always; a hypothesis version rides along when the plugin is
    installed, repo convention per test_property.py),
  * transports — inproc determinism and real tcp sockets,
  * the acceptance bar — healthy inproc service runs produce placements
    and JCTs **bit-identical** to `ClusterSim` (live parity + a
    committed golden), chaos plans over the ``comm_send``/``agent``/
    ``heartbeat`` seams still complete every job with exactly one
    effective placement per task and nonzero lease reclaims,
  * wall-clock agents — reconnect backoff capped by
    ``RecoveryPolicy.probe_secs`` and clock-derived heartbeat deadlines,
    both under a monkeypatched clock.

Regenerate the golden after an intentional semantic change with:

    PYTHONPATH=src python tests/test_service.py --regen
"""

import itertools
import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core import FaultPlan, RecoveryPolicy, faults
from repro.sim import make_workload
from repro.sim.cluster import ClusterSim, SimConfig, scheme
from repro.svc import (Msg, SeqGate, ServiceConfig, connect, decode, encode,
                       listen, run_service_workload)
from repro.svc import wire
from repro.svc.agent import Agent, VirtualAgent
from repro.svc.comm import Channel, CommClosed
from repro.svc.scheduler import SchedulerCore, SchedulerService

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "golden_service.json")
SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")

_ADDRS = itertools.count()


def _addr() -> str:
    return f"inproc://svc-test-{next(_ADDRS)}"


# ----------------------------------------------------------------------
# wire codec
# ----------------------------------------------------------------------

def test_wire_roundtrip_all_payload_types():
    dag = make_workload("production", 1, seed=4)[0]
    arr = np.linspace(0.0, 1.0, 7, dtype=np.float64).reshape(1, 7)
    msg = Msg(wire.SUBMIT, "client", 3, {
        "dag": dag, "arr": arr, "blob": b"\x00\xffraw",
        "f": 0.1 + 0.2, "i": 41, "s": "x", "none": None,
        "nested": {"inner": [1.5, {"deep": np.int64(9)}]},
    })
    back = decode(encode(msg))
    assert (back.kind, back.sender, back.seq) == (msg.kind, msg.sender, 3)
    p = back.payload
    # repr-exact floats: the parity contract rides on this
    assert repr(p["f"]) == repr(0.1 + 0.2)
    np.testing.assert_array_equal(p["arr"], arr)
    assert p["arr"].dtype == arr.dtype
    assert p["blob"] == b"\x00\xffraw"
    assert p["i"] == 41 and p["s"] == "x" and p["none"] is None
    assert p["nested"]["inner"][1]["deep"] == 9
    d2 = p["dag"]
    np.testing.assert_array_equal(d2.duration, dag.duration)
    np.testing.assert_array_equal(d2.demand, dag.demand)
    np.testing.assert_array_equal(d2.stage_of, dag.stage_of)
    assert len(d2.parents) == len(dag.parents)
    for a, b in zip(d2.parents, dag.parents):
        np.testing.assert_array_equal(a, b)
    assert d2.name == dag.name


# ----------------------------------------------------------------------
# SeqGate: exactly-once, in-order
# ----------------------------------------------------------------------

def _m(seq, sender="a"):
    return Msg(wire.TASK_DONE, sender, seq, {"n": seq})


def test_seqgate_dups_and_reorders():
    g = SeqGate()
    assert [x.seq for x in g.admit(_m(1))] == [1]
    assert g.admit(_m(1)) == []                      # dup of admitted
    assert g.admit(_m(4)) == []                      # future: parked
    assert g.admit(_m(4)) == []                      # dup of parked
    assert g.admit(_m(3)) == []                      # still gapped on 2
    assert [x.seq for x in g.admit(_m(2))] == [2, 3, 4]   # gap fills
    assert [x.seq for x in g.admit(_m(5))] == [5]
    assert g.stats == {"admitted": 5, "dups": 2, "reorders": 2}
    # senders are independent streams
    assert [x.seq for x in g.admit(_m(1, "b"))] == [1]


def test_seqgate_unsequenced_passthrough():
    g = SeqGate()
    hb = Msg(wire.HEARTBEAT, "a", 0, {"machine": 1})
    assert g.admit(hb) == [hb]
    assert g.admit(hb) == [hb]           # no dedup outside the protocol
    assert g.stats["admitted"] == 0


# ----------------------------------------------------------------------
# Channel reliability: lossy delivery == clean-delivery oracle
# ----------------------------------------------------------------------

_CH_REC = RecoveryPolicy(rpc_timeout=0.1, backoff_cap=0.5)


def _lossy_exchange(plan_text, n_msgs=40, max_cycles=600):
    """Send ``n_msgs`` sequenced messages through an inproc pair under
    ``plan_text``; drive virtual time until everything is admitted and
    acked.  Returns (admitted payload ids, sender chan, receiver chan)."""
    with faults.scope(plan_text):
        accepted = []
        lst = listen(_addr(), accepted.append)
        cli = connect(lst.addr)
        srv = accepted[0]
        clk = [0.0]
        snd = Channel(cli, "cli", _CH_REC, lambda: clk[0])
        rcv = Channel(srv, "srv", _CH_REC, lambda: clk[0])
        for i in range(n_msgs):
            snd.send(wire.TASK_DONE, lease=i, t=float(i))
        got = []
        for _ in range(max_cycles):
            got += [int(m.payload["lease"]) for m in rcv.poll(clk[0])]
            snd.poll(clk[0])
            if len(got) == n_msgs and snd.unacked == 0:
                break
            clk[0] += 0.13
        lst.close()
        return got, snd, rcv


def test_channel_clean_delivery():
    got, snd, rcv = _lossy_exchange("seed=0")
    assert got == list(range(40))
    assert snd.unacked == 0
    assert snd.stats["retransmits"] == 0
    assert rcv.gate.stats["dups"] == 0


def test_channel_survives_drop_dup_delay_interleavings():
    got, snd, rcv = _lossy_exchange(
        "seed=7;comm_send:drop@0.25;comm_send:dup@0.2;"
        "comm_send:delay@0.15,delay=0.3")
    assert got == list(range(40))         # exactly once, in order
    assert snd.unacked == 0               # every message eventually acked
    assert snd.stats["retransmits"] > 0   # the protocol actually worked
    assert rcv.gate.stats["dups"] > 0


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_channel_reliability_property():
    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(seed=st.integers(0, 2**16),
           p_drop=st.floats(0.0, 0.4),
           p_dup=st.floats(0.0, 0.4),
           p_delay=st.floats(0.0, 0.4),
           n=st.integers(1, 25))
    def run(seed, p_drop, p_dup, p_delay, n):
        plan = (f"seed={seed};comm_send:drop@{p_drop:.3f};"
                f"comm_send:dup@{p_dup:.3f};"
                f"comm_send:delay@{p_delay:.3f},delay=0.2")
        got, snd, _ = _lossy_exchange(plan, n_msgs=n)
        assert got == list(range(n))
        assert snd.unacked == 0

    run()


def test_channel_reacks_when_first_ack_is_lost():
    """Receiver's first ack dropped -> sender retransmits -> receiver
    treats the dup as a no-op but re-acks it, so the sender drains."""
    # n is the per-comm physical send counter: the receiver's comm sends
    # ack #1 first (n=1) — drop exactly that one
    got, snd, rcv = _lossy_exchange("seed=0;comm_send:drop@1.0,n=1",
                                    n_msgs=3)
    assert got == [0, 1, 2]
    assert snd.unacked == 0
    assert snd.stats["retransmits"] >= 1
    assert rcv.gate.stats["dups"] >= 1


# ----------------------------------------------------------------------
# transports
# ----------------------------------------------------------------------

def test_inproc_connect_requires_listener():
    with pytest.raises(CommClosed):
        connect("inproc://nobody-home")


def test_tcp_channel_roundtrip():
    with faults.scope(FaultPlan()):
        accepted = []
        lst = listen("tcp://127.0.0.1:0", accepted.append)
        cli = connect(lst.addr)
        snd = Channel(cli, "cli")
        arr = np.arange(6, dtype=np.float32)
        snd.send(wire.TASK_DONE, lease=1, arr=arr)
        deadline = time.monotonic() + 10.0
        while not accepted and time.monotonic() < deadline:
            time.sleep(0.005)
        rcv = Channel(accepted[0], "srv")
        got = []
        while not got and time.monotonic() < deadline:
            got = rcv.poll()
            time.sleep(0.005)
        assert got and got[0].kind == wire.TASK_DONE
        np.testing.assert_array_equal(got[0].payload["arr"], arr)
        assert got[0].payload["arr"].dtype == np.float32
        while snd.unacked and time.monotonic() < deadline:
            snd.poll()
            time.sleep(0.005)
        assert snd.unacked == 0
        snd.close()
        rcv.close()
        lst.close()


# ----------------------------------------------------------------------
# healthy-path decision parity (the tentpole acceptance bar)
# ----------------------------------------------------------------------

def _parity_workload(n=6, seed=3, interarrival=25.0):
    dags = make_workload("production", n, seed=seed)
    rng = np.random.default_rng(0)
    arrivals, t = [], 0.0
    for i, dag in enumerate(dags):
        arrivals.append((t, dag, i % 2))
        t += float(rng.exponential(interarrival))
    return arrivals


def _parity_pair():
    arrivals = _parity_workload()
    sim = ClusterSim(SimConfig(n_machines=12, seed=0, speculate=False,
                               record_placements=True,
                               fault_plan=FaultPlan()),
                     scheme("dagps")).run(arrivals)
    svc = run_service_workload(arrivals, ServiceConfig(n_machines=12,
                                                       seed=0),
                               scheme("dagps"), fault_plan=FaultPlan())
    return sim, svc


def _golden_doc(svc):
    return {
        "jcts": {str(j.job_id): repr(j.jct) for j in svc.jobs},
        "makespan": repr(svc.makespan),
        "placements": [[repr(t), j, tk, m] for t, j, tk, m in
                       svc.placements],
    }


def test_service_matches_simulator_bit_for_bit():
    """Healthy inproc service run == `ClusterSim`, placement for
    placement: same (time, job, task, machine) grant sequence, same JCTs
    and makespan at full float precision."""
    sim, svc = _parity_pair()
    assert len(svc.placements) == len(sim.placements)
    assert svc.placements == sim.placements
    assert sorted((j.job_id, repr(j.jct)) for j in svc.jobs) == \
        sorted((j.job_id, repr(j.jct)) for j in sim.jobs)
    assert repr(svc.makespan) == repr(sim.makespan)
    # every task placed exactly once on the healthy path too
    assert all(v == 1 for v in svc.effective.values())
    # ... and the committed golden pins both against drift
    golden = json.load(open(GOLDEN))
    assert _golden_doc(svc) == golden


def test_serve_passthrough_matches_simulator():
    """`schedule_cluster(serve=True)` routes the same workload through
    the service and lands on the simulator path's exact JCTs."""
    from repro.launch.cluster import TPUJob, schedule_cluster

    jobs = [TPUJob(f"j{i}", "generic", [
        dict(name="a", seconds=40.0 + 5 * i, chips=0.4, hbm=0.3, deps=[]),
        dict(name="b", seconds=25.0, chips=0.5, hbm=0.4, deps=[0]),
        dict(name="c", seconds=10.0, chips=0.2, hbm=0.2, deps=[1]),
    ], group=i % 2) for i in range(4)]
    kw = dict(n_slices=8, interarrival=30.0, seed=1, policy="dagps",
              fault_plan=FaultPlan())
    plain = schedule_cluster(jobs, speculate=False, **kw)
    served = schedule_cluster(jobs, serve=True, **kw)
    assert sorted((j.job_id, repr(j.jct)) for j in served.jobs) == \
        sorted((j.job_id, repr(j.jct)) for j in plain.jobs)
    assert repr(served.makespan) == repr(plain.makespan)
    assert served.fault_stats["service"]["placements"] > 0


def test_example_serve_json_emits_service_fault_stats():
    """examples/cluster_sim.py --serve --json surfaces the service's
    fault_stats (the satellite: stats reachable from the CLI surface)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop(faults.FAULTS_ENV, None)
    proc = subprocess.run(
        [sys.executable,
         os.path.join(os.path.dirname(__file__), os.pardir, "examples",
                      "cluster_sim.py"),
         "--serve", "--json", "--jobs", "3", "--slices", "8",
         "--schemes", "dagps"],
        capture_output=True, text=True, env=env, timeout=420)
    assert proc.returncode == 0, proc.stderr[-3000:]
    doc = json.loads(proc.stdout.strip().splitlines()[-1])
    assert doc["jobs"] == 3
    fs = doc["fault_stats"]
    assert fs["service"]["placements"] > 0
    assert fs["service"]["lease_reclaims"] == 0
    assert "comm" in fs and fs["comm"]["sent"] > 0


# ----------------------------------------------------------------------
# chaos: liveness + exactly-once under comm/agent/heartbeat faults
# ----------------------------------------------------------------------

CHAOS_PLAN = ("seed=5;comm_send:drop@0.08;comm_send:dup@0.08;"
              "comm_send:delay@0.05,delay=0.5;"
              "agent:crash@1.0,machine=3,count=1;"
              "agent:partition@0.03,delay=4.0;heartbeat:drop@0.08")


def _chaos_workload(n=5, seed=5, interarrival=20.0):
    dags = make_workload("production", n, seed=seed)
    rng = np.random.default_rng(1)
    arrivals, t = [], 0.0
    for dag in dags:
        arrivals.append((t, dag, 0))
        t += float(rng.exponential(interarrival))
    return arrivals


def test_chaos_run_completes_every_job_exactly_once():
    """The acceptance chaos bar: drops, dups, delays, one agent crash
    and transient partitions — every job still completes, every task has
    exactly one effective placement, and the lease machinery visibly
    worked (reclaims > 0, late task_dones for reclaimed leases counted
    as no-ops)."""
    arrivals = _chaos_workload()
    res = run_service_workload(arrivals, ServiceConfig(n_machines=10,
                                                       seed=0),
                               scheme("dagps"), fault_plan=CHAOS_PLAN)
    assert len(res.jobs) == len(arrivals)
    assert all(v == 1 for v in res.effective.values())
    n_tasks = sum(a[1].n for a in arrivals)
    assert len(res.effective) == n_tasks
    svc = res.fault_stats["service"]
    assert svc["completions"] == n_tasks
    assert svc["lease_reclaims"] > 0
    assert res.fault_stats["heartbeat"]["losses"] >= 1   # the crash
    comm = res.fault_stats["comm"]
    assert comm["dropped"] > 0 and comm["duped"] > 0
    assert comm["retransmits"] > 0
    # the stats themselves travelled over the chaotic wire
    assert res.fault_stats["injections"].get("comm_send.drop", 0) > 0


def test_silent_machine_leases_reclaimed_and_requeued():
    """All beats from one machine drop: its leases are reclaimed after
    ``hb_lost_after`` and requeued elsewhere; the workload completes."""
    arrivals = _chaos_workload(4, seed=11)
    res = run_service_workload(
        arrivals, ServiceConfig(n_machines=8, seed=0),
        scheme("dagps"), fault_plan="seed=2;heartbeat:drop@1.0,machine=2")
    assert len(res.jobs) == 4
    assert all(v == 1 for v in res.effective.values())
    assert res.fault_stats["heartbeat"]["losses"] >= 1
    assert res.fault_stats["service"]["lease_reclaims"] >= 0
    assert res.fault_stats["injections"].get("heartbeat.drop", 0) > 0


def test_ambient_env_plan_chaos_smoke():
    """Runs under whatever REPRO_FAULTS carries (the CI service-chaos
    job sets a comm_send+agent plan; locally this is a healthy smoke).
    The liveness invariants must hold either way."""
    arrivals = _chaos_workload(3, seed=17)
    res = run_service_workload(arrivals, ServiceConfig(n_machines=8,
                                                       seed=0),
                               scheme("dagps"))
    assert len(res.jobs) == 3
    assert all(v == 1 for v in res.effective.values())


# ----------------------------------------------------------------------
# wall-clock agent: reconnect backoff + clock-derived beats
# ----------------------------------------------------------------------

def test_agent_backoff_capped_by_probe_secs():
    rec = RecoveryPolicy(backoff=0.1, backoff_cap=5.0, probe_secs=0.8)
    ag = Agent("inproc://nowhere", 0, recovery=rec)
    assert [ag.backoff_delay(a) for a in range(6)] == \
        [0.1, 0.2, 0.4, 0.8, 0.8, 0.8]
    # without the probe cadence the plain cap applies
    ag2 = Agent("inproc://nowhere", 0,
                recovery=RecoveryPolicy(backoff=0.1, backoff_cap=5.0,
                                        probe_secs=None))
    assert ag2.backoff_delay(10) == 5.0


def test_agent_reconnect_retries_on_schedule_then_connects():
    """connect_with_retry sleeps the capped-backoff schedule between
    failures (monkeypatched clock: no wall time passes) and returns the
    comm as soon as the connector succeeds."""
    rec = RecoveryPolicy(backoff=0.1, backoff_cap=5.0, probe_secs=0.8)
    clk = [0.0]
    slept = []

    def fake_sleep(s):
        slept.append(s)
        clk[0] += s

    sentinel = object()
    attempts = [0]

    def connector(addr):
        attempts[0] += 1
        if attempts[0] <= 5:
            raise CommClosed("scheduler down")
        return sentinel

    ag = Agent("inproc://nowhere", 0, recovery=rec, clock=lambda: clk[0],
               sleep=fake_sleep, connector=connector)
    assert ag.connect_with_retry() is sentinel
    assert ag.reconnect_delays == [0.1, 0.2, 0.4, 0.8, 0.8]
    assert slept == ag.reconnect_delays
    assert clk[0] == pytest.approx(sum(ag.reconnect_delays))


def test_agent_reconnect_gives_up_after_max_attempts():
    ag = Agent("inproc://nowhere", 0,
               recovery=RecoveryPolicy(backoff=0.001, backoff_cap=0.002),
               sleep=lambda s: None,
               connector=lambda a: (_ for _ in ()).throw(CommClosed("x")))
    with pytest.raises(CommClosed):
        ag.connect_with_retry(max_attempts=3)
    assert len(ag.reconnect_delays) == 2


def test_agent_beats_advance_off_the_clock():
    """The beat deadline is clock-derived: spinning the poll loop with a
    frozen clock emits one beat; a long stall emits one (late) beat, not
    a burst; the next deadline is period after the beat that fired."""
    with faults.scope(FaultPlan()):
        accepted = []
        lst = listen(_addr(), accepted.append)
        comm = connect(lst.addr)
        clk = [100.0]
        ag = Agent("unused", 3, period=1.0, clock=lambda: clk[0],
                   sleep=lambda s: None)
        ch = Channel(comm, "agent-3", None, lambda: clk[0])
        nb = ag.step(ch, clk[0])               # due now -> beat fires
        for _ in range(5):
            nb = ag.step(ch, nb)               # frozen clock: no beats
        assert len(ag.beats) == 1
        clk[0] += 3.7                          # poll-loop stall
        nb = ag.step(ch, nb)
        assert len(ag.beats) == 2              # one catch-up beat
        assert nb == pytest.approx(103.7 + 1.0)
        srv = Channel(accepted[0], "srv", None, lambda: clk[0])
        kinds = [m.kind for m in srv.poll()]
        assert kinds.count(wire.HEARTBEAT) == 2
        lst.close()


def test_wall_clock_service_over_tcp_end_to_end():
    """The deployment shape: scheduler served from a thread on real
    sockets, wall-clock `Agent` threads, a `Client` fetching stats over
    the wire.  Compressed lease durations keep it fast."""
    from repro.svc.client import Client

    with faults.scope(FaultPlan()):
        cfg = ServiceConfig(n_machines=4, seed=0, heartbeat_period=0.2,
                            groups=(0,))
        core = SchedulerCore(cfg, scheme("dagps"))
        svc = SchedulerService(core, "tcp://127.0.0.1:0")
        agents = []
        try:
            svc.serve_in_thread(poll_interval=0.005)
            for m in range(4):
                ag = Agent(svc.addr, m, period=0.2, time_scale=0.0005)
                ag.start()
                agents.append(ag)
            client = Client(connect(svc.addr))
            dags = make_workload("production", 2, seed=21)
            handles = [client.submit(dag, t=0.0) for dag in dags]
            deadline = time.monotonic() + 120.0
            while client.pending and time.monotonic() < deadline:
                client.poll()
                time.sleep(0.01)
            assert client.pending == 0, "jobs did not complete over tcp"
            assert all(h.result is not None for h in handles)
            stats = client.stats(timeout=10.0)
            fs = stats["fault_stats"]
            assert fs["service"]["completions"] == sum(d.n for d in dags)
            assert all(v == 1 for v in core.effective.values())
        finally:
            for ag in agents:
                ag.stop()
            svc.close()


if __name__ == "__main__":
    if "--regen" in sys.argv:
        _, svc = _parity_pair()
        with open(GOLDEN, "w") as f:
            json.dump(_golden_doc(svc), f, indent=1)
        print(f"wrote {GOLDEN}: {len(svc.placements)} placements, "
              f"{len(svc.jobs)} jobs")
