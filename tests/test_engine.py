"""Engine-layer tests: backend parity, Space edge cases, snapshot/restore.

The headline invariant of the placement engine: every backend produces
tick-identical schedules.  The parity tests build the same DAGs through the
reference backend (per-task grid rescans — the semantic oracle) and the
batched backend (windowed ready-set scans) and require bit-equal
(machine, start) placements.
"""

import numpy as np
import pytest

from repro.core import (DAG, Space, available_backends, build_schedule,
                        get_backend)
from repro.core.builder import partition_totally_ordered
from repro.core.engine import JitBackend, scan_starts
from repro.core.engine.base import ceil32
from repro.sim.workload import production_dag, query_dag


def _assert_same_schedule(a, b, ctx=""):
    assert a.makespan == b.makespan, f"makespan differs {ctx}"
    assert np.array_equal(a.start, b.start), f"starts differ {ctx}"
    assert np.array_equal(a.machine, b.machine), f"machines differ {ctx}"
    assert np.array_equal(a.order, b.order), f"order differs {ctx}"


class TestBackendParity:
    def test_production_dags_tick_identical(self):
        """>= 20 seeded production DAGs: batched == reference, bit for bit."""
        for seed in range(20):
            dag = production_dag(np.random.default_rng(seed), scale=0.35, share=3)
            ref = build_schedule(dag, 3, ticks=96, backend="reference")
            bat = build_schedule(dag, 3, ticks=96, backend="batched")
            _assert_same_schedule(ref, bat, f"(production seed={seed})")

    def test_tpcds_dags_tick_identical(self):
        """TPC-DS style DAGs have low-jitter stages: exercises the hint path."""
        for seed in range(4):
            dag = query_dag(np.random.default_rng(seed), preset="tpcds")
            ref = build_schedule(dag, 4, ticks=128, backend="reference")
            bat = build_schedule(dag, 4, ticks=128, backend="batched")
            _assert_same_schedule(ref, bat, f"(tpcds seed={seed})")

    @pytest.mark.skipif(not JitBackend.available(), reason="jax unavailable")
    def test_jit_backend_tick_identical(self):
        for seed in (0, 7):
            dag = production_dag(np.random.default_rng(seed), scale=0.35, share=3)
            bat = build_schedule(dag, 3, ticks=96, backend="batched")
            jit = build_schedule(dag, 3, ticks=96, backend="jit")
            _assert_same_schedule(bat, jit, f"(jit seed={seed})")

    def test_grid_edge_growth_rescan(self):
        """Starts whose run straddles the grid edge are cleared in the
        window bitmap (truncated run); after growth they must be rescanned,
        not skipped — regression for a batched/reference divergence."""
        from repro.core.engine import FORWARD, BACKWARD

        results = {}
        for name in ("reference", "batched"):
            s = Space(m=1, d=1, horizon=10)
            s.commit(0, 0, 0, 9, np.array([1.0]))  # cells 0-8 fully busy
            sess = get_backend(name).session(s, FORWARD)
            results[name] = sess.place(1, np.array([0.5]), 2, 0, (0, 0.0, b"x"))
        assert results["batched"] == results["reference"] == (0, 9)

    def test_backward_peer_cache_growth_rescan(self):
        """Backward mirror of the grid-edge rule: a peer bitmap scanned
        when the grid was shorter has unsound clear bits above edge - k;
        once the real deadline grows the grid those starts must be settled
        live, not trusted — regression for a batched/reference divergence."""
        from repro.core.engine import BACKWARD, PeerTask

        results = {}
        for name in ("reference", "batched"):
            s = Space(m=1, d=1, horizon=10)
            sess = get_backend(name).session(s, BACKWARD)
            # placing A announces peer B with an *estimated* deadline of 10;
            # B's bitmap clears starts 5..6 only because their runs crossed
            # the then-grid end
            peers = [PeerTask(tid=2, anchor=10, demand=np.array([0.5]),
                              dur_ticks=6)]
            a = sess.place(1, np.array([0.5]), 2, 8, (0, 0.0, b"a"),
                           peers_fn=lambda: peers)
            s.commit(1, a[0], a[1], 2, np.array([0.5]))
            # B's real deadline is 12: the grid grows and start 6 now fits
            results[name] = sess.place(2, np.array([0.5]), 6, 12,
                                       (1, 0.0, b"b"))
        assert results["batched"] == results["reference"]
        assert results["reference"][1] == 6

    def test_registry(self):
        names = available_backends()
        assert {"reference", "batched", "jit"} <= set(names)
        assert get_backend("batched").name == "batched"
        with pytest.raises(ValueError):
            get_backend("no-such-backend")


class TestScanKernel:
    def test_matches_fit_starts(self):
        """The batched bitmap over a window == the reference scan's fits."""
        rng = np.random.default_rng(3)
        s = Space(m=3, d=2, horizon=64)
        for t in range(25):  # clutter the grid
            v = rng.uniform(0.1, 0.6, 2)
            m, t0 = s.earliest_fit(v, int(rng.integers(1, 6)), int(rng.integers(0, 40)))
            s.commit(t, m, t0, 3, v)
        Vs = rng.uniform(0.2, 0.7, (5, 2))
        ks = rng.integers(1, 9, 5)
        goods = scan_starts(s.avail, Vs, ks, 0, s.T)
        for g in range(5):
            ms, ts = s._fit_starts(Vs[g], int(ks[g]), -s.off, s.T - s.off)
            expect = np.zeros((s.T, s.m), dtype=bool)
            expect[ts + s.off, ms] = True
            assert np.array_equal(goods[g].reshape(s.T, s.m), expect)

    def test_reverse_layout(self):
        s = Space(m=2, d=1, horizon=16)
        s.commit(0, 0, 0, 16, np.array([1.0]))  # machine 0 fully busy
        good = scan_starts(s.avail, np.array([[0.5]]), np.array([4]), 0, 13,
                           reverse=True)
        grid = good.reshape(13, 2)
        # row j is start t = 12 - j; machine 1 free everywhere, machine 0 never
        assert grid[:, 1].all() and not grid[:, 0].any()

    def test_ceil32_equivalence(self):
        rng = np.random.default_rng(0)
        a = rng.uniform(0, 1, 4096).astype(np.float32)
        v = rng.uniform(0, 1, 4096)
        assert np.array_equal(a >= v, a >= ceil32(v))
        assert ceil32(a) is a  # float32 passes through untouched


class TestSpaceEdgeCases:
    def test_grow_front_offset_bookkeeping(self):
        s = Space(m=1, d=1, horizon=8)
        s.commit(0, 0, 2, 3, np.array([0.5]))
        before = s.avail[0, :, 0].copy()
        off0 = s.off
        s._grow_front()
        assert s.off == off0 + 8 and s.T == 16
        # logical content preserved: old cells shifted by the growth
        assert np.array_equal(s.avail[0, 8:, 0], before)
        assert (s.avail[0, :8, 0] == 1.0).all()
        # committed region still visible at the same logical coords
        assert not s.check_fit_exact(0, 2, 3, np.array([0.6]))
        assert s.check_fit_exact(0, 2, 3, np.array([0.5]))

    def test_grow_back_keeps_logical_coords(self):
        s = Space(m=1, d=1, horizon=8)
        s.commit(0, 0, 0, 4, np.array([0.9]))
        s._grow_back()
        assert s.T == 16 and s.off == 0
        assert s.check_fit_exact(0, 4, 12, np.array([0.9]))
        assert not s.check_fit_exact(0, 0, 4, np.array([0.2]))

    def test_hint_soundness_earliest(self):
        """A prior identical placement is a sound floor: with or without the
        hint, earliest_fit returns the same slot (the space only fills up)."""
        rng = np.random.default_rng(1)
        s = Space(m=3, d=2, horizon=64)
        v = np.array([0.55, 0.35])
        hint = None
        for t in range(12):
            plain = s.clone().earliest_fit(v, 4, 2)
            hinted = s.earliest_fit(v, 4, 2, hint)
            assert plain == hinted
            s.commit(t, *hinted, 4, v)
            hint = hinted
            if rng.random() < 0.5:  # unrelated clutter never breaks soundness
                w = rng.uniform(0.05, 0.3, 2)
                m2, t2 = s.earliest_fit(w, 2, 0)
                s.commit(100 + t, m2, t2, 2, w)

    def test_hint_soundness_latest(self):
        s = Space(m=2, d=1, horizon=40)
        v = np.array([0.7])
        hint = None
        for t in range(6):
            plain = s.clone().latest_fit(v, 3, 30)
            hinted = s.latest_fit(v, 3, 30, hint)
            assert plain == hinted
            s.commit(t, *hinted, 3, v)
            hint = hinted

    def test_fit_first_matches_full_scan(self):
        rng = np.random.default_rng(5)
        s = Space(m=2, d=2, horizon=48)
        for t in range(30):
            v = rng.uniform(0.2, 0.8, 2)
            k = int(rng.integers(1, 5))
            m, t0 = s.earliest_fit(v, k, 0)
            s.commit(t, m, t0, k, v)
        for _ in range(40):
            v = rng.uniform(0.2, 0.9, 2)
            k = int(rng.integers(1, 7))
            lo, hi = 0, s.T - s.off - k
            ms, ts = s._fit_starts(v, k, lo, hi + k)
            first = s.fit_first(v, k, lo, hi)
            latest = s.fit_first(v, k, lo, hi, latest=True)
            if len(ts) == 0:
                assert first is None and latest is None
            else:
                tmin, tmax = int(ts.min()), int(ts.max())
                assert first == (int(ms[ts == tmin].min()), tmin)
                assert latest == (int(ms[ts == tmax].min()), tmax)

    def test_snapshot_restore_exact(self):
        rng = np.random.default_rng(9)
        s = Space(m=2, d=2, horizon=16)
        s.commit(0, 0, 0, 4, np.array([0.3, 0.4]))
        snap = s.snapshot()
        grid0 = s.avail.copy()
        # commits, growth in both directions, nested snapshot/rollback
        s.commit(1, 1, 2, 5, np.array([0.6, 0.1]))
        s.latest_fit(np.array([0.5, 0.5]), 40, 8)     # forces front growth
        inner = s.snapshot()
        s.commit(2, 0, -3, 2, np.array([0.2, 0.2]))
        s.restore(inner)
        s._grow_back()
        s.commit(3, 1, 30, 4, np.array([0.9, 0.9]))   # in back-grown region
        s.restore(snap)
        assert s.T == grid0.shape[1] and len(s.placements) == 1
        assert np.array_equal(s.avail, grid0)
        assert s.makespan_ticks == 4

    def test_restore_keep_extent(self):
        s = Space(m=1, d=1, horizon=8)
        snap = s.snapshot()
        s._grow_back()
        s.commit(0, 0, 10, 2, np.array([0.5]))
        s.restore(snap, keep_extent=True)
        assert s.T == 16 and len(s.placements) == 0
        assert (s.avail == 1.0).all()


class TestPartitionEdgeCases:
    def test_single_task(self):
        d = DAG(duration=np.array([2.0]), demand=np.array([[0.5, 0.5]]),
                stage_of=np.array([0]), parents=[np.array([], int)])
        parts = partition_totally_ordered(d)
        assert len(parts) == 1 and list(parts[0]) == [0]
        sched = build_schedule(d, 2)
        assert sched.makespan == pytest.approx(2.0)

    def test_fully_parallel(self):
        n = 6
        d = DAG(duration=np.full(n, 1.0), demand=np.full((n, 2), 0.4),
                stage_of=np.zeros(n, int),
                parents=[np.array([], int) for _ in range(n)])
        parts = partition_totally_ordered(d)
        assert len(parts) == 1  # no barrier anywhere: nothing is ordered
        sched = build_schedule(d, 3)
        sched.validate()

    def test_empty_dag(self):
        d = DAG(duration=np.empty(0), demand=np.empty((0, 2)),
                stage_of=np.empty(0, int), parents=[])
        sched = build_schedule(d, 2)
        assert sched.makespan == 0.0
