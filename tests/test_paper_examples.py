"""Paper worked examples: Fig. 2 and the appendix adversarial DAGs.

Fig. 2's exact demand vectors are not published in the text; we use a
construction with the same structure (three pairwise-conflicting tasks
{t0, t1, t3}, long tasks {t0, t2, t4} that OPT overlaps) and the same
qualitative outcome: DAGPS == OPT while CPSched/Tetris pay ~2-3x.
"""

import numpy as np
import pytest

from repro.core import DAG, build_schedule, new_lb
from repro.core.baselines import bfs_order, cp_order, simulate_execution
from repro.sim.workload import lemma1_dag, tetris_trap_dag

T, EPS = 100.0, 0.02


def fig2_dag() -> DAG:
    dur = np.array([T, EPS * T, T * (1 - 4 * EPS), EPS * T, T * (1 - 2 * EPS)])
    dem = np.array([
        [0.80, 0.05],   # t0: long
        [0.75, 0.10],   # t1 -> t2
        [0.10, 0.80],   # t2: long
        [0.70, 0.25],   # t3 -> t4 (conflicts t2 on r1)
        [0.05, 0.10],   # t4: long
    ])
    parents = [np.array([], int), np.array([], int), np.array([1]),
               np.array([], int), np.array([3])]
    return DAG(duration=dur, demand=dem, stage_of=np.arange(5),
               parents=parents, name="fig2")


def test_fig2_dagps_matches_opt():
    dag = fig2_dag()
    opt = T * (1 + 2 * EPS)
    sched = build_schedule(dag, m=1, ticks=400)
    sched.validate()
    assert sched.makespan <= opt * 1.02


def test_fig2_cp_and_tetris_pay_2x():
    dag = fig2_dag()
    opt = T * (1 + 2 * EPS)
    cp = simulate_execution(dag, 1, order=cp_order(dag))
    tet = simulate_execution(dag, 1, policy="tetris")
    assert cp >= 1.8 * opt
    assert tet >= 1.8 * opt


def test_fig2_online_follows_schedule():
    dag = fig2_dag()
    sched = build_schedule(dag, m=1, ticks=400)
    dg = simulate_execution(dag, 1, policy="dagps", pri_score=sched.pri_score)
    assert dg <= T * (1 + 2 * EPS) * 1.02


def test_lemma1_dependency_blind_loses():
    """Fig. 17: schedulers ignoring structure pay ~Omega(d) on the red-task
    DAG; DAGPS's structural tie-break finds the red tasks."""
    dag = lemma1_dag(d=4, k=6, t=10.0)
    lb = new_lb(dag, 1)
    # dependency-blind: BFS with adversarial stage ids runs red tasks last
    blind = simulate_execution(dag, 1, order=bfs_order(dag))
    sched = build_schedule(dag, m=1)
    sched.validate()
    dagps = simulate_execution(dag, 1, policy="dagps", pri_score=sched.pri_score)
    assert blind > 1.5 * lb
    assert dagps <= blind
    assert sched.makespan <= 1.35 * lb


def test_tetris_trap():
    """Fig. 19 spirit: greedy packing serializes long tasks DAGPS overlaps."""
    dag = tetris_trap_dag(d=4)
    sched = build_schedule(dag, m=1)
    sched.validate()
    tet = simulate_execution(dag, 1, policy="tetris")
    assert sched.makespan <= tet * 1.05  # never worse than the greedy packer
