"""Property-based tests (hypothesis) for system invariants."""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -e .[test])"
)
from hypothesis import given, settings, strategies as st

from repro.core import DAG, build_schedule, new_lb, simulate_execution
from repro.core.baselines import bfs_order
from repro.core.online import DeficitCounters
from repro.optim.compression import dequantize, quantize_int8


@st.composite
def small_dags(draw):
    n_stages = draw(st.integers(2, 7))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    tasks, durs, dems, deps = [], [], [], []
    for s in range(n_stages):
        tasks.append(int(rng.integers(1, 5)))
        durs.append(float(rng.uniform(0.5, 20.0)))
        dems.append(np.clip(rng.uniform(0.05, 0.8, 4), 0.05, 0.8))
        n_par = int(rng.integers(0, min(s, 2) + 1))
        deps.append(sorted(rng.choice(s, size=n_par, replace=False).tolist()) if s and n_par else [])
    from repro.core.dag import from_stage_graph
    return from_stage_graph(tasks, durs, dems, deps, rng=rng)


@settings(max_examples=15, deadline=None)
@given(small_dags(), st.integers(1, 4))
def test_schedule_respects_dependencies_and_beats_nothing(dag, m):
    sched = build_schedule(dag, m=m, ticks=128)
    sched.validate()                       # deps + capacity
    assert dag.validate_order(sched.order)
    # constructed makespan is never below the lower bound (allow tick fuzz)
    lb = new_lb(dag, m)
    assert sched.makespan >= lb * 0.98 - 2 * sched.tick


@settings(max_examples=15, deadline=None)
@given(small_dags(), st.integers(1, 4))
def test_executor_work_conserving_and_bounded(dag, m):
    ms = simulate_execution(dag, m, order=bfs_order(dag))
    lb = new_lb(dag, m)
    serial = float(dag.duration.sum())
    assert lb * 0.999 <= ms <= serial + 1e-6


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 3), min_size=5, max_size=60),
       st.floats(0.05, 0.5))
def test_deficit_counters_never_exceed_bound_when_enforced(allocs, kappa):
    """If the scheduler always serves must_serve() when set, deficits stay
    within kappa*C + one allocation quantum."""
    C = 10.0
    dc = DeficitCounters({0: 1.0, 1: 1.0, 2: 1.0, 3: 1.0}, capacity=C, kappa=kappa)
    for g in allocs:
        forced = dc.must_serve()
        dc.allocated(forced if forced is not None else g, 1.0)
        worst = max(dc.deficit.values())
        assert worst <= kappa * C + 1.0 + 1e-9


def _space_interleaving_oracle(seed: int, n_ops: int) -> None:
    """Random snapshot/branch/restore interleavings leave the grid
    bit-identical to a clone-based oracle.

    The construction memo trees lean on exactly this: every branch of the
    variant trie assumes a restore returns the grid (cells, extents,
    placement list, physical shape) to the checkpoint state *exactly* —
    no float drift, no leaked growth.  The oracle is the expensive
    alternative the undo log replaces: a full clone at every snapshot.
    """
    from repro.core import Space

    rng = np.random.default_rng(seed)
    s = Space(m=int(rng.integers(1, 4)), d=int(rng.integers(1, 3)),
              horizon=int(rng.integers(8, 24)))
    stack = []  # (snapshot, full clone at snapshot time)
    tid = 0
    for _ in range(n_ops):
        op = rng.random()
        if op < 0.45:  # commit somewhere feasible (earliest or latest fit)
            v = rng.uniform(0.05, 0.9, s.d)
            k = int(rng.integers(1, 6))
            if rng.random() < 0.5:
                m, t0 = s.earliest_fit(v, k, int(rng.integers(0, 12)))
            else:
                m, t0 = s.latest_fit(v, k, int(rng.integers(4, 16)))
            s.commit(tid, m, t0, k, v)
            tid += 1
        elif op < 0.6:  # grow explicitly (restore must shrink it back)
            (s._grow_front if rng.random() < 0.5 else s._grow_back)()
        elif op < 0.8 or not stack:  # snapshot a new branch point
            stack.append((s.snapshot(), s.clone()))
        else:  # restore to a random depth (pops everything above it)
            depth = int(rng.integers(0, len(stack)))
            snap, oracle = stack[depth]
            del stack[depth + 1:]
            s.restore(snap)
            assert s.T == oracle.T and s.off == oracle.off
            assert np.array_equal(s.avail, oracle.avail), \
                "grid not bit-identical to clone oracle after restore"
            assert len(s.placements) == len(oracle.placements)
            assert s._min_start == oracle._min_start
            assert s._max_end == oracle._max_end
            assert s.makespan_ticks == oracle.makespan_ticks
    while stack:  # unwind the whole tree back to the root
        snap, oracle = stack.pop()
        s.restore(snap)
        assert np.array_equal(s.avail, oracle.avail)
        assert s.T == oracle.T and s.off == oracle.off


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(5, 60))
def test_space_restore_matches_clone_oracle(seed, n_ops):
    """Hypothesis sweep of the snapshot/branch/restore state machine."""
    _space_interleaving_oracle(seed, n_ops)


@settings(max_examples=15, deadline=None)
@given(small_dags(), st.integers(1, 4))
def test_memoized_build_matches_plain(dag, m):
    """Random DAGs: the memoized builder is bit-identical to no-memo."""
    a = build_schedule(dag, m=m, ticks=128, memoize=True)
    b = build_schedule(dag, m=m, ticks=128, memoize=False)
    assert a.makespan == b.makespan
    assert np.array_equal(a.start, b.start)
    assert np.array_equal(a.machine, b.machine)
    assert np.array_equal(a.order, b.order)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 512))
def test_int8_compression_relative_error(seed, n):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32) * rng.uniform(1e-3, 1e3)
    q, s = quantize_int8(x)
    err = np.abs(dequantize(np.asarray(q), np.asarray(s)) - x)
    assert err.max() <= float(s) * 0.5 + 1e-12  # half-ULP of the int8 grid
