"""Dynamic DAGs: graph mutations, incremental reachability, delta
rebuilds, and mid-run schedule repair in the cluster simulator.

Covers the mutation edge cases the paper's recurring-pipeline regime
exercises: cycle/validity rejection, digest freshness per mutation kind,
incremental reachability == full recompute, delta rebuild == full build
(bit parity, across backends x memo), and the simulator's dynamic-run
semantics (noop rules, speed edits, mid-run stage arrival).
"""

import numpy as np
import pytest

from repro.core import available_backends
from repro.core.buildsvc import BuildService
from repro.core.builder import (assert_schedules_equal, build_schedule,
                                rebuild_schedule)
from repro.core.dag import (add_dependency, append_stage, append_tasks,
                            dag_digest, from_stage_graph, resize_stage,
                            retarget_deadline, scale_durations, scale_speeds,
                            _pack_reach)
from repro.sim.cluster import run_workload
from repro.sim.workload import (mut_append_stage, mut_resize_stage,
                                mut_retarget, periodic_dag, s12_dynamic)


def _template():
    return periodic_dag(np.random.default_rng(5), name="recurring")


def _chain(durs=(20.0, 20.0, 20.0)):
    dem = np.full(4, 0.3)
    return from_stage_graph([1] * len(durs), list(durs), [dem] * len(durs),
                            [[]] + [[i] for i in range(len(durs) - 1)],
                            name="chain")


def _decision_key(res):
    return ([(j.job_id, repr(j.jct)) for j in
             sorted(res.jobs, key=lambda j: j.job_id)], repr(res.makespan))


# ----------------------------------------------------------------------
# mutation validity: cycles, bad ids, degenerate edits are rejected
# ----------------------------------------------------------------------

def test_add_dependency_rejects_cycles_and_duplicates():
    dag = _template()
    with pytest.raises(ValueError, match="topological"):
        add_dependency(dag, 5, 2)          # back-edge = cycle
    with pytest.raises(ValueError, match="topological"):
        add_dependency(dag, 3, 3)          # self-loop
    with pytest.raises(ValueError, match="no such task"):
        add_dependency(dag, 0, dag.n + 7)
    c = next(t for t in range(dag.n) if len(dag.parents[t]))
    p = int(dag.parents[c][0])
    with pytest.raises(ValueError, match="already exists"):
        add_dependency(dag, p, c)


def test_append_tasks_rejects_forward_parents():
    dag = _template()
    with pytest.raises(ValueError, match="earlier tasks"):
        # first appended task (id n) depending on the second (id n+1)
        append_tasks(dag, [1.0, 1.0], [np.full(4, 0.1)] * 2,
                     [dag.n_stages] * 2, [[dag.n + 1], []])
    with pytest.raises(ValueError, match="nothing to append"):
        append_tasks(dag, [], [], [], [])


def test_resize_stage_rejects_degenerate_edits():
    dag = _template()
    q = int((dag.stage_of == 1).sum())
    with pytest.raises(ValueError, match="unchanged"):
        resize_stage(dag, 1, q)
    with pytest.raises(ValueError, match="at least one task"):
        resize_stage(dag, 1, 0)
    with pytest.raises(ValueError, match="no such stage"):
        resize_stage(dag, dag.n_stages + 3, 2)


def test_shrink_rejects_orphaning_children():
    # build a 2-wide stage whose children each hang off ONE member only
    # (not all-to-all): dropping the high member orphans its private child
    dem = np.full(4, 0.2)
    dag, _ = append_tasks(
        _chain((5.0,)), [3.0, 3.0], [dem, dem], [1, 1], [[0], [0]])
    dag, _ = append_tasks(dag, [2.0], [dem], [2], [[2]])  # child of high twin
    with pytest.raises(ValueError, match="orphan"):
        resize_stage(dag, 1, 1)


def test_scale_durations_rejects_nonpositive():
    with pytest.raises(ValueError, match="positive"):
        scale_durations(_chain(), 0.0)


# ----------------------------------------------------------------------
# digest freshness: every mutation kind moves the content digest
# ----------------------------------------------------------------------

def test_every_mutation_kind_changes_digest():
    dag = _template()
    base = dag_digest(dag)
    dem = np.full(4, 0.1)
    muts = {
        "append_tasks": append_tasks(dag, [2.0], [dem], [dag.n_stages], [[0]]),
        "append_stage": append_stage(dag, 2, 3.0, dem, parent_stages=(0,)),
        "resize_grow": resize_stage(dag, 1, int((dag.stage_of == 1).sum()) + 1),
        "retarget": retarget_deadline(dag, 0.8),
        "speeds": scale_speeds(dag, 1.5),
        "add_dep": add_dependency(
            dag, 0, next(t for t in range(1, dag.n)
                         if 0 not in dag.parents[t])),
    }
    digests = {base}
    edit_digests = set()
    for kind, (new, delta) in muts.items():
        assert delta.base_digest == base, kind
        assert delta.new_digest == dag_digest(new), kind
        assert delta.new_digest not in digests, f"{kind} digest collision"
        digests.add(delta.new_digest)
        assert delta.digest not in edit_digests, f"{kind} edit-key collision"
        edit_digests.add(delta.digest)
    # id_map invariants: pure edits keep identity, grow shifts, never lies
    assert np.array_equal(muts["retarget"][1].id_map, np.arange(dag.n))
    grow_map = muts["resize_grow"][1].id_map
    assert len(grow_map) == dag.n and (grow_map >= 0).all()


def test_completed_mutation_digest_is_deterministic():
    a = retarget_deadline(_template(), 0.8)[1].digest
    b = retarget_deadline(_template(), 0.8)[1].digest
    assert a == b                         # same edit on same base: same key
    assert a != retarget_deadline(_template(), 0.9)[1].digest


# ----------------------------------------------------------------------
# incremental reachability == full recompute, eager and lazy base
# ----------------------------------------------------------------------

def _mutants(dag):
    dem = np.full(4, 0.1)
    yield "append_tasks", append_tasks(
        dag, [2.0, 1.0], [dem, dem], [dag.n_stages] * 2, [[0, 3], [dag.n]])[0]
    yield "append_stage", append_stage(
        dag, 3, 2.0, dem, parent_stages=(int(dag.stage_of.max()),))[0]
    yield "resize_grow", resize_stage(
        dag, 1, int((dag.stage_of == 1).sum()) + 2)[0]
    yield "resize_shrink", resize_stage(
        dag, 1, max(int((dag.stage_of == 1).sum()) - 1, 1))[0]
    yield "retarget", retarget_deadline(dag, 0.7)[0]
    yield "add_dep", add_dependency(
        dag, 0, next(t for t in range(1, dag.n)
                     if 0 not in dag.parents[t]))[0]


@pytest.mark.parametrize("eager", [True, False],
                         ids=["eager-base", "lazy-base"])
def test_incremental_reachability_matches_full_recompute(eager):
    dag = _template()
    if eager:
        dag.anc_bits                       # force the closure pre-mutation
    for kind, new in _mutants(dag):
        want = _pack_reach(new.n, new.parents)
        assert new.anc_bits.shape == want.shape, kind
        assert (new.anc_bits == want).all(), \
            f"{kind}: incremental ancestor bits != full recompute"


# ----------------------------------------------------------------------
# delta rebuild == full build, bit for bit, backends x memo
# ----------------------------------------------------------------------

def _edits(dag):
    yield "resize", mut_resize_stage(stage=1, delta_q=1)(dag)[0]
    yield "append", mut_append_stage()(dag)[0]
    yield "retime", mut_retarget(0.8)(dag)[0]


@pytest.mark.parametrize("backend", available_backends())
@pytest.mark.parametrize("memoize", [True, False], ids=["memo", "nomemo"])
def test_delta_rebuild_bit_parity(backend, memoize):
    dag = _template()
    base = build_schedule(dag, 4, backend=backend, memoize=memoize)
    for kind, new in _edits(dag):
        # check_parity builds from scratch too and asserts bit equality
        got = rebuild_schedule(base, new, backend=backend, memoize=memoize,
                               check_parity=True)
        info = got.build_info
        assert info is not None, kind
        if kind in ("resize", "append"):
            assert info.reused_parts > 0, \
                f"{kind}: delta rebuild reused no partitions"


def test_delta_rebuild_chains_across_edits():
    dag = _template()
    s0 = build_schedule(dag, 4)
    d1 = mut_resize_stage(stage=1, delta_q=1)(dag)[0]
    s1 = rebuild_schedule(s0, d1, check_parity=True)
    d2 = mut_append_stage()(d1)[0]
    s2 = rebuild_schedule(s1, d2, check_parity=True)
    assert s2.build_info.reused_parts > 0


def test_rebuild_requires_build_info():
    dag = _template()
    s = build_schedule(dag, 4)
    s.build_info = None
    with pytest.raises(ValueError, match="build_info"):
        rebuild_schedule(s, mut_retarget(0.9)(dag)[0])


# ----------------------------------------------------------------------
# build service: delta resubmission parity + edit-key dedup
# ----------------------------------------------------------------------

def test_buildsvc_resubmit_parity_and_dedup():
    dag = _template()
    new, delta = mut_resize_stage(stage=1, delta_q=1)(dag)
    want = build_schedule(new, 4)
    with BuildService(workers=2, mode="thread") as svc:
        h = svc.submit(dag, 4)
        h.result(timeout=120)
        h2 = svc.resubmit(h, new, delta)
        assert_schedules_equal(h2.result(timeout=120), want)
        before = svc.stats["resubmit_deduped"]
        h3 = svc.resubmit(h, new, delta)   # same (base, edit): dedup front
        assert_schedules_equal(h3.result(timeout=120), want)
        assert svc.stats["resubmit_deduped"] == before + 1
        assert svc.stats["resubmits"] == 2


# ----------------------------------------------------------------------
# simulator: dynamic runs repair mid-flight, noop rules, speed edits
# ----------------------------------------------------------------------

_SIM = dict(n_machines=16, interarrival=10.0, seed=5)


def test_s12_resize_reuses_majority_of_placements():
    dags, muts = s12_dynamic("resize", n_jobs=5)
    res = run_workload(dags, "dagps", mutations=muts, **_SIM)
    ms = res.mutation_stats
    assert ms["events"] == len(muts)
    assert ms["pre_arrival"] == len(muts)  # edits land before arrival
    assert ms["delta_builds"] > 0
    reuse = ms["tasks_reused"] / max(ms["tasks_total"], 1)
    assert reuse >= 0.5, f"placement reuse {reuse:.1%} below acceptance bar"


def test_no_mutations_is_bit_identical_to_seed_path():
    dags, _ = s12_dynamic("resize", n_jobs=4)
    want = _decision_key(run_workload(dags, "dagps", **_SIM))
    got = _decision_key(run_workload(dags, "dagps", mutations=[], **_SIM))
    assert got == want


def test_mutation_after_job_completion_is_noop():
    dags, _ = s12_dynamic("resize", n_jobs=3)
    base = run_workload(dags, "dagps", **_SIM)
    muts = [(base.makespan + 100.0, 0, mut_retarget(0.5))]
    res = run_workload(dags, "dagps", mutations=muts, **_SIM)
    assert res.mutation_stats["events"] == 1
    assert res.mutation_stats["noops"] == 1
    assert res.mutation_stats["applied"] == 0
    assert _decision_key(res) == _decision_key(base)


def test_mutating_only_completed_stages_is_noop():
    # two-stage chain: stage 0 (5s) is long done at t=30, stage 1 (50s)
    # still runs -> an edit touching only stage-0 tasks must noop
    dag = _chain((5.0, 50.0))
    s0_ids = np.nonzero(dag.stage_of == 0)[0]
    muts = [(30.0, 0, lambda d: scale_durations(d, 1.3, ids=s0_ids))]
    base = run_workload([dag], "dagps", **_SIM)
    res = run_workload([dag], "dagps", mutations=muts, **_SIM)
    assert res.mutation_stats["noops"] == 1
    assert res.mutation_stats["applied"] == 0
    assert _decision_key(res) == _decision_key(base)


def test_speed_change_shortens_makespan():
    dag = _chain((20.0, 20.0, 20.0))
    base = run_workload([dag], "dagps", **_SIM)
    res = run_workload([dag], "dagps",
                       mutations=[(30.0, "speed", None, 2.0)], **_SIM)
    assert res.mutation_stats["speed_changes"] == 1
    assert res.makespan < base.makespan


def test_midrun_append_grows_the_running_job():
    dags, muts = s12_dynamic("midrun", n_jobs=3)
    res = run_workload(dags, "dagps", mutations=muts, **_SIM)
    ms = res.mutation_stats
    assert ms["applied"] >= 1 and ms["speed_changes"] == 1
    job0 = next(j for j in res.jobs if j.job_id == 0)
    assert job0.n_tasks == dags[0].n + 2   # mut_append_stage(q=2) landed
    assert len(res.jobs) == len(dags)      # everything still finishes
