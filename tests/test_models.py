"""Per-architecture smoke tests + model-level behaviors."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.serve import pad_prefill_state

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, S)
    toks = jax.random.randint(KEY, shape, 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.vlm_patches:
        batch["patches"] = jax.random.normal(
            KEY, (B, cfg.vlm_patches, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one train step, shapes + finiteness."""
    cfg = configs.get_smoke(arch)
    params = M.init_params(cfg, KEY, dtype=jnp.float32)
    batch = _batch(cfg)
    logits = M.forward(params, cfg, batch)
    S_out = batch["tokens"].shape[1] + cfg.vlm_patches
    expect = (2, S_out, cfg.n_codebooks, cfg.vocab_padded) if cfg.n_codebooks > 1 \
        else (2, S_out, cfg.vocab_padded)
    assert logits.shape == expect
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    from repro.optim import AdamWConfig
    from repro.train import TrainConfig, make_train_step, init_train_state
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10))
    params, opt = init_train_state(cfg, tcfg, KEY, dtype=jnp.float32)
    step = make_train_step(cfg, tcfg)
    p2, o2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(o2["step"]) == 1


@pytest.mark.parametrize("arch", ["granite3_8b", "gemma2_2b", "rwkv6_7b",
                                  "recurrentgemma_2b", "musicgen_large",
                                  "mixtral_8x7b", "deepseek_moe_16b",
                                  "qwen2_vl_7b", "codeqwen15_7b",
                                  "phi4_mini_3_8b"])
def test_decode_matches_forward(arch):
    """prefill(S-1) + decode_step(S-1) == forward(S)[-1] for every family."""
    cfg = configs.get_smoke(arch)
    if cfg.vlm_patches:
        pytest.skip("vlm decode covered separately (patch cache semantics)")
    params = M.init_params(cfg, jax.random.PRNGKey(1), dtype=jnp.float32)
    B, S = 2, 12
    shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, S)
    toks = jax.random.randint(jax.random.PRNGKey(2), shape, 0, cfg.vocab)
    full = M.forward(params, cfg, {"tokens": toks})
    _, state = M.prefill(params, cfg, {"tokens": toks[:, : S - 1]})
    state = pad_prefill_state(cfg, state, S)
    dl, _ = M.decode_step(params, cfg, state, toks[:, S - 1 : S],
                          jnp.full((B,), S - 1, jnp.int32))
    err = float(jnp.max(jnp.abs(dl[:, 0] - full[:, -1])))
    assert err < 2e-2, err


def test_decode_ring_buffer_local_attention():
    """Sliding-window ring cache: decoding past the window stays finite and
    matches a full-cache decode on the overlapping window."""
    cfg = configs.get_smoke(mixtral := "mixtral_8x7b")
    params = M.init_params(cfg, KEY, dtype=jnp.float32)
    B = 1
    state = M.init_decode_state(cfg, B, S_max=cfg.window, dtype=jnp.float32)
    pos = jnp.zeros((B,), jnp.int32)
    tok = jnp.zeros((B, 1), jnp.int32)
    for i in range(cfg.window + 4):  # wrap the ring
        logits, state = M.decode_step(params, cfg, state, tok, pos)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        pos = pos + 1


def test_moe_capacity_drops_tokens():
    from repro.models.layers import MoEConfig, moe_forward, moe_init
    d = 32
    cfg_tight = MoEConfig(n_experts=4, top_k=2, d_expert=64, capacity_factor=0.5)
    p = moe_init(KEY, d, cfg_tight, dtype=jnp.float32)
    x = jax.random.normal(KEY, (2, 16, d), jnp.float32)
    y_tight = moe_forward(p, x, cfg_tight)
    cfg_loose = MoEConfig(n_experts=4, top_k=2, d_expert=64, capacity_factor=8.0)
    y_loose = moe_forward(p, x, cfg_loose)
    assert not np.allclose(np.asarray(y_tight), np.asarray(y_loose))


def test_vocab_padding_masked():
    cfg = configs.get_smoke("granite3_8b")
    assert cfg.vocab_padded == cfg.vocab  # 256 already aligned
    import dataclasses
    cfg2 = dataclasses.replace(cfg, vocab=250)
    params = M.init_params(cfg2, KEY, dtype=jnp.float32)
    logits = M.forward(params, cfg2, _batch(cfg2))
    pad = np.asarray(logits, np.float32)[..., 250:]
    assert (pad < -1e8).all()


def test_loss_decreases_under_training():
    from repro.optim import AdamWConfig
    from repro.train import TrainConfig, make_train_step, init_train_state
    from repro.data import DataConfig, make_batch
    cfg = configs.get_smoke("phi4_mini_3_8b")
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=2e-3, warmup_steps=2, total_steps=40))
    params, opt = init_train_state(cfg, tcfg, KEY, dtype=jnp.float32)
    step = jax.jit(make_train_step(cfg, tcfg))
    losses = []
    for i in range(6):
        b = make_batch(cfg, DataConfig(), i % 2, 8, 32)  # 2 repeating batches
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_grad_accumulation_matches_full_batch():
    from repro.optim import AdamWConfig
    from repro.train import TrainConfig, make_train_step, init_train_state
    cfg = configs.get_smoke("granite3_8b")
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10, grad_clip=0.0)
    batch = _batch(cfg, B=4, S=16)
    outs = {}
    for mb, order in ((1, None), (2, None), (2, (1, 0))):
        tcfg = TrainConfig(optimizer=opt_cfg, microbatches=mb, microbatch_order=order)
        params, opt = init_train_state(cfg, tcfg, KEY, dtype=jnp.float32)
        step = make_train_step(cfg, tcfg)
        p2, _, m = step(params, opt, batch)
        outs[(mb, order)] = (m["loss"], p2)
    l1, p1 = outs[(1, None)]
    for key in ((2, None), (2, (1, 0))):
        l2, p2 = outs[key]
        assert abs(float(l1) - float(l2)) < 1e-4
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-5)
