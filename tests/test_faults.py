"""Fault-injection harness + degraded-mode recoveries (core/faults.py).

Four layers of coverage:

  * the plan grammar and firing machinery — parse/describe round-trip,
    deterministic seeded firing, context match filters, max-count,
    thread-local suppression, env-plan masking,
  * sharded-matcher launch recovery — retry, hang timeout, quarantine
    to the conservative all-eligible mask (a sound superset, so exact),
    probe recovery,
  * build-service recovery — pool retry, inline fallback, worker-crash
    supervision (the regression bar: a crash neither hangs
    ``BuildHandle.result()`` nor loses dedup sharers), poison-digest
    quarantine,
  * the acceptance property: any *exact-recoverable* plan reproduces
    the fault-free simulator decisions bit-for-bit.  Seeded
    deterministic versions always run; a hypothesis version rides along
    when the plugin is installed (repo convention, see test_property.py).
"""

import numpy as np
import pytest

from repro.core import FaultPlan, FaultSpec, InjectedFault, RecoveryPolicy
from repro.core import build_schedule, faults
from repro.core.buildsvc import MP_ENV, BuildService
from repro.core.engine import kernels
from repro.core.online import MatcherConfig
from repro.core.shard import ShardedMatcher
from repro.sim.cluster import run_workload
from repro.sim.workload import (online_mix_workload, periodic_dag,
                                production_dag)

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


@pytest.fixture(autouse=True)
def _fault_isolation():
    """Pin a fault-free baseline (masks any ambient REPRO_FAULTS smoke
    plan — tests opt back in with an inner scope) and keep the sticky
    kernel demotions from leaking across tests."""
    kernels.reset_demotions()
    with faults.scope(FaultPlan()):
        yield
    kernels.reset_demotions()


# ----------------------------------------------------------------------
# plan grammar + firing machinery
# ----------------------------------------------------------------------

def test_parse_describe_roundtrip():
    text = ("seed=7;shard_launch:raise@0.3;shard_launch:hang@0.1,delay=0.2;"
            "build_worker:crash@1,attempt_lt=2;heartbeat:drop@0.05;"
            "kernel_impl:raise@0.5,count=3,impl=xla")
    plan = FaultPlan.parse(text)
    again = FaultPlan.parse(plan.describe())
    assert again.specs == plan.specs
    assert again.seed == plan.seed == 7
    assert again.describe() == plan.describe()


def test_firing_is_deterministic_across_instances():
    def fires(spec_text):
        plan = FaultPlan.parse(spec_text)
        return [plan.query("shard_launch", shard=s, wave=w) is not None
                for s in range(4) for w in range(25)]

    a = fires("seed=11;shard_launch:raise@0.3")
    b = fires("seed=11;shard_launch:raise@0.3")
    assert a == b                      # pure function of (seed, seam, ctx)
    assert 0 < sum(a) < len(a)         # actually probabilistic
    assert fires("seed=12;shard_launch:raise@0.3") != a


def test_match_filters_and_lt_suffix():
    plan = FaultPlan.parse("seed=0;build_worker:raise@1,digest=abc,attempt_lt=2")
    assert plan.query("build_worker", digest="abc", attempt=0) is not None
    assert plan.query("build_worker", digest="abc", attempt=2) is None
    assert plan.query("build_worker", digest="xyz", attempt=0) is None
    assert plan.query("shard_launch", shard=0) is None


def test_max_count_and_stats():
    plan = FaultPlan.parse("seed=0;kernel_impl:raise@1,count=2")
    fired = [plan.query("kernel_impl", op="x", call=i) is not None
             for i in range(5)]
    assert fired == [True, True, False, False, False]
    assert plan.snapshot() == {"kernel_impl.raise": 2}


def test_exact_recoverable_classification():
    exact = FaultPlan.parse(
        "seed=1;shard_launch:raise@0.5;build_worker:crash;kernel_impl:raise")
    assert exact.is_exact_recoverable()
    assert not FaultPlan.parse("seed=1;heartbeat:drop@0.1").is_exact_recoverable()
    assert FaultPlan().is_exact_recoverable()


def test_invalid_seam_and_kind_rejected():
    with pytest.raises(ValueError):
        FaultSpec(seam="nope")
    with pytest.raises(ValueError):
        FaultSpec(seam="heartbeat", kind="nope")


def test_env_plan_and_scope_masking(monkeypatch):
    monkeypatch.setenv(faults.FAULTS_ENV, "seed=3;kernel_impl:raise@1")
    with faults.scope(None):                  # env plan visible
        assert faults.query("kernel_impl", op="o") is not None
        with faults.scope(FaultPlan()):       # empty plan masks env
            assert faults.query("kernel_impl", op="o") is None
    assert faults.query("kernel_impl", op="o") is None   # autouse mask


def test_suppressed_disarms_a_seam_on_this_thread():
    with faults.scope(FaultPlan.parse("seed=0;build_worker:raise@1")):
        with faults.suppressed("build_worker"):
            faults.maybe_fail("build_worker", digest="d", attempt=0)
        with pytest.raises(InjectedFault) as ei:
            faults.maybe_fail("build_worker", digest="d", attempt=0)
        assert ei.value.seam == "build_worker"


# ----------------------------------------------------------------------
# sharded-matcher launch recovery (quarantine mask is a sound superset)
# ----------------------------------------------------------------------

def _elig_setup(seed=3, m=16, n=5):
    rng = np.random.default_rng(seed)
    avail = rng.uniform(0.2, 1.0, size=(m, 4))
    dem = rng.uniform(0.05, 0.3, size=(n, 4))
    return avail, dem


def _mk_matcher(m=16, shards=2, **rec):
    kw = dict(launch_timeout=5.0, launch_retries=1, backoff=0.001,
              backoff_cap=0.002, quarantine_after=2, probe_every=2)
    kw.update(rec)
    return ShardedMatcher(MatcherConfig(), m, {0: 1.0}, n_shards=shards,
                          recovery=RecoveryPolicy(**kw))


def test_launch_quarantine_probe_cycle_is_exact():
    """raise-all on shard 0 drives retry -> quarantine -> conservative
    mask -> probe recovery; every wave's mask stays a superset of the
    healthy one and the recovered wave is identical to it."""
    avail, dem = _elig_setup()
    with _mk_matcher() as sm:
        el0, any0 = sm.eligibility(avail, dem)       # healthy (empty plan)
        with faults.scope("seed=1;shard_launch:raise@1,shard=0,count=4"):
            for _ in range(3):                       # 2 failures + 1 probe wait
                el, anym = sm.eligibility(avail, dem)
                assert (el >= el0).all() and (anym >= any0).all()
            assert sm.quarantined == [True, False]
            assert sm.launch_failures == 2 and sm.quarantine_events == 1
            assert sm.launch_retries == 2            # one retry per failure
            # injection budget exhausted: the next probe recovers shard 0
            el, anym = sm.eligibility(avail, dem)
        assert sm.probe_recoveries == 1 and sm.quarantined == [False, False]
        np.testing.assert_array_equal(el, el0)
        np.testing.assert_array_equal(anym, any0)
        assert sm.recovery_secs > 0.0


def test_hung_launch_abandoned_by_timeout():
    avail, dem = _elig_setup(seed=5)
    with _mk_matcher(launch_timeout=0.1) as sm:
        el0, any0 = sm.eligibility(avail, dem)
        with faults.scope("seed=1;shard_launch:hang@1,shard=0,count=1,"
                          "delay=0.5"):
            el, anym = sm.eligibility(avail, dem)    # attempt 0 hangs, 1 wins
        np.testing.assert_array_equal(el, el0)
        np.testing.assert_array_equal(anym, any0)
        assert sm.launch_retries == 1 and sm.launch_failures == 0
        assert sm.recovery_secs >= 0.1


def test_probe_secs_wall_clock_triggers_probe(monkeypatch):
    """Regression: probe_every counts waves, so long waves starve probes.
    probe_secs fires the probe on wall clock even when the wave floor is
    astronomically far (the ROADMAP fault follow-up)."""
    clock = [100.0]
    monkeypatch.setattr("repro.core.shard.time.monotonic", lambda: clock[0])
    avail, dem = _elig_setup()
    with _mk_matcher(launch_retries=0, quarantine_after=1,
                     probe_every=10 ** 9, probe_secs=30.0) as sm:
        el0, any0 = sm.eligibility(avail, dem)       # healthy baseline
        with faults.scope("seed=1;shard_launch:raise@1,shard=0,count=1"):
            sm.eligibility(avail, dem)               # fail -> quarantine
            assert sm.quarantined == [True, False]
            # same wall clock: below probe_secs, wave floor unreachable
            sm.eligibility(avail, dem)
            assert sm.quarantined == [True, False]
            assert sm.probe_recoveries == 0
            # the next wave is 31 simulated-wall seconds later: probe due
            clock[0] += 31.0
            el, anym = sm.eligibility(avail, dem)
        assert sm.probe_recoveries == 1
        assert sm.quarantined == [False, False]
        np.testing.assert_array_equal(el, el0)
        np.testing.assert_array_equal(anym, any0)


def test_probe_secs_none_keeps_pure_wave_counting(monkeypatch):
    """probe_secs=None restores the seed cadence: no amount of wall-clock
    silence probes a quarantined shard before the wave floor."""
    clock = [0.0]
    monkeypatch.setattr("repro.core.shard.time.monotonic", lambda: clock[0])
    avail, dem = _elig_setup()
    with _mk_matcher(launch_retries=0, quarantine_after=1, probe_every=4,
                     probe_secs=None) as sm:
        with faults.scope("seed=1;shard_launch:raise@1,shard=0,count=1"):
            sm.eligibility(avail, dem)               # fail -> quarantine
            for _ in range(3):                       # waves 1-3 < floor 4
                clock[0] += 1e6                      # wall clock irrelevant
                sm.eligibility(avail, dem)
            assert sm.probe_recoveries == 0
            assert sm.quarantined == [True, False]
            sm.eligibility(avail, dem)               # wave 4: floor reached
        assert sm.probe_recoveries == 1
        assert sm.quarantined == [False, False]


# ----------------------------------------------------------------------
# kernel-dispatch demotion (exact: numpy is the defining oracle)
# ----------------------------------------------------------------------

@pytest.mark.skipif(not kernels.have_jax(), reason="needs jax")
def test_kernel_fault_demotes_to_exact_result(monkeypatch):
    monkeypatch.setenv(kernels.HEARTBEAT_MIN_M_ENV, "1")   # promote xla
    avail, dem = _elig_setup(seed=7)
    fd, rigid, fung = np.arange(4), np.array([0, 1]), np.array([2, 3])
    args = (avail, dem, fd, rigid, fung, 0.25, True)
    want = kernels.machines_with_candidates(*args)         # healthy xla
    with faults.scope("seed=1;kernel_impl:raise@1,impl=xla,count=1"):
        got = kernels.machines_with_candidates(*args)      # faults -> numpy
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])
    snap = kernels.demotions_snapshot()
    assert snap.get("machines_with_candidates.xla.demoted") == 1
    # demotion is sticky: the faulted impl stays off the dispatch chain
    assert "xla" in kernels.demoted_impls("machines_with_candidates")
    kernels.machines_with_candidates(*args)
    assert kernels.demotions_snapshot() == snap            # no re-demotion
    kernels.reset_demotions()
    assert not kernels.demoted_impls("machines_with_candidates")


def test_oom_and_misaligned_kinds_raise_typed_faults():
    plan = FaultPlan.parse("seed=0;kernel_impl:oom@1,count=1;"
                           "kernel_impl:misaligned@1")
    with faults.scope(plan):
        with pytest.raises(faults.SimulatedOOM):
            faults.maybe_fail("kernel_impl", op="o", impl="pallas", call=0)
        with pytest.raises(faults.SimulatedMisalignedGrid):
            faults.maybe_fail("kernel_impl", op="o", impl="pallas", call=1)
    assert issubclass(faults.SimulatedOOM, InjectedFault)
    assert issubclass(faults.SimulatedMisalignedGrid, InjectedFault)


@pytest.mark.skipif(not kernels._have_pallas(), reason="needs pallas")
def test_pallas_oom_walks_demotion_ladder_exactly(monkeypatch):
    """A simulated device OOM on the pallas impl demotes pallas -> xla;
    a simulated misaligned-grid on xla then demotes to numpy.  Every rung
    returns the bit-identical decision (numpy is the defining oracle and
    the device impls mutate nothing before their launch returns)."""
    monkeypatch.setenv(kernels.KERNELS_ENV,
                       "machines_with_candidates=pallas")
    avail, dem = _elig_setup(seed=7)
    fd, rigid, fung = np.arange(4), np.array([0, 1]), np.array([2, 3])
    args = (avail, dem, fd, rigid, fung, 0.25, True)
    assert kernels.resolve("machines_with_candidates")[0] == "pallas"
    dem0 = kernels.demotions_snapshot()    # counters are process-cumulative
    want = kernels.machines_with_candidates(*args)         # healthy pallas

    with faults.scope("seed=2;kernel_impl:oom@1,impl=pallas,count=1"):
        got = kernels.machines_with_candidates(*args)
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])
    assert kernels.demoted_impls("machines_with_candidates") == {"pallas"}
    assert kernels.resolve("machines_with_candidates")[0] == "xla"  # sticky

    with faults.scope("seed=2;kernel_impl:misaligned@1,impl=xla,count=1"):
        got = kernels.machines_with_candidates(*args)
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])
    assert kernels.demoted_impls("machines_with_candidates") == \
        {"pallas", "xla"}
    assert kernels.resolve("machines_with_candidates")[0] == "numpy"
    snap = kernels.demotions_snapshot()
    for impl in ("pallas", "xla"):
        key = f"machines_with_candidates.{impl}.demoted"
        assert snap.get(key, 0) - dem0.get(key, 0) == 1


# ----------------------------------------------------------------------
# build-service recovery (supervised futures survive crashes/retries)
# ----------------------------------------------------------------------

def _build_dag(seed=1):
    return production_dag(np.random.default_rng(seed), scale=0.3, share=3)


def _assert_same_schedule(a, b):
    assert a.makespan == b.makespan
    assert np.array_equal(a.start, b.start)
    assert np.array_equal(a.machine, b.machine)
    assert np.array_equal(a.order, b.order)


_TINY_REC = dict(backoff=0.001, backoff_cap=0.002)


def test_build_retries_then_succeeds_and_dedup_shares():
    dag = _build_dag(1)
    want = build_schedule(dag, 8)
    with faults.scope("seed=1;build_worker:raise@1,attempt_lt=2"):
        with BuildService(workers=2, mode="thread",
                          recovery=RecoveryPolicy(**_TINY_REC)) as svc:
            h1 = svc.submit(dag, 8)
            h2 = svc.submit(dag, 8)                  # dedup sharer
            _assert_same_schedule(h1.result(timeout=60), want)
            _assert_same_schedule(h2.result(timeout=60), want)
    assert svc.stats["retries"] == 2                 # attempts 0 and 1 fail
    assert svc.stats["deduped"] == 1
    assert svc.stats["inline_fallbacks"] == 0
    assert svc.stats["recovery_secs"] > 0


def test_exhausted_retries_fall_back_inline():
    dag = _build_dag(2)
    want = build_schedule(dag, 8)
    with faults.scope("seed=1;build_worker:raise@1"):    # every pool attempt
        with BuildService(workers=2, mode="thread",
                          recovery=RecoveryPolicy(build_retries=1,
                                                  **_TINY_REC)) as svc:
            got = svc.submit(dag, 8).result(timeout=60)
    _assert_same_schedule(got, want)
    assert svc.stats["retries"] == 2
    assert svc.stats["inline_fallbacks"] == 1


def test_worker_crash_neither_hangs_nor_loses_sharers(monkeypatch):
    """Satellite regression bar: a worker process dying mid-build must
    not hang ``BuildHandle.result()`` and every dedup sharer of the
    crashed digest still gets its schedule (supervised futures)."""
    monkeypatch.setenv(MP_ENV, "fork")   # children inherit the env plan live
    monkeypatch.setenv(faults.FAULTS_ENV,
                       "seed=1;build_worker:crash@1,attempt_lt=1")
    dag = _build_dag(3)
    want = build_schedule(dag, 8)
    with faults.scope(None):             # parent defers to env, like workers
        with BuildService(workers=2, mode="process",
                          recovery=RecoveryPolicy(backoff=0.01,
                                                  backoff_cap=0.02)) as svc:
            h1 = svc.submit(dag, 8)
            h2 = svc.submit(dag, 8)
            _assert_same_schedule(h1.result(timeout=120), want)
            _assert_same_schedule(h2.result(timeout=120), want)
    assert svc.stats["worker_crashes"] >= 1
    assert svc.stats["deduped"] == 1
    assert svc.stats["quarantined_digests"] == 0


def test_crash_looping_digest_quarantined_to_inline(monkeypatch):
    monkeypatch.setenv(MP_ENV, "fork")
    monkeypatch.setenv(faults.FAULTS_ENV, "seed=1;build_worker:crash@1")
    dag = _build_dag(4)
    want = build_schedule(dag, 8)
    with faults.scope(None):
        with BuildService(workers=1, mode="process",
                          recovery=RecoveryPolicy(backoff=0.01,
                                                  backoff_cap=0.02,
                                                  quarantine_after=2,
                                                  build_retries=5)) as svc:
            got = svc.submit(dag, 8).result(timeout=120)
    _assert_same_schedule(got, want)
    assert svc.stats["worker_crashes"] == 2
    assert svc.stats["quarantined_digests"] == 1
    assert svc.stats["inline_fallbacks"] == 1


# ----------------------------------------------------------------------
# memo/cache seam: corruption or eviction costs a rebuild, never a
# mis-placement (doubles as the delta-rebuild invalidation safety net)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("spec", [
    "seed=2;memo:corrupt@0.5",
    "seed=3;memo:drop@0.4",
    "seed=4;memo:corrupt@0.3,op=place",
    "seed=6;memo:drop@0.6,op=pass",
])
def test_memo_faults_force_rebuild_never_misplacement(spec):
    # periodic workloads re-query the memo heavily (recurring stages);
    # production DAGs can build memo-cold, which would never fire the seam
    dag = periodic_dag(np.random.default_rng(6))
    want = build_schedule(dag, 6, memoize=True)
    plan = FaultPlan.parse(spec)
    assert plan.is_exact_recoverable()
    with faults.scope(plan):
        got = build_schedule(dag, 6, memoize=True)
    _assert_same_schedule(got, want)
    assert plan.snapshot()                           # plan actually fired


def test_memo_corruption_is_detected_and_discarded():
    from repro.core.memo import counters_snapshot

    dag = periodic_dag(np.random.default_rng(6))
    want = build_schedule(dag, 6, memoize=True)
    before = counters_snapshot()["memo_discarded"]
    with faults.scope("seed=2;memo:corrupt@0.5"):
        got = build_schedule(dag, 6, memoize=True)
    _assert_same_schedule(got, want)
    # the checksum caught every corrupted entry (miss -> live re-search)
    assert counters_snapshot()["memo_discarded"] > before


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 10_000), prob=st.floats(0.1, 0.9))
    def test_memo_fault_property(seed, prob):
        dag = periodic_dag(np.random.default_rng(8))
        want = build_schedule(dag, 6, memoize=True)
        plan = FaultPlan.parse(
            f"seed={seed};memo:corrupt@{prob:.3f};memo:drop@{prob / 2:.3f}")
        with faults.scope(plan):
            got = build_schedule(dag, 6, memoize=True)
        _assert_same_schedule(got, want)


# ----------------------------------------------------------------------
# acceptance property: exact-recoverable plans are decision-exact
# ----------------------------------------------------------------------

_SIM_KW = dict(n_machines=24, interarrival=2.0, n_groups=2, seed=6,
               build_machines=4, matcher_shards=2)
_REC = RecoveryPolicy(launch_timeout=5.0, launch_retries=1, backoff=0.001,
                      backoff_cap=0.002, quarantine_after=2, probe_every=3)
_HEALTHY_KEY = {}


def _decision_key(res):
    return ([(j.job_id, repr(j.jct)) for j in
             sorted(res.jobs, key=lambda j: j.job_id)],
            repr(res.makespan))


def _healthy_key():
    if "key" not in _HEALTHY_KEY:
        res = run_workload(online_mix_workload(6, seed=6), "dagps",
                           fault_plan=FaultPlan(), **_SIM_KW)
        _HEALTHY_KEY["key"] = _decision_key(res)
    return _HEALTHY_KEY["key"]


def _assert_exact(plan):
    assert plan.is_exact_recoverable()
    res = run_workload(online_mix_workload(6, seed=6), "dagps",
                       fault_plan=plan, recovery=_REC, **_SIM_KW)
    assert _decision_key(res) == _healthy_key()
    return res


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_exact_recoverable_plan_is_decision_exact(seed):
    res = _assert_exact(FaultPlan.parse(
        f"seed={seed};shard_launch:raise@0.5;"
        "shard_launch:hang@0.2,delay=0.005"))
    assert res.fault_stats["injections"]             # plan actually fired
    shard = res.fault_stats["shard"]
    assert shard["launch_retries"] + shard["quarantined_launches"] > 0
    assert res.fault_stats["recovery_secs"] > 0


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None,
              suppress_health_check=[HealthCheck.function_scoped_fixture])
    @given(seed=st.integers(0, 10_000),
           prob=st.floats(0.1, 0.9))
    def test_exact_recovery_property(seed, prob):
        _assert_exact(FaultPlan.parse(
            f"seed={seed};shard_launch:raise@{prob:.3f}"))
