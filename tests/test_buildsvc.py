"""Concurrent build service: digest dedup, worker-pool parity, and the
thread-safety hardening of the shared engine state it leans on.

Determinism contract: build_schedule is a pure function of (DAG content,
m, knobs), so the service's worker pool and dedup front must be invisible
in the output — every test here diffs against a plain serial loop.  The
concurrency smokes hammer the state that used to be single-thread-only:
kernels.PROFILE dispatch accounting, the XLA bucket LRU, memo.COUNTERS.
"""

import threading

import numpy as np
import pytest

from repro.core import build_schedule
from repro.core.buildsvc import BuildService, build_many
from repro.core.dag import DAG, dag_digest
from repro.core.engine import JitBackend, kernels
from repro.core.engine.base import ceil32
from repro.core.memo import COUNTERS
from repro.sim import clear_schedule_cache, run_workload
from repro.sim.workload import production_dag


def _dag_copy(dag, parents=None, duration=None, demand=None, stage_of=None):
    return DAG(
        duration=dag.duration.copy() if duration is None else duration,
        demand=dag.demand.copy() if demand is None else demand,
        stage_of=dag.stage_of.copy() if stage_of is None else stage_of,
        parents=[p.copy() for p in dag.parents] if parents is None else parents,
        name=dag.name,
    )


def _assert_same_schedule(a, b, ctx=""):
    assert a.makespan == b.makespan, f"makespan differs {ctx}"
    assert np.array_equal(a.start, b.start), f"starts differ {ctx}"
    assert np.array_equal(a.machine, b.machine), f"machines differ {ctx}"
    assert np.array_equal(a.order, b.order), f"order differs {ctx}"


class TestDagDigest:
    def _base(self):
        return production_dag(np.random.default_rng(0), scale=0.35, share=3)

    def test_equal_content_collides(self):
        dag = self._base()
        assert dag_digest(dag) == dag_digest(_dag_copy(dag))

    def test_parent_row_order_is_presentation_not_content(self):
        """Edge insertion order within a parents row must not change the
        digest: every consumer treats the row as a set."""
        dag = self._base()
        perm = [p[::-1].copy() for p in dag.parents]
        assert any(len(p) > 1 for p in perm), "corpus DAG lost its joins"
        assert dag_digest(dag) == dag_digest(_dag_copy(dag, parents=perm))

    def test_permuted_identical_siblings_collide(self):
        """Inserting interchangeable stage siblings in a different order
        is a content no-op — all id-indexed arrays come out equal — and
        must collide; permuting *distinguishable* tasks relabels ids and
        must not (schedules are id-indexed)."""
        def stage_dag(sib_durs):
            n = 1 + len(sib_durs)
            return DAG(duration=np.array([4.0] + list(sib_durs)),
                       demand=np.vstack([[0.5, 0.2]] * n),
                       stage_of=np.array([0] + [1] * len(sib_durs)),
                       parents=[np.empty(0, np.int64)]
                       + [np.array([0])] * len(sib_durs))

        a = stage_dag([1.0, 1.0, 1.0])
        b = stage_dag([1.0, 1.0, 1.0])     # siblings "inserted" in any order
        assert dag_digest(a) == dag_digest(b)
        c = stage_dag([1.0, 2.0, 1.0])
        d = stage_dag([2.0, 1.0, 1.0])     # distinguishable: ids now differ
        assert dag_digest(c) != dag_digest(d)

    def test_differing_demand_and_duration_do_not_collide(self):
        dag = self._base()
        dem = dag.demand.copy()
        dem[0, 0] = min(dem[0, 0] + 0.01, 1.0)
        assert dag_digest(dag) != dag_digest(_dag_copy(dag, demand=dem))
        dur = dag.duration.copy()
        dur[1] += 0.5
        assert dag_digest(dag) != dag_digest(_dag_copy(dag, duration=dur))

    def test_differing_structure_does_not_collide(self):
        dag = self._base()
        stage = dag.stage_of.copy()
        stage[-1] = stage[-2]
        assert dag_digest(dag) != dag_digest(_dag_copy(dag, stage_of=stage))
        parents = [p.copy() for p in dag.parents]
        victim = next(i for i, p in enumerate(parents) if len(p) > 1)
        parents[victim] = parents[victim][:-1]
        assert dag_digest(dag) != dag_digest(_dag_copy(dag, parents=parents))

    def test_simulator_cache_and_service_share_the_digest(self):
        """One canonical digest: the service's dedup key and the sim
        cache key must start from the same bytes."""
        dag = self._base()
        svc = BuildService(workers=1, mode="serial")
        assert svc.key_for(dag, 3)[0] == dag_digest(dag)


class TestBuildService:
    def _dags(self, n=4):
        return [production_dag(np.random.default_rng(s), scale=0.35, share=3)
                for s in range(n)]

    @pytest.mark.parametrize("mode", ["serial", "thread", "process"])
    def test_build_many_parity(self, mode):
        dags = self._dags(3)
        serial = [build_schedule(d, 3, ticks=96) for d in dags]
        got = build_many(dags, 3, workers=2, mode=mode, ticks=96)
        for s, g, d in zip(serial, got, dags):
            _assert_same_schedule(s, g, f"(mode={mode})")
            assert g.dag is d, "Schedule must rebind the submitted DAG"

    def test_dedup_front(self):
        dag = self._dags(1)[0]
        twin = _dag_copy(dag)
        with BuildService(workers=2, mode="thread") as svc:
            a = svc.submit(dag, 3, ticks=96)
            b = svc.submit(dag, 3, ticks=96)       # same object
            c = svc.submit(twin, 3, ticks=96)      # equal content
            d = svc.submit(dag, 4, ticks=96)       # different share: rebuild
            _assert_same_schedule(a.result(), b.result())
            _assert_same_schedule(a.result(), c.result())
            assert c.result().dag is twin
            assert svc.stats["submitted"] == 4
            assert svc.stats["built"] == 2
            assert svc.stats["deduped"] == 2
            d.result()

    def test_completed_entries_serve_as_cache(self):
        dag = self._dags(1)[0]
        with BuildService(workers=2, mode="thread") as svc:
            first = svc.submit(dag, 3, ticks=96)
            first.result()                      # finished and retired
            again = svc.submit(dag, 3, ticks=96)
            assert svc.stats["built"] == 1
            _assert_same_schedule(first.result(), again.result())

    def test_knobs_partition_the_key(self):
        dag = self._dags(1)[0]
        svc = BuildService(workers=1, mode="serial")
        keys = {svc.key_for(dag, 3),
                svc.key_for(dag, 3, ticks=128),
                svc.key_for(dag, 3, memoize=False),
                svc.key_for(dag, 3, backend="reference"),
                svc.key_for(dag, 4)}
        assert len(keys) == 5
        with pytest.raises(TypeError):
            svc.key_for(dag, 3, bogus_knob=1)

    def test_shutdown_rejects_new_work(self):
        svc = BuildService(workers=1, mode="serial")
        svc.shutdown()
        with pytest.raises(RuntimeError):
            svc.submit(self._dags(1)[0], 3)

    def test_bad_mode_and_workers(self):
        with pytest.raises(ValueError):
            BuildService(workers=2, mode="fibers")
        with pytest.raises(ValueError):
            BuildService(workers=0)

    def test_build_and_clear_cache(self):
        dag = self._dags(1)[0]
        with BuildService(workers=1, mode="serial") as svc:
            a = svc.build(dag, 3, ticks=96)
            svc.clear_cache()
            b = svc.build(dag, 3, ticks=96)
            assert svc.stats["built"] == 2      # cache dropped in between
            _assert_same_schedule(a, b)

    def test_env_defaults(self, monkeypatch):
        from repro.core import buildsvc

        monkeypatch.setenv(buildsvc.WORKERS_ENV, "3")
        monkeypatch.setenv(buildsvc.MODE_ENV, "thread")
        svc = BuildService()
        assert svc.workers == 3 and svc.mode == "thread"
        monkeypatch.delenv(buildsvc.WORKERS_ENV)
        assert buildsvc.default_workers() >= 1
        monkeypatch.setenv(buildsvc.MP_ENV, "fork")
        assert buildsvc._default_mp_context().get_start_method() == "fork"


class TestSimIntegration:
    def test_build_workers_bit_identical(self):
        """The whole point: overlapped construction changes wall clock
        only — every scheduling decision matches the serial path."""
        dags = [production_dag(np.random.default_rng(60 + s), scale=0.35,
                               share=3) for s in range(6)]
        kw = dict(n_machines=20, interarrival=4.0, seed=9, build_machines=3)
        clear_schedule_cache()
        base = run_workload(dags, "dagps", **kw)
        clear_schedule_cache()
        par = run_workload(dags, "dagps", build_workers=2, **kw)
        assert np.array_equal(base.jcts(), par.jcts())
        assert base.makespan == par.makespan
        clear_schedule_cache()
        nocache = run_workload(dags, "dagps", build_workers=2,
                               schedule_cache=False, **kw)
        assert np.array_equal(base.jcts(), nocache.jcts())

    def test_non_dagps_schemes_skip_the_service(self):
        dags = [production_dag(np.random.default_rng(70), scale=0.35, share=3)]
        res = run_workload(dags, "tez", n_machines=10, seed=1,
                           build_workers=4)
        assert len(res.jobs) == 1


class TestThreadSafetyHardening:
    def test_counters_add_is_atomic(self):
        base = COUNTERS["places_evaluated"]
        n_threads, n_adds = 8, 5000

        def work():
            for _ in range(n_adds):
                COUNTERS.add("places_evaluated")

        ts = [threading.Thread(target=work) for _ in range(n_threads)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        assert COUNTERS["places_evaluated"] == base + n_threads * n_adds

    def test_dispatch_profile_counts_exact_under_threads(self):
        kernels.reset_profile()
        avail = np.ones((4, 64, 2), dtype=np.float32)
        Vs = ceil32(np.full((3, 2), 0.4))
        ks = np.array([2, 3, 4])
        n_threads, n_calls = 8, 40
        ref = kernels.scan_starts(avail, Vs, ks, 0, 32)
        errs = []

        def work():
            try:
                for _ in range(n_calls):
                    got = kernels.scan(avail, Vs, ks, 0, 32)
                    assert np.array_equal(got, ref)
            except Exception as e:  # pragma: no cover - failure path
                errs.append(e)

        ts = [threading.Thread(target=work) for _ in range(n_threads)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        assert not errs
        snap = kernels.profile_snapshot()
        total = sum(calls for key, (calls, _s) in snap.items()
                    if key.startswith("scan."))
        assert total == n_threads * n_calls, "dispatch accounting dropped calls"

    def test_bucket_cache_builds_each_key_once(self):
        built = []
        cache = kernels._BucketCache(
            lambda *k: built.append(k) or object(), cap=16)
        barrier = threading.Barrier(8)

        def work():
            barrier.wait()
            for i in range(4):
                cache.get((i,))

        ts = [threading.Thread(target=work) for _ in range(8)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        assert sorted(built) == [(0,), (1,), (2,), (3,)]

    def test_concurrent_builds_all_backends(self):
        """Thread-mode hammer across every backend at once — the jit
        sessions exercise per-Space device mirrors and the shared compile
        caches under real concurrency; outputs must equal solo builds."""
        backends = ["reference", "batched"]
        if JitBackend.available():
            backends.append("jit")
        dags = [production_dag(np.random.default_rng(s), scale=0.35, share=3)
                for s in range(3)]
        expect = {be: [build_schedule(d, 3, ticks=96, backend=be)
                       for d in dags] for be in backends}
        with BuildService(workers=4, mode="thread") as svc:
            handles = [(be, i, svc.submit(d, 3, ticks=96, backend=be))
                       for be in backends for i, d in enumerate(dags)]
            for be, i, h in handles:
                _assert_same_schedule(expect[be][i], h.result(),
                                      f"(backend={be}, dag={i})")


class TestMinBatchAutotune:
    def test_env_override_wins(self, monkeypatch):
        from repro.core.engine import jit as J

        monkeypatch.setattr(J, "MIN_DEVICE_G", None)
        monkeypatch.setenv("REPRO_JIT_MIN_BATCH", "7")
        assert J.min_device_g() == 7

    def test_auto_by_platform(self, monkeypatch):
        from repro.core.engine import jit as J

        if not J._HAVE_JAX:
            pytest.skip("requires jax")
        monkeypatch.delenv("REPRO_JIT_MIN_BATCH", raising=False)
        monkeypatch.setattr(J.jax, "default_backend", lambda: "tpu")
        monkeypatch.setattr(J, "MIN_DEVICE_G", None)
        assert J.min_device_g() == 4      # real accelerator: low floor
        monkeypatch.setattr(J.jax, "default_backend", lambda: "cpu")
        monkeypatch.setattr(J, "MIN_DEVICE_G", None)
        assert J.min_device_g() == 16     # CPU host: launch overhead wins

    def test_monkeypatched_constant_is_honored(self, monkeypatch):
        from repro.core.engine import jit as J

        monkeypatch.setattr(J, "MIN_DEVICE_G", 3)
        assert J.min_device_g() == 3
