"""Fused-wave parity: the device match_wave kernels ≡ the numpy wave loop.

The ``match_wave`` op (engine/wave.py) runs a whole heartbeat wave —
eligibility, pack scoring, bundling/deficit gating and the avail update —
as one device launch.  Its contract is *bit*-exactness: the xla and
pallas-interpret implementations must reproduce the numpy wave's pick
sequence, overbook flags, EMA observations, deficit ledgers and the
availability matrix down to the last ulp, across carried-over matcher
state, shard counts, external churn, and sticky demotion after injected
kernel faults.
"""

import numpy as np
import pytest

from repro.core import faults
from repro.core.engine import kernels
from repro.core.online import Matcher, MatcherConfig
from repro.core.shard import ShardedMatcher

from test_online_parity import _batch_from, _random_heartbeat, _wave_oracle

IMPLS = ["xla", "pallas"]


def _impl_available(impl: str) -> bool:
    ent = kernels._REGISTRY.get(("match_wave", impl))
    return ent is not None and ent[1]()


def _force(monkeypatch, impl: str) -> None:
    monkeypatch.setenv(kernels.KERNELS_ENV, f"match_wave={impl}")


def _run_waves(sm, avail, alive, batch, n_waves):
    """Drive n_waves through sm.match_wave, logging (row, machine) picks."""
    out = []
    for _ in range(n_waves):
        got = []

        def cb(gi, m):
            got.append((gi, m))
            avail[m] -= batch.dem[gi]

        sm.match_wave(avail, alive, batch, cb)
        out.append(got)
    return out


def _assert_state_equal(sm, oracle, s_avail, o_avail, ctx=""):
    assert s_avail.tobytes() == o_avail.tobytes(), ctx
    assert sm.matcher._ema_score == oracle._ema_score, ctx
    assert sm.matcher._ema_srpt == oracle._ema_srpt, ctx
    assert sm.matcher.deficits.deficit == oracle.deficits.deficit, ctx


def _one_corpus(seed):
    rng = np.random.default_rng(31337 + seed)
    tasks, jobs, cfg, shares, _ = _random_heartbeat(rng)
    batch = _batch_from(tasks, jobs)
    M = int(rng.integers(5, 40))
    avail0 = rng.uniform(0.0, 1.2, (M, 4))
    alive = rng.random(M) < 0.9
    return batch, cfg, shares, M, avail0, alive


@pytest.mark.parametrize("impl", IMPLS)
def test_fused_wave_parity_all_shard_counts(impl, monkeypatch):
    """Forced device wave ≡ the numpy oracle: picks, EMA, deficits, avail.

    Several consecutive waves against carried-over matcher and device-
    resident state (the avail/EMA/deficit mirrors persist across waves),
    for 1/2/4 shards; everything must match the host loop bitwise.
    """
    if not _impl_available(impl):
        pytest.skip(f"match_wave {impl} implementation unavailable")
    _force(monkeypatch, impl)
    kernels.reset_profile()
    try:
        for seed in range(8):
            batch, cfg, shares, M, avail0, alive = _one_corpus(seed)
            oracle = Matcher(cfg, capacity=float(M), shares=shares)
            o_avail = avail0.copy()
            want = [_wave_oracle(oracle, o_avail, alive, batch)
                    for _ in range(3)]
            for n_shards in (1, 2, 4):
                sm = ShardedMatcher(cfg, M, shares, n_shards=n_shards,
                                    capacity=float(M))
                s_avail = avail0.copy()
                with sm:
                    got = _run_waves(sm, s_avail, alive, batch, 3)
                assert got == want, (impl, seed, n_shards)
                _assert_state_equal(sm, oracle, s_avail, o_avail,
                                    (impl, seed, n_shards))
    finally:
        kernels.reset_demotions()
    prof = kernels.profile_snapshot()
    # the forced impl really ran (a silent demotion to numpy would make
    # this parity check vacuous)
    assert prof.get(f"match_wave.{impl}", (0, 0))[0] > 0
    assert f"match_wave.{impl}.demoted" not in prof


def test_fused_wave_under_churn(monkeypatch):
    """Device-resident state survives external mutation between waves.

    Task finishes (avail rows restored), machine failures/rejoins (alive
    flips + row zeroing), and batch turnover (new candidate columns) all
    happen host-side between waves; the dirty-row sync must land the
    fused wave on exactly the numpy decisions, and the wave must stay at
    most 2 launches (wave + dirty-row scatter)."""
    if not _impl_available("xla"):
        pytest.skip("match_wave xla implementation unavailable")
    _force(monkeypatch, "xla")
    kernels.reset_profile()
    rng = np.random.default_rng(4242)
    try:
        for seed in range(4):
            batch, cfg, shares, M, avail0, alive0 = _one_corpus(seed)
            oracle = Matcher(cfg, capacity=float(M), shares=shares)
            sm = ShardedMatcher(cfg, M, shares, n_shards=1,
                                capacity=float(M))
            o_avail = avail0.copy()
            s_avail = avail0.copy()
            alive = alive0.copy()
            with sm:
                for wave in range(6):
                    want = _wave_oracle(oracle, o_avail, alive, batch)
                    got = _run_waves(sm, s_avail, alive, batch, 1)[0]
                    assert got == want, (seed, wave)
                    _assert_state_equal(sm, oracle, s_avail, o_avail,
                                        (seed, wave))
                    # external churn the device mirror cannot see coming
                    rows = rng.integers(0, M, size=3)
                    bump = rng.uniform(0.0, 0.5, (3, 4))
                    for r, b in zip(rows, bump):
                        o_avail[r] += b
                        s_avail[r] += b
                    flip = int(rng.integers(0, M))
                    alive[flip] = ~alive[flip]
                    if wave % 2 == 1:       # batch turnover mid-run
                        tasks, jobs, _cfg, _sh, _ = _random_heartbeat(
                            np.random.default_rng(9000 + seed * 10 + wave))
                        nb = _batch_from(tasks, jobs)
                        keep = np.isin(nb.grp, list(shares))
                        if keep.any():
                            batch = nb.take(np.flatnonzero(keep))
    finally:
        kernels.reset_demotions()
    prof = kernels.profile_snapshot()
    waves = prof.get("match_wave.xla.waves", (0, 0))[0]
    launches = prof.get("match_wave.xla.launches", (0, 0))[0]
    assert waves > 0
    assert launches <= 2 * waves
    assert "match_wave.xla.demoted" not in prof


def test_fused_wave_demotion_is_decision_exact(monkeypatch):
    """An injected kernel fault sticky-demotes the wave back onto the
    numpy loop with zero decision drift: the fault fires before the
    device impl touches any matcher state."""
    if not _impl_available("xla"):
        pytest.skip("match_wave xla implementation unavailable")
    _force(monkeypatch, "xla")
    batch, cfg, shares, M, avail0, alive = _one_corpus(2)
    oracle = Matcher(cfg, capacity=float(M), shares=shares)
    o_avail = avail0.copy()
    want = [_wave_oracle(oracle, o_avail, alive, batch) for _ in range(3)]
    try:
        with faults.scope("seed=1;kernel_impl:raise@1,impl=xla,count=1"):
            sm = ShardedMatcher(cfg, M, shares, n_shards=1,
                                capacity=float(M))
            s_avail = avail0.copy()
            with sm:
                got = _run_waves(sm, s_avail, alive, batch, 3)
            assert got == want
            _assert_state_equal(sm, oracle, s_avail, o_avail)
        assert kernels.demotions_snapshot().get("match_wave.xla.demoted",
                                                0) >= 1
    finally:
        kernels.reset_demotions()


def test_fused_wave_custom_fairness_delegates_to_numpy(monkeypatch):
    """A fairness fn the kernel cannot mirror falls back to the host loop
    inline (not via demotion) — decisions unchanged, no device wave."""
    if not _impl_available("xla"):
        pytest.skip("match_wave xla implementation unavailable")
    _force(monkeypatch, "xla")
    kernels.reset_profile()

    def halved(demand):
        return 0.5 * float(np.max(demand))

    batch, cfg0, shares, M, avail0, alive = _one_corpus(5)
    import dataclasses
    cfg = dataclasses.replace(cfg0, fairness=halved)
    oracle = Matcher(cfg, capacity=float(M), shares=shares)
    o_avail = avail0.copy()
    want = [_wave_oracle(oracle, o_avail, alive, batch) for _ in range(2)]
    try:
        sm = ShardedMatcher(cfg, M, shares, n_shards=1, capacity=float(M))
        s_avail = avail0.copy()
        with sm:
            got = _run_waves(sm, s_avail, alive, batch, 2)
        assert got == want
        _assert_state_equal(sm, oracle, s_avail, o_avail)
    finally:
        kernels.reset_demotions()
    prof = kernels.profile_snapshot()
    assert "match_wave.xla.waves" not in prof      # no device wave ran
    assert "match_wave.xla.demoted" not in prof    # and none was demoted


def test_match_wave_auto_promotes_with_machine_count(monkeypatch):
    """match_wave rides the heartbeat auto-promotion ladder: numpy below
    the device threshold, xla at/above it; an explicit pin wins."""
    if not kernels.have_jax():
        pytest.skip("jax unavailable")
    monkeypatch.delenv(kernels.KERNELS_ENV, raising=False)
    monkeypatch.delenv(kernels.HEARTBEAT_MIN_M_ENV, raising=False)
    thr = kernels.heartbeat_device_min_m()
    assert kernels.heartbeat_impl("match_wave", thr - 1) == "numpy"
    assert kernels.heartbeat_impl("match_wave", thr) == "xla"
    monkeypatch.setenv(kernels.KERNELS_ENV, "match_wave=numpy")
    assert kernels.heartbeat_impl("match_wave", thr) == "numpy"


def test_sim_routed_mode_runs_and_differs_from_exact():
    """SimConfig.matcher_mode='routed' is a valid (lossy) preset: the sim
    completes every job; an unknown mode raises."""
    from repro.sim import make_workload, run_workload

    dags = make_workload("production", 4, seed=11)
    exact = run_workload(dags, "dagps", n_machines=8, interarrival=5.0,
                         seed=11, n_groups=2, matcher_shards=2)
    routed = run_workload(dags, "dagps", n_machines=8, interarrival=5.0,
                          seed=11, n_groups=2, matcher_shards=2,
                          matcher_mode="routed")
    assert len(routed.jobs) == len(exact.jobs) == 4
    assert routed.makespan > 0
    with pytest.raises(ValueError, match="matcher_mode"):
        run_workload(dags[:1], "dagps", n_machines=4, interarrival=5.0,
                     seed=11, matcher_mode="bogus")


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_fused_wave_parity_hypothesis(seed):
        """Property form of the seeded parity sweep (xla, one shard)."""
        if not _impl_available("xla"):
            pytest.skip("match_wave xla implementation unavailable")
        import os
        old = os.environ.get(kernels.KERNELS_ENV)
        os.environ[kernels.KERNELS_ENV] = "match_wave=xla"
        try:
            rng = np.random.default_rng(seed)
            tasks, jobs, cfg, shares, _ = _random_heartbeat(rng)
            batch = _batch_from(tasks, jobs)
            M = int(rng.integers(5, 40))
            avail0 = rng.uniform(0.0, 1.2, (M, 4))
            alive = rng.random(M) < 0.9
            oracle = Matcher(cfg, capacity=float(M), shares=shares)
            o_avail = avail0.copy()
            want = [_wave_oracle(oracle, o_avail, alive, batch)
                    for _ in range(2)]
            sm = ShardedMatcher(cfg, M, shares, n_shards=1,
                                capacity=float(M))
            s_avail = avail0.copy()
            with sm:
                got = _run_waves(sm, s_avail, alive, batch, 2)
            assert got == want
            _assert_state_equal(sm, oracle, s_avail, o_avail)
        finally:
            kernels.reset_demotions()
            if old is None:
                os.environ.pop(kernels.KERNELS_ENV, None)
            else:
                os.environ[kernels.KERNELS_ENV] = old
except ImportError:  # pragma: no cover - hypothesis ships with .[test]
    pass
