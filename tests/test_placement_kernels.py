"""Kernel-dispatch layer tests (core/engine/kernels.py + kernels/placement_scan).

Three contracts, each locked here:

  * the windowed feasibility scan is BIT-IDENTICAL across the numpy, xla
    and pallas (interpret mode) implementations over the full engine
    corpus's grid states — float32 compare + integer run counting leave
    no room for drift, and ``ceil32`` makes the float32 demand rounding
    exact (hypothesis property test);
  * the accelerated heartbeat ops are sound SUPERSETS of the exact numpy
    masks (directed rounding can only add eligibility, never drop it),
    which makes them decision-exact for their skip-only consumers — the
    full simulator produces identical results under every implementation;
  * the jit backend's device-resident session (persistent grid mirror,
    async lazy rows) returns exactly what the numpy kernel returns, under
    commits, rollbacks and growth in both directions.
"""

import os

import numpy as np
import pytest

from repro.core import DAG, Space, build_schedule
from repro.core.engine import JitBackend, kernels, packing
from repro.core.engine.base import ceil32

HAVE_JAX = kernels.have_jax()
needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax unavailable")


def _cluttered_space(seed, m=None, d=None, horizon=None, commits=30):
    rng = np.random.default_rng(seed)
    m = m or int(rng.integers(1, 9))
    d = d or int(rng.integers(1, 5))
    s = Space(m=m, d=d, horizon=horizon or int(rng.integers(32, 200)))
    for t in range(commits):
        v = rng.uniform(0.1, 0.6, s.d)
        k = int(rng.integers(1, 12))
        mm, t0 = s.earliest_fit(v, k, int(rng.integers(0, 60)))
        s.commit(t, mm, t0, k, v)
    return s, rng


class TestScanParity:
    """All scan implementations agree bit-for-bit."""

    @needs_jax
    def test_random_grids_all_impls(self):
        for seed in range(8):
            s, rng = _cluttered_space(seed)
            g = int(rng.integers(1, 30))
            Vs = ceil32(rng.uniform(0.2, 0.8, (g, s.d)))
            ks = rng.integers(1, 160, g)   # crosses the LONG_K bucket edge
            plo = int(rng.integers(0, 10))
            phi = int(rng.integers(plo + 5, s.T))
            for rev in (False, True):
                ref = kernels.scan_starts(s.avail, Vs, ks, plo, phi, rev)
                xla = kernels._scan_xla(s.avail, Vs, ks, plo, phi, rev)
                assert np.array_equal(ref, xla), f"xla != numpy (seed {seed})"

    @needs_jax
    def test_pallas_interpret_matches_numpy(self):
        for seed in range(4):
            s, rng = _cluttered_space(seed, commits=20)
            g = int(rng.integers(1, 12))
            Vs = ceil32(rng.uniform(0.2, 0.8, (g, s.d)))
            ks = rng.integers(1, 12, g)
            phi = min(s.T, 40)
            for rev in (False, True):
                ref = kernels.scan_starts(s.avail, Vs, ks, 0, phi, rev)
                pal = kernels._scan_pallas(s.avail, Vs, ks, 0, phi, rev)
                assert np.array_equal(ref, pal), f"pallas != numpy (seed {seed})"

    @needs_jax
    def test_pallas_ref_oracle_matches_kernel(self):
        """kernel.py (interpret) vs ref.py on identical padded operands."""
        from repro.kernels.placement_scan import kernel as psk, ref as psr

        rng = np.random.default_rng(7)
        m, L, d, g, W = 3, 96, 2, 8, 48
        win = rng.uniform(0.0, 1.0, (m, L, d)).astype(np.float32)
        Vs = rng.uniform(0.2, 0.8, (g, d)).astype(np.float32)
        ks = rng.integers(1, 40, g).astype(np.int32)
        a = np.asarray(psk.scan_bitmaps(win, Vs, ks, 80, W, interpret=True))
        b = np.asarray(psr.scan_bitmaps(win, Vs, ks, 80, W))
        assert np.array_equal(a != 0, b != 0)

    def test_dispatch_env_selection_and_fallback(self, monkeypatch):
        monkeypatch.setenv(kernels.KERNELS_ENV, "scan=xla")
        impl, _fn = kernels.resolve("scan")
        assert impl == ("xla" if HAVE_JAX else "numpy")
        monkeypatch.setenv(kernels.KERNELS_ENV, "all=numpy")
        assert kernels.active() == {op: "numpy" for op in kernels.OPS}
        if HAVE_JAX:
            # all=<impl> must not accelerate the decision-capable ops —
            # those require an explicit per-op opt-in
            monkeypatch.setenv(kernels.KERNELS_ENV, "all=xla")
            act = kernels.active()
            assert act["scan"] == act["machines_with_candidates"] == "xla"
            for op in kernels.EXPLICIT_ONLY:
                assert act[op] == "numpy"
            monkeypatch.setenv(kernels.KERNELS_ENV, "heartbeat_masks=xla")
            assert kernels.active()["heartbeat_masks"] == "xla"
        monkeypatch.setenv(kernels.KERNELS_ENV, "scan=nope")
        with pytest.raises(ValueError):
            kernels.resolve("scan")
        monkeypatch.setenv(kernels.KERNELS_ENV, "bogus_op=numpy")
        with pytest.raises(ValueError):
            kernels.resolve("scan")
        monkeypatch.delenv(kernels.KERNELS_ENV)
        # pack_score / heartbeat_masks stay numpy unless explicitly pinned
        assert kernels.resolve("pack_score")[0] == "numpy"
        assert kernels.resolve("heartbeat_masks")[0] == "numpy"

    @needs_jax
    def test_dispatch_routes_scan_through_xla(self, monkeypatch):
        monkeypatch.setenv(kernels.KERNELS_ENV, "scan=xla")
        s, rng = _cluttered_space(11, commits=15)
        Vs = ceil32(rng.uniform(0.2, 0.8, (3, s.d)))
        ks = rng.integers(1, 8, 3)
        got = kernels.scan(s.avail, Vs, ks, 0, 30, False)
        ref = kernels.scan_starts(s.avail, Vs, ks, 0, 30, False)
        assert np.array_equal(got, ref)
        assert kernels.PROFILE.get("scan.xla", [0])[0] > 0


class TestCeil32Exactness:
    def test_seeded_boundaries(self):
        rng = np.random.default_rng(0)
        a = rng.uniform(0, 1, 4096).astype(np.float32)
        v = a.astype(np.float64) + rng.uniform(-1e-9, 1e-9, 4096)
        assert np.array_equal(a >= v, a >= ceil32(v))

    def test_hypothesis_boundary_exactness(self):
        """For any float32 grid cell a and float64 demand v:
        (a >= v) == (a >= ceil32(v)) — the scan's float32 compare is exact."""
        hypothesis = pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        f32 = st.floats(min_value=0.0, max_value=2.0, width=32,
                        allow_nan=False)
        ulp = st.integers(min_value=-4, max_value=4)
        off = st.floats(min_value=-1e-7, max_value=1e-7, allow_nan=False)

        @settings(max_examples=300, deadline=None)
        @given(f32, ulp, off)
        def check(a32, n, eps):
            a = np.float32(a32)
            # adversarial demand: a few float64 ulps around the grid value
            v = np.float64(a)
            for _ in range(abs(n)):
                v = np.nextafter(v, np.inf if n > 0 else -np.inf)
            v = v + eps
            c = ceil32(np.asarray([v]))[0]
            assert bool(a >= v) == bool(a >= c)

        check()


class TestHeartbeatSuperset:
    def _rand_state(self, rng, n, m, d=4):
        avail = rng.uniform(-0.05, 1.0, (m, d))
        dem = rng.uniform(0.0, 0.9, (n, d))
        return avail, dem

    @needs_jax
    def test_superset_property_seeded(self):
        fd, rd, gd = np.arange(4), np.array([0, 1]), np.array([2, 3])
        rng = np.random.default_rng(2)
        for trial in range(40):
            n, m = int(rng.integers(1, 20)), int(rng.integers(1, 40))
            avail, dem = self._rand_state(rng, n, m)
            slack = float(rng.uniform(0.0, 0.5))
            ob = bool(rng.integers(0, 2))
            exact, any_exact = packing.machines_with_candidates(
                avail, dem, fd, rd, gd, slack, ob)
            sup, any_sup = kernels._machines_with_candidates_xla(
                avail, dem, fd, rd, gd, slack, ob)
            assert (exact <= sup).all(), "xla dropped an eligible pair"
            assert (any_exact <= any_sup).all()
            pal, any_pal = kernels._machines_with_candidates_pallas(
                avail, dem, fd, rd, gd, slack, ob)
            assert (exact <= pal).all(), "pallas dropped an eligible pair"
            assert np.array_equal(sup, pal), "xla and pallas disagree"

    @needs_jax
    def test_superset_property_hypothesis(self):
        hypothesis = pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        fd, rd, gd = np.arange(4), np.array([0, 1]), np.array([2, 3])
        finite = st.floats(min_value=0.0, max_value=1.0, allow_nan=False)

        @settings(max_examples=60, deadline=None)
        @given(st.integers(0, 2**31 - 1), finite, finite)
        def check(seed, a0, d0):
            rng = np.random.default_rng(seed)
            avail, dem = self._rand_state(rng, 4, 6)
            # plant an exact-boundary pair: demand == avail on dim 0
            avail[0, 0] = a0
            dem[0, 0] = a0
            dem[1, 0] = d0
            exact, _ = packing.machines_with_candidates(
                avail, dem, fd, rd, gd, 0.25, True)
            sup, _ = kernels._machines_with_candidates_xla(
                avail, dem, fd, rd, gd, 0.25, True)
            assert (exact <= sup).all()

        check()

    @needs_jax
    def test_heartbeat_masks_xla_union_superset(self):
        fd, rd, gd = np.arange(4), np.array([0, 1]), np.array([2, 3])
        rng = np.random.default_rng(6)
        for _ in range(10):
            avail, dem = self._rand_state(rng, 8, 12)
            fits_e, over_e = packing.heartbeat_masks(avail, dem, fd, rd, gd,
                                                     0.25, True)
            fits_x, over_x = kernels._heartbeat_masks_xla(avail, dem, fd, rd,
                                                          gd, 0.25, True)
            # only the union is contract-bearing (see kernels module doc)
            assert ((fits_e | over_e) <= (fits_x | over_x)).all()
            assert (fits_e <= fits_x).all()

    @needs_jax
    def test_fits_mask_and_pack_score_xla_shapes(self):
        rng = np.random.default_rng(8)
        avail = rng.uniform(0, 1, (5, 4))
        dem = rng.uniform(0, 0.8, (3, 4))
        # fits_mask xla is a superset of the exact mask, all shape variants
        assert (packing.fits_mask(avail, dem)
                <= kernels._fits_mask_xla(avail, dem)).all()
        assert (packing.fits_mask(avail[0], dem[0])
                <= kernels._fits_mask_xla(avail[0], dem[0])).all()
        assert kernels._fits_mask_xla(avail, dem, dims=np.empty(0, int)).all()
        got = kernels._fits_mask_xla(avail, dem, dims=[0, 2], slack=0.1)
        assert got.shape == (3, 5)
        # pack_score xla: float32 — close to, not identical with, the oracle
        np.testing.assert_allclose(kernels._pack_score_xla(avail, dem),
                                   packing.pack_score(avail, dem), rtol=1e-5)
        np.testing.assert_allclose(
            kernels._pack_score_xla(avail[0], dem, clip=True),
            packing.pack_score(avail[0], dem, clip=True), rtol=1e-5)

    @needs_jax
    def test_empty_candidate_batch_shapes(self):
        fd, rd, gd = np.arange(4), np.array([0, 1]), np.array([2, 3])
        avail = np.ones((3, 4))
        dem = np.empty((0, 4))
        for fn in (kernels._machines_with_candidates_xla,
                   kernels._machines_with_candidates_pallas):
            elig, any_m = fn(avail, dem, fd, rd, gd, 0.25, True)
            assert elig.shape == (0, 3) and any_m.shape == (3,)
            assert not any_m.any()
        fits, over = kernels._heartbeat_masks_xla(avail, dem, fd, rd, gd,
                                                  0.25, True)
        assert fits.shape == over.shape == (0, 3)

    @needs_jax
    def test_sim_decisions_identical_under_xla_heartbeat(self, monkeypatch):
        """The whole simulator — picks, JCTs, makespan — is bit-identical
        when the heartbeat eligibility runs through the xla superset
        implementation (the skip-only consumer argument)."""
        from repro.sim import make_workload, run_workload

        dags = make_workload("tpcds", 4, seed=5)
        monkeypatch.delenv(kernels.KERNELS_ENV, raising=False)
        base = run_workload(dags, "dagps", n_machines=12, interarrival=5.0,
                            seed=5)
        monkeypatch.setenv(kernels.KERNELS_ENV,
                           "machines_with_candidates=xla")
        xla = run_workload(dags, "dagps", n_machines=12, interarrival=5.0,
                           seed=5)
        assert base.makespan == xla.makespan
        assert np.array_equal(base.jcts(), xla.jcts())
        assert kernels.PROFILE.get(
            "machines_with_candidates.xla", [0])[0] > 0


@needs_jax
class TestDeviceResidentSession:
    def _drain(self, goods, g):
        """Materialize a scan_kernel result (ndarray or lazy loaders)."""
        if isinstance(goods, np.ndarray):
            return goods
        return np.stack([goods[i]() for i in range(g)])

    def test_device_scan_matches_numpy_under_mutation(self, monkeypatch):
        from repro.core.engine import jit as J

        monkeypatch.setattr(J, "MIN_DEVICE_G", 1)
        be = JitBackend()
        rng = np.random.default_rng(9)
        s = Space(m=4, d=3, horizon=64)
        snaps = []
        for round_ in range(60):
            op = rng.random()
            if op < 0.5:
                v = rng.uniform(0.1, 0.5, 3)
                k = int(rng.integers(1, 10))
                mm, t0 = s.earliest_fit(v, k, int(rng.integers(0, 40)))
                s.commit(round_, mm, t0, k, v)
            elif op < 0.6:
                (s._grow_front if rng.random() < 0.5 else s._grow_back)()
            elif op < 0.75 or not snaps:
                snaps.append(s.snapshot())
            else:
                s.restore(snaps.pop())
            g = int(rng.integers(2, 12))
            Vs = ceil32(rng.uniform(0.2, 0.7, (g, 3)))
            ks = rng.integers(1, 12, g)
            plo = int(rng.integers(0, max(s.T - 10, 1)))
            phi = int(rng.integers(plo + 2, s.T))
            rev = bool(rng.integers(0, 2))
            got = self._drain(be.scan_kernel(s, Vs, ks, plo, phi, rev), g)
            ref = kernels.scan_starts(s.avail, Vs, ks, plo, phi, rev)
            assert np.array_equal(got, ref), f"device != numpy (round {round_})"

    def test_async_rows_capture_launch_state(self, monkeypatch):
        """A lazy row materialized AFTER later commits must reflect the
        grid as of the launch, exactly like a synchronous scan would."""
        from repro.core.engine import jit as J

        monkeypatch.setattr(J, "MIN_DEVICE_G", 1)
        be = JitBackend()
        s = Space(m=2, d=1, horizon=32)
        Vs = ceil32(np.full((3, 1), 0.6))
        ks = np.array([2, 2, 2])
        goods = be.scan_kernel(s, Vs, ks, 0, 16, False)
        ref = kernels.scan_starts(s.avail, Vs, ks, 0, 16, False)
        s.commit(0, 0, 0, 16, np.array([1.0]))   # machine 0 now fully busy
        got = self._drain(goods, 3)
        assert np.array_equal(got, ref), "lazy row leaked post-launch state"

    def test_min_batch_one_single_task_scan(self, monkeypatch):
        """REPRO_JIT_MIN_BATCH=1 (the accelerator setting) must not crash
        g=1 scans — the hybrid split needs a peer row, so singletons take
        the numpy path regardless of the threshold."""
        from repro.core.engine import jit as J

        monkeypatch.setattr(J, "MIN_DEVICE_G", 1)
        be = JitBackend()
        s = Space(m=2, d=1, horizon=32)
        Vs = ceil32(np.full((1, 1), 0.5))
        got = be.scan_kernel(s, Vs, np.array([3]), 0, 16, False)
        ref = kernels.scan_starts(s.avail, Vs, np.array([3]), 0, 16, False)
        assert np.array_equal(self._drain(got, 1), ref)

    def test_warm_rebuild_compiles_nothing(self):
        """Steady state: after one warm-up build of a DAG shape, repeat
        builds hit only cached scan/update buckets — zero compiles, zero
        evictions (the invariant behind the bench's jit_retraces row;
        first builds compile their buffer-length buckets on demand, which
        the bench's untimed warm-up absorbs)."""
        from repro.sim.workload import production_dag
        from repro.core import build_schedule as bs

        dag = production_dag(np.random.default_rng(5), scale=0.35, share=3)
        bs(dag, 3, backend="jit")          # warm-up: compiles on demand
        n0 = kernels.XLA_STATS["compiles"]
        e0 = kernels.XLA_STATS["evictions"]
        bs(dag, 3, backend="jit")
        assert kernels.XLA_STATS["compiles"] == n0, "bucket cache thrashed"
        assert kernels.XLA_STATS["evictions"] == e0

    def test_jit_build_parity_device_path_forced(self, monkeypatch):
        from repro.core.engine import jit as J
        from repro.sim.workload import production_dag

        monkeypatch.setattr(J, "MIN_DEVICE_G", 2)
        J.reset_profile()
        dag = production_dag(np.random.default_rng(3), scale=0.35, share=3)
        bat = build_schedule(dag, 3, ticks=96, backend="batched")
        jit = build_schedule(dag, 3, ticks=96, backend="jit")
        assert bat.makespan == jit.makespan
        assert np.array_equal(bat.start, jit.start)
        assert np.array_equal(bat.machine, jit.machine)
        assert J.PROFILE["device_calls"] > 0, "device path never exercised"

    def test_build_parity_under_forced_pallas_dispatch(self, monkeypatch):
        """End-to-end: a build whose batched scans route through the
        Pallas interpret kernels is bit-identical to the numpy build.
        (Tiny DAG — interpret mode is orders of magnitude slower.)"""
        rng = np.random.default_rng(4)
        n = 12
        dag = DAG(duration=rng.uniform(1, 6, n),
                  demand=rng.uniform(0.1, 0.6, (n, 2)),
                  stage_of=np.repeat(np.arange(4), 3),
                  parents=[np.empty(0, np.int64)] * 3
                  + [np.array([i - 3]) for i in range(3, n)])
        monkeypatch.delenv(kernels.KERNELS_ENV, raising=False)
        base = build_schedule(dag, 3, ticks=64, backend="batched")
        monkeypatch.setenv(kernels.KERNELS_ENV, "scan=pallas")
        pal = build_schedule(dag, 3, ticks=64, backend="batched")
        assert base.makespan == pal.makespan
        assert np.array_equal(base.start, pal.start)
        assert np.array_equal(base.machine, pal.machine)
        assert kernels.PROFILE.get("scan.pallas", [0])[0] > 0

    def test_bucket_cache_bounded(self):
        cache = kernels._BucketCache(lambda *k: object(), cap=4)
        before = kernels.XLA_STATS["compiles"]
        for i in range(10):
            cache.get((i,))
        assert len(cache) == 4
        assert kernels.XLA_STATS["compiles"] - before == 10
        assert kernels.XLA_STATS["evictions"] >= 6
        # re-getting a cached key neither compiles nor evicts
        n = kernels.XLA_STATS["compiles"]
        cache.get((9,))
        assert kernels.XLA_STATS["compiles"] == n
