"""Unit tests for construction-memo correctness edge cases (core/memo.py)
and the frag-score / candidate-enumeration tie handling they lean on.

The memo's exactness rests on three claims, each locked here:

  * pass keys are *set* digests — permuted-but-equal id sets must collide
    (that is a correct hit: place_pass heapifies, so its outcome is
    order-independent);
  * a windowed place entry validates only against bit-equal window
    content — any commit, rollback or deadline-growth that changes the
    cells a search examined must miss (the PR 2 stale-bitmap bug class);
  * degenerate inputs (zero-task DAGs, single-partition DAGs) take the
    memo paths without tripping them.
"""

import numpy as np
import pytest

from repro.core import DAG, Space, build_schedule, get_backend
from repro.core.builder import (_Placer, _span_lb_ticks, candidate_troublesome,
                                frag_scores, partition_totally_ordered)
from repro.core.engine import BACKWARD, FORWARD
from repro.core.memo import COUNTERS, ConstructionMemo, item_hash


def _placer(dag, m=2, horizon=64, memo=True):
    space = Space(m, dag.d, horizon)
    mo = ConstructionMemo(space) if memo else None
    k = np.maximum(dag.duration.astype(np.int64), 1)
    return _Placer(dag, space, k, get_backend("batched"), mo), space, mo


def _chain_dag(durs, demand=0.5):
    n = len(durs)
    return DAG(duration=np.asarray(durs, float),
               demand=np.full((n, 2), demand),
               stage_of=np.arange(n),
               parents=[np.empty(0, np.int64)] + [np.array([i]) for i in range(n - 1)])


def _par_dag(durs, demand=0.5):
    n = len(durs)
    return DAG(duration=np.asarray(durs, float),
               demand=np.full((n, 2), demand),
               stage_of=np.zeros(n, np.int64),
               parents=[np.empty(0, np.int64) for _ in range(n)])


class TestPassKeyDigest:
    def test_permuted_ids_same_key_and_same_replay(self):
        """Permuted-but-equal id sets digest identically, and the replayed
        pass is bit-identical to the live one."""
        dag = _par_dag([3, 2, 4, 2, 3])
        pl, space, memo = _placer(dag)
        ids = np.array([0, 1, 2, 3, 4])
        perm = np.array([4, 2, 0, 3, 1])
        assert memo.pass_key(ids, FORWARD) == memo.pass_key(perm, FORWARD)
        assert memo.pass_key(ids, FORWARD) != memo.pass_key(ids, BACKWARD)

        snap = space.snapshot()
        assert pl.place_forward(ids)
        live = [(p.task, p.machine, p.start) for p in space.placements]
        space.restore(snap)
        before = COUNTERS["passes_replayed"]
        pl2 = pl.branch()
        pl2.is_placed[:] = False
        assert pl2.place_forward(perm)       # same set, permuted order
        assert COUNTERS["passes_replayed"] == before + 1
        replay = [(p.task, p.machine, p.start) for p in space.placements]
        assert replay == live

    def test_different_sets_different_keys(self):
        dag = _par_dag([3, 2, 4])
        _pl, _space, memo = _placer(dag)
        a = memo.pass_key(np.array([0, 1]), FORWARD)
        b = memo.pass_key(np.array([0, 2]), FORWARD)
        assert a != b

    def test_item_hash_sensitivity(self):
        """Every component of a placement triple perturbs the hash."""
        h = item_hash(3, 1, 10)
        assert h != item_hash(4, 1, 10)
        assert h != item_hash(3, 2, 10)
        assert h != item_hash(3, 1, 11)
        assert h == item_hash(3, 1, 10)


class TestWindowedPlaceMemo:
    def test_hit_requires_bit_equal_window(self):
        """A commit inside the recorded window invalidates the entry; one
        outside leaves it valid (that is the whole point of windowing)."""
        space = Space(2, 1, 64)
        memo = ConstructionMemo(space)
        vb = np.float32(0.5).tobytes()
        memo.place_put(FORWARD, vb, 3, 0, True, m=0, t0=4)  # window [0, 7)
        assert memo.place_get(FORWARD, vb, 3, 0) == (0, 4)
        snap = space.snapshot()
        space.commit(9, 0, 2, 2, np.array([0.5]))           # inside window
        assert memo.place_get(FORWARD, vb, 3, 0) is None
        space.restore(snap)
        assert memo.place_get(FORWARD, vb, 3, 0) == (0, 4)  # rollback exact
        space.commit(9, 0, 30, 2, np.array([0.5]))          # outside window
        assert memo.place_get(FORWARD, vb, 3, 0) == (0, 4)

    def test_memo_invalidation_after_deadline_growth(self):
        """The PR 2 stale-bitmap bug class, replayed against the memo: a
        backward placement recorded under a short grid must not leak into
        a query whose deadline grew the grid — the memoized and plain
        builds of both queries stay identical to the reference backend."""
        for memoize in (True, False):
            results = {}
            for name in ("reference", "batched"):
                s = Space(m=1, d=1, horizon=10)
                memo = ConstructionMemo(s) if memoize else None
                dag = _par_dag([2, 6], demand=0.5)
                dag = DAG(duration=np.array([2.0, 6.0]),
                          demand=np.array([[0.5], [0.5]]),
                          stage_of=np.array([0, 1]),
                          parents=[np.empty(0, np.int64), np.empty(0, np.int64)])
                k = np.array([2, 6], dtype=np.int64)
                pl = _Placer(dag, s, k, get_backend(name), memo)
                sess = get_backend(name).session(s, BACKWARD)
                a = sess.place(0, np.array([0.5]), 2, 8, (0, 0.0, b"a"))
                s.commit(0, a[0], a[1], 2, np.array([0.5]))
                # deadline 12 grows the grid past the recorded horizon
                sess2 = get_backend(name).session(s, BACKWARD)
                results[name] = sess2.place(1, np.array([0.5]), 6, 12,
                                            (1, 0.0, b"b"))
            assert results["batched"] == results["reference"]

    def test_anchor_is_part_of_the_key(self):
        space = Space(1, 1, 32)
        memo = ConstructionMemo(space)
        vb = np.float32(0.5).tobytes()
        memo.place_put(FORWARD, vb, 2, 0, True, m=0, t0=0)
        assert memo.place_get(FORWARD, vb, 2, 5) is None
        assert memo.place_get(BACKWARD, vb, 2, 0) is None
        assert memo.place_get(FORWARD, vb, 3, 0) is None


class TestCrossPartitionMemo:
    def test_periodic_dag_hits_across_partitions_bit_identical(self):
        """A recurring-pipeline DAG (identical phases behind barriers)
        splits into identical sub-builds; the content-addressed place memo
        of period 1 must serve periods 2..P — and stay bit-identical to
        the no-memo build on every backend."""
        from repro.core.memo import reset_counters
        from repro.sim.workload import periodic_dag

        dag = periodic_dag(np.random.default_rng(2))
        assert len(partition_totally_ordered(dag)) > 3
        reset_counters()
        memo = build_schedule(dag, 4, memoize=True)
        assert COUNTERS["places_memoized_xpart"] > 0, \
            "cross-partition lever is dead on its home workload"
        plain = build_schedule(dag, 4, memoize=False)
        ref = build_schedule(dag, 4, memoize=False, backend="reference")
        for other in (plain, ref):
            assert memo.makespan == other.makespan
            assert np.array_equal(memo.start, other.start)
            assert np.array_equal(memo.machine, other.machine)

    def test_attach_keeps_place_memo_drops_pass_memo(self):
        s1 = Space(2, 1, 32)
        memo = ConstructionMemo(s1)
        vb = np.float32(0.5).tobytes()
        memo.place_put(FORWARD, vb, 2, 0, True, m=0, t0=0)
        memo.pass_put(memo.pass_key(np.array([0]), FORWARD), 2, [(0, 0, 0)])
        assert len(memo._pass) == 1
        s2 = Space(2, 1, 32)
        memo.attach(s2)
        assert memo.space is s2 and memo._n == 0 and memo.ckey == 0
        assert len(memo._pass) == 0, "pass plans must not cross partitions"
        # the place entry survives and now counts as a cross-partition hit
        before = COUNTERS["places_memoized_xpart"]
        assert memo.place_get(FORWARD, vb, 2, 0) == (0, 0)
        assert COUNTERS["places_memoized_xpart"] == before + 1

    def test_duplicate_slot_digest_multiplicity(self):
        """Two identical tasks legally sharing one (machine, start) slot:
        the additive digest must distinguish 0, 1 and 2 copies (an XOR
        multiset hash cancels the pair — the bug class the periodic
        workload exposed)."""
        space = Space(1, 1, 32)
        memo = ConstructionMemo(space)
        v = np.array([0.3])
        d0 = memo._window_digest(0, 8)
        space.commit(0, 0, 2, 3, v)
        d1 = memo._window_digest(0, 8)
        space.commit(1, 0, 2, 3, v)      # identical content, same slot
        d2 = memo._window_digest(0, 8)
        assert d0 != d1 and d1 != d2 and d0 != d2

    def test_digest_is_content_addressed_not_task_addressed(self):
        """Same (machine, start, k, demand) committed under different task
        ids must digest identically — that is what makes cross-partition
        hits sound."""
        a, b = Space(1, 1, 32), Space(1, 1, 32)
        ma, mb = ConstructionMemo(a), ConstructionMemo(b)
        a.commit(3, 0, 2, 3, np.array([0.4]))
        b.commit(7, 0, 2, 3, np.array([0.4]))   # different task id
        assert ma.ckey == mb.ckey
        b2 = Space(1, 1, 32)
        mb2 = ConstructionMemo(b2)
        b2.commit(7, 0, 2, 3, np.array([0.5]))  # different demand
        assert mb2.ckey != ma.ckey


class TestDegenerateDags:
    def test_zero_task_dag(self):
        d = DAG(duration=np.empty(0), demand=np.empty((0, 2)),
                stage_of=np.empty(0, int), parents=[])
        for memoize in (True, False):
            s = build_schedule(d, 2, memoize=memoize)
            assert s.makespan == 0.0 and len(s.order) == 0

    def test_single_partition_single_task(self):
        d = DAG(duration=np.array([2.0]), demand=np.array([[0.5, 0.5]]),
                stage_of=np.array([0]), parents=[np.empty(0, np.int64)])
        assert len(partition_totally_ordered(d)) == 1
        a = build_schedule(d, 2, memoize=True)
        b = build_schedule(d, 2, memoize=False)
        assert a.makespan == b.makespan == pytest.approx(2.0)
        assert np.array_equal(a.start, b.start)
        assert np.array_equal(a.machine, b.machine)

    def test_span_lb_ticks_degenerate(self):
        d = _chain_dag([2, 3, 4])
        k = np.array([2, 3, 4], dtype=np.int64)
        assert _span_lb_ticks(d, 4, k) == 9          # pure chain
        p = _par_dag([1, 1, 1, 1], demand=1.0)
        kk = np.ones(4, dtype=np.int64)
        assert _span_lb_ticks(p, 2, kk) == 2         # pure work bound


class TestFragAndCandidateTies:
    def test_frag_scores_all_equal_durations(self):
        """All-equal durations collapse the long-score levels to one value;
        frag scores stay in (0, 1] and the sweep still yields candidates."""
        dag = _par_dag([5.0] * 8, demand=0.3)
        fs = frag_scores(dag, 4)
        assert fs.shape == (1,)
        assert 0.0 < fs[0] <= 1.0
        cands = candidate_troublesome(dag, 4)
        assert len(cands) >= 1                       # at least the empty set
        assert not cands[0].any()                    # empty set first
        seen = {c.tobytes() for c in cands}
        assert len(seen) == len(cands)               # deduplicated

    def test_frag_scores_empty_stage(self):
        """A stage index with no tasks keeps its neutral score of 1."""
        d = DAG(duration=np.array([2.0, 3.0]), demand=np.full((2, 2), 0.4),
                stage_of=np.array([0, 2]),           # stage 1 is empty
                parents=[np.empty(0, np.int64), np.empty(0, np.int64)])
        fs = frag_scores(d, 2)
        assert fs.shape == (3,)
        assert fs[1] == 1.0

    def test_candidate_levels_k_larger_than_task_count(self):
        """n_long/n_frag far above the distinct-value count must not
        produce duplicate thresholds or crash the quantile path."""
        dag = _chain_dag([1.0, 2.0, 3.0])
        cands = candidate_troublesome(dag, 2, n_long=50, n_frag=50)
        assert 1 <= len(cands) <= 24
        seen = {c.tobytes() for c in cands}
        assert len(seen) == len(cands)
        for c in cands:                              # all candidates closed
            assert np.array_equal(c, dag.closure_mask(c))

    def test_candidate_zero_duration_guard(self):
        """Degenerate near-zero durations: long_score stays finite."""
        dag = _par_dag([1e-3, 1e-3], demand=0.2)
        cands = candidate_troublesome(dag, 2)
        assert len(cands) >= 1
        sched = build_schedule(dag, 2)
        sched.validate()

    def test_max_candidates_cap_keeps_spread_and_empty(self):
        rng = np.random.default_rng(5)
        from repro.sim.workload import production_dag
        dag = production_dag(rng, scale=0.5, share=4)
        cands = candidate_troublesome(dag, 4, max_candidates=5)
        assert len(cands) <= 5
        assert not cands[0].any()                    # empty set survives


def _space_interleaving_oracle(seed: int, n_ops: int) -> None:
    """Random snapshot/branch/restore interleavings vs a clone oracle.

    Seeded twin of tests/test_property.py::
    test_space_restore_matches_clone_oracle (the hypothesis sweep), kept
    here too so the invariant runs even where hypothesis is absent.
    """
    rng = np.random.default_rng(seed)
    s = Space(m=int(rng.integers(1, 4)), d=int(rng.integers(1, 3)),
              horizon=int(rng.integers(8, 24)))
    stack = []
    tid = 0
    for _ in range(n_ops):
        op = rng.random()
        if op < 0.45:
            v = rng.uniform(0.05, 0.9, s.d)
            k = int(rng.integers(1, 6))
            if rng.random() < 0.5:
                m, t0 = s.earliest_fit(v, k, int(rng.integers(0, 12)))
            else:
                m, t0 = s.latest_fit(v, k, int(rng.integers(4, 16)))
            s.commit(tid, m, t0, k, v)
            tid += 1
        elif op < 0.6:
            (s._grow_front if rng.random() < 0.5 else s._grow_back)()
        elif op < 0.8 or not stack:
            stack.append((s.snapshot(), s.clone()))
        else:
            depth = int(rng.integers(0, len(stack)))
            snap, oracle = stack[depth]
            del stack[depth + 1:]
            s.restore(snap)
            assert s.T == oracle.T and s.off == oracle.off
            assert np.array_equal(s.avail, oracle.avail), \
                "grid not bit-identical to clone oracle after restore"
            assert len(s.placements) == len(oracle.placements)
            assert s._min_start == oracle._min_start
            assert s._max_end == oracle._max_end
    while stack:
        snap, oracle = stack.pop()
        s.restore(snap)
        assert np.array_equal(s.avail, oracle.avail)
        assert s.T == oracle.T and s.off == oracle.off


def test_space_restore_matches_clone_oracle_seeded():
    for seed in range(25):
        _space_interleaving_oracle(seed, 40)


class TestCounters:
    def test_counters_move_and_reset(self):
        from repro.core.memo import counters_snapshot, reset_counters
        reset_counters()
        dag = _par_dag([3, 2, 4, 2, 3])
        build_schedule(dag, 2, memoize=True)
        snap = counters_snapshot()
        assert snap["passes_run"] > 0
        assert snap["places_evaluated"] > 0
        reset_counters()
        assert sum(counters_snapshot().values()) == 0
