"""Unit tests for the DAGPS core: DAG ops, space, builder, bounds, online."""

import numpy as np
import pytest

from repro.core import (DAG, DeficitCounters, Matcher, MatcherConfig,
                        PendingTask, JobView, Space, all_bounds, bfs_order,
                        build_schedule, cp_length, mod_cp, new_lb,
                        partition_totally_ordered, simulate_execution, t_work)
from repro.core.builder import candidate_troublesome, frag_scores
from repro.sim.workload import production_dag


def chain_dag(n=4, dur=2.0, dem=0.5):
    return DAG(duration=np.full(n, dur), demand=np.full((n, 2), dem),
               stage_of=np.arange(n),
               parents=[np.array([], int)] + [np.array([i]) for i in range(n - 1)])


def diamond_dag():
    #     0
    #   1   2
    #     3
    return DAG(duration=np.array([1.0, 2.0, 3.0, 1.0]),
               demand=np.full((4, 2), 0.4),
               stage_of=np.arange(4),
               parents=[np.array([], int), np.array([0]), np.array([0]),
                        np.array([1, 2])])


class TestDAG:
    def test_closure(self):
        d = diamond_dag()
        mask = np.array([True, False, False, True])  # {0, 3}
        closed = d.closure_mask(mask)
        assert closed.all()  # 1 and 2 are on paths 0->3

    def test_split_subsets_disjoint_cover(self):
        d = diamond_dag()
        t = np.array([False, True, False, False])
        t2, o, p, c = d.split_subsets(d.closure_mask(t))
        total = t2.astype(int) + o.astype(int) + p.astype(int) + c.astype(int)
        assert (total == 1).all()
        assert p[0] and c[3] and o[2]

    def test_partition_chain(self):
        d = chain_dag(5)
        parts = partition_totally_ordered(d)
        assert len(parts) == 5

    def test_partition_diamond(self):
        parts = partition_totally_ordered(diamond_dag())
        assert len(parts) == 3  # {0}, {1,2}, {3}

    def test_validate_order(self):
        d = diamond_dag()
        assert d.validate_order([0, 1, 2, 3])
        assert not d.validate_order([1, 0, 2, 3])


class TestSpace:
    def test_commit_and_makespan(self):
        s = Space(m=2, d=2, horizon=10)
        m, t = s.earliest_fit(np.array([0.6, 0.6]), 3, 0)
        s.commit(0, m, t, 3, np.array([0.6, 0.6]))
        m2, t2 = s.earliest_fit(np.array([0.6, 0.6]), 3, 0)
        s.commit(1, m2, t2, 3, np.array([0.6, 0.6]))
        assert s.makespan_ticks == 3  # second machine
        m3, t3 = s.earliest_fit(np.array([0.6, 0.6]), 3, 0)
        assert t3 == 3 or m3 not in (m, m2)

    def test_grow_back(self):
        s = Space(m=1, d=1, horizon=8)
        m, t = s.earliest_fit(np.array([1.0]), 30, 0)
        s.commit(0, m, t, 30, np.array([1.0]))
        assert s.T >= 30

    def test_latest_fit_packs_before_deadline(self):
        s = Space(m=1, d=1, horizon=20)
        m, t = s.latest_fit(np.array([0.9]), 4, 10)
        assert t == 6
        s.commit(0, m, t, 4, np.array([0.9]))
        m2, t2 = s.latest_fit(np.array([0.9]), 4, 10)
        assert t2 == 2

    def test_front_growth_negative_coords(self):
        s = Space(m=1, d=1, horizon=8)
        m, t = s.latest_fit(np.array([0.5]), 20, 4)
        assert t < 0  # grew the front; logical coords go negative
        s.commit(0, m, t, 20, np.array([0.5]))
        assert s.makespan_ticks == 20

    def test_overcommit_raises(self):
        s = Space(m=1, d=1, horizon=8)
        s.commit(0, 0, 0, 4, np.array([0.9]))
        with pytest.raises(RuntimeError):
            s.commit(1, 0, 0, 4, np.array([0.9]))

    def test_runs_of_k_window_shorter_than_run(self):
        from repro.core.space import runs_of_k
        ok = np.ones((3, 5), dtype=bool)
        # window shorter than the run: nothing can start (used to mis-slice
        # the cumsum and raise on long tasks scanned near the grid end)
        assert runs_of_k(ok, 7).shape == (3, 0)
        assert runs_of_k(ok, 6).shape == (3, 0)
        # boundary: window exactly k long has the single start position
        out = runs_of_k(ok, 5)
        assert out.shape == (3, 1) and out.all()
        # a gap still blocks the run
        ok[1, 2] = False
        assert not runs_of_k(ok, 5)[1, 0]


class TestBuilder:
    def test_schedule_valid_on_random_dags(self):
        for seed in range(4):
            dag = production_dag(np.random.default_rng(seed))
            sched = build_schedule(dag, m=4)
            sched.validate()
            assert dag.validate_order(sched.order)

    def test_deterministic(self):
        dag = production_dag(np.random.default_rng(7))
        a = build_schedule(dag, m=4)
        b = build_schedule(dag, m=4)
        assert a.makespan == b.makespan
        assert (a.order == b.order).all()

    def test_candidates_deduped(self):
        dag = production_dag(np.random.default_rng(3))
        cands = candidate_troublesome(dag, m=4)
        seen = {c.tobytes() for c in cands}
        assert len(seen) == len(cands)

    def test_frag_scores_bounded(self):
        dag = production_dag(np.random.default_rng(5))
        fs = frag_scores(dag, 4)
        assert ((fs > 0) & (fs <= 1.0)).all()

    def test_empty_candidate_always_present(self):
        dag = chain_dag(3)
        cands = candidate_troublesome(dag, m=2)
        assert any(not c.any() for c in cands)


class TestBounds:
    def test_chain(self):
        d = chain_dag(4, dur=2.0, dem=0.5)
        assert cp_length(d) == pytest.approx(8.0)
        assert t_work(d, 2) == pytest.approx(4 * 2 * 0.5 / 2)
        assert new_lb(d, 2) == pytest.approx(8.0)

    def test_bounds_are_lower_bounds(self):
        for seed in range(4):
            dag = production_dag(np.random.default_rng(100 + seed))
            m = 4
            lb = new_lb(dag, m)
            for scheme_makespan in [
                simulate_execution(dag, m, order=bfs_order(dag)),
                simulate_execution(dag, m, policy="tetris"),
            ]:
                assert scheme_makespan >= lb * 0.999

    def test_newlb_tightest(self):
        for seed in range(4):
            dag = production_dag(np.random.default_rng(200 + seed))
            b = all_bounds(dag, 4)
            assert b["newlb"] >= max(b["cplen"], b["twork"]) - 1e-9


class TestOnline:
    def _tasks(self, n, group=0, pri=None):
        return [PendingTask(job_id=group, task_id=i,
                            demand=np.array([0.3, 0.3, 0.1, 0.1]),
                            duration=1.0,
                            pri_score=(pri[i] if pri is not None else 1.0))
                for i in range(n)]

    def test_bundling_fills_machine(self):
        m = Matcher(MatcherConfig(), capacity=10, shares={0: 1.0})
        jobs = {0: JobView(0, 0, 10.0)}
        picks = m.find_tasks_for_machine(0, np.ones(4), self._tasks(8), jobs)
        assert len(picks) == 3  # 0.3 cores each -> 3 fit

    def test_overbooking_only_fungible(self):
        cfg = MatcherConfig(max_overbook=1.5)
        m = Matcher(cfg, capacity=10, shares={0: 1.0})
        jobs = {0: JobView(0, 0, 1.0)}
        t_net = [PendingTask(0, 0, np.array([0.1, 0.1, 0.9, 0.1]), 1.0)]
        picks = m.find_tasks_for_machine(0, np.array([1.0, 1.0, 0.5, 1.0]),
                                         t_net, jobs)
        assert picks and picks[0][1] is True  # overbooked network
        t_cpu = [PendingTask(0, 0, np.array([0.9, 0.1, 0.1, 0.1]), 1.0)]
        picks = m.find_tasks_for_machine(0, np.array([0.5, 1.0, 1.0, 1.0]),
                                         t_cpu, jobs)
        assert not picks  # cores are rigid

    def test_deficit_bounds_unfairness(self):
        dc = DeficitCounters({0: 1.0, 1: 1.0}, capacity=10, kappa=0.1)
        for _ in range(10):
            dc.allocated(0, 1.0)  # group 0 hogs
        g, d = dc.most_deprived()
        assert g == 1
        assert dc.must_serve() == 1  # deficit 5 >= kappa*C = 1

    def test_set_groups_add_remove_mid_run(self):
        dc = DeficitCounters({0: 1.0, 1: 1.0}, capacity=10, kappa=0.1)
        for _ in range(4):
            dc.allocated(0, 1.0)          # group 0 hogs -> 1 deprived
        assert dc.must_serve() == 1
        # a queue joins mid-run: zero deficit, shares renormalized
        dc.set_groups({0: 1.0, 1: 1.0, 2: 2.0})
        assert dc.deficit[2] == 0.0
        assert abs(sum(dc.share.values()) - 1.0) < 1e-12
        assert dc.share[2] == pytest.approx(0.5)
        assert dc.must_serve() == 1       # existing deprivation survives churn
        # the deprived queue leaves: its deficit is dropped entirely
        dc.set_groups({0: 1.0, 2: 1.0})
        assert 1 not in dc.deficit and 1 not in dc.share
        assert set(dc.deficit) == {0, 2}
        for _ in range(6):
            dc.allocated(0, 1.0)
        assert dc.must_serve() == 2
        # each allocation is conservative: shares sum to 1, so one call
        # moves the total deficit by sum(share)*w - w = 0
        before = sum(dc.deficit.values())
        dc.allocated(2, 1.0)
        assert sum(dc.deficit.values()) == pytest.approx(before)

    def test_jain_index_edge_cases(self):
        dc = DeficitCounters({0: 1.0, 1: 3.0}, capacity=10, kappa=0.1)
        # zero usage everywhere -> degenerate window counts as fair
        assert dc.jain_index({}) == 1.0
        assert dc.jain_index({0: 0.0, 1: 0.0}) == 1.0
        # usage proportional to share -> perfectly fair
        assert dc.jain_index({0: 1.0, 1: 3.0}) == pytest.approx(1.0)
        # one group starved -> n-group worst case is 1/n
        assert dc.jain_index({0: 4.0, 1: 0.0}) == pytest.approx(0.5)
        # single group is always perfectly fair, whatever its usage
        solo = DeficitCounters({7: 2.0}, capacity=4, kappa=0.1)
        assert solo.jain_index({7: 0.0}) == 1.0
        assert solo.jain_index({7: 123.0}) == pytest.approx(1.0)

    def test_priority_steers_choice(self):
        m = Matcher(MatcherConfig(use_srpt=False), capacity=10, shares={0: 1.0})
        jobs = {0: JobView(0, 0, 1.0)}
        pri = np.array([0.1, 0.9, 0.5])
        tasks = self._tasks(3, pri=pri)
        picks = m.find_tasks_for_machine(0, np.ones(4), tasks, jobs)
        assert picks[0][0].task_id == 1  # highest priScore first
